"""Fault injection & recovery (ISSUE 8): the fault-enabled engine must be
float64-exact against the numpy fault oracles across the scheduler x
fault-kind grid, bitwise-stable under unroll and `shard_map`, and pay
ZERO carried-state overhead when faults are disabled.

Parity conventions follow tests/test_traffic.py: integer event counters
and SLO histograms compare with `array_equal`; float accumulators
(work sums, goodput) use rtol/atol 1e-9 because summation order differs
between `jnp.sum` and the oracle's Python loop.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import vecsim
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.simulator import Job
from repro.faults import (FAULT_PARAM_KEYS, attach_fault_process,
                          event_totals, fault_events)
from repro.faults.oracle import ClosedFaultOracle, FaultTrafficOracle
from repro.traffic import arrivals

TOL = 1e-9


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


FAULT_KW = {
    "spot": dict(kill_rate=1 / 600.0, restore_rate=1 / 900.0),
    "crash": dict(crash_rate=1 / 900.0, replace_s=300.0),
    "degrade": dict(degrade_rate=1 / 600.0, degrade_s=240.0,
                    degrade_factor=0.4),
}

# exact-match keys per path: everything integer-counted or histogram-
# bucketed, including the fault event totals and re-execution counters
_EXACT_TRAFFIC = ("n_arrived", "n_admitted", "n_dropped", "n_completed",
                  "lat_hist", "wait_hist", "all_done",
                  "n_preempted", "n_reexec", "n_shed",
                  "n_kill_events", "node_down_ticks")
_EXACT_CLOSED = ("all_done", "n_preempted", "n_reexec", "n_shed",
                 "n_kill_events", "node_down_ticks")


def _fleet(n=4, slots=3, frac=0.3):
    return make_cluster(n, "t3.large", slots_per_node=slots,
                        cpu_initial_fraction=frac)


def _traffic_scenario(mode, rng_seed=7, **kw):
    tmpl = arrivals.make_template(6, seed=3)
    sc = arrivals.build_traffic_scenario(_fleet(), tmpl, mode="poisson",
                                         rate=0.05, rng_seed=rng_seed)
    return attach_fault_process(sc, mode=mode, dt=5.0,
                                **{**FAULT_KW[mode], **kw})


def _traffic_cfg(mode, scheduler="cash", **kw):
    base = dict(n_ticks=300, dt=5.0, scheduler=scheduler,
                telemetry="predicted", traffic="poisson", table_slots=24,
                slo_bins=16, faults=mode, max_retries=2,
                blacklist_horizon_s=120.0, preempt_notice_s=20.0)
    base.update(kw)
    return vecsim.VecSimConfig(**base)


def _cpu_jobs(seed, n_jobs=3, tasks_per=5):
    rng = np.random.default_rng(seed)
    jobs, tid = [], 0
    for j in range(n_jobs):
        tasks = []
        for _ in range(tasks_per):
            ann = (Annotation.BURST_CPU if rng.random() < 0.6
                   else Annotation.NONE)
            tasks.append(Task(tid=tid, job=f"j{j}", vertex="map",
                              work_cpu=float(rng.uniform(20, 80)),
                              demand_cpu=float(rng.uniform(0.4, 1.0)),
                              annotation=ann))
            tid += 1
        jobs.append(Job(name=f"j{j}", tasks=tasks))
    return jobs


def _closed_scenario(mode, seed=11):
    nodes = make_cluster(3, "t3.large", slots_per_node=2,
                         cpu_initial_fraction=0.3)
    sc = vecsim.build_scenario(nodes, _cpu_jobs(seed), submit="parallel")
    return attach_fault_process(sc, mode=mode, dt=5.0, **FAULT_KW[mode])


def _closed_cfg(mode, scheduler="cash", **kw):
    base = dict(n_ticks=400, dt=5.0, scheduler=scheduler,
                telemetry="predicted", faults=mode, max_retries=2,
                blacklist_horizon_s=120.0, preempt_notice_s=20.0)
    base.update(kw)
    return vecsim.VecSimConfig(**base)


def _row(res, i=0):
    return {k: np.asarray(v)[i] for k, v in res.items()
            if not isinstance(v, dict)}


def _bitwise_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f":
        return np.array_equal(a, b, equal_nan=True)
    return np.array_equal(a, b)


def _assert_parity(eng, ora, exact):
    for k, ov in ora.items():
        ev, ov = np.asarray(eng[k]), np.asarray(ov)
        if k in exact:
            assert np.array_equal(ev, ov), f"{k}: engine {ev} != oracle {ov}"
        else:
            assert np.allclose(ev, ov, rtol=TOL, atol=TOL, equal_nan=True), \
                f"{k}: engine {ev} != oracle {ov}"


# ---------------------------------------------------------------------------
# engine vs oracle parity: scheduler x fault-kind grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ("cash", "stock"))
@pytest.mark.parametrize("mode", ("spot", "crash", "degrade"))
def test_traffic_fault_parity(scheduler, mode):
    """Open-loop path under faults: counters/histograms exact, float
    accumulators to 1e-9, vs the eager `FaultTrafficOracle` replay."""
    sc = _traffic_scenario(mode)
    cfg = _traffic_cfg(mode, scheduler)
    eng = _row(vecsim.run_scenarios([sc], cfg))
    ora = FaultTrafficOracle(sc, cfg).run()
    _assert_parity(eng, ora, _EXACT_TRAFFIC)
    assert ora["n_completed"] > 0
    if mode != "degrade":
        # the faults actually bite: kills happened and work re-executed
        assert ora["n_kill_events"] > 0 and ora["n_reexec"] > 0
        # a drained table accounts for every admitted task exactly once
        assert ora["n_completed"] + ora["n_shed"] == ora["n_admitted"] \
            or not ora["all_done"]


@pytest.mark.parametrize("scheduler", ("cash", "stock"))
@pytest.mark.parametrize("mode", ("spot", "crash", "degrade"))
def test_closed_fault_parity(scheduler, mode):
    """Closed-batch path under faults vs the eager `ClosedFaultOracle`:
    the kill/requeue/shed bookkeeping and makespan agree."""
    sc = _closed_scenario(mode)
    cfg = _closed_cfg(mode, scheduler)
    eng = _row(vecsim.run_scenarios([sc], cfg))
    ora = ClosedFaultOracle(sc, cfg).run()
    _assert_parity(eng, ora, _EXACT_CLOSED)
    if mode != "degrade":
        assert ora["n_kill_events"] > 0


def test_shed_past_max_retries():
    """A task killed more than `max_retries` times is SHED: it leaves the
    table (stream still drains) and counts in `n_shed`, never in
    `n_completed` — engine and oracle agree exactly."""
    sc = _traffic_scenario("spot", kill_rate=1 / 80.0,
                           restore_rate=1 / 120.0)
    cfg = _traffic_cfg("spot", max_retries=0, blacklist_horizon_s=0.0,
                       preempt_notice_s=0.0)
    eng = _row(vecsim.run_scenarios([sc], cfg))
    ora = FaultTrafficOracle(sc, cfg).run()
    _assert_parity(eng, ora, _EXACT_TRAFFIC)
    assert ora["n_shed"] > 0
    assert ora["n_completed"] + ora["n_shed"] == ora["n_admitted"] \
        or not ora["all_done"]


# ---------------------------------------------------------------------------
# determinism & zero-overhead acceptance
# ---------------------------------------------------------------------------

def test_zero_kill_spot_bitwise_equals_fault_free():
    """A spot process with kill_rate=0 must reproduce the fault-free run
    bit for bit: the liveness machinery is a no-op when nobody dies."""
    tmpl = arrivals.make_template(6, seed=3)
    plain = arrivals.build_traffic_scenario(_fleet(), tmpl, mode="poisson",
                                            rate=0.05, rng_seed=7)
    faulty = attach_fault_process(plain, mode="spot", dt=5.0,
                                  kill_rate=0.0, restore_rate=0.0)
    kw = dict(n_ticks=300, dt=5.0, scheduler="cash", telemetry="predicted",
              traffic="poisson", table_slots=24, slo_bins=16)
    a = vecsim.run_scenarios([plain], vecsim.VecSimConfig(**kw))
    b = vecsim.run_scenarios([faulty], vecsim.VecSimConfig(
        faults="spot", max_retries=2, **kw))
    for k, va in a.items():
        if isinstance(va, dict):
            continue
        assert _bitwise_equal(va, b[k]), k


def test_fault_stream_ignores_scheduler_axis():
    """CASH-vs-stock comparisons see bit-identical fault streams: the
    stream keys off (seed, rng_seed, fl_*) only, so the scheduler axis
    never perturbs the faults it is judged under."""
    sc = _traffic_scenario("spot")
    evs = [fault_events(_traffic_cfg("spot", s), sc, np.float64)
           for s in ("cash", "stock")]
    for k in evs[0]:
        assert np.array_equal(np.asarray(evs[0][k]), np.asarray(evs[1][k]))
    # and replays are deterministic: eager call == eager call
    again = fault_events(_traffic_cfg("spot", "cash"), sc, np.float64)
    assert all(np.array_equal(np.asarray(evs[0][k]), np.asarray(again[k]))
               for k in evs[0])
    tot = event_totals(evs[0])
    assert int(tot["n_kill_events"]) == int(np.sum(np.asarray(
        evs[0]["died"])))
    assert int(tot["node_down_ticks"]) == int(np.sum(~np.asarray(
        evs[0]["alive"])))


def test_notice_stream_presence():
    """`notice` rides the spot/crash streams only when a preemption
    notice is configured, and only flags nodes that really die within
    the window."""
    sc = _traffic_scenario("spot")
    ev = fault_events(_traffic_cfg("spot", preempt_notice_s=20.0), sc,
                      np.float64)
    assert "notice" in ev
    alive = np.asarray(ev["alive"])
    notice = np.asarray(ev["notice"])
    k = int(round(20.0 / 5.0))
    n_ticks = alive.shape[0]
    for t, n in zip(*np.nonzero(notice)):
        hz = alive[t + 1: min(t + 1 + k, n_ticks), n]
        assert alive[t, n] and not hz.all(), (t, n)
    ev0 = fault_events(_traffic_cfg("spot", preempt_notice_s=0.0), sc,
                       np.float64)
    assert "notice" not in ev0


@pytest.mark.parametrize("unroll", (2, 4))
def test_faulty_unroll_bitwise(unroll):
    """The k-unrolled tick scan stays bitwise-identical under faults
    (the fault xs slice cleanly across unrolled steps)."""
    sc = _traffic_scenario("spot")
    a = vecsim.run_scenarios([sc], _traffic_cfg("spot", unroll=1))
    b = vecsim.run_scenarios([sc], _traffic_cfg("spot", unroll=unroll))
    for k, va in a.items():
        if isinstance(va, dict):
            continue
        assert _bitwise_equal(va, b[k]), k


def test_fault_free_scan_carries_no_fault_state(monkeypatch):
    """Zero-overhead acceptance: with `faults='none'` the tick scan's
    carry must not contain ANY fault bookkeeping (retry counts, lost
    work, re-exec counters) — the machinery is statically absent, not
    zero-filled."""
    captured = []
    orig = jax.lax.scan

    def spy(f, init, xs=None, **kw):
        if isinstance(init, dict):
            captured.append(set(init.keys()))
        return orig(f, init, xs, **kw)

    monkeypatch.setattr(jax.lax, "scan", spy)
    fault_keys = {"retry", "work_lost", "tb_retry", "tb_work",
                  "n_reexec", "n_shed"}

    # unique n_ticks force fresh traces so the spy sees the carry
    tmpl = arrivals.make_template(6, seed=3)
    tsc = arrivals.build_traffic_scenario(_fleet(), tmpl, mode="poisson",
                                          rate=0.05, rng_seed=7)
    vecsim.run_scenarios([tsc], vecsim.VecSimConfig(
        n_ticks=311, dt=5.0, traffic="poisson", table_slots=24,
        slo_bins=16))
    csc = vecsim.build_scenario(make_cluster(3, "t3.large",
                                             slots_per_node=2,
                                             cpu_initial_fraction=0.3),
                                _cpu_jobs(11), submit="parallel")
    vecsim.run_scenarios([csc], vecsim.VecSimConfig(n_ticks=313, dt=5.0))
    assert captured, "spy saw no dict-carry scans (stale jit cache?)"
    for keys in captured:
        assert not (keys & fault_keys), keys & fault_keys

    # and the same carries DO appear once faults are on
    captured.clear()
    fsc = attach_fault_process(tsc, mode="spot", dt=5.0, **FAULT_KW["spot"])
    vecsim.run_scenarios([fsc], vecsim.VecSimConfig(
        n_ticks=311, dt=5.0, traffic="poisson", table_slots=24,
        slo_bins=16, faults="spot", max_retries=2))
    assert any(keys & fault_keys for keys in captured)


def test_stacker_rejects_half_faulty_group():
    """One compile group must be uniformly faulty or uniformly clean —
    a mixed group has no consistent static `cfg.faults`."""
    plain = vecsim.build_scenario(make_cluster(2, "t3.large",
                                               slots_per_node=2),
                                  _cpu_jobs(1, n_jobs=1))
    faulty = attach_fault_process(plain, mode="spot", dt=5.0,
                                  kill_rate=0.01)
    with pytest.raises(ValueError, match="uniform"):
        vecsim.stack_scenarios([plain, faulty])
    stacked = vecsim.stack_scenarios([faulty, faulty])
    for k in FAULT_PARAM_KEYS:
        assert k in stacked and stacked[k].shape == (2,)


def test_attach_fault_process_validates_and_copies():
    sc = {"slots": np.array([2, 2])}
    out = attach_fault_process(sc, mode="spot", dt=5.0, kill_rate=0.01)
    assert "fl_p_kill" not in sc          # original untouched
    assert set(FAULT_PARAM_KEYS) <= set(out)
    with pytest.raises(ValueError, match="mode"):
        attach_fault_process(sc, mode="meteor")
    with pytest.raises(ValueError, match="dt"):
        attach_fault_process(sc, mode="spot", dt=0.0)
    with pytest.raises(ValueError, match="degrade_factor"):
        attach_fault_process(sc, mode="degrade", degrade_factor=0.0)


# ---------------------------------------------------------------------------
# shard_map bitwise parity (forced devices need a fresh process)
# ---------------------------------------------------------------------------

_FAULT_SHARD_SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro import sweep
    from repro.core import vecsim
    from repro.core.cluster import make_cluster
    from repro.faults import attach_fault_process
    from repro.traffic import arrivals

    tmpl = arrivals.make_template(6, seed=3)

    def builder(rng_seed):
        fleet = make_cluster(4, "t3.large", slots_per_node=3,
                             cpu_initial_fraction=0.3)
        sc = arrivals.build_traffic_scenario(fleet, tmpl, mode="poisson",
                                             rate=0.05, rng_seed=rng_seed)
        return attach_fault_process(sc, mode="spot", dt=5.0,
                                    kill_rate=1 / 600.0,
                                    restore_rate=1 / 900.0)

    spec = sweep.SweepSpec(builder, axes={"rng_seed": list(range(4))},
                           base=vecsim.VecSimConfig(
                               n_ticks=300, dt=5.0, traffic="poisson",
                               faults="spot", max_retries=2,
                               blacklist_horizon_s=120.0,
                               preempt_notice_s=20.0, table_slots=24,
                               slo_bins=16))
    a = sweep.run_sweep(spec.groups(), shards=1)
    b = sweep.run_sweep(spec.groups(), shards=2)
    sa, sb = a.scalars(), b.scalars()
    assert set(sa) == set(sb)
    for k in sa:
        ka, kb = np.asarray(sa[k]), np.asarray(sb[k])
        eq = (np.array_equal(ka, kb, equal_nan=True)
              if ka.dtype.kind == "f" else np.array_equal(ka, kb))
        assert eq, k
    assert sa["n_kill_events"].sum() > 0
    print("BITWISE_OK")
""")


def test_faulty_shard_map_bitwise_subprocess():
    """A fault-enabled sweep sharded 2-way over the scenario axis must
    reproduce the unsharded run bit for bit, fault counters included."""
    proc = subprocess.run([sys.executable, "-c", _FAULT_SHARD_SCRIPT],
                          capture_output=True, text=True,
                          env=_subprocess_env(2), timeout=300)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "BITWISE_OK" in proc.stdout


def _subprocess_env(n_devices: int) -> dict:
    env = dict(os.environ)
    flags = " ".join(f for f in env.get("XLA_FLAGS", "").split()
                     if "xla_force_host_platform_device_count" not in f)
    env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count="
                        + str(n_devices)).strip()
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


# ---------------------------------------------------------------------------
# churn benchmark gate (ISSUE 8 satellite): fast in tier-1, saturation slow
# ---------------------------------------------------------------------------

def test_churn_fast_gate():
    """The fast-mode churn benchmark: identical fault streams across the
    scheduler axis, real preemptions, and CASH (credit-aware blacklist +
    preempt notice) wasting no more work than credit-blind stock."""
    from benchmarks import churn_bench
    stats = churn_bench.run(fast=True)     # asserts the <= 1.0 gate itself
    assert stats["kill_events"] > 0
    assert stats["wasted_work_ratio_cash_vs_stock"] <= 1.0
    for s in ("cash", "stock"):
        assert stats["schedulers"][s]["goodput_vcpu_s"] > 0


@pytest.mark.slow
def test_churn_saturation_slow():
    """Saturation variant: double the arrival rate so the fleet runs a
    standing backlog under churn. The grid must still produce finite
    metrics, identical kill streams across schedulers, and a shed/drop
    pressure-release path that actually engages."""
    from repro import sweep as sweeplib

    n_nodes, slots, n_seeds, n_ticks, dt = 6, 4, 3, 1500, 5.0
    tmpl = arrivals.make_template(8, seed=1, work=(30.0, 90.0),
                                  burst_fraction=0.75)
    rate = 2.0 * n_nodes * slots / 300.0

    def builder(rng_seed):
        fleet = make_cluster(n_nodes, "t3.large", slots_per_node=slots,
                             cpu_initial_fraction=0.3)
        sc = arrivals.build_traffic_scenario(fleet, tmpl, mode="poisson",
                                             rate=rate, rng_seed=rng_seed)
        return attach_fault_process(sc, mode="spot", dt=dt,
                                    kill_rate=1 / 1000.0,
                                    restore_rate=1 / 400.0)

    spec = sweeplib.SweepSpec(
        builder,
        axes={"scheduler": ("cash", "stock"),
              "rng_seed": list(range(n_seeds))},
        base=vecsim.VecSimConfig(
            n_ticks=n_ticks, dt=dt, traffic="poisson", faults="spot",
            max_retries=3, blacklist_horizon_s=120.0,
            preempt_notice_s=120.0, table_slots=2 * n_nodes * slots,
            slo_bins=32))
    res = sweeplib.run_sweep(spec, shards=1)
    cols = res.scalars()
    seeds = np.array([p.coord_dict["rng_seed"] for p in res.points])
    kills = cols["n_kill_events"].astype(int)
    assert kills.sum() > 0
    # identical streams: kill counts match per seed across schedulers
    for s in range(n_seeds):
        assert len(set(kills[seeds == s])) == 1, (s, kills[seeds == s])
    for k in ("goodput", "work_lost", "n_completed"):
        assert np.isfinite(cols[k]).all(), k
    # saturated: admission control or shedding released pressure
    assert (cols["n_dropped"].sum() + cols["n_shed"].sum()) > 0
