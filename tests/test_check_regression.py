"""Benchmark regression gate (ISSUE 9 satellite): the comparator in
`benchmarks.check_regression` over fixture JSONs — gated throughput keys
fail past the threshold, improvements and missing sections never do, and
the CLI exit codes match.
"""
import copy
import json

import pytest

from benchmarks import check_regression as cr


@pytest.fixture()
def baseline():
    # the committed BENCH_vecsim.json shape, reduced to what the gate reads
    return {
        "fast": {
            "vec_ticks_nodes_scen_per_s": 3_000_000.0,
            "sharded": {"ticks_nodes_scen_per_s": 3_400_000.0,
                        "bitwise_equal_vmap": True},
            "speedup": 250.0,
            "meta": {"platform": "cpu"},
        },
        "traffic": {
            "traffic_ticks_nodes_scen_per_s": 3_100_000.0,
            "throughput_ratio_vs_closed": 0.97,
        },
        "churn": {"wasted_work_ratio_cash_vs_stock": 0.8,
                  "schedulers": {"cash": {"goodput_vcpu_s": 70_000.0},
                                 "stock": {"goodput_vcpu_s": 69_000.0}}},
        "serve": {"serve_ticks_reps_scen_per_s": 2_700_000.0,
                  "speedup_vs_python_loop": 56.0},
    }


def test_identical_docs_pass(baseline):
    assert cr.compare(baseline, baseline) == []


def test_gated_drop_fails(baseline):
    cand = copy.deepcopy(baseline)
    cand["fast"]["vec_ticks_nodes_scen_per_s"] *= 0.80     # -20%
    regs = cr.compare(baseline, cand)
    assert [(r.section, r.key) for r in regs] == \
        [("fast", "vec_ticks_nodes_scen_per_s")]
    assert regs[0].drop == pytest.approx(0.20)
    assert "vec_ticks_nodes_scen_per_s" in str(regs[0])


def test_nested_sharded_key_gated(baseline):
    cand = copy.deepcopy(baseline)
    cand["fast"]["sharded"]["ticks_nodes_scen_per_s"] *= 0.5
    regs = cr.compare(baseline, cand)
    assert [(r.section, r.key) for r in regs] == \
        [("fast", "sharded.ticks_nodes_scen_per_s")]


def test_serve_throughput_gated(baseline):
    cand = copy.deepcopy(baseline)
    cand["serve"]["serve_ticks_reps_scen_per_s"] *= 0.5
    regs = cr.compare(baseline, cand)
    assert [(r.section, r.key) for r in regs] == \
        [("serve", "serve_ticks_reps_scen_per_s")]


def test_churn_goodput_gated(baseline):
    """The churn gate keys are deterministic simulation outcomes, not
    wall-clock rates — a goodput drop is a semantic regression."""
    cand = copy.deepcopy(baseline)
    cand["churn"]["schedulers"]["cash"]["goodput_vcpu_s"] *= 0.8
    regs = cr.compare(baseline, cand)
    assert [(r.section, r.key) for r in regs] == \
        [("churn", "schedulers.cash.goodput_vcpu_s")]


def test_drop_within_threshold_passes(baseline):
    cand = copy.deepcopy(baseline)
    for sec, key in (("fast", "vec_ticks_nodes_scen_per_s"),
                     ("traffic", "traffic_ticks_nodes_scen_per_s")):
        cand[sec][key] *= 0.90                             # -10% < 15%
    assert cr.compare(baseline, cand) == []


def test_threshold_is_configurable(baseline):
    cand = copy.deepcopy(baseline)
    cand["traffic"]["traffic_ticks_nodes_scen_per_s"] *= 0.90
    assert cr.compare(baseline, cand, threshold=0.05) != []
    assert cr.compare(baseline, cand, threshold=0.15) == []


def test_improvement_never_fails(baseline):
    cand = copy.deepcopy(baseline)
    cand["fast"]["vec_ticks_nodes_scen_per_s"] *= 10.0
    cand["traffic"]["traffic_ticks_nodes_scen_per_s"] *= 10.0
    assert cr.compare(baseline, cand) == []


def test_ungated_keys_ignored(baseline):
    """Only the throughput keys gate — SLO/churn/ratio drift does not."""
    cand = copy.deepcopy(baseline)
    cand["fast"]["speedup"] = 1.0
    cand["traffic"]["throughput_ratio_vs_closed"] = 0.5
    cand["churn"]["wasted_work_ratio_cash_vs_stock"] = 99.0
    cand["serve"]["speedup_vs_python_loop"] = 1.0
    assert cr.compare(baseline, cand) == []


def test_missing_sections_and_keys_skipped(baseline):
    """A section or key absent on either side is skipped, never failed:
    a fast CI run must not gate full-mode numbers, and a pre-section
    baseline must not fail the first run that adds it."""
    cand = copy.deepcopy(baseline)
    del cand["traffic"]
    assert cr.compare(baseline, cand) == []
    old = copy.deepcopy(baseline)
    del old["fast"]["sharded"]
    assert cr.compare(old, baseline) == []
    assert cr.compare({}, baseline) == []
    # non-numeric / non-positive baselines cannot divide: skipped
    weird = copy.deepcopy(baseline)
    weird["fast"]["vec_ticks_nodes_scen_per_s"] = "fast"
    assert cr.compare(weird, baseline) == []
    zero = copy.deepcopy(baseline)
    zero["fast"]["vec_ticks_nodes_scen_per_s"] = 0.0
    assert cr.compare(zero, baseline) == []


def test_cli_exit_codes(tmp_path, baseline, capsys):
    bad = copy.deepcopy(baseline)
    bad["fast"]["vec_ticks_nodes_scen_per_s"] *= 0.5
    bp = tmp_path / "base.json"
    cp = tmp_path / "cand.json"
    bp.write_text(json.dumps(baseline))
    cp.write_text(json.dumps(bad))
    assert cr.main([str(bp), str(bp)]) == 0
    assert cr.main([str(bp), str(cp)]) == 1
    err = capsys.readouterr().err
    assert "PERF REGRESSION" in err
    assert cr.main([str(bp), str(cp), "--threshold", "0.6"]) == 0
    assert cr.main([str(bp), str(tmp_path / "missing.json")]) == 1


def test_run_driver_check_flag(tmp_path, monkeypatch):
    """The real `benchmarks.run --fast --check` driver: it snapshots the
    committed --out baseline BEFORE overwriting, stamps provenance, and
    exits nonzero when a gated throughput metric regressed. The heavy
    benchmark bodies are stubbed; the driver wiring is real."""
    import benchmarks as bpkg
    from benchmarks import run as run_mod

    fresh = {"vec_ticks_nodes_scen_per_s": 1_000_000.0,
             "sharded": {"ticks_nodes_scen_per_s": 1_100_000.0}}
    stubs = {
        "fig7_cpu_burst": {"run_batched": lambda fast=True: None},
        "fig8_utilization": {"run_batched": lambda fast=True: None},
        "fig9_query_completion": {"run_batched": lambda fast=True: None},
        "fig11_cost": {"run_batched": lambda fast=True: None},
        "ablation_joint": {"run_batched": lambda fast=True: None},
        "sweep_smoke": {"run": lambda fast=True: None},
        "vecsim_bench": {"run": lambda fast=True: dict(fresh)},
        "roofline": {"vecsim_phases": lambda fast=True: {}},
        "traffic_bench": {"run": lambda fast=True: {
            "throughput_ratio_vs_closed": 1.0,
            "traffic_ticks_nodes_scen_per_s": 1_000_000.0}},
        "churn_bench": {"run": lambda fast=True: {
            "wasted_work_ratio_cash_vs_stock": 0.9}},
        "serve_bench": {"run": lambda fast=True: {
            "serve_ticks_reps_scen_per_s": 2_000_000.0,
            "speedup_vs_python_loop": 60.0}},
    }
    for mod, attrs in stubs.items():
        m = __import__(f"benchmarks.{mod}", fromlist=list(attrs))
        for name, fn in attrs.items():
            monkeypatch.setattr(m, name, fn)
        monkeypatch.setattr(bpkg, mod, m, raising=False)
    # _tune_xla_flags respects an explicit device-count flag; pin it to 1
    # so calling the real driver cannot initialize the process-wide jax
    # backend with forced extra host devices (which would un-skip and
    # perturb multi-device tests later in the same pytest run)
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=1")

    out = tmp_path / "BENCH_vecsim.json"
    committed = {"fast": dict(fresh, vec_ticks_nodes_scen_per_s=2e6,
                              sharded={"ticks_nodes_scen_per_s": 1.1e6})}
    out.write_text(json.dumps(committed))
    with pytest.raises(SystemExit):
        run_mod.main(["--fast", "--check", "--out", str(out)])
    written = json.loads(out.read_text())
    # the fresh numbers DID overwrite the baseline (snapshot was first),
    # and provenance landed alongside the per-mode sections
    assert written["fast"]["vec_ticks_nodes_scen_per_s"] == 1_000_000.0
    prov = written["provenance"]
    assert prov["jax"] and prov["jaxlib"] and prov["timestamp_utc"]
    assert prov["platform"]
    # second run compares against the fresh (equal) numbers: gate passes
    run_mod.main(["--fast", "--check", "--out", str(out)])
    run_mod.main(["--fast", "--out", str(out)])     # no --check: no gate
