"""End-to-end behaviour of the paper's system: CASH's headline effects hold
on the full stack (simulator + schedulers + billing), and the JAX runtime
integration trains/serves with credit-aware scheduling in the loop."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.core.annotations import Annotation
from repro.core.experiments import run_cpu_experiment, run_disk_pair
from repro.sched.train_scheduler import CashTrainScheduler, make_hosts
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainConfig, Trainer


def test_cash_beats_stock_on_disk_workload():
    """The paper's central claim at the 10-VM scale: CASH improves both
    query completion and makespan over stock YARN."""
    pair = run_disk_pair("10vm", seeds=(1,))
    assert pair["cash"]["avg_qct"] < pair["stock"]["avg_qct"]
    assert pair["cash"]["makespan"] <= pair["stock"]["makespan"] * 1.01


def test_cash_is_cheapest_t3_option():
    """CPU side: CASH <= reordered elapsed; cheaper than unlimited (which
    bills surplus credits) and than EMR."""
    res = {label: run_cpu_experiment(label, n_nodes=10, seed=0)
           for label in ("emr", "reordered", "unlimited", "cash")}
    assert res["cash"].cumulative_total() <= res["reordered"].cumulative_total() * 1.005
    assert res["cash"].billing.total < res["unlimited"].billing.total
    assert res["cash"].billing.total < res["emr"].billing.total
    assert res["unlimited"].billing.surplus_cost > 0.0


def test_training_with_cash_scheduler_in_the_loop():
    """Trainer + CASH shard scheduler: loss decreases and rebalancing keeps
    all shards owned."""
    cfg = reduced_config(ARCHS["granite-3-2b"])
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, num_shards=4)
    hosts = make_hosts(4)
    sched = CashTrainScheduler(hosts, num_shards=4,
                               bottleneck=Annotation.BURST_CPU)
    trainer = Trainer(cfg, data_cfg,
                      opt_cfg=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                              total_steps=8),
                      train_cfg=TrainConfig(steps=8, log_every=100,
                                            rebalance_every=3),
                      scheduler=sched)
    hist = trainer.run()
    assert hist[-1]["loss"] < hist[0]["loss"]
    owned = sorted(s for h in hosts for s in h.assigned_shards)
    assert owned == [0, 1, 2, 3]
