"""repro.traffic: open-loop arrival processes, the ring-buffer task
table, SLO histogram metrics, and the plumbing that rides along
(traffic-aware sweep manifests, the 24 h surplus billing window).

The load-bearing assertions are EXACT: the engine's latency/queue-wait
histograms (and therefore every percentile) must equal the pure-Python
`TrafficOracle` replay bit-for-bit under float64, because both sides
bucket identical ``tick_index * dt`` products with the same comparison.
Scalar accumulators (sums of per-slot floats) use a tight tolerance —
summation order differs between `jnp.sum` and the oracle's loop.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core import cost, vecsim
from repro.core.cluster import make_cluster
from repro.sweep.runner import run_sweep
from repro.sweep.spec import SweepSpec
from repro.traffic import arrivals, slo
from repro.traffic.oracle import TrafficOracle

TOL = 1e-9


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _fleet(n=4, slots=3, frac=0.3):
    return make_cluster(n, "t3.large", slots_per_node=slots,
                        cpu_initial_fraction=frac)


_EXACT = ("n_arrived", "n_admitted", "n_dropped", "n_completed",
          "lat_hist", "wait_hist", "all_done")


def _assert_engine_matches_oracle(cfg, sc, i, res):
    o = TrafficOracle(sc, cfg).run()
    for k, v in o.items():
        e = np.asarray(res[k])[i]
        if k in _EXACT:
            assert np.array_equal(e, np.asarray(v)), \
                f"{k}: engine {e} != oracle {v}"
        else:
            assert np.allclose(e, v, rtol=TOL, atol=TOL, equal_nan=True), \
                f"{k}: engine {e} != oracle {v}"
    return o


# ---------------------------------------------------------------------------
# engine vs oracle parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler,telemetry,burst_fraction", [
    ("cash", "predicted", 0.7), ("cash", "stale", 0.7),
    ("cash", "oracle", 0.7), ("stock", "predicted", 0.7),
    # all-burst template: the single-queue fast path the throughput
    # benchmark runs (no per-class rank split)
    ("cash", "predicted", 1.0),
])
def test_poisson_matches_oracle(scheduler, telemetry, burst_fraction):
    """Open-loop Poisson through the jitted scan == the Python replay,
    histograms exactly, across schedulers and telemetry modes."""
    cfg = vecsim.VecSimConfig(n_ticks=400, dt=5.0, scheduler=scheduler,
                              telemetry=telemetry, traffic="poisson",
                              table_slots=20, slo_bins=32)
    tmpl = arrivals.make_template(6, seed=3, burst_fraction=burst_fraction)
    scs = [arrivals.build_traffic_scenario(_fleet(3, 4, 0.5), tmpl,
                                           mode="poisson", rate=0.05,
                                           rng_seed=s) for s in (0, 1)]
    res = vecsim.run_scenarios(scs, cfg)
    for i, sc in enumerate(scs):
        o = _assert_engine_matches_oracle(cfg, sc, i, res)
        assert o["n_completed"] > 0


def test_diurnal_matches_oracle():
    """Rate-modulated Poisson: the sinusoidal lambda is drawn inside the
    compiled program from the same folded key the oracle uses."""
    cfg = vecsim.VecSimConfig(n_ticks=500, dt=10.0, scheduler="cash",
                              telemetry="stale", traffic="diurnal",
                              table_slots=24, slo_bins=24)
    tmpl = arrivals.make_template(5, seed=7)
    sc = arrivals.build_traffic_scenario(_fleet(), tmpl, mode="diurnal",
                                         rate=0.05, amp=0.8, period=2000.0,
                                         phase=300.0, rng_seed=5)
    res = vecsim.run_scenarios([sc], cfg)
    o = _assert_engine_matches_oracle(cfg, sc, 0, res)
    # the modulation actually modulates: arrival counts are not constant
    counts = np.asarray(arrivals.arrival_counts(cfg, sc, np.float64))
    assert counts.sum() == o["n_arrived"]
    assert counts.std() > 0


def test_replay_matches_oracle_and_drains():
    """Trace replay: every trace job admitted at its submit tick, the
    stream drains, and rng_seed does not perturb a replay."""
    rng = np.random.default_rng(0)
    t = np.sort(rng.uniform(0.0, 1500.0, 40))
    tk = rng.integers(0, 5, 40)
    tmpl = arrivals.make_template(5, seed=7)
    cfg = vecsim.VecSimConfig(n_ticks=600, dt=5.0, scheduler="cash",
                              telemetry="predicted", traffic="replay",
                              table_slots=12, slo_bins=24)
    scs = [arrivals.build_traffic_scenario(_fleet(), tmpl, mode="replay",
                                           trace_t=t, trace_tmpl=tk,
                                           rng_seed=s) for s in (0, 3)]
    res = vecsim.run_scenarios(scs, cfg)
    for i, sc in enumerate(scs):
        o = _assert_engine_matches_oracle(cfg, sc, i, res)
        assert o["all_done"] and o["n_completed"] == 40
    # replay ignores the rng stream entirely
    for k in ("makespan", "lat_hist", "n_completed"):
        assert np.array_equal(np.asarray(res[k])[0], np.asarray(res[k])[1])


def test_ring_buffer_recycles_and_sheds_load():
    """A table far smaller than the job count still completes a multiple
    of its capacity (slots recycle); overload is dropped and counted, and
    live occupancy never exceeds the capacity C."""
    C = 10
    cfg = vecsim.VecSimConfig(n_ticks=600, dt=5.0, scheduler="cash",
                              traffic="poisson", table_slots=C,
                              slo_bins=16, sample_period=25.0)
    tmpl = arrivals.make_template(4, seed=1)
    sc = arrivals.build_traffic_scenario(_fleet(3, 2, 0.4), tmpl,
                                         mode="poisson", rate=0.08,
                                         rng_seed=2)
    res = vecsim.run_scenarios([sc], cfg)
    n_done = int(res["n_completed"][0])
    assert n_done > 2 * C, "slots did not recycle"
    assert int(res["n_dropped"][0]) > 0, "overload was not shed"
    assert int(res["n_arrived"][0]) == int(res["n_admitted"][0]) \
        + int(res["n_dropped"][0])
    occ = np.asarray(res["timeline"]["occupancy"][0])
    assert occ.max() <= C
    # histograms account for every completion
    assert int(np.asarray(res["lat_hist"])[0].sum()) == n_done
    _assert_engine_matches_oracle(cfg, sc, 0, res)


def test_fifo_across_recycled_slots():
    """Queue-wait ordering follows global arrival order, not slot index:
    with a single-slot fleet every job's wait is non-decreasing in
    arrival order — guaranteed only if placement ranks by arrival seq."""
    nodes = make_cluster(1, "t3.large", slots_per_node=1,
                         cpu_initial_fraction=1.0)
    t = np.array([0.0, 0.0, 0.0, 0.0])          # burst of 4 at t=0
    tmpl = {"tmpl_work": np.array([40.0]), "tmpl_dem": np.array([0.5]),
            "tmpl_cls": np.array([vecsim.CLS_NONE], np.int32)}
    cfg = vecsim.VecSimConfig(n_ticks=600, dt=1.0, scheduler="stock",
                              traffic="replay", table_slots=4, slo_bins=32)
    sc = arrivals.build_traffic_scenario(nodes, tmpl, mode="replay",
                                         trace_t=t, rng_seed=0)
    res = vecsim.run_scenarios([sc], cfg)
    assert bool(res["all_done"][0])
    o = _assert_engine_matches_oracle(cfg, sc, 0, res)
    # 4 identical sequential jobs: waits 0, s, 2s, 3s for service time s
    h = o["wait_hist"]
    assert h.sum() == 4 and np.count_nonzero(h) == 4


# ---------------------------------------------------------------------------
# SLO histogram/percentile unit behavior
# ---------------------------------------------------------------------------

def test_slo_bucketing_and_percentiles():
    edges = slo.bin_edges(8, 100.0, 1.0)
    assert edges[0] == 0.0 and edges[1] == 1.0 and edges[-1] == 100.0
    assert slo.bucket_index(0.0, edges) == 0       # below first upper edge
    assert slo.bucket_index(1.0, edges) == 1       # boundary goes up
    assert slo.bucket_index(1e9, edges) == 7       # overflow -> last bin
    h = np.zeros(8, np.int64)
    for x in (0.5, 2.0, 3.0, 99.0):
        h[slo.bucket_index(x, edges)] += 1
    # nearest-rank on the histogram: upper edge of the covering bin
    p50 = float(slo.hist_percentile(h, edges, 0.50))
    assert p50 == edges[slo.bucket_index(2.0, edges) + 1]
    assert np.isnan(float(slo.hist_percentile(np.zeros(8), edges, 0.5)))
    with pytest.raises(ValueError):
        slo.bin_edges(1, 100.0, 1.0)
    with pytest.raises(ValueError):
        slo.bin_edges(8, 1.0, 1.0)


def test_load_trace_roundtrip_and_validation(tmp_path):
    t = np.array([1.0, 4.0, 4.0, 9.0])
    k = np.array([0, 2, 1, 0], np.int32)
    npz = tmp_path / "trace.npz"
    np.savez(npz, arr_t=t, arr_tmpl=k)
    rt, rk = arrivals.load_trace(npz)
    assert np.array_equal(rt, t) and np.array_equal(rk, k)
    txt = tmp_path / "trace.txt"
    np.savetxt(txt, np.stack([t, k.astype(float)], axis=1))
    rt, rk = arrivals.load_trace(txt)
    assert np.array_equal(rt, t) and np.array_equal(rk, k)
    bad = tmp_path / "unsorted.txt"
    np.savetxt(bad, np.array([[3.0], [1.0]]))
    with pytest.raises(ValueError, match="unsorted.txt"):
        arrivals.load_trace(bad)


# ---------------------------------------------------------------------------
# sweep integration: one compile, traffic-aware manifest
# ---------------------------------------------------------------------------

def _traffic_spec(tmpl, base, nodes):
    def builder(rate, rng_seed):
        return arrivals.build_traffic_scenario(nodes, tmpl, mode="poisson",
                                               rate=rate, rng_seed=rng_seed)
    return SweepSpec(builder, {"rate": [0.04, 0.08], "rng_seed": [0, 1, 2]},
                     base=base)


def test_seed_rate_sweep_compiles_once():
    """Per-scenario rng_seed and rate are batched data, not static config:
    a seed x rate grid is ONE compile group, and its per-point results
    match per-scenario single runs."""
    base = vecsim.VecSimConfig(n_ticks=200, dt=5.0, traffic="poisson",
                               table_slots=16, slo_bins=16)
    spec = _traffic_spec(arrivals.make_template(4, seed=1), base,
                         _fleet(3, 3, 0.4))
    groups = spec.groups()
    assert len(groups) == 1 and len(groups[0]) == 6
    res = run_sweep(spec, shards=1)
    cols = res.scalars()
    for name in ("lat_p95", "wait_p99", "n_dropped", "n_completed"):
        assert name in cols and cols[name].shape == (6,)
    # spot-check one point against a solo run of its scenario
    sc = groups[0].scenarios[4]
    solo = vecsim.run_scenarios([sc], base)
    assert np.array_equal(np.asarray(solo["lat_hist"])[0],
                          np.asarray(res.groups[0].outputs["lat_hist"])[4])


def test_workqueue_names_changed_trace(tmp_path):
    """A resumed sweep whose traffic content changed refuses the
    checkpoint dir and NAMES the traffic component, not just 'content'."""
    base = vecsim.VecSimConfig(n_ticks=120, dt=5.0, traffic="poisson",
                               table_slots=12, slo_bins=8)
    nodes = _fleet(2, 2, 0.4)
    d = tmp_path / "q"
    run_sweep(_traffic_spec(arrivals.make_template(4, seed=1), base, nodes),
              shards=1, checkpoint_dir=str(d))
    man = json.loads((d / "manifest.json").read_text())
    assert "traffic" in man["components"]
    with pytest.raises(ValueError, match="traffic content"):
        run_sweep(_traffic_spec(arrivals.make_template(4, seed=99), base,
                                nodes),
                  shards=1, checkpoint_dir=str(d))


def test_closed_sweep_manifest_has_no_traffic_component(tmp_path):
    """Closed-batch sweeps keep their pre-traffic fingerprints: the
    traffic component appends only when traffic scenarios are present."""
    from repro.core.annotations import Task
    from repro.core.simulator import Job

    def builder(seed):
        rng = np.random.RandomState(seed)
        tasks = [Task(tid=seed * 100 + i, job=f"j{seed}", vertex="v",
                      work_cpu=float(rng.uniform(20, 60)),
                      demand_cpu=0.5) for i in range(4)]
        return vecsim.build_scenario(_fleet(2, 2, 0.4),
                                     [Job(f"j{seed}", tasks)])

    spec = SweepSpec(builder, {"seed": [0, 1]},
                     base=vecsim.VecSimConfig(n_ticks=300, dt=1.0))
    d = tmp_path / "q"
    run_sweep(spec, shards=1, checkpoint_dir=str(d))
    man = json.loads((d / "manifest.json").read_text())
    assert "traffic" not in man["components"]
    assert ":traffic=" not in man["fingerprint"]


# ---------------------------------------------------------------------------
# 24 h surplus billing window (core.cost)
# ---------------------------------------------------------------------------

W = cost.SURPLUS_WINDOW_S


def test_surplus_window_boundary_semantics():
    """Window w covers (w*W, (w+1)*W]: accrual exactly AT the rollover
    bills into the window that ends there; just after starts the next."""
    at = cost.window_surplus_bills([W], [10.0])
    assert len(at) == 1 and at[0].surplus_vcpu_seconds == 10.0
    before = cost.window_surplus_bills([np.nextafter(W, 0.0)], [10.0])
    assert len(before) == 1 and before[0].surplus_vcpu_seconds == 10.0
    after = cost.window_surplus_bills([np.nextafter(W, np.inf)], [10.0])
    assert len(after) == 2
    assert after[0].surplus_vcpu_seconds == 0.0
    assert after[1].surplus_vcpu_seconds == 10.0
    assert after[1].index == 1 and after[1].start_s == W


def test_surplus_window_telescopes_multiday():
    t = np.array([0.5 * W, W, 1.5 * W, 2.0 * W, 2.7 * W])
    c = np.array([3.0, 5.0, 8.0, 11.0, 11.5])
    bills = cost.window_surplus_bills(t, c)
    assert [b.surplus_vcpu_seconds for b in bills] == [5.0, 6.0, 0.5]
    assert sum(b.surplus_vcpu_seconds for b in bills) == c[-1]
    assert bills[0].usd == pytest.approx(
        5.0 / cost.VCPU_SECONDS_PER_CREDIT_HOUR
        * cost.UNLIMITED_USD_PER_VCPU_HOUR)
    ext = cost.window_surplus_bills([0.1 * W], [2.0], horizon_s=3.2 * W)
    assert len(ext) == 4 and all(b.surplus_vcpu_seconds == 0.0
                                 for b in ext[1:])
    with pytest.raises(ValueError):
        cost.window_surplus_bills([2.0, 1.0], [0.0, 1.0])
    with pytest.raises(ValueError):
        cost.window_surplus_bills([1.0, 2.0], [1.0, 0.0])


def test_surplus_window_from_traffic_timeline():
    """Multi-day diurnal run on unlimited nodes: the timeline's
    cumulative surplus series splits into 24 h bills that sum exactly to
    the engine's total surplus_credits."""
    nodes = make_cluster(2, "t3.large", slots_per_node=3,
                         cpu_initial_fraction=0.05, unlimited=True)
    tmpl = arrivals.make_template(4, seed=2, demand=(0.8, 1.0),
                                  burst_fraction=1.0)
    dt = 64.0
    n_ticks = int(2.5 * W / dt)                     # 2.5 simulated days
    cfg = vecsim.VecSimConfig(n_ticks=n_ticks, dt=dt, scheduler="cash",
                              traffic="diurnal", table_slots=24,
                              slo_bins=16, sample_period=16 * dt)
    sc = arrivals.build_traffic_scenario(nodes, tmpl, mode="diurnal",
                                         rate=0.02, amp=0.9, period=W,
                                         rng_seed=0)
    res = vecsim.run_scenarios([sc], cfg)
    total = float(res["surplus_credits"][0])
    assert total > 0.0, "unlimited fleet under load accrued no surplus"
    # close the series with the end-of-run total: the sampled timeline
    # stops at the last sample tick, before the final accruals
    times = np.append(np.asarray(res["timeline_t"]), n_ticks * dt)
    cum = np.append(np.asarray(res["timeline"]["surplus_cum"][0]), total)
    bills = cost.window_surplus_bills(times, cum, horizon_s=n_ticks * dt)
    assert len(bills) == 3                           # 2.5 days -> 3 windows
    assert sum(b.surplus_vcpu_seconds for b in bills) == pytest.approx(
        total, rel=1e-9)
    assert all(b.surplus_vcpu_seconds >= 0.0 for b in bills)


# ---------------------------------------------------------------------------
# saturation tier
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multiday_saturation_sweep():
    """Multi-day open-loop saturation: a seed x scheduler grid over a
    3-day diurnal stream, oracle-checked at full horizon for one point.
    Slow tier — the default tier-1 lane deselects this."""
    nodes = _fleet(4, 3, 0.2)
    tmpl = arrivals.make_template(6, seed=11)
    dt = 60.0
    n_ticks = int(3 * W / dt)
    base = vecsim.VecSimConfig(n_ticks=n_ticks, dt=dt, traffic="diurnal",
                               table_slots=48, slo_bins=48,
                               slo_max_s=6.0 * 3600.0)

    def builder(rng_seed):
        return arrivals.build_traffic_scenario(nodes, tmpl, mode="diurnal",
                                               rate=0.03, amp=0.7, period=W,
                                               rng_seed=rng_seed)

    spec = SweepSpec(builder, {"scheduler": ["cash", "stock"],
                               "rng_seed": [0, 1]}, base=base)
    res = run_sweep(spec, shards=1)
    cols = res.scalars()
    assert np.all(cols["n_completed"] > 100)
    assert np.all(np.isfinite(cols["lat_p99"]))
    g = res.groups[0]
    sc = builder(rng_seed=g.points[0].coord_dict["rng_seed"])
    _assert_engine_matches_oracle(g.cfg, sc, 0, g.outputs)
