"""Serving: KV manager accounting, sampler, continuous-batching engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import forward, init_params
from repro.serve.engine import Engine, ServeRequest
from repro.serve.kv_cache import KVCacheManager
from repro.serve.sampler import SamplerConfig, sample


class TestKVManager:
    def test_admit_release_cycle(self):
        kv = KVCacheManager(2, 128)
        s0 = kv.admit(10, 5)
        s1 = kv.admit(11, 7)
        assert not kv.can_admit(3)
        kv.release(s0)
        assert kv.can_admit(3)
        assert kv.active() == {11: s1}

    def test_overflow_guard(self):
        kv = KVCacheManager(1, 8)
        s = kv.admit(1, 6)
        kv.append_token(s)
        with pytest.raises(RuntimeError):
            kv.append_token(s)


class TestSampler:
    def test_greedy(self):
        logits = jnp.array([[0.0, 5.0, 1.0]])
        assert int(sample(logits, jax.random.PRNGKey(0))[0]) == 1

    def test_top_k_restricts_support(self):
        logits = jnp.array([[0.0, 5.0, 4.9, -10.0]])
        cfg = SamplerConfig(temperature=1.0, top_k=2)
        draws = {int(sample(logits, jax.random.PRNGKey(i), cfg)[0])
                 for i in range(40)}
        assert draws <= {1, 2}

    def test_top_p(self):
        logits = jnp.array([[10.0, 9.9, -20.0, -20.0]])
        cfg = SamplerConfig(temperature=1.0, top_p=0.9)
        draws = {int(sample(logits, jax.random.PRNGKey(i), cfg)[0])
                 for i in range(40)}
        assert draws <= {0, 1}


class TestEngine:
    def _engine(self, n_slots=3):
        cfg = reduced_config(ARCHS["granite-3-2b"])
        params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        return cfg, params, Engine(cfg, params, n_slots=n_slots, max_len=64,
                                   impl="xla")

    def test_serves_batched_requests(self):
        cfg, params, eng = self._engine()
        rng = np.random.default_rng(0)
        for i in range(5):            # > slots: exercises continuous batching
            prompt = rng.integers(0, cfg.vocab_size, size=(4,)).tolist()
            eng.submit(ServeRequest(rid=i, prompt=prompt, max_new_tokens=3))
        done = eng.run_until_done()
        assert len(done) == 5
        assert all(len(r.output) == 3 for r in done)
        assert all(0 <= t < cfg.vocab_size for r in done for t in r.output)

    def test_engine_matches_forward_greedy(self):
        """First generated token == forward-pass argmax on the prompt."""
        cfg, params, eng = self._engine(n_slots=1)
        prompt = [3, 7, 11, 2]
        eng.submit(ServeRequest(rid=0, prompt=prompt, max_new_tokens=1))
        done = eng.run_until_done()
        tokens = jnp.asarray([prompt], jnp.int32)
        logits, _ = forward(cfg, params, {"tokens": tokens}, impl="xla")
        want = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
        assert done[0].output[0] == want

    def test_rejects_recurrent_families(self):
        cfg = reduced_config(ARCHS["mamba2-130m"])
        params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        with pytest.raises(ValueError):
            Engine(cfg, params, n_slots=1, max_len=32)
