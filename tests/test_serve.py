"""Serving: KV manager accounting, sampler, continuous-batching engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import forward, init_params
from repro.serve.engine import Engine, ServeRequest
from repro.serve.kv_cache import KVCacheManager
from repro.serve.sampler import SamplerConfig, sample


class TestKVManager:
    def test_admit_release_cycle(self):
        kv = KVCacheManager(2, 128)
        s0 = kv.admit(10, 5)
        s1 = kv.admit(11, 7)
        assert not kv.can_admit(3)
        kv.release(s0)
        assert kv.can_admit(3)
        assert kv.active() == {11: s1}

    def test_overflow_guard(self):
        kv = KVCacheManager(1, 8)
        s = kv.admit(1, 6)
        kv.append_token(s)
        with pytest.raises(RuntimeError):
            kv.append_token(s)

    def test_can_admit_at_length_boundary(self):
        """Admission needs strict headroom: a prompt of max_len (or one
        under) must leave room for at least one generated token."""
        kv = KVCacheManager(2, 16)
        assert kv.can_admit(14)
        assert not kv.can_admit(16)
        s = kv.admit(1, 14)
        kv.append_token(s)           # 15: the last token that fits
        with pytest.raises(RuntimeError):
            kv.append_token(s)       # 16 would exceed max_len

    def test_can_admit_exhausts_on_slots_not_length(self):
        kv = KVCacheManager(1, 128)
        kv.admit(1, 4)
        assert not kv.can_admit(4)   # slot-bound, length irrelevant

    def test_blocks_at_block_boundary(self):
        """Paged accounting rounds up per BLOCK_TOKENS=128: crossing the
        boundary by one token takes a whole extra block."""
        kv = KVCacheManager(2, 512)
        s = kv.admit(1, 128)
        assert kv.slots[s].blocks() == 1
        kv.append_token(s)           # 129 tokens
        assert kv.slots[s].blocks() == 2
        assert kv.used_blocks() == 2
        t = kv.admit(2, 0)           # empty prompt still holds one block
        assert kv.slots[t].blocks() == 1

    def test_release_then_readmit_recycles_lowest_slot(self):
        """Released slots go back to the free pool and readmission takes
        the lowest index with fresh length state — the recycling contract
        the serving-fleet oracle leans on."""
        kv = KVCacheManager(3, 64)
        s0 = kv.admit(10, 5)
        s1 = kv.admit(11, 6)
        s2 = kv.admit(12, 7)
        assert (s0, s1, s2) == (0, 1, 2)
        kv.release(s1)
        kv.release(s0)
        assert kv.free_slots() == [0, 1]
        r = kv.admit(13, 3)
        assert r == 0                # lowest free index first
        assert kv.lengths()[r] == 3  # stale length from rid 10 is gone
        assert kv.active() == {12: s2, 13: r}


class TestSampler:
    def test_greedy(self):
        logits = jnp.array([[0.0, 5.0, 1.0]])
        assert int(sample(logits, jax.random.PRNGKey(0))[0]) == 1

    def test_top_k_restricts_support(self):
        logits = jnp.array([[0.0, 5.0, 4.9, -10.0]])
        cfg = SamplerConfig(temperature=1.0, top_k=2)
        draws = {int(sample(logits, jax.random.PRNGKey(i), cfg)[0])
                 for i in range(40)}
        assert draws <= {1, 2}

    def test_top_p(self):
        logits = jnp.array([[10.0, 9.9, -20.0, -20.0]])
        cfg = SamplerConfig(temperature=1.0, top_p=0.9)
        draws = {int(sample(logits, jax.random.PRNGKey(i), cfg)[0])
                 for i in range(40)}
        assert draws <= {0, 1}

    def test_deterministic_under_fixed_key(self):
        """Same (logits, key, config) -> same token, for every sampler
        mode; different keys may (and for this spread do) disagree."""
        logits = jnp.asarray(
            np.random.default_rng(7).normal(size=(4, 32)), jnp.float32)
        for cfg in (SamplerConfig(),
                    SamplerConfig(temperature=0.7),
                    SamplerConfig(temperature=1.0, top_k=8),
                    SamplerConfig(temperature=1.3, top_p=0.8)):
            a = sample(logits, jax.random.PRNGKey(42), cfg)
            b = sample(logits, jax.random.PRNGKey(42), cfg)
            assert np.array_equal(np.asarray(a), np.asarray(b)), cfg
        stoch = SamplerConfig(temperature=1.5)
        draws = {tuple(np.asarray(sample(logits, jax.random.PRNGKey(i),
                                         stoch))) for i in range(10)}
        assert len(draws) > 1


class TestEngine:
    def _engine(self, n_slots=3):
        cfg = reduced_config(ARCHS["granite-3-2b"])
        params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        return cfg, params, Engine(cfg, params, n_slots=n_slots, max_len=64,
                                   impl="xla")

    def test_serves_batched_requests(self):
        cfg, params, eng = self._engine()
        rng = np.random.default_rng(0)
        for i in range(5):            # > slots: exercises continuous batching
            prompt = rng.integers(0, cfg.vocab_size, size=(4,)).tolist()
            eng.submit(ServeRequest(rid=i, prompt=prompt, max_new_tokens=3))
        done = eng.run_until_done()
        assert len(done) == 5
        assert all(len(r.output) == 3 for r in done)
        assert all(0 <= t < cfg.vocab_size for r in done for t in r.output)

    def test_engine_matches_forward_greedy(self):
        """First generated token == forward-pass argmax on the prompt."""
        cfg, params, eng = self._engine(n_slots=1)
        prompt = [3, 7, 11, 2]
        eng.submit(ServeRequest(rid=0, prompt=prompt, max_new_tokens=1))
        done = eng.run_until_done()
        tokens = jnp.asarray([prompt], jnp.int32)
        logits, _ = forward(cfg, params, {"tokens": tokens}, impl="xla")
        want = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
        assert done[0].output[0] == want

    def test_rejects_recurrent_families(self):
        cfg = reduced_config(ARCHS["mamba2-130m"])
        params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        with pytest.raises(ValueError):
            Engine(cfg, params, n_slots=1, max_len=32)
