"""Algorithm 2: CloudWatch staleness + credit prediction."""
import pytest

from repro.core.cluster import make_cluster
from repro.core.credits import CloudWatchEmulator, CreditPredictor, StaleCredits


def test_actuals_refresh_every_5_minutes():
    nodes = make_cluster(1, "t3.2xlarge", cpu_initial_fraction=0.5)
    w = CloudWatchEmulator("cpu")
    w.observe(0.0, nodes, {0: 0.0})
    first = w.latest_actual(0)
    # balance changes, but the published sample stays until 300 s pass
    nodes[0].cpu.serve(8.0, 100.0)
    w.observe(100.0, nodes, {0: 8.0})
    assert w.latest_actual(0).balance == first.balance
    w.observe(301.0, nodes, {0: 8.0})
    assert w.latest_actual(0).balance != first.balance


def test_predictor_tracks_between_actuals():
    nodes = make_cluster(1, "t3.2xlarge", cpu_initial_fraction=0.5)
    w = CloudWatchEmulator("cpu")
    pred = CreditPredictor(w)
    stale = StaleCredits(w)
    # burn credits at full burst for 250 s, observing each second
    for t in range(251):
        w.observe(float(t), nodes, {0: 8.0})
        nodes[0].cpu.serve(8.0, 1.0)
    est = pred.update(250.0, nodes)[0]
    actual = nodes[0].cpu.balance
    stale_est = stale.update(250.0, nodes)[0]
    # prediction lands near truth; the 5-min stale sample does not
    assert abs(est - actual) < abs(stale_est - actual) * 0.2
    assert est == pytest.approx(actual, rel=0.1)


def test_prediction_clamped_to_bucket_range():
    nodes = make_cluster(1, "t3.2xlarge", cpu_initial_fraction=0.0)
    w = CloudWatchEmulator("cpu")
    pred = CreditPredictor(w)
    for t in range(0, 290, 10):
        w.observe(float(t), nodes, {0: 8.0})
        nodes[0].cpu.serve(8.0, 10.0)
    est = pred.update(289.0, nodes)[0]
    assert 0.0 <= est <= nodes[0].cpu.capacity
