"""Straggler detection (ISSUE 8 satellite): the reactive median-EMA
detector, the predictive time-to-deplete flag, and flag-for-flag
agreement between the Python `StragglerMonitor` and the vectorized
`predictive_blacklist` the batched engine traces per tick."""
import jax
import numpy as np
import pytest

from repro.core.token_bucket import TokenBucket
from repro.sched.straggler import (StragglerMonitor, predictive_blacklist,
                                   time_to_deplete_vec)


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


# ---------------------------------------------------------------------------
# reactive: median-EMA step timings
# ---------------------------------------------------------------------------

def test_reactive_flags_only_slow_hosts():
    mon = StragglerMonitor(4, slow_factor=1.5)
    for _ in range(5):
        for h in range(3):
            mon.record_step(h, 1.0)
        mon.record_step(3, 2.0)        # 2x the median: a straggler
    assert mon.reactive_stragglers() == [3]
    assert mon.flagged() == [3]


def test_reactive_first_sample_replaces_then_ema():
    mon = StragglerMonitor(1)
    mon.record_step(0, 10.0)
    assert mon.timings[0].ema == 10.0          # n=0: seed, not blend
    mon.record_step(0, 0.0)
    assert mon.timings[0].ema == pytest.approx(0.7 * 10.0)


def test_reactive_ignores_silent_hosts():
    """Hosts with no recorded steps join neither the median nor the
    flag list; an all-silent monitor flags nothing."""
    mon = StragglerMonitor(3, slow_factor=1.5)
    assert mon.reactive_stragglers() == []
    mon.record_step(0, 1.0)
    mon.record_step(1, 10.0)
    med = sorted(t.ema for t in mon.timings.values() if t.n > 0)
    assert len(med) == 2
    assert 2 not in mon.reactive_stragglers()


# ---------------------------------------------------------------------------
# predictive: credit-forecast time-to-deplete
# ---------------------------------------------------------------------------

def _bucket(balance, baseline=0.6, burst=2.0, unlimited=False):
    return TokenBucket(baseline=baseline, burst=burst, capacity=3000.0,
                       balance=balance, unlimited=unlimited)


def test_predictive_flags_soon_to_deplete():
    mon = StragglerMonitor(3, horizon_s=120.0)
    buckets = {
        0: _bucket(100.0),     # t_dep = 100 / (2.0 - 0.6) ~= 71 s  < 120
        1: _bucket(1000.0),    # t_dep ~= 714 s                     > 120
        2: _bucket(0.0, unlimited=True),    # never throttles
    }
    demand = {h: 2.0 for h in buckets}
    assert mon.predictive_stragglers(buckets, demand) == [0]
    # below-baseline demand never drains regardless of balance
    assert mon.predictive_stragglers(buckets, {h: 0.5 for h in buckets}) \
        == []


def test_time_to_deplete_vec_matches_python():
    """The vectorized form IS `TokenBucket.time_to_deplete`, elementwise
    (inf where not draining or unlimited)."""
    rng = np.random.default_rng(0)
    n = 64
    balance = rng.uniform(0.0, 500.0, n)
    demand = rng.uniform(0.0, 3.0, n)
    baseline = rng.uniform(0.3, 1.0, n)
    burst = baseline + rng.uniform(0.0, 2.0, n)
    unlimited = rng.random(n) < 0.2
    vec = np.asarray(time_to_deplete_vec(balance, demand, baseline, burst,
                                         unlimited.astype(np.float64)))
    for i in range(n):
        b = TokenBucket(baseline=baseline[i], burst=burst[i],
                        capacity=1e9, balance=balance[i],
                        unlimited=bool(unlimited[i]))
        assert vec[i] == b.time_to_deplete(demand[i]), i


def test_vectorized_blacklist_agrees_with_monitor():
    """ISSUE 8 acceptance: `predictive_blacklist` (traced in the engine)
    and `StragglerMonitor.predictive_stragglers` (eager Python) must
    agree flag-for-flag on identical bucket states."""
    rng = np.random.default_rng(7)
    n, horizon = 48, 120.0
    balance = rng.uniform(0.0, 300.0, n)
    demand = rng.uniform(0.0, 3.0, n)
    baseline = rng.uniform(0.3, 1.0, n)
    burst = baseline + rng.uniform(0.0, 2.0, n)
    unlimited = rng.random(n) < 0.15

    mask = np.asarray(predictive_blacklist(
        balance, demand, baseline, burst, unlimited.astype(np.float64),
        horizon))
    mon = StragglerMonitor(n, horizon_s=horizon)
    buckets = {i: TokenBucket(baseline=baseline[i], burst=burst[i],
                              capacity=1e9, balance=balance[i],
                              unlimited=bool(unlimited[i]))
               for i in range(n)}
    flags = mon.predictive_stragglers(buckets,
                                      {i: demand[i] for i in range(n)})
    assert sorted(np.nonzero(mask)[0].tolist()) == flags
    assert flags, "degenerate draw: no straggler-to-be in the fixture"
    assert len(flags) < n, "degenerate draw: everyone flagged"


def test_blacklist_horizon_zero_flags_nothing():
    mask = predictive_blacklist(np.zeros(4), np.full(4, 3.0),
                                np.full(4, 0.6), np.full(4, 2.0),
                                np.zeros(4), 0.0)
    assert not np.asarray(mask).any()


def test_flagged_merges_reactive_and_predictive():
    mon = StragglerMonitor(3, slow_factor=1.5, horizon_s=120.0)
    for _ in range(4):
        mon.record_step(0, 1.0)
        mon.record_step(1, 1.0)
        mon.record_step(2, 5.0)              # reactive straggler
    buckets = {0: _bucket(10.0), 1: _bucket(1000.0), 2: _bucket(1000.0)}
    demand = {h: 2.0 for h in buckets}       # node 0: predicted depletion
    assert mon.flagged(buckets, demand) == [0, 2]
