"""repro.sweep (spec -> group -> shard -> stream -> aggregate) + the
engine features it rides on: streamed timeline ys, per-scenario rng
streams, and the joint-scheduler ablation knobs.

Covers the ROADMAP items this subsystem absorbs:
  * timeline sampling on the batched path (float64 parity with the Python
    simulator's sampled series, sample-for-sample);
  * scenario-axis sharding (bitwise parity with the single-device vmap
    path, exercised in a subprocess with forced host-platform devices);
  * `shuffle="random"` statistical parity (makespan distribution over
    seeds vs the Python Mersenne shuffle — distributional, not exact);
  * `cash-joint` at saturation scale (oracle equivalence sweep + the
    anti-affinity x pool-weight ablation grid as a `SweepSpec` grid).

No `hypothesis` usage — everything here is deterministic.
"""
import json
import os
import pathlib
import random
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro import sweep
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.scheduler import JointCashScheduler, StockScheduler
from repro.core.simulator import Job, SimConfig, Simulation
from repro.core.workloads import make_hibench_workload, make_tpcds_suite, reset_tids
from repro.core import vecsim

TOL = 1e-6


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


# ---------------------------------------------------------------------------
# scenario generators
# ---------------------------------------------------------------------------

def _small_jobs(seed: int, n_tasks: int = 8, disk: bool = False):
    rng = np.random.RandomState(seed)
    tasks = []
    for k in range(n_tasks):
        if disk and k % 3 == 2:
            tasks.append(Task(
                tid=1000 * seed + k, job=f"j{seed}", vertex="root_input",
                work_disk=float(rng.uniform(2000, 6000)),
                demand_disk=float(rng.uniform(500, 2500)),
                work_cpu=float(rng.uniform(10, 30)),
                demand_cpu=float(rng.uniform(0.2, 0.8)),
                annotation=Annotation.BURST_DISK))
        else:
            tasks.append(Task(
                tid=1000 * seed + k, job=f"j{seed}", vertex="map",
                work_cpu=float(rng.uniform(20, 60)),
                demand_cpu=float(rng.uniform(0.3, 0.9)),
                annotation=Annotation.BURST_CPU if k % 2
                else Annotation.NONE))
    return [Job(name=f"j{seed}", tasks=tasks)]


def _small_cluster(n_nodes: int = 3, frac: float = 0.3):
    return make_cluster(n_nodes, "t3.large", cpu_initial_fraction=frac,
                        disk_initial_credits=200_000.0)


def _small_scenario(seed: int, **kw):
    return vecsim.build_scenario(_small_cluster(), _small_jobs(seed, **kw),
                                 rng_seed=seed)


# ---------------------------------------------------------------------------
# spec layer
# ---------------------------------------------------------------------------

def test_sample_tick_indices_match_python_cadence():
    # dt=1, period=10 -> every 10th tick; dt=0.5 -> every 20th
    assert vecsim.sample_tick_indices(35, 1.0, 10.0) == (0, 10, 20, 30)
    assert vecsim.sample_tick_indices(50, 0.5, 10.0) == (0, 20, 40)
    # non-divisible period: greedy "first tick past next_sample"
    assert vecsim.sample_tick_indices(16, 1.0, 2.5) == (0, 3, 5, 8, 10, 13, 15)
    # non-dyadic dt: the helper accumulates `now += dt` like the Python
    # loop, reproducing its float drift (0.1 summed 100x < 10.0 -> tick 101)
    assert vecsim.sample_tick_indices(250, 0.1, 10.0) == (0, 101, 200)


def test_spec_axis_routing_and_grouping():
    calls = []

    def builder(seed):
        calls.append(seed)
        return _small_scenario(seed)

    spec = sweep.SweepSpec(
        builder,
        axes={"scheduler": ["cash", "stock"], "telemetry": ["predicted"],
              "seed": [1, 2, 3]},
        base=vecsim.VecSimConfig(n_ticks=100),
    )
    points = spec.expand()
    assert len(points) == 6 == spec.n_points
    # "seed" collides with VecSimConfig.seed but the builder accepts it ->
    # scenario axis: the engine seed stays at base for every point
    assert all(p.cfg.seed == 0 for p in points)
    assert {p.cfg.scheduler for p in points} == {"cash", "stock"}
    groups = spec.groups()
    assert sorted(len(g) for g in groups) == [3, 3]
    # memoized: 3 distinct scenarios built once each, shared by both groups
    assert sorted(calls) == [1, 2, 3]


def test_spec_configure_derives_static_fields():
    modes = {"a": ("cash", "cpu"), "b": ("cash-joint", "joint")}
    spec = sweep.SweepSpec(
        lambda seed: _small_scenario(seed),
        axes={"mode": list(modes), "seed": [5]},
        configure=lambda c: dict(zip(("scheduler", "resource"),
                                     modes[c["mode"]])),
    )
    cfgs = {p.coord_dict["mode"]: p.cfg for p in spec.expand()}
    assert cfgs["a"].scheduler == "cash" and cfgs["a"].resource == "cpu"
    assert cfgs["b"].scheduler == "cash-joint" and cfgs["b"].resource == "joint"
    with pytest.raises(ValueError):
        sweep.SweepSpec(lambda seed: _small_scenario(seed),
                        axes={"seed": [1]},
                        configure=lambda c: {"not_a_field": 1}).expand()


def test_spec_rejects_unconsumed_axis():
    """A typo'd axis (neither builder param nor config field) would
    silently duplicate the grid — without a configure hook it must raise."""
    with pytest.raises(ValueError, match="telemety"):
        sweep.SweepSpec(lambda seed: _small_scenario(seed),
                        axes={"seed": [1], "telemety": ["predicted"]})
    # ...but a configure hook may consume arbitrary axes (fig7's "label")
    sweep.SweepSpec(lambda seed: _small_scenario(seed),
                    axes={"seed": [1], "mode": ["a"]},
                    configure=lambda c: {})


# ---------------------------------------------------------------------------
# streamed timeline: float64 parity with the Python simulator's samples
# ---------------------------------------------------------------------------

def test_timeline_matches_python_sampled_series():
    jobs = _small_jobs(3, n_tasks=10, disk=True)
    sim = Simulation(_small_cluster(), StockScheduler(vecsim.IdentityRng()),
                     SimConfig(max_time=20_000.0))
    sim.submit_parallel(_small_jobs(3, n_tasks=10, disk=True))
    res = sim.run()
    tl = res.timeline
    assert len(tl["t"]) > 5

    sc = vecsim.build_scenario(_small_cluster(), jobs)
    out = vecsim.run_scenarios([sc], vecsim.VecSimConfig(
        n_ticks=2000, scheduler="stock", sample_period=10.0))
    assert bool(out["all_done"][0])
    s = len(tl["t"])
    assert np.allclose(out["timeline_t"][:s], tl["t"])
    for key in ("cpu_util", "cpu_credit_mean", "cpu_credit_std",
                "disk_credit_mean", "disk_credit_std", "iops"):
        np.testing.assert_allclose(out["timeline"][key][0][:s], tl[key],
                                   rtol=1e-9, atol=TOL, err_msg=key)
    # the Python loop stops sampling at the makespan; past it the vec
    # cluster is idle — utilization must be zero there
    past = out["timeline_t"] >= out["makespan"][0]
    assert np.all(out["timeline"]["cpu_util"][0][past] == 0.0)
    # queue depth drains to zero by completion
    assert out["timeline"]["queue_depth"][0][-1] == 0


def test_timeline_off_outputs_unchanged():
    sc = _small_scenario(4)
    out_off = vecsim.run_scenarios([sc], vecsim.VecSimConfig(n_ticks=400))
    out_on = vecsim.run_scenarios([sc], vecsim.VecSimConfig(
        n_ticks=400, sample_period=25.0))
    assert "timeline" not in out_off and "timeline" in out_on
    for k in ("makespan", "finish", "surplus_credits"):
        np.testing.assert_array_equal(out_off[k], out_on[k])


# ---------------------------------------------------------------------------
# runner: chunked + resumable; sharded bitwise parity (subprocess)
# ---------------------------------------------------------------------------

def _seed_spec(n_ticks=400, sample_period=0.0):
    return sweep.SweepSpec(
        lambda seed: _small_scenario(seed),
        axes={"scheduler": ["cash", "stock"], "seed": [1, 2, 3, 4, 5]},
        base=vecsim.VecSimConfig(n_ticks=n_ticks,
                                 sample_period=sample_period),
    )


def test_chunked_run_bitwise_equals_unchunked():
    spec = _seed_spec(sample_period=50.0)
    whole = sweep.run_sweep(spec, shards=1)
    chunked = sweep.run_sweep(spec, shards=1, chunk_size=2)
    for k, v in whole.scalars().items():
        np.testing.assert_array_equal(v, chunked.scalars()[k], err_msg=k)
    for g_w, g_c in zip(whole.groups, chunked.groups):
        np.testing.assert_array_equal(g_w.outputs["finish"],
                                      g_c.outputs["finish"])
        np.testing.assert_array_equal(
            g_w.outputs["timeline"]["cpu_credit_std"],
            g_c.outputs["timeline"]["cpu_credit_std"])


def test_checkpoint_resume_skips_completed_chunks(tmp_path):
    spec = _seed_spec()
    first = sweep.run_sweep(spec, shards=1, chunk_size=2,
                            checkpoint_dir=str(tmp_path))
    assert first.meta["resumed_scenarios"] == 0
    assert first.meta["computed_scenarios"] == first.meta["n_points"]
    # the manifest is written atomically and carries its components, so a
    # mismatch can say WHAT changed
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert set(manifest["components"]) == {"spec", "chunk_size", "layout"}
    second = sweep.run_sweep(spec, shards=1, chunk_size=2,
                             checkpoint_dir=str(tmp_path))
    assert second.meta["resumed_scenarios"] == second.meta["n_points"]
    assert second.meta["computed_scenarios"] == 0
    np.testing.assert_array_equal(first.scalars()["makespan"],
                                  second.scalars()["makespan"])
    # a different chunk layout would mis-slice the saved chunks — refuse,
    # and name the offending component
    with pytest.raises(ValueError, match="chunk_size"):
        sweep.run_sweep(spec, shards=1, chunk_size=3,
                        checkpoint_dir=str(tmp_path))
    # a different spec must refuse the same checkpoint directory
    other = sweep.SweepSpec(lambda seed: _small_scenario(seed),
                            axes={"seed": [9]})
    with pytest.raises(ValueError, match="spec axes/base"):
        sweep.run_sweep(other, shards=1, checkpoint_dir=str(tmp_path))
    # an EDITED BUILDER (same axes/base, different scenario content) must
    # refuse too — resuming another builder's chunks would silently label
    # old results with new intent
    edited = sweep.SweepSpec(
        lambda seed: _small_scenario(seed, n_tasks=9),
        axes={"scheduler": ["cash", "stock"], "seed": [1, 2, 3, 4, 5]},
        base=vecsim.VecSimConfig(n_ticks=400))
    with pytest.raises(ValueError, match="scenario content"):
        sweep.run_sweep(edited, shards=1, chunk_size=2,
                        checkpoint_dir=str(tmp_path))


def test_crash_mid_save_resumes_cleanly(tmp_path):
    """A worker dying mid-save leaves a torn ``*.tmp.npz`` and a stale
    claim; the next run must ignore/clean both, recompute only the lost
    chunk, and reproduce the full result bitwise."""
    spec = _seed_spec()
    first = sweep.run_sweep(spec, shards=1, chunk_size=2,
                            checkpoint_dir=str(tmp_path))
    victim = tmp_path / "group000_chunk0001.npz"
    assert victim.exists()
    victim.unlink()
    torn = tmp_path / "group000_chunk0001.dead-owner.tmp.npz"
    torn.write_bytes(b"half-written npz from a crashed save")
    claim = tmp_path / "group000_chunk0001.claim"
    claim.write_text('{"owner": "dead-owner"}')
    stale = time.time() - 3600.0    # well past the lease
    os.utime(torn, (stale, stale))
    os.utime(claim, (stale, stale))

    second = sweep.run_sweep(spec, shards=1, chunk_size=2,
                             checkpoint_dir=str(tmp_path))
    assert second.meta["computed_scenarios"] == 2   # just the lost chunk
    for k, v in first.scalars().items():
        np.testing.assert_array_equal(v, second.scalars()[k], err_msg=k)
    assert not list(tmp_path.glob("*.tmp.npz"))     # debris swept
    assert not list(tmp_path.glob("*.claim"))       # lease stolen+released


# scenario generator shared VERBATIM by the crash subprocess and the
# parent's reference run: the checkpoint fingerprint hashes the scenario
# CONTENT, so both sides must build identical scenarios
_CRASH_GEN = textwrap.dedent("""
    import numpy as np
    from repro.core import vecsim
    from repro.core.annotations import Annotation, Task
    from repro.core.cluster import make_cluster
    from repro.core.simulator import Job

    def scenario(seed):
        rng = np.random.RandomState(seed)
        tasks = [Task(tid=100 * seed + k, job="j", vertex="map",
                      work_cpu=float(rng.uniform(20, 60)),
                      demand_cpu=float(rng.uniform(0.3, 0.9)),
                      annotation=Annotation.BURST_CPU if k % 2
                      else Annotation.NONE)
                 for k in range(6)]
        nodes = make_cluster(2, "t3.large", slots_per_node=2,
                             cpu_initial_fraction=0.3)
        return vecsim.build_scenario(nodes, [Job(name="j", tasks=tasks)],
                                     rng_seed=seed)

    def spec(sweep, vecsim):
        return sweep.SweepSpec(lambda seed: scenario(seed),
                               axes={"scheduler": ["cash", "stock"],
                                     "seed": list(range(4))},
                               base=vecsim.VecSimConfig(n_ticks=300))
""")

_CRASH_SCRIPT = _CRASH_GEN + textwrap.dedent("""
    import os, sys
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro import sweep
    from repro.sweep import runner

    # die INSIDE the second background save, after the owner-unique tmp
    # file is written but before its atomic rename — the pipelined
    # runner's writer thread is mid-persist while the main thread has
    # already dispatched (and claimed) the next chunk
    orig_save = runner.WorkQueue.save
    state = {"n": 0}

    def dying_save(self, gi, ci, outputs):
        state["n"] += 1
        if state["n"] == 2:
            p = self._path(gi, ci)
            tmp = p.with_name(f"{p.stem}.{self.owner}.tmp.npz")
            tmp.write_bytes(b"torn half-written npz from a crashed save")
            os._exit(7)
        orig_save(self, gi, ci, outputs)

    runner.WorkQueue.save = dying_save
    sweep.run_sweep(spec(sweep, vecsim), shards=1, chunk_size=2,
                    checkpoint_dir=sys.argv[1])
    print("UNEXPECTED SURVIVAL")
""")


def test_crash_mid_background_save_resumes_cleanly(tmp_path):
    """Kill the whole process from inside the pipelined runner's writer
    thread, mid-save: on disk that leaves finished chunks, ONE torn
    ``*.tmp.npz`` (never a torn final NPZ — renames are atomic) and the
    dead owner's claims. A resumed run must recompute exactly the lost
    chunks and reproduce the no-checkpoint result bitwise, sweeping the
    debris."""
    proc = subprocess.run(
        [sys.executable, "-c", _CRASH_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, env=_subprocess_env(1), timeout=300)
    assert proc.returncode == 7, (proc.returncode, proc.stderr[-4000:])
    assert "UNEXPECTED SURVIVAL" not in proc.stdout

    done = sorted(p.name for p in tmp_path.glob("group*_chunk*.npz")
                  if ".tmp." not in p.name)
    torn = list(tmp_path.glob("*.tmp.npz"))
    claims = list(tmp_path.glob("*.claim"))
    assert len(done) == 1, done          # save #1 landed, #2 died mid-write
    assert len(torn) == 1                # the torn tmp, atomically separate
    assert claims                        # the dead owner's leases persist
    # age the debris past the lease so the resume steals/sweeps right away
    stale = time.time() - 3600.0
    for f in torn + claims:
        os.utime(f, (stale, stale))

    ns: dict = {}
    exec(compile(_CRASH_GEN, "<crash-gen>", "exec"), ns)  # same scenarios
    spec = ns["spec"](sweep, vecsim)
    resumed = sweep.run_sweep(spec, shards=1, chunk_size=2,
                              checkpoint_dir=str(tmp_path))
    fresh = sweep.run_sweep(spec, shards=1, chunk_size=2)
    assert resumed.meta["resumed_scenarios"] == 2       # the surviving chunk
    assert resumed.meta["computed_scenarios"] == 6
    for k, v in fresh.scalars().items():
        np.testing.assert_array_equal(v, resumed.scalars()[k], err_msg=k)
    assert not list(tmp_path.glob("*.tmp.npz"))         # debris swept
    assert not list(tmp_path.glob("*.claim"))


def test_results_save_load_roundtrip(tmp_path):
    spec = _seed_spec(sample_period=100.0)
    res = sweep.run_sweep(spec, shards=1)
    res.save(str(tmp_path / "artifact"))
    assert (tmp_path / "artifact.json").exists()
    back = sweep.SweepResult.load(str(tmp_path / "artifact"))
    for k, v in res.scalars().items():
        np.testing.assert_array_equal(v, back.scalars()[k], err_msg=k)
    pts = back.select(scheduler="cash", seed=3)
    assert len(pts) == 1
    orig = res.point_outputs(pts[0].index)
    loaded = back.point_outputs(pts[0].index)
    np.testing.assert_array_equal(orig["finish"], loaded["finish"])
    np.testing.assert_array_equal(orig["timeline"]["cpu_util"],
                                  loaded["timeline"]["cpu_util"])


_SHARD_SCRIPT = textwrap.dedent("""
    import sys
    import jax
    jax.config.update("jax_enable_x64", True)
    n_shards = int(sys.argv[1])
    assert len(jax.local_devices()) >= n_shards, jax.local_devices()
    import numpy as np
    from repro import sweep
    from repro.core import vecsim
    from repro.core.annotations import Annotation, Task
    from repro.core.cluster import make_cluster
    from repro.core.simulator import Job

    def scenario(seed):
        rng = np.random.RandomState(seed)
        tasks = [Task(tid=100 * seed + k, job="j", vertex="map",
                      work_cpu=float(rng.uniform(20, 60)),
                      demand_cpu=float(rng.uniform(0.3, 0.9)),
                      annotation=Annotation.BURST_CPU if k % 2
                      else Annotation.NONE)
                 for k in range(6)]
        nodes = make_cluster(2, "t3.large", slots_per_node=2,
                             cpu_initial_fraction=0.3)
        return vecsim.build_scenario(nodes, [Job(name="j", tasks=tasks)],
                                     rng_seed=seed)

    spec = sweep.SweepSpec(lambda seed: scenario(seed),
                           axes={"seed": list(range(6))},
                           base=vecsim.VecSimConfig(n_ticks=200,
                                                    sample_period=20.0))
    groups = spec.groups()
    a = sweep.run_sweep(groups, shards=1)
    b = sweep.run_sweep(groups, shards=n_shards)
    sa, sb = a.scalars(), b.scalars()
    for k in sa:
        assert np.array_equal(sa[k], sb[k]), k
    ga, gb = a.groups[0].outputs, b.groups[0].outputs
    assert np.array_equal(ga["finish"], gb["finish"])
    assert np.array_equal(ga["timeline"]["cpu_credit_std"],
                          gb["timeline"]["cpu_credit_std"])
    print("BITWISE_OK")
""")


def _subprocess_env(n_devices: int) -> dict:
    env = dict(os.environ)
    flags = " ".join(f for f in env.get("XLA_FLAGS", "").split()
                     if "xla_force_host_platform_device_count" not in f)
    env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count="
                        + str(n_devices)).strip()
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


@pytest.mark.parametrize("n_dev", [2, 4])
def test_sharded_bitwise_equals_vmap_subprocess(n_dev):
    """The `shard_map` mesh path must reproduce the vmap path bit for bit
    at both 2- and 4-way sharding (ISSUE 5 acceptance). Forced
    host-platform devices require a fresh process (XLA reads the flag at
    backend init)."""
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT, str(n_dev)],
                          capture_output=True, text=True,
                          env=_subprocess_env(n_dev), timeout=300)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "BITWISE_OK" in proc.stdout


_DRAIN_SCRIPT = textwrap.dedent("""
    import hashlib, json, sys
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro import sweep
    from repro.core import vecsim
    from repro.core.annotations import Annotation, Task
    from repro.core.cluster import make_cluster
    from repro.core.simulator import Job

    def scenario(seed):
        rng = np.random.RandomState(seed)
        tasks = [Task(tid=100 * seed + k, job="j", vertex="map",
                      work_cpu=float(rng.uniform(20, 60)),
                      demand_cpu=float(rng.uniform(0.3, 0.9)),
                      annotation=Annotation.BURST_CPU if k % 2
                      else Annotation.NONE)
                 for k in range(6)]
        nodes = make_cluster(2, "t3.large", slots_per_node=2,
                             cpu_initial_fraction=0.3)
        return vecsim.build_scenario(nodes, [Job(name="j", tasks=tasks)],
                                     rng_seed=seed)

    # TWO compile groups x 4 chunks: the flat cross-group work pool must
    # let a worker blocked on one group's claims drain the other
    spec = sweep.SweepSpec(lambda seed: scenario(seed),
                           axes={"scheduler": ["cash", "stock"],
                                 "seed": list(range(4))},
                           base=vecsim.VecSimConfig(n_ticks=300))
    res = sweep.run_sweep(spec, shards=1, chunk_size=1,
                          checkpoint_dir=sys.argv[1])
    sha = hashlib.sha256()
    for g in res.groups:
        sha.update(np.ascontiguousarray(g.outputs["finish"]).tobytes())
    print("RESULT " + json.dumps({
        "computed": int(res.meta["computed_scenarios"]),
        "resumed": int(res.meta["resumed_scenarios"]),
        "makespan": [float(x) for x in res.scalars()["makespan"]],
        "finish_sha": sha.hexdigest(),
    }))
""")


def test_multihost_drain_zero_double_compute(tmp_path):
    """Two runner processes pointed at ONE work-queue directory must drain
    the grid together: every chunk computed exactly once across the pair
    (claims are exclusive within the lease) and both return the complete,
    bitwise-identical `SweepResult` (ISSUE 5 acceptance)."""
    env = _subprocess_env(1)
    procs = [subprocess.Popen(
        [sys.executable, "-c", _DRAIN_SCRIPT, str(tmp_path)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)
        for _ in range(2)]
    outs = []
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, err[-4000:]
        (line,) = [l for l in out.splitlines() if l.startswith("RESULT ")]
        outs.append(json.loads(line[len("RESULT "):]))

    # full coverage, zero double-compute: the 8 chunks (2 groups x 4) were
    # computed exactly once across the two workers (which worker got how
    # many is a scheduling accident — the split just has to sum)
    assert outs[0]["computed"] + outs[1]["computed"] == 8
    assert outs[0]["computed"] + outs[0]["resumed"] == 8
    assert outs[1]["computed"] + outs[1]["resumed"] == 8
    # both workers assemble the SAME complete result, bit for bit
    assert outs[0]["makespan"] == outs[1]["makespan"]
    assert outs[0]["finish_sha"] == outs[1]["finish_sha"]
    # the queue drained clean: no leftover claims or torn saves
    assert not list(tmp_path.glob("*.claim"))
    assert not list(tmp_path.glob("*.tmp.npz"))


def test_no_pmap_in_src():
    """ISSUE 5 acceptance: the mesh/`shard_map` path fully replaced
    `jax.pmap` — it must not appear anywhere under src/."""
    src = pathlib.Path(__file__).resolve().parent.parent / "src"
    hits = [str(p) for p in src.rglob("*.py") if "pmap" in p.read_text()]
    assert not hits, hits


# ---------------------------------------------------------------------------
# shuffle="random": statistical parity with the Python Mersenne shuffle
# ---------------------------------------------------------------------------

def _shuffle_cluster(n: int = 4):
    """Credit-asymmetric fleet: half the nodes fully depleted, half full —
    random placement materially moves the makespan."""
    nodes = make_cluster(n, "t3.large", cpu_initial_fraction=0.0)
    for i, nd in enumerate(nodes):
        nd.cpu.balance = 0.0 if i < n // 2 else nd.cpu.capacity
    return nodes


def _shuffle_jobs():
    """Fewer tasks than slots: placement is one-shot, so the shuffle alone
    decides which node serves which task (no backfill to wash it out)."""
    rng = np.random.RandomState(7)
    tasks = [Task(tid=100 + k, job="j0", vertex="map",
                  work_cpu=float(rng.uniform(100, 800)), demand_cpu=1.0,
                  annotation=Annotation.BURST_CPU)
             for k in range(6)]
    return [Job(name="j0", tasks=tasks)]


def test_shuffle_random_distributional_parity():
    """ROADMAP: the vec engine's counter-based permutations vs the Python
    Mersenne shuffle — compare the makespan distribution over seeds, not
    trajectories. Deterministic (fixed seed sets on both sides)."""
    n_seeds = 24
    py = []
    for s in range(n_seeds):
        sim = Simulation(_shuffle_cluster(), StockScheduler(random.Random(s)),
                         SimConfig(max_time=8_000.0))
        sim.submit_parallel(_shuffle_jobs())
        py.append(sim.run().makespan)
    py = np.asarray(py)
    assert py.std() > 0.0, "scenario must be shuffle-sensitive"

    scens = [vecsim.build_scenario(_shuffle_cluster(), _shuffle_jobs(),
                                   rng_seed=s) for s in range(n_seeds)]
    out = vecsim.run_scenarios(scens, vecsim.VecSimConfig(
        n_ticks=4_000, scheduler="stock", shuffle="random"))
    assert bool(out["all_done"].all())
    vm = out["makespan"]
    assert vm.std() > 0.0

    # same support and matching first two moments (loose: 24 draws)
    assert abs(vm.mean() - py.mean()) / py.mean() < 0.10
    assert 0.5 < vm.std() / py.std() < 2.0
    assert vm.min() >= py.min() - TOL and vm.max() <= py.max() + TOL


def test_shuffle_random_seed_streams_differ_within_batch():
    """Distinct per-scenario rng_seed values must yield distinct streams in
    ONE compiled batch (the single-compile seed-sweep feature)."""
    scens = [vecsim.build_scenario(_shuffle_cluster(), _shuffle_jobs(),
                                   rng_seed=s) for s in range(8)]
    out = vecsim.run_scenarios(scens, vecsim.VecSimConfig(
        n_ticks=4_000, scheduler="stock", shuffle="random"))
    assert len(set(np.round(out["makespan"], 6))) > 1


# ---------------------------------------------------------------------------
# cash-joint at saturation scale + the ablation grid (ROADMAP)
# ---------------------------------------------------------------------------

def _saturated_setup(seed: int, n_nodes: int = 5):
    """Mixed disk-burst TPC-DS + cpu-burst HiBench at full cluster
    saturation (the ablation_joint regime, shrunk to test scale)."""
    reset_tids()
    nodes = make_cluster(n_nodes, "t3.2xlarge", ebs_size_gb=170.0,
                         cpu_initial_fraction=0.3, disk_initial_credits=0.0)
    jobs = make_tpcds_suite(300.0, n_nodes, 8, seed=seed)
    cpu_jobs = make_hibench_workload("sql_aggregation", n_nodes, 8,
                                     seed=seed + 7)
    return nodes, jobs + cpu_jobs[:2]


def _joint_oracle(seed: int, **sched_kw):
    nodes, jobs = _saturated_setup(seed)
    sim = Simulation(nodes,
                     JointCashScheduler(vecsim.IdentityRng(), **sched_kw),
                     SimConfig(max_time=20_000.0, resource="joint"))
    sim.submit_parallel(jobs)
    return sim.run(), jobs


@pytest.mark.slow
def test_joint_saturation_equivalence_sweep():
    """Batched-vs-oracle equivalence for cash-joint at saturation scale
    (~400 tasks, every slot contended), expressed as a seed-axis
    `SweepSpec` — the subsystem's first real consumer. Saturation scale
    makes this the suite's costliest sweep: marked ``slow`` (tier-1 runs
    ``-m "not slow"`` by default; opt in with ``-m ""``)."""
    seeds = (1, 2)
    oracles = {s: _joint_oracle(s) for s in seeds}

    def builder(seed):
        nodes, jobs = _saturated_setup(seed)
        return vecsim.build_scenario(nodes, jobs)

    n_ticks = int(max(o.makespan for o, _ in oracles.values())) + 50
    spec = sweep.SweepSpec(
        builder, axes={"seed": list(seeds)},
        base=vecsim.VecSimConfig(n_ticks=n_ticks, scheduler="cash-joint",
                                 resource="joint"),
    )
    result = sweep.run_sweep(spec, shards=1)
    assert bool(result.scalars()["all_done"].all())
    for s in seeds:
        (pt,) = result.select(seed=s)
        out = result.point_outputs(pt.index)
        oracle, jobs = oracles[s]
        assert out["makespan"] == pytest.approx(oracle.makespan, abs=TOL)
        assert out["surplus_credits"] == pytest.approx(
            oracle.surplus_credits, abs=TOL)
        for ji, j in enumerate(jobs):
            assert out["job_completion"][ji] == pytest.approx(
                oracle.job_completion[j.name], abs=TOL)


def test_joint_ablation_grid():
    """Anti-affinity on/off x pool weights as a `SweepSpec` grid over the
    static ablation knobs; the two off-default corners are oracle-checked
    (the Python JointCashScheduler grew the same knobs)."""
    seed = 3

    def builder(n_tasks):
        return vecsim.build_scenario(_small_cluster(4),
                                     _small_jobs(seed, n_tasks, disk=True))

    spec = sweep.SweepSpec(
        builder,
        axes={"joint_anti_affinity": [True, False],
              "joint_cpu_weight": [0.3, 0.5, 0.7],
              "n_tasks": [12]},
        base=vecsim.VecSimConfig(n_ticks=1_500, scheduler="cash-joint",
                                 resource="joint"),
    )
    assert len(spec.groups()) == 6
    result = sweep.run_sweep(spec, shards=1)
    scal = result.scalars()
    assert bool(scal["all_done"].all())
    assert np.isfinite(scal["makespan"]).all()

    for aa, w in ((False, 0.3), (True, 0.7)):
        sim = Simulation(_small_cluster(4),
                         JointCashScheduler(vecsim.IdentityRng(),
                                            anti_affinity=aa, cpu_weight=w),
                         SimConfig(max_time=20_000.0, resource="joint"))
        sim.submit_parallel(_small_jobs(seed, 12, disk=True))
        oracle = sim.run()
        (pt,) = result.select(joint_anti_affinity=aa, joint_cpu_weight=w)
        out = result.point_outputs(pt.index)
        assert out["makespan"] == pytest.approx(oracle.makespan, abs=TOL)
        assert out["surplus_credits"] == pytest.approx(
            oracle.surplus_credits, abs=TOL)
