"""Self-healing sweep runner (ISSUE 8): heartbeat lease renewal (a slow
chunk on a live host is never stolen; a killed runner's chunks are),
retry with exponential backoff on transient chunk failures, and
quarantine of chunks that fail every attempt — the rest of the grid
drains with the poisoned rows NaN-filled."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap
import threading
import time

import jax
import numpy as np
import pytest

from repro import sweep
from repro.core import vecsim
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.simulator import Job
from repro.sweep import runner as runner_mod
from repro.sweep.runner import RunnerOptions, WorkQueue


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _scenario(seed):
    rng = np.random.RandomState(seed)
    tasks = [Task(tid=100 * seed + k, job="j", vertex="map",
                  work_cpu=float(rng.uniform(20, 60)),
                  demand_cpu=float(rng.uniform(0.3, 0.9)),
                  annotation=Annotation.BURST_CPU if k % 2
                  else Annotation.NONE)
             for k in range(6)]
    nodes = make_cluster(2, "t3.large", slots_per_node=2,
                         cpu_initial_fraction=0.3)
    return vecsim.build_scenario(nodes, [Job(name="j", tasks=tasks)],
                                 rng_seed=seed)


def _spec(n_seeds=4):
    return sweep.SweepSpec(_scenario, axes={"seed": list(range(n_seeds))},
                           base=vecsim.VecSimConfig(n_ticks=200))


# ---------------------------------------------------------------------------
# heartbeat: the lease clock tracks owner LIVENESS, not chunk wall time
# ---------------------------------------------------------------------------

def test_heartbeat_renews_and_drops_stolen_claims(tmp_path):
    """A heartbeating owner keeps its claim past many lease periods; once
    the heartbeat stops the lease ages out and a peer steals it — and the
    comatose owner's next heartbeat/release must NOT touch the thief's
    claim."""
    q1 = WorkQueue(tmp_path, "fp", lease_s=0.6)
    q2 = WorkQueue(tmp_path, "fp", lease_s=0.6)
    assert q1.try_claim(0, 0)
    assert not q2.try_claim(0, 0)

    q1.start_heartbeat(period_s=0.15)
    time.sleep(1.5)                       # 2.5 lease periods
    assert not q2.try_claim(0, 0), "live owner's claim was stolen"
    q1.stop_heartbeat()

    time.sleep(0.9)                       # now genuinely stale
    assert q2.try_claim(0, 0), "stale claim not stolen"

    # the old owner wakes up: heartbeat drops the stolen claim from its
    # renewal set, release leaves the thief's claim in place
    q1.heartbeat()
    assert (0, 0) not in q1._owned
    q1.release(0, 0)
    claim = tmp_path / "group000_chunk0000.claim"
    assert claim.exists()
    assert json.loads(claim.read_text())["owner"] == q2.owner


def test_slow_chunk_on_live_host_never_stolen(tmp_path, monkeypatch):
    """Regression (ISSUE 8 satellite): chunk wall time 3x the lease, two
    workers draining the same queue — with heartbeat renewal every chunk
    is computed exactly ONCE across the pair (the write-once lease clock
    used to let worker B steal worker A's still-running chunk)."""
    lease = 0.5
    calls = []
    orig = runner_mod._run_arrays

    def slow(arrays, cfg, statics, shards, donate):
        calls.append(threading.get_ident())
        time.sleep(3 * lease)             # claim older than lease mid-compute
        return orig(arrays, cfg, statics, shards, donate)

    monkeypatch.setattr(runner_mod, "_run_arrays", slow)
    spec = _spec(2)
    opts = RunnerOptions(shards=1, chunk_size=1, pipeline=False,
                         checkpoint_dir=str(tmp_path), lease_s=lease)
    results = {}

    def work(name):
        results[name] = sweep.run_sweep(spec, opts)

    ta = threading.Thread(target=work, args=("a",))
    tb = threading.Thread(target=work, args=("b",))
    ta.start(); time.sleep(0.1); tb.start()
    ta.join(timeout=120); tb.join(timeout=120)
    assert set(results) == {"a", "b"}

    # zero double-compute: 2 chunks, exactly 2 computes across both
    assert len(calls) == 2, f"chunk stolen mid-compute: {len(calls)} computes"
    sa, sb = results["a"].scalars(), results["b"].scalars()
    assert np.array_equal(sa["makespan"], sb["makespan"])
    assert not list(tmp_path.glob("*.claim"))
    assert not list(tmp_path.glob("*.quarantine.json"))


_HANG_SCRIPT = textwrap.dedent("""
    import os, sys, time
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro import sweep
    from repro.core import vecsim
    from repro.core.annotations import Annotation, Task
    from repro.core.cluster import make_cluster
    from repro.core.simulator import Job
    from repro.sweep import runner

    def scenario(seed):
        rng = np.random.RandomState(seed)
        tasks = [Task(tid=100 * seed + k, job="j", vertex="map",
                      work_cpu=float(rng.uniform(20, 60)),
                      demand_cpu=float(rng.uniform(0.3, 0.9)),
                      annotation=Annotation.BURST_CPU if k % 2
                      else Annotation.NONE)
                 for k in range(6)]
        nodes = make_cluster(2, "t3.large", slots_per_node=2,
                             cpu_initial_fraction=0.3)
        return vecsim.build_scenario(nodes, [Job(name="j", tasks=tasks)],
                                     rng_seed=seed)

    orig = runner._run_arrays
    calls = {"n": 0}

    def hang(arrays, cfg, statics, shards, donate):
        calls["n"] += 1
        if calls["n"] == 2:
            # second chunk: signal the parent, then wedge mid-compute
            # while HOLDING the claim — the parent SIGKILLs us here
            open(sys.argv[2], "w").write("hung")
            time.sleep(600)
        return orig(arrays, cfg, statics, shards, donate)

    runner._run_arrays = hang
    spec = sweep.SweepSpec(scenario, axes={"seed": [0, 1]},
                           base=vecsim.VecSimConfig(n_ticks=200))
    sweep.run_sweep(spec, shards=1, chunk_size=1,
                    checkpoint_dir=sys.argv[1],
                    options=sweep.RunnerOptions(pipeline=False))
""")


def _subprocess_env() -> dict:
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_killed_runner_chunk_retried_by_peer_exactly_once(tmp_path):
    """ISSUE 8 acceptance: SIGKILL a runner mid-chunk; a peer with the
    same queue steals the dead claim after the lease expires and computes
    that chunk exactly once — the dead runner's finished chunk is resumed
    from its NPZ, not recomputed."""
    marker = tmp_path / "hung"
    qdir = tmp_path / "q"
    proc = subprocess.Popen(
        [sys.executable, "-c", _HANG_SCRIPT, str(qdir), str(marker)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        env=_subprocess_env())
    try:
        deadline = time.time() + 120
        while not marker.exists():
            assert proc.poll() is None, proc.stderr.read().decode()[-4000:]
            assert time.time() < deadline, "worker never reached chunk 2"
            time.sleep(0.05)
        proc.kill()
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()

    # the kill left chunk 0 saved and chunk 1's claim orphaned
    assert (qdir / "group000_chunk0000.npz").exists()
    (orphan,) = list(qdir.glob("*.claim"))
    # expire the dead owner's lease (instead of sleeping lease_s out)
    old = time.time() - 7200
    os.utime(orphan, (old, old))

    calls = []
    orig = runner_mod._run_arrays

    def counting(arrays, cfg, statics, shards, donate):
        calls.append(int(np.asarray(arrays["rng_seed"]).ravel()[0]))
        return orig(arrays, cfg, statics, shards, donate)

    runner_mod._run_arrays = counting
    try:
        res = sweep.run_sweep(
            sweep.SweepSpec(_scenario, axes={"seed": [0, 1]},
                            base=vecsim.VecSimConfig(n_ticks=200)),
            shards=1, chunk_size=1, checkpoint_dir=str(qdir),
            options=RunnerOptions(pipeline=False))
    finally:
        runner_mod._run_arrays = orig

    # exactly ONE compute (the dead runner's in-flight chunk, seed 1);
    # chunk 0 resumed from the dead runner's finished NPZ
    assert calls == [1]
    assert res.meta["computed_scenarios"] == 1
    assert res.meta["resumed_scenarios"] == 1
    assert res.meta["quarantined_chunks"] == []
    assert np.isfinite(res.scalars()["makespan"]).all()
    assert not list(qdir.glob("*.claim"))


# ---------------------------------------------------------------------------
# retry with backoff; quarantine after max_attempts
# ---------------------------------------------------------------------------

def test_transient_failure_retries_then_succeeds(tmp_path, monkeypatch):
    """A chunk that fails twice then succeeds completes within
    max_attempts=3 — correct results, no quarantine, and the backoff
    schedule (b, 2b) actually waited between attempts."""
    clean = sweep.run_sweep(_spec(1), shards=1,
                            options=RunnerOptions(pipeline=False))
    times = []
    orig = runner_mod._run_arrays

    def flaky(arrays, cfg, statics, shards, donate):
        times.append(time.perf_counter())
        if len(times) <= 2:
            raise RuntimeError("transient device loss")
        return orig(arrays, cfg, statics, shards, donate)

    monkeypatch.setattr(runner_mod, "_run_arrays", flaky)
    backoff = 0.2
    res = sweep.run_sweep(_spec(1), shards=1,
                          options=RunnerOptions(
                              pipeline=False, max_attempts=3,
                              backoff_s=backoff,
                              checkpoint_dir=str(tmp_path)))
    assert len(times) == 3
    assert times[1] - times[0] >= backoff            # b
    assert times[2] - times[1] >= 2 * backoff        # 2b
    assert res.meta["quarantined_chunks"] == []
    assert np.array_equal(res.scalars()["makespan"],
                          clean.scalars()["makespan"])
    assert not list(tmp_path.glob("*.quarantine.json"))


def test_pipeline_finalize_failure_falls_back_to_redispatch(monkeypatch):
    """Pipeline path: when consuming the already-dispatched device tree
    fails, the retry re-dispatches the chunk from host arrays — the sweep
    still completes with correct results."""
    clean = sweep.run_sweep(_spec(2), shards=1,
                            options=RunnerOptions(pipeline=False))
    state = {"n": 0}
    orig = runner_mod._finalize_arrays

    def flaky_finalize(dev, n_real, cfg):
        state["n"] += 1
        if state["n"] == 1:           # tear the first device->host transfer
            raise RuntimeError("transfer torn")
        return orig(dev, n_real, cfg)

    monkeypatch.setattr(runner_mod, "_finalize_arrays", flaky_finalize)
    res = sweep.run_sweep(_spec(2), shards=1,
                          options=RunnerOptions(pipeline=True,
                                                max_attempts=2,
                                                backoff_s=0.01))
    assert state["n"] >= 2            # retry re-dispatched and re-finalized
    assert res.meta["quarantined_chunks"] == []
    assert np.array_equal(res.scalars()["makespan"],
                          clean.scalars()["makespan"])


def _poison(target_seed):
    """A compute wrapper that always fails for the chunk holding
    ``target_seed``."""
    orig = runner_mod._run_arrays
    calls = {"n": 0}

    def run(arrays, cfg, statics, shards, donate):
        if target_seed in np.asarray(arrays["rng_seed"]).ravel():
            calls["n"] += 1
            raise RuntimeError("poisoned input")
        return orig(arrays, cfg, statics, shards, donate)

    return run, calls


def test_quarantine_poisoned_chunk_grid_drains(tmp_path, monkeypatch):
    """A chunk failing every attempt is quarantined: marker on disk,
    mirrored in the manifest, listed in meta, its scenario rows NaN — and
    every OTHER point of the grid drains intact."""
    poison, calls = _poison(target_seed=3)
    monkeypatch.setattr(runner_mod, "_run_arrays", poison)
    res = sweep.run_sweep(_spec(4), shards=1,
                          options=RunnerOptions(
                              pipeline=False, chunk_size=2, max_attempts=2,
                              backoff_s=0.01, checkpoint_dir=str(tmp_path)))
    assert calls["n"] == 2                    # exactly max_attempts tries

    # marker file is the authority; the manifest mirror stays legible and
    # leaves the fingerprint components untouched
    rec = json.loads(
        (tmp_path / "group000_chunk0001.quarantine.json").read_text())
    assert rec["attempts"] == 2 and "poisoned" in rec["error"]
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["quarantined"] == [[0, 1]]
    assert set(man["components"]) == {"spec", "chunk_size", "layout"}
    assert res.meta["quarantined_chunks"] == [[0, 1]]

    cols = res.scalars()
    seeds = np.array([p.coord_dict["seed"] for p in res.points])
    healthy, poisoned = seeds < 2, seeds >= 2
    assert np.isfinite(cols["makespan"][healthy]).all()
    assert np.isnan(cols["makespan"][poisoned]).all()
    assert not cols["all_done"][poisoned].any()
    assert cols["all_done"][healthy].all()
    assert not list(tmp_path.glob("*.claim"))


def test_resumed_run_honors_quarantine_marker(tmp_path, monkeypatch):
    """A later run against the same queue must NOT burn attempts on a
    quarantined chunk, even with healthy compute: the marker is respected,
    healthy chunks resume from their NPZs, the rows stay NaN."""
    poison, _ = _poison(target_seed=3)
    monkeypatch.setattr(runner_mod, "_run_arrays", poison)
    sweep.run_sweep(_spec(4), shards=1,
                    options=RunnerOptions(
                        pipeline=False, chunk_size=2, max_attempts=2,
                        backoff_s=0.01, checkpoint_dir=str(tmp_path)))

    calls = []

    def counting(arrays, cfg, statics, shards, donate):
        calls.append(1)
        raise AssertionError("resumed run should not recompute anything")

    monkeypatch.setattr(runner_mod, "_run_arrays", counting)
    res = sweep.run_sweep(_spec(4), shards=1,
                          options=RunnerOptions(
                              pipeline=False, chunk_size=2, max_attempts=2,
                              backoff_s=0.01, checkpoint_dir=str(tmp_path)))
    assert not calls
    assert res.meta["resumed_scenarios"] == 2
    assert res.meta["quarantined_chunks"] == [[0, 1]]
    cols = res.scalars()
    seeds = np.array([p.coord_dict["seed"] for p in res.points])
    assert np.isnan(cols["makespan"][seeds >= 2]).all()
    assert np.isfinite(cols["makespan"][seeds < 2]).all()


def test_quarantine_without_checkpoint_dir(monkeypatch):
    """Quarantine is not a WorkQueue-only feature: an un-checkpointed
    sweep with a poisoned chunk still drains, NaN rows and meta intact
    (pipeline path — the writer thread does the quarantining there)."""
    poison, calls = _poison(target_seed=3)
    monkeypatch.setattr(runner_mod, "_run_arrays", poison)
    orig_fin = runner_mod._finalize_arrays
    state = {"n": 0}

    def flaky_finalize(dev, n_real, cfg):
        # writer jobs run in submission order: call 1 is chunk 0's first
        # attempt (healthy), call 2 is chunk 1's — fail that one so the
        # retry falls through to the poisoned `_run_arrays`
        state["n"] += 1
        if state["n"] == 2:
            raise RuntimeError("poisoned input")
        return orig_fin(dev, n_real, cfg)

    monkeypatch.setattr(runner_mod, "_finalize_arrays", flaky_finalize)
    res = sweep.run_sweep(_spec(4), shards=1,
                          options=RunnerOptions(
                              pipeline=True, chunk_size=2, max_attempts=2,
                              backoff_s=0.01))
    # chunk 0 recovers on the re-dispatch attempt; chunk 1 (seed 3) fails
    # every attempt and is NaN-filled in-memory
    assert res.meta["quarantined_chunks"] == [[0, 1]]
    cols = res.scalars()
    seeds = np.array([p.coord_dict["seed"] for p in res.points])
    assert np.isfinite(cols["makespan"][seeds < 2]).all()
    assert np.isnan(cols["makespan"][seeds >= 2]).all()


def test_fully_poisoned_group_raises(monkeypatch):
    """A group with NO healthy chunk has no structure to NaN-fill from —
    that is a fully-poisoned sweep, and it must fail loudly."""

    def always_fail(arrays, cfg, statics, shards, donate):
        raise RuntimeError("dead on arrival")

    monkeypatch.setattr(runner_mod, "_run_arrays", always_fail)
    with pytest.raises(RuntimeError, match="quarantined"):
        sweep.run_sweep(_spec(2), shards=1,
                        options=RunnerOptions(pipeline=False, chunk_size=2,
                                              max_attempts=1,
                                              backoff_s=0.01))
