"""Vectorized credit-aware serving fleet (core.servesim, ISSUE 10).

Correctness is anchored the same three ways as the traffic engine:

  * the pure-Python `ServeFleetOracle` replay — real `KVCacheManager`
    slot accounting + the `admission_order` visit contract — matches
    float64-exactly (integer counters / histograms bit-for-bit, summed
    float accumulators at 1e-9: summation order differs between
    `jnp.sum` and the oracle's loop, the test_traffic convention);
  * the fused `ops.serve_admit` tick is BITWISE-equal to the unfused
    packed-cumsum tick, for both schedulers, and the Pallas interpret
    path matches the XLA reference at ragged (non-lane-multiple) shapes;
  * k-unrolled scans and the shard_map dispatch reproduce the k=1 vmap
    results bit for bit, decision-trace rings included.
"""
import dataclasses
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import servesim
from repro.kernels import ops
from repro.obs import registry as obsreg
from repro.obs import ring as obsring
from repro.sched.serve_scheduler import admission_order
from repro.serve.oracle import ServeFleetOracle
from repro.traffic import arrivals

TOL = 1e-9

# exact on both sides: integer counters, histograms, and tick*dt products
_EXACT = ("n_arrived", "n_admitted", "n_dropped", "n_completed",
          "lat_hist", "wait_hist", "all_done", "makespan", "last_finish",
          "node_busy_seconds")


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _scenario(rng_seed=7, n_replicas=4, unlimited=False, rate=0.6,
              amp=0.0):
    tmpl = arrivals.make_serve_template(4, seed=3)
    return arrivals.build_serve_scenario(
        tmpl, n_replicas=n_replicas, balance0=400.0, baseline=150.0,
        burst=1500.0, capacity=500.0, unlimited=unlimited, rate=rate,
        amp=amp, period=600.0, rng_seed=rng_seed)


def _cfg(**kw):
    kw.setdefault("n_ticks", 300)
    kw.setdefault("kv_slots", 3)
    kw.setdefault("table_slots", 32)
    kw.setdefault("slo_bins", 16)
    return servesim.ServeSimConfig(**kw)


def _assert_engine_matches_oracle(cfg, sc, i, res):
    o = ServeFleetOracle(sc, cfg).run()
    for k, v in o.items():
        e = np.asarray(res[k])[i]
        if k in _EXACT:
            assert np.array_equal(e, np.asarray(v)), \
                f"{k}: engine {e} != oracle {v}"
        else:
            assert np.allclose(e, v, rtol=TOL, atol=TOL, equal_nan=True), \
                f"{k}: engine {e} != oracle {v}"
    return o


# ---------------------------------------------------------------------------
# engine vs oracle parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler,traffic", [
    ("cash", "poisson"), ("rr", "diurnal"),
])
def test_matches_oracle(scheduler, traffic):
    sc = _scenario(amp=0.5 if traffic == "diurnal" else 0.0)
    cfg = _cfg(scheduler=scheduler, traffic=traffic)
    res = servesim.run_batch(arrivals.stack_serve_scenarios([sc]), cfg)
    o = _assert_engine_matches_oracle(cfg, sc, 0, res)
    assert o["n_completed"] > 0 and o["tokens_decoded"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("scheduler", ["cash", "rr"])
@pytest.mark.parametrize("traffic", ["poisson", "diurnal"])
@pytest.mark.parametrize("rng_seed", [7, 11, 23])
@pytest.mark.parametrize("unlimited", [False, True])
def test_matches_oracle_full_grid(scheduler, traffic, rng_seed, unlimited):
    """The full parity grid — scheduler x arrival process x stream seed x
    overdraft mode (tier-2: the two-combo tier-1 test covers the hot
    paths)."""
    sc = _scenario(rng_seed=rng_seed, unlimited=unlimited,
                   amp=0.5 if traffic == "diurnal" else 0.0)
    cfg = _cfg(scheduler=scheduler, traffic=traffic, n_ticks=500)
    res = servesim.run_batch(arrivals.stack_serve_scenarios([sc]), cfg)
    _assert_engine_matches_oracle(cfg, sc, 0, res)


def test_batched_scenarios_match_solo():
    """Scenarios in one stacked batch see exactly their solo results
    (slot recycling state never leaks across the vmap axis)."""
    scens = [_scenario(rng_seed=s) for s in (7, 11)]
    cfg = _cfg(scheduler="cash")
    both = servesim.run_batch(arrivals.stack_serve_scenarios(scens), cfg)
    for i, sc in enumerate(scens):
        solo = servesim.run_batch(arrivals.stack_serve_scenarios([sc]), cfg)
        for k in ("n_completed", "lat_hist", "tokens_decoded"):
            assert np.array_equal(np.asarray(both[k])[i],
                                  np.asarray(solo[k])[0]), k


# ---------------------------------------------------------------------------
# fused kernel: bitwise vs unfused, interpret vs xla
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["cash", "rr"])
def test_fused_matches_unfused_bitwise(scheduler):
    sc = _scenario()
    batch = arrivals.stack_serve_scenarios([sc])
    outs = {}
    for fusion in ("unfused", "fused"):
        cfg = _cfg(scheduler=scheduler, fusion=fusion, trace_slots=4096)
        outs[fusion] = servesim.run_batch(batch, cfg)
    for k in outs["unfused"]:
        a = np.asarray(outs["unfused"][k])
        b = np.asarray(outs["fused"][k])
        assert np.array_equal(a, b, equal_nan=a.dtype.kind == "f"), k


@pytest.mark.parametrize("policy", ["cash", "rr"])
def test_serve_admit_interpret_matches_xla(policy):
    """The Pallas kernel (interpret mode) against the XLA reference at
    ragged, non-lane-multiple shapes — lane padding must be inert."""
    key = jax.random.PRNGKey(0)
    C, R = 37, 5
    pend = np.asarray(jax.random.bernoulli(key, 0.5, (C,)))
    rank = np.where(pend, np.cumsum(pend) - 1, 999).astype(np.int32)

    def f(k, shape, lo, hi):
        return jax.random.uniform(jax.random.fold_in(key, k), shape,
                                  jnp_dtype, lo, hi)
    jnp_dtype = np.float64
    args = (pend, rank, np.full(C, -1, np.int32),
            np.asarray(f(1, (C,), 0.0, 100.0)),
            np.asarray(f(2, (C,), 0.0, 50.0)),
            np.full(C, 900.0), np.full(C, 60.0),
            np.asarray(f(3, (R,), 0.0, 300.0)),
            np.full(R, 150.0), np.full(R, 1500.0), np.full(R, 500.0),
            np.zeros(R, bool), np.asarray([3, 0, 2, 1, 3], np.int32),
            np.int32(pend.sum()), np.int32(2))
    kw = dict(dt=1.0, policy=policy, max_rounds=3)
    o_x = ops.serve_admit(*args, impl="xla", **kw)
    o_i = ops.serve_admit(*args, impl="interpret", **kw)
    for a, b in zip(o_x, o_i):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "f":
            assert np.allclose(a, b, rtol=1e-12, atol=1e-12)
        else:
            assert np.array_equal(a, b)


@pytest.mark.parametrize("k", [2, 4])
def test_unroll_bitwise(k):
    """k tick bodies unrolled per scan step (non-divisible tick count)
    reproduce k=1 bit for bit."""
    batch = arrivals.stack_serve_scenarios([_scenario()])
    base = servesim.run_batch(batch, _cfg(n_ticks=123))
    rolled = servesim.run_batch(batch, _cfg(n_ticks=123, unroll=k))
    for key in base:
        assert np.array_equal(np.asarray(base[key]),
                              np.asarray(rolled[key])), key


# ---------------------------------------------------------------------------
# decision trace + registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["cash", "rr"])
def test_event_parity(scheduler):
    """The engine's device ring decodes to exactly the oracle's event
    stream: admission (place), release, drop, deplete/regen — decision
    fields int-for-int."""
    sc = _scenario()
    cfg = _cfg(scheduler=scheduler, trace_slots=8192)
    res = servesim.run_batch(arrivals.stack_serve_scenarios([sc]), cfg)
    ora = ServeFleetOracle(sc, cfg, collect_events=True)
    ora.run()
    events = obsring.decode(res["trace_ev_i"][0], res["trace_ev_f"][0],
                            res["trace_head"][0])
    obsring.assert_event_parity(events, ora.events,
                                total=int(res["trace_head"][0]))
    kinds = {e.kind for e in events}
    assert obsring.EV_PLACE in kinds and obsring.EV_RELEASE in kinds


def test_trace_release_fields():
    """EV_RELEASE rows carry (slot, replica, latency) — the replica is
    the one the request actually resided on."""
    sc = _scenario()
    cfg = _cfg(scheduler="cash", trace_slots=8192)
    res = servesim.run_batch(arrivals.stack_serve_scenarios([sc]), cfg)
    events = obsring.decode(res["trace_ev_i"][0], res["trace_ev_f"][0],
                            res["trace_head"][0])
    rel = [e for e in events if e.kind == obsring.EV_RELEASE]
    assert rel and all(0 <= e.aux < 4 and e.value >= 0.0 for e in rel)
    # every release's (slot, replica) pairs with a preceding placement
    seen = set()
    ok = True
    for e in events:
        if e.kind == obsring.EV_PLACE:
            seen.add((e.subject, e.aux))
        elif e.kind == obsring.EV_RELEASE:
            ok = ok and (e.subject, e.aux) in seen
    assert ok


def test_registry_validates_serve_outputs():
    """Every serving-fleet output key is declared in the metrics
    registry (tokens_prefilled / tokens_decoded ride the scalar table)."""
    sc = _scenario()
    cfg = _cfg(trace_slots=2048)
    res = servesim.run_batch(arrivals.stack_serve_scenarios([sc]), cfg)
    obsreg.validate_outputs(res)
    assert obsreg.spec("tokens_prefilled").scope == "scalar"
    assert obsreg.spec("tokens_decoded").scope == "scalar"


# ---------------------------------------------------------------------------
# shard_map parity (subprocess: forced host device count)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.core import servesim
    from repro.traffic import arrivals

    tmpl = arrivals.make_serve_template(4, seed=3)
    scens = [arrivals.build_serve_scenario(
        tmpl, n_replicas=4, balance0=400.0, baseline=150.0, burst=1500.0,
        capacity=500.0, rate=0.6, rng_seed=s) for s in (7, 11, 23)]
    batch = arrivals.stack_serve_scenarios(scens)
    cfg = servesim.ServeSimConfig(n_ticks=200, kv_slots=3, table_slots=32,
                                  slo_bins=16, trace_slots=2048)
    a = servesim.run_batch(batch, cfg)
    b = servesim.run_batch_sharded(batch, cfg, n_shards=2)
    for k in a:
        ka, kb = np.asarray(a[k]), np.asarray(b[k])
        eq = (np.array_equal(ka, kb, equal_nan=True)
              if ka.dtype.kind == "f" else np.array_equal(ka, kb))
        assert eq, k
    print("BITWISE_OK")
""")


def test_sharded_matches_vmap_bitwise_subprocess():
    """`run_batch_sharded` (2-way scenario mesh, padded ragged batch)
    reproduces the vmap path bit for bit, trace rings included."""
    env = dict(os.environ)
    flags = " ".join(f for f in env.get("XLA_FLAGS", "").split()
                     if "xla_force_host_platform_device_count" not in f)
    env["XLA_FLAGS"] = \
        (flags + " --xla_force_host_platform_device_count=2").strip()
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=300)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "BITWISE_OK" in proc.stdout


# ---------------------------------------------------------------------------
# contracts: admission order, fusion choice, config/scenario validation
# ---------------------------------------------------------------------------

def test_admission_order_contract():
    credits = [10.0, 30.0, 30.0, 5.0]
    assert admission_order(credits, credit_aware=True) == [1, 2, 0, 3]
    assert admission_order(credits, credit_aware=False, ptr=2) == \
        [2, 3, 0, 1]


def test_serve_fusion_choice_platform():
    auto = _cfg(fusion="auto")
    assert servesim.serve_fusion_choice(auto, platform="cpu") == "unfused"
    assert servesim.serve_fusion_choice(auto, platform="tpu") == "fused"
    assert servesim.serve_fusion_choice(_cfg(fusion="fused"),
                                        platform="cpu") == "fused"
    assert servesim.serve_fusion_choice(_cfg(fusion="unfused"),
                                        platform="tpu") == "unfused"
    with pytest.raises(ValueError, match="fusion"):
        servesim.serve_fusion_choice(_cfg(fusion="bogus"))


def test_config_validation():
    batch = arrivals.stack_serve_scenarios([_scenario()])
    with pytest.raises(NotImplementedError, match="cash|rr"):
        servesim.run_batch(batch, _cfg(scheduler="stock"))
    with pytest.raises(NotImplementedError, match="stochastic"):
        servesim.run_batch(batch, _cfg(traffic="replay"))
    with pytest.raises(ValueError, match="kv_slots"):
        servesim.run_batch(batch, _cfg(kv_slots=0))


def test_stack_requires_uniform_fleet():
    with pytest.raises(ValueError, match="uniform replica count"):
        arrivals.stack_serve_scenarios([_scenario(n_replicas=4),
                                        _scenario(n_replicas=5)])


def test_stack_pads_templates_only():
    t2 = arrivals.make_serve_template(2, seed=1)
    t5 = arrivals.make_serve_template(5, seed=2)
    a = arrivals.build_serve_scenario(t2, n_replicas=3, rng_seed=1)
    b = arrivals.build_serve_scenario(t5, n_replicas=3, rng_seed=2)
    batch = arrivals.stack_serve_scenarios([a, b])
    assert batch["tmpl_pre"].shape == (2, 5)
    assert batch["rep_balance0"].shape == (2, 3)
    # tmpl_n guards the mod-indexing: padded rows never instantiate
    assert list(batch["tmpl_n"]) == [2, 5]
