"""Assigned-architecture configs: published sizes, shape table, skips."""
import pytest

from repro.configs import ARCHS, SHAPES, all_cells, get_config, shape_applicable

# published parameter counts (total, active), tolerance 6%
PUBLISHED = {
    "granite-20b": (20.1e9, None),
    "qwen1.5-110b": (111e9, None),
    "granite-3-2b": (2.5e9, None),
    "yi-34b": (34.4e9, None),
    "whisper-large-v3": (1.55e9, None),
    "jamba-1.5-large-398b": (398e9, 94e9),
    "mamba2-130m": (130e6, None),
    "phi3.5-moe-42b-a6.6b": (41.9e9, 6.6e9),
    "dbrx-132b": (132e9, 36e9),
    "llava-next-34b": (34.4e9, None),
}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_counts_match_published(arch):
    total, active = PUBLISHED[arch]
    cfg = ARCHS[arch]
    got = cfg.param_count()
    assert abs(got - total) / total < 0.06, (arch, got, total)
    if active is not None:
        got_a = cfg.param_count(active_only=True)
        assert abs(got_a - active) / active < 0.06, (arch, got_a, active)


def test_exact_dims_from_brief():
    c = get_config("qwen1.5-110b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (80, 8192, 64, 8, 49152, 152064)
    assert c.qkv_bias
    c = get_config("dbrx-132b")
    assert (c.moe.num_experts, c.moe.top_k) == (16, 4)
    c = get_config("granite-20b")
    assert c.num_kv_heads == 1          # MQA
    c = get_config("jamba-1.5-large-398b")
    assert c.hybrid_period == 8 and c.num_attention_layers() == 9


def test_shape_table():
    names = [s.name for s in SHAPES]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    by = {s.name: s for s in SHAPES}
    assert (by["train_4k"].seq_len, by["train_4k"].global_batch) == (4096, 256)
    assert (by["long_500k"].seq_len, by["long_500k"].global_batch) == (524288, 1)
    assert by["decode_32k"].kind == "decode"


def test_long_500k_skips():
    """long_500k runs only for sub-quadratic archs (ssm/hybrid)."""
    long = [s for s in SHAPES if s.name == "long_500k"][0]
    runnable = {a for a, c in ARCHS.items()
                if shape_applicable(c, long)[0]}
    assert runnable == {"mamba2-130m", "jamba-1.5-large-398b"}


def test_cell_count():
    cells = all_cells(include_skips=True)
    assert len(cells) == 40
    assert sum(1 for *_, ok, _ in cells if ok) == 32


def test_padded_vocab_divisible_by_128():
    for cfg in ARCHS.values():
        assert cfg.padded_vocab_size % 128 == 0
        assert cfg.padded_vocab_size >= cfg.vocab_size
