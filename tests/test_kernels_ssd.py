"""Mamba-2 SSD kernel: chunked == sequential == Pallas, across shapes/chunks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

CASES = [
    # (b, l, h, p, n, chunk)
    (1, 64, 2, 16, 16, 16),
    (2, 128, 3, 16, 32, 32),
    (1, 256, 4, 32, 64, 64),
    (2, 128, 1, 64, 16, 128),       # single chunk
]


def _mk(b, l, h, p, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, l, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, l, n)) * 0.3
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("case", CASES)
def test_chunked_ref_matches_sequential(case):
    b, l, h, p, n, chunk = case
    x, dt, A, Bm, Cm = _mk(b, l, h, p, n)
    y_seq, s_seq = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
    y_chk, s_chk = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5), (jnp.bfloat16, 3e-2)])
def test_pallas_matches_sequential(case, dtype, tol):
    b, l, h, p, n, chunk = case
    x, dt, A, Bm, Cm = _mk(b, l, h, p, n)
    x = x.astype(dtype)
    y_seq, _ = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
    y_pal = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_seq, np.float32),
                               atol=tol, rtol=tol)


@given(chunk_pow=st.integers(2, 5), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_chunk_size_invariance(chunk_pow, seed):
    """SSD output must not depend on the chunking (property)."""
    x, dt, A, Bm, Cm = _mk(1, 128, 2, 8, 16, seed=seed)
    y_ref, _ = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=128)
    y, _ = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=2 ** chunk_pow)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-5, rtol=5e-5)


def test_state_streaming_equivalence():
    """Processing two halves with carried state == processing the whole."""
    x, dt, A, Bm, Cm = _mk(1, 128, 2, 8, 16, seed=7)
    y_full, s_full = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=32)
    y1, s1 = ref.ssd_chunked_ref(x[:, :64], dt[:, :64], A, Bm[:, :64],
                                 Cm[:, :64], chunk=32)
    y2, s2 = ref.ssd_chunked_ref(x[:, 64:], dt[:, 64:], A, Bm[:, 64:],
                                 Cm[:, 64:], chunk=32, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=3e-5, rtol=3e-5)
