"""Collective-bytes HLO parser: synthetic lines + a real lowered module."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.hlo_analysis import collective_bytes


def test_parses_simple_ops():
    hlo = """
  %ag = bf16[4,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%add
  %rs = f32[16,16]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = s32[8]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    total, kinds = collective_bytes(hlo)
    assert kinds["all-gather"] == 4 * 128 * 2
    assert kinds["all-reduce"] == 1024 * 4
    assert kinds["reduce-scatter"] == 16 * 16 * 4
    assert kinds["collective-permute"] == 8 * 4
    assert total == sum(kinds[k] for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "collective-permute", "all-to-all"))


def test_start_done_counted_once():
    hlo = """
  %s = f32[256]{0} all-gather-start(%x)
  %d = f32[256]{0} all-gather-done(%s)
"""
    total, kinds = collective_bytes(hlo)
    assert kinds["all-gather"] == 256 * 4
    assert kinds["n_all-gather"] == 1


def test_tuple_results():
    hlo = "%t = (f32[64]{0}, f32[64]{0}) all-reduce(%a, %b)"
    total, kinds = collective_bytes(hlo)
    assert kinds["all-reduce"] == 2 * 64 * 4


def test_real_sharded_matmul_has_allreduce():
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices")
    mesh = Mesh(np.array(jax.devices()[:2]), ("model",))
    n = 64
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((n, n), jnp.float32)
    f = jax.jit(lambda a, b: a @ b,
                in_shardings=(NamedSharding(mesh, P(None, "model")),
                              NamedSharding(mesh, P("model", None))),
                out_shardings=NamedSharding(mesh, P()))
    hlo = f.lower(x, w).compile().as_text()
    total, kinds = collective_bytes(hlo)
    # contracting-dim sharding forces an all-reduce of the (n, n) result
    assert kinds["all-reduce"] >= n * n * 4
