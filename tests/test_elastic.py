"""Elastic scaling (ISSUE 8 satellite): `plan()` picks a valid mesh for
any surviving host set and partitions data shards completely; `resume()`
reshards the latest checkpoint onto the new plan's mesh (exercised in a
subprocess — forced host-platform devices require a fresh backend)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.sched import elastic


# ---------------------------------------------------------------------------
# plan(): mesh selection + data-shard re-split
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_hosts,dph,num_shards", [
    (4, 2, 16), (3, 2, 16), (1, 4, 7), (5, 1, 5), (2, 3, 1),
])
def test_plan_shard_map_complete_and_disjoint(n_hosts, dph, num_shards):
    """Host loss re-splits the data pipeline with no loss and no
    duplication: every shard id lands on exactly one survivor."""
    p = elastic.plan(n_hosts, dph, num_shards)
    seen = [s for h in range(n_hosts) for s in p.shard_map[h]]
    assert sorted(seen) == list(range(num_shards))
    assert len(seen) == len(set(seen))
    # deterministic round-robin: a pure function of the survivor count
    again = elastic.plan(n_hosts, dph, num_shards)
    assert again.shard_map == p.shard_map


def test_plan_single_survivor():
    """Degenerate recovery: one host left takes the whole grid."""
    p = elastic.plan(1, 4, 12)
    assert p.shard_map == {0: list(range(12))}
    assert p.mesh_shape == (4, 1)
    assert p.n_devices == 4


def test_plan_mesh_shape_with_model_parallel():
    p = elastic.plan(3, 4, 8, model_parallel=2)
    assert p.mesh_shape == (6, 2)
    assert p.n_devices == 12


def test_plan_rejects_indivisible_pool():
    """An alive pool not divisible by the model-parallel degree has no
    valid mesh — better to fail the re-plan than wedge the collective."""
    with pytest.raises(ValueError, match="divisible"):
        elastic.plan(3, 1, 8, model_parallel=2)
    with pytest.raises(ValueError, match="alive"):
        elastic.plan(0, 2, 8)


def test_plan_uneven_shard_counts_stay_balanced():
    """7 shards over 3 survivors: counts differ by at most one."""
    p = elastic.plan(3, 2, 7)
    counts = sorted(len(v) for v in p.shard_map.values())
    assert sum(counts) == 7 and counts[-1] - counts[0] <= 1


# ---------------------------------------------------------------------------
# resume(): checkpoint restore resharded for the survivors' mesh
# ---------------------------------------------------------------------------

_RESUME_SCRIPT = textwrap.dedent("""
    import json, sys
    import jax
    import numpy as np
    from repro.sched import elastic
    from repro.train import checkpoint as CKPT

    ckpt_dir = sys.argv[1]
    rng = np.random.default_rng(0)
    state = {"params": {"w1": rng.standard_normal((8, 16)).astype(np.float32),
                        "norm": rng.standard_normal((16,)).astype(np.float32)},
             "opt": {"w1": rng.standard_normal((8, 16)).astype(np.float32),
                     "norm": np.zeros((16,), np.float32)}}
    CKPT.save(ckpt_dir, 7, state, extra={"tokens": 123})

    def check(n_alive, dph):
        p = elastic.plan(n_alive, dph, num_shards=8)
        restored, step, extra, mesh = elastic.resume(ckpt_dir, state, p)
        assert step == 7 and extra["tokens"] == 123
        devs = set()
        for key in ("params", "opt"):
            for name, ref in state[key].items():
                got = restored[key][name]
                assert np.array_equal(np.asarray(got), ref), (key, name)
                devs |= set(d.id for d in got.sharding.device_set)
        # the restored tree lives on the NEW plan's device pool, and the
        # FSDP-ruled weight is actually split over the data axis
        assert devs == set(d.id for d in np.asarray(mesh.devices).ravel())
        w1 = restored["params"]["w1"]
        assert not w1.sharding.is_fully_replicated
        n_frag = len({tuple((sl.start, sl.stop) for sl in s.index)
                      for s in w1.addressable_shards})
        return {"n_devices": p.n_devices, "mesh": list(p.mesh_shape),
                "w1_fragments": n_frag}

    full = check(n_alive=2, dph=2)       # healthy: 2 hosts x 2 devices
    lost = check(n_alive=1, dph=2)       # one host down: reshard onto 2
    print("RESULT " + json.dumps({"full": full, "lost": lost}))
""")


def _subprocess_env(n_devices: int) -> dict:
    env = dict(os.environ)
    flags = " ".join(f for f in env.get("XLA_FLAGS", "").split()
                     if "xla_force_host_platform_device_count" not in f)
    env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count="
                        + str(n_devices)).strip()
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_resume_reshards_across_host_loss_subprocess(tmp_path):
    """ISSUE 8 satellite: restore the same checkpoint first on the full
    4-device mesh, then after a simulated host loss on the 2-device
    survivor mesh — values bit-identical both times, and the FSDP weight
    is genuinely re-split (4 fragments, then 2)."""
    proc = subprocess.run(
        [sys.executable, "-c", _RESUME_SCRIPT, str(tmp_path / "ckpt")],
        capture_output=True, text=True, env=_subprocess_env(4), timeout=300)
    assert proc.returncode == 0, proc.stderr[-4000:]
    (line,) = [l for l in proc.stdout.splitlines()
               if l.startswith("RESULT ")]
    out = json.loads(line[len("RESULT "):])
    assert out["full"] == {"n_devices": 4, "mesh": [4, 1],
                           "w1_fragments": 4}
    assert out["lost"] == {"n_devices": 2, "mesh": [2, 1],
                           "w1_fragments": 2}
