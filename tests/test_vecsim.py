"""Vectorized fleet simulator (core.vecsim) vs the Python `Simulation`
oracle, plus bucket-serve kernel properties.

Under float64 the `lax.scan` engine must reproduce the oracle's makespan,
per-job completion times and surplus credits within 1e-6*dt on CASH /
stock / joint scenarios (the engine is written to match tick-for-tick; the
tolerance only absorbs float reassociation). The oracle runs with an
identity-shuffle rng so its node order matches `shuffle="none"`.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.scheduler import (
    CashScheduler,
    JointCashScheduler,
    StockScheduler,
)
from repro.core.simulator import Job, SimConfig, Simulation
from repro.core.token_bucket import TokenBucket
from repro.core import vecsim
from repro.kernels import ops, ref

TOL = 1e-6  # * dt (dt = 1.0 below)


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


# ---------------------------------------------------------------------------
# scenario generators (deterministic; rebuilt fresh for oracle and engine)
# ---------------------------------------------------------------------------

def _mixed_jobs(seed: int, n_jobs: int = 3, tasks_per: int = 5, *,
                net: bool = True, disk: bool = True):
    rng = np.random.RandomState(seed)
    tid = [10_000 * (seed + 1)]

    def nt(**kw):
        tid[0] += 1
        return Task(tid=tid[0], job=kw.pop("job"), **kw)

    jobs = []
    for j in range(n_jobs):
        maps = []
        for k in range(tasks_per):
            if disk and k % 3 == 2:
                maps.append(nt(job=f"j{j}", vertex="root_input",
                               work_disk=float(rng.uniform(2000, 6000)),
                               demand_disk=float(rng.uniform(500, 2500)),
                               work_cpu=float(rng.uniform(10, 30)),
                               demand_cpu=float(rng.uniform(0.2, 0.8)),
                               annotation=Annotation.BURST_DISK))
            else:
                maps.append(nt(job=f"j{j}", vertex="map",
                               work_cpu=float(rng.uniform(20, 60)),
                               demand_cpu=float(rng.uniform(0.3, 0.9)),
                               annotation=Annotation.BURST_CPU))
        extra = []
        if net:
            extra.append(nt(job=f"j{j}", vertex="shuffle",
                            work_net=float(rng.uniform(1e9, 3e9)),
                            demand_net=float(rng.uniform(3e8, 3e9)),
                            work_cpu=float(rng.uniform(3, 8)),
                            demand_cpu=0.3,
                            depends_on=[m.tid for m in maps],
                            dep_threshold=0.4,
                            annotation=Annotation.NETWORK))
        extra.append(nt(job=f"j{j}", vertex="reduce",
                        work_cpu=float(rng.uniform(5, 15)),
                        demand_cpu=float(rng.uniform(0.2, 0.6)),
                        depends_on=[m.tid for m in maps]))
        jobs.append(Job(name=f"j{j}", tasks=maps + extra))
    return jobs


def _cluster(n_nodes: int, unlimited: bool = False, frac: float = 0.3):
    return make_cluster(n_nodes, "t3.large", cpu_initial_fraction=frac,
                        disk_initial_credits=200_000.0, unlimited=unlimited)


_SCHED = {"cash": CashScheduler, "stock": StockScheduler,
          "cash-joint": JointCashScheduler}


def _run_oracle(jobs, scheduler, *, resource="cpu", telemetry="predicted",
                n_nodes=4, unlimited=False, sequential=False):
    nodes = _cluster(n_nodes, unlimited)
    cfg = SimConfig(max_time=20_000.0, resource=resource, telemetry=telemetry)
    sim = Simulation(nodes, _SCHED[scheduler](vecsim.IdentityRng()), cfg)
    (sim.submit_sequential if sequential else sim.submit_parallel)(jobs)
    return sim.run()


def _run_vec(scenarios, scheduler, *, resource="cpu", telemetry="predicted",
             sequential=False, impl="xla", n_ticks=2000):
    cfg = vecsim.VecSimConfig(n_ticks=n_ticks, scheduler=scheduler,
                              resource=resource, telemetry=telemetry,
                              impl=impl)
    return vecsim.run_scenarios(scenarios, cfg)


def _assert_equivalent(out, i, oracle, jobs):
    assert bool(out["all_done"][i]), "vectorized run did not finish"
    assert out["makespan"][i] == pytest.approx(oracle.makespan, abs=TOL)
    for ji, j in enumerate(jobs):
        assert out["job_mask"][i][ji]
        assert out["job_completion"][i][ji] == pytest.approx(
            oracle.job_completion[j.name], abs=TOL)
    assert out["surplus_credits"][i] == pytest.approx(
        oracle.surplus_credits, abs=TOL)
    assert out["total_cpu_work"][i] == pytest.approx(
        oracle.total_cpu_work, rel=1e-9, abs=TOL)


# ---------------------------------------------------------------------------
# equivalence: engine vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["cash", "stock"])
def test_matches_oracle_mixed_workload(scheduler):
    """CASH/stock on a mixed cpu+disk+network DAG workload."""
    jobs = _mixed_jobs(3)
    oracle = _run_oracle(_mixed_jobs(3), scheduler)
    sc = vecsim.build_scenario(_cluster(4), jobs)
    out = _run_vec([sc], scheduler)
    _assert_equivalent(out, 0, oracle, jobs)


@pytest.mark.parametrize("telemetry", ["stale", "oracle"])
def test_matches_oracle_telemetry_modes(telemetry):
    """SS5.1 ablation modes (predicted is covered by every other test)."""
    jobs = _mixed_jobs(5, net=False)
    oracle = _run_oracle(_mixed_jobs(5, net=False), "cash",
                         telemetry=telemetry)
    sc = vecsim.build_scenario(_cluster(4), jobs)
    out = _run_vec([sc], "cash", telemetry=telemetry)
    _assert_equivalent(out, 0, oracle, jobs)


def test_matches_oracle_disk_resource():
    """Scheduler driven by the EBS credit pool (paper SS6.5)."""
    jobs = _mixed_jobs(4)
    oracle = _run_oracle(_mixed_jobs(4), "cash", resource="disk")
    sc = vecsim.build_scenario(_cluster(4), jobs)
    out = _run_vec([sc], "cash", resource="disk")
    _assert_equivalent(out, 0, oracle, jobs)


def test_matches_oracle_joint():
    """JointCashScheduler with both credit pools (paper SS8 extension)."""
    jobs = _mixed_jobs(6)
    oracle = _run_oracle(_mixed_jobs(6), "cash-joint", resource="joint")
    sc = vecsim.build_scenario(_cluster(4), jobs)
    out = _run_vec([sc], "cash-joint", resource="joint")
    _assert_equivalent(out, 0, oracle, jobs)


def test_matches_oracle_unlimited_surplus():
    """T3-unlimited: surplus credits must match to 1e-6*dt. Buckets start
    empty so bursting overdrafts immediately."""
    jobs = _mixed_jobs(7, net=False, disk=False)
    nodes = _cluster(4, unlimited=True, frac=0.0)
    sim = Simulation(nodes, CashScheduler(vecsim.IdentityRng()),
                     SimConfig(max_time=20_000.0))
    sim.submit_parallel(_mixed_jobs(7, net=False, disk=False))
    oracle = sim.run()
    assert oracle.surplus_credits > 0.0  # scenario must actually overdraft
    sc = vecsim.build_scenario(_cluster(4, unlimited=True, frac=0.0), jobs)
    out = _run_vec([sc], "cash")
    _assert_equivalent(out, 0, oracle, jobs)


def test_matches_oracle_sequential_submission():
    """Wave-gated job admission (submit_sequential)."""
    jobs = _mixed_jobs(8, net=False)
    oracle = _run_oracle(_mixed_jobs(8, net=False), "cash", sequential=True)
    sc = vecsim.build_scenario(_cluster(3), jobs, submit="sequential")
    out = _run_vec([sc], "cash", sequential=True)
    _assert_equivalent(out, 0, oracle, jobs)


def test_emit_task_times_off_matches_scalars():
    """``emit_task_times=False`` drops the (T,) start/finish carries (the
    multi-day sweep slimming) but must leave every scalar output — the
    makespan included, now tracked by a scalar last-release — unchanged."""
    jobs = _mixed_jobs(9, net=False)
    sc = vecsim.build_scenario(_cluster(4), jobs)
    full = _run_vec([sc], "cash")
    cfg = vecsim.VecSimConfig(n_ticks=2000, scheduler="cash", impl="xla",
                              emit_task_times=False)
    slim = vecsim.run_scenarios([sc], cfg)
    for k in ("makespan", "all_done", "surplus_credits", "total_cpu_work",
              "cpu_work_served", "node_busy_seconds"):
        assert np.array_equal(np.asarray(full[k]), np.asarray(slim[k])), k
    for k in ("finish", "start", "job_completion", "job_mask"):
        assert k in full and k not in slim, k


def test_heterogeneous_batch_matches_per_scenario_oracles():
    """Stacking pads tasks/nodes/groups — padded scenarios must still agree
    with their own oracle, and padding must not leak across the batch."""
    specs = [(11, 2, 3, 2), (12, 3, 6, 4), (13, 4, 4, 3)]  # seed,jobs,tasks,N
    scenarios, oracles, alljobs = [], [], []
    for seed, n_jobs, tasks_per, n_nodes in specs:
        jobs = _mixed_jobs(seed, n_jobs=n_jobs, tasks_per=tasks_per)
        oracles.append(_run_oracle(
            _mixed_jobs(seed, n_jobs=n_jobs, tasks_per=tasks_per), "cash",
            n_nodes=n_nodes))
        scenarios.append(vecsim.build_scenario(_cluster(n_nodes), jobs))
        alljobs.append(jobs)
    out = _run_vec(scenarios, "cash")
    for i, (oracle, jobs) in enumerate(zip(oracles, alljobs)):
        _assert_equivalent(out, i, oracle, jobs)
        # padded job slots must be masked out
        assert not out["job_mask"][i][len(jobs):].any()


# ---------------------------------------------------------------------------
# bucket-serve kernel: scalar-oracle equivalence + invariants
# ---------------------------------------------------------------------------

@given(
    baseline=st.floats(0.0, 10.0),
    headroom=st.floats(0.0, 10.0),
    balance_frac=st.floats(0.0, 1.0),
    demand=st.floats(0.0, 30.0),
    dt=st.floats(0.1, 100.0),
    unlimited=st.booleans(),
)
@settings(max_examples=100, deadline=None)
def test_bucket_serve_ref_matches_scalar_bucket(baseline, headroom,
                                                balance_frac, demand, dt,
                                                unlimited):
    """kernels.ref.bucket_serve_ref == TokenBucket.serve, branch for branch."""
    cap = 10_000.0
    b = TokenBucket(baseline=baseline, burst=baseline + headroom,
                    capacity=cap, balance=cap * balance_frac,
                    unlimited=unlimited)
    before = b.balance
    work_py = b.serve(demand, dt)
    w, nb, sur = ref.bucket_serve_ref(
        np.float64(before), np.float64(demand), np.float64(baseline),
        np.float64(baseline + headroom), np.float64(cap),
        np.float64(1.0 if unlimited else 0.0), dt=dt)
    assert float(w) == pytest.approx(work_py, rel=1e-12, abs=1e-9)
    assert float(nb) == pytest.approx(b.balance, rel=1e-12, abs=1e-9)
    assert float(sur) == pytest.approx(b.surplus_used, rel=1e-12, abs=1e-9)


@given(seed=st.integers(0, 50), dt=st.floats(0.25, 4.0))
@settings(max_examples=25, deadline=None)
def test_bucket_serve_invariants(seed, dt):
    """Fleet-wide invariants: balance in [0, cap], work <= min(demand,
    burst)*dt, surplus only where unlimited."""
    rng = np.random.RandomState(seed)
    n = 64
    baseline = rng.uniform(0.0, 5.0, n)
    burst = baseline + rng.uniform(0.0, 5.0, n)
    cap = rng.uniform(10.0, 1000.0, n)
    bal = cap * rng.uniform(0.0, 1.0, n)
    dem = rng.uniform(0.0, 12.0, n)
    unl = (rng.uniform(size=n) < 0.5).astype(np.float64)
    w, nb, sur = ref.bucket_serve_ref(bal, dem, baseline, burst, cap, unl,
                                      dt=float(dt))
    w, nb, sur = np.asarray(w), np.asarray(nb), np.asarray(sur)
    assert (nb >= -1e-9).all() and (nb <= cap + 1e-9).all()
    assert (w <= np.minimum(dem, burst) * dt + 1e-9).all()
    assert (w >= -1e-12).all()
    assert (sur >= -1e-12).all()
    assert (sur[unl < 0.5] == 0.0).all()
    # credit conservation where the bucket is not saturated or overdrafted
    interior = (nb > 1e-9) & (nb < cap - 1e-9) & (sur == 0.0)
    np.testing.assert_allclose(nb[interior],
                               (bal + baseline * dt - w)[interior],
                               rtol=1e-9, atol=1e-9)


def test_bucket_serve_pallas_interpret_matches_xla():
    """The Pallas kernel (interpret mode on CPU) must agree with the XLA
    reference, including the ragged tail past a (8x128) tile."""
    rng = np.random.RandomState(0)
    n = 1200  # not a multiple of 1024: exercises padding
    baseline = rng.uniform(0.0, 5.0, n)
    burst = baseline + rng.uniform(0.0, 5.0, n)
    cap = rng.uniform(10.0, 1000.0, n)
    bal = cap * rng.uniform(0.0, 1.0, n)
    dem = rng.uniform(0.0, 12.0, n)
    unl = (rng.uniform(size=n) < 0.5).astype(np.float64)
    out_ref = ops.bucket_serve(bal, dem, baseline, burst, cap, unl,
                               dt=1.0, impl="xla")
    out_pal = ops.bucket_serve(bal, dem, baseline, burst, cap, unl,
                               dt=1.0, impl="interpret")
    for a, b in zip(out_ref, out_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("impl", ["xla", "interpret"])
@pytest.mark.parametrize("split_dist", [False, True])
def test_bucket_serve_distribute_fused_matches_unfused(impl, split_dist):
    """ISSUE 5 acceptance: the fused serve+distribute op must match the
    unfused serve-then-stacked-gather formulation bitwise in float64, on
    both the XLA reference and the Pallas interpret path, with and without
    a distinct distribution demand (the network dual-regulator case)."""
    rng = np.random.RandomState(7)
    n, t = 11, 333          # ragged vs both the lane and the task tile
    baseline = rng.uniform(0.0, 5.0, n)
    burst = baseline + rng.uniform(0.0, 5.0, n)
    cap = rng.uniform(10.0, 1000.0, n)
    bal = cap * rng.uniform(0.0, 1.0, n)
    dem = rng.uniform(0.0, 12.0, n)
    dem[0] = 0.0            # an idle node: its tasks' shares must be zero
    unl = (rng.uniform(size=n) < 0.5).astype(np.float64)
    nidx = rng.randint(0, n, t).astype(np.int32)
    dem_task = rng.uniform(0.0, 2.0, t)
    dist = rng.uniform(0.0, 12.0, n) if split_dist else None

    # unfused reference: serve, then the old stacked gather + pro-rata
    w, nb, sur = ref.bucket_serve_ref(bal, dem, baseline, burst, cap, unl,
                                      dt=1.0)
    dd = dem if dist is None else dist
    g = np.stack([np.asarray(w), np.asarray(dd)])[:, nidx]
    share_ref = np.zeros_like(dem_task)
    m = g[1] > 0.0
    share_ref[m] = g[0][m] * dem_task[m] / g[1][m]

    share, w2, nb2, sur2 = ops.bucket_serve_distribute(
        bal, dem, baseline, burst, cap, unl, nidx, dem_task, dt=1.0,
        impl=impl, dist_demand=dist)
    np.testing.assert_array_equal(np.asarray(share), share_ref)
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(nb2), np.asarray(nb))
    np.testing.assert_array_equal(np.asarray(sur2), np.asarray(sur))


def test_vecsim_interpret_impl_smoke():
    """The whole engine runs with the Pallas kernel in interpret mode."""
    jobs = _mixed_jobs(2, n_jobs=1, tasks_per=3, net=False, disk=False)
    sc = vecsim.build_scenario(_cluster(2), jobs)
    out_x = _run_vec([sc], "cash", impl="xla", n_ticks=150)
    out_i = _run_vec([sc], "cash", impl="interpret", n_ticks=150)
    assert bool(out_i["all_done"][0])
    assert out_i["makespan"][0] == pytest.approx(float(out_x["makespan"][0]))
