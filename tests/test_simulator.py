"""Cluster simulator: determinism, conservation, paper-experiment structure."""
import pytest

from repro.core import (
    Annotation,
    SCHEDULERS,
    SimConfig,
    Simulation,
    Task,
    make_cluster,
)
from repro.core.simulator import Job
from repro.core.workloads import make_tpcds_suite, reset_tids


def _small_run(sched="cash", seed=1):
    reset_tids()
    nodes = make_cluster(3, "m5.2xlarge", ebs_size_gb=100.0,
                         disk_initial_credits=0.0)
    sim = Simulation(nodes, SCHEDULERS[sched](),
                     SimConfig(resource="disk", max_time=50_000))
    sim.submit_parallel(make_tpcds_suite(100.0, 3, 8, seed=seed))
    return sim.run()


def test_deterministic():
    a = _small_run()
    b = _small_run()
    assert a.makespan == b.makespan
    assert a.job_completion == b.job_completion


def test_all_tasks_finish_and_work_conserved():
    r = _small_run()
    assert r.tasks, "no tasks completed"
    for t in r.tasks:
        rem = t.remaining()
        assert max(rem.values()) <= 1e-6
        assert t.finish_time is not None and t.finish_time >= t.start_time


def test_dependencies_respected():
    r = _small_run()
    by_id = {t.tid: t for t in r.tasks}
    for t in r.tasks:
        if not t.depends_on:
            continue
        th = t.dep_threshold if t.dep_threshold is not None else 1.0
        done_before = sum(
            1 for d in t.depends_on if by_id[d].finish_time <= t.start_time)
        assert done_before / len(t.depends_on) + 1e-9 >= min(th, 1.0)


def test_sequential_jobs_gate():
    reset_tids()
    nodes = make_cluster(2, "m5.2xlarge")
    sim = Simulation(nodes, SCHEDULERS["stock"](), SimConfig(resource="cpu"))
    t1 = Task(tid=1, job="a", vertex="map", work_cpu=10.0, demand_cpu=1.0)
    t2 = Task(tid=2, job="b", vertex="map", work_cpu=10.0, demand_cpu=1.0)
    sim.submit_sequential([Job("a", [t1]), Job("b", [t2])])
    sim.run()
    assert t2.start_time >= t1.finish_time


def test_throttling_extends_elapsed():
    """A CPU-hungry wave on zero-credit burstables runs ~baseline/demand
    slower than on fixed-rate instances."""
    def run(instance):
        reset_tids()
        nodes = make_cluster(1, instance, cpu_initial_fraction=0.0)
        sim = Simulation(nodes, SCHEDULERS["stock"](), SimConfig(resource="cpu"))
        tasks = [Task(tid=i + 1, job="j", vertex="map", work_cpu=100.0,
                      demand_cpu=0.9, annotation=Annotation.BURST_CPU)
                 for i in range(8)]
        sim.submit_parallel([Job("j", tasks)])
        return sim.run().makespan

    m5 = run("m5.2xlarge")          # no throttle
    t3 = run("t3.2xlarge")          # throttled to 3.2/7.2
    assert t3 > m5 * 1.8
