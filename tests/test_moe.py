"""MoE dispatch invariants (property-based) + aux loss behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, reduced_config
from repro.configs.base import MoEConfig
from repro.models.moe import init_moe, moe_block


def _cfg(num_experts=4, top_k=2, cap=1.25, every=1):
    base = reduced_config(ARCHS["phi3.5-moe-42b-a6.6b"])
    return dataclasses.replace(base, moe=MoEConfig(
        num_experts=num_experts, top_k=top_k, d_ff=64,
        every=every, capacity_factor=cap))


def test_output_shape_and_finite():
    cfg = _cfg()
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_block(cfg, p, x, group_size=16)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))


def test_huge_capacity_equals_dense_topk():
    """With capacity >= all tokens, no drops: output is the exact gated sum
    of the top-k expert MLPs (reference implementation)."""
    cfg = _cfg(cap=100.0)
    m = cfg.moe
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y, _ = moe_block(cfg, p, x, group_size=8)

    # dense reference
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, m.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for e in range(m.num_experts):
        h = jax.nn.silu(x @ p["w1"][e]) * (x @ p["w3"][e])
        ye = h @ p["w2"][e]
        w_e = jnp.sum(jnp.where(gi == e, gv, 0.0), axis=-1)
        want = want + w_e[..., None] * ye
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_capacity_drops_reduce_output_mass():
    cfg_hi = _cfg(cap=100.0)
    cfg_lo = _cfg(cap=0.25)
    p = init_moe(cfg_hi, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg_hi.d_model))
    y_hi, _ = moe_block(cfg_hi, p, x, group_size=32)
    y_lo, _ = moe_block(cfg_lo, p, x, group_size=32)
    assert float(jnp.linalg.norm(y_lo)) < float(jnp.linalg.norm(y_hi))


def test_aux_loss_penalizes_imbalance():
    cfg = _cfg()
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    # positive activations + a one-column router weight = every token's top
    # choice is expert 0 -> skewed load -> higher aux loss
    p_biased = dict(p)
    bias = jnp.zeros((cfg.d_model, cfg.moe.num_experts))
    p_biased["router"] = bias.at[:, 0].set(1.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3),
                                  (1, 32, cfg.d_model))) + 0.1
    _, aux_fair = moe_block(cfg, p, x, group_size=32)
    _, aux_skew = moe_block(cfg, p_biased, x, group_size=32)
    assert float(aux_skew) > float(aux_fair)


@given(tokens_pow=st.integers(3, 6), k=st.integers(1, 3),
       e_pow=st.integers(2, 3), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_dispatch_conservation(tokens_pow, k, e_pow, seed):
    """Every kept token contributes with combined gate weight <= 1; no token
    appears in more than k expert buffers."""
    e = 2 ** e_pow
    if k > e:
        return
    cfg = _cfg(num_experts=e, top_k=k)
    p = init_moe(cfg, jax.random.PRNGKey(seed), jnp.float32)
    s = 2 ** tokens_pow
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, s, cfg.d_model))
    y, aux = moe_block(cfg, p, x, group_size=s)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
