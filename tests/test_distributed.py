"""Sharding rules, gradient compression, pipeline parallelism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import compression as C
from repro.distributed import sharding as SH


def cpu_mesh(data=1, model=1):
    devs = np.array(jax.devices()[:data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


class TestShardingRules:
    def test_divisibility_fallback(self):
        mesh = cpu_mesh(1, 1)
        # with axis sizes of 1 everything divides; simulate size via _fit
        spec = SH.spec_for_param(("layers", "attn", "wq"), (4, 128, 256), mesh)
        assert spec == P(None, "data", "model")

    def test_moe_expert_parallel(self):
        mesh = cpu_mesh(1, 1)
        spec = SH.spec_for_param(("layers", "moe", "w1"), (4, 16, 128, 256), mesh)
        assert spec == P(None, "model", "data", None)
        # dense-rule w1 unchanged outside moe paths
        spec2 = SH.spec_for_param(("layers", "mlp", "w1"), (4, 128, 256), mesh)
        assert spec2 == P(None, "data", "model")

    def test_norms_replicated(self):
        mesh = cpu_mesh(1, 1)
        assert SH.spec_for_param(("layers", "ln1", "scale"), (4, 128), mesh) \
            == P(None, None)

    def test_embed_vocab_parallel(self):
        mesh = cpu_mesh(1, 1)
        assert SH.spec_for_param(("embed",), (512, 128), mesh) == P("model", "data")

    def test_constrain_noop_without_mesh(self):
        SH.set_mesh(None)
        x = jnp.ones((4, 4))
        assert SH.constrain(x, "dp", None) is x


class TestCompression:
    def test_roundtrip_small_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s, meta = C.quantize_int8(x)
        back = C.dequantize_int8(q, s, meta)
        assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6

    def test_wire_volume_cut(self):
        grads = {"a": jnp.ones((4096, 256)), "b": jnp.ones((1000,))}
        comp, unc = C.wire_bytes(grads)
        assert comp < unc * 0.6      # ~4x cut vs bf16 minus scale overhead

    def test_error_feedback_unbiased(self):
        """With EF, the *accumulated* applied gradient converges to the true
        accumulated gradient (quantization noise does not build up)."""
        key = jax.random.PRNGKey(1)
        g_true = jax.random.normal(key, (512,)) * 1e-3
        err = None
        applied = jnp.zeros_like(g_true)
        for _ in range(50):
            deq, err = C.compress_tree(g_true, err)
            applied = applied + deq
        # mean applied per step ~ g_true
        np.testing.assert_allclose(np.asarray(applied / 50),
                                   np.asarray(g_true), atol=1e-6)

    @given(n=st.integers(1, 2000), scale=st.floats(1e-6, 1e3), seed=st.integers(0, 99))
    @settings(max_examples=30, deadline=None)
    def test_quantize_bounds(self, n, scale, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale
        q, s, meta = C.quantize_int8(x)
        back = C.dequantize_int8(q, s, meta)
        assert back.shape == x.shape
        # block-wise max error bound: scale/127... scale per block <= max|x|
        assert float(jnp.max(jnp.abs(back - x))) <= scale * 5.0 / 127 + 1e-5


class TestPipeline:
    def test_pipeline_matches_sequential(self):
        """2-stage pipeline over a 2-device axis == sequential stage apply."""
        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices")
        devs = np.array(jax.devices()[:2])
        mesh = Mesh(devs, ("pod",))
        d = 16

        def stage_fn(params, x):
            return jnp.tanh(x @ params["w"])

        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (2, d, d)) * 0.5
        stage_params = {"w": ws}
        xs = jax.random.normal(jax.random.PRNGKey(1), (4, 3, d))  # M=4 mb

        from repro.distributed.pipeline import pipeline_forward
        got = pipeline_forward(stage_fn, stage_params, xs, mesh, axis="pod")
        want = jnp.tanh(jnp.tanh(xs @ ws[0]) @ ws[1])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
