"""Observability (ISSUE 9): the device event ring must record the same
decision stream the numpy replay oracle derives (placement + credit
rank, blacklist triggers with predicted time-to-deplete, preempt /
shed / drop, SLO overflow, bucket deplete/regen crossings), stay
bitwise-stable under unroll / fusion / `shard_map`, and cost ZERO
carried state when disabled. The host side — trace sink, Perfetto/JSONL
export, runner spans, metrics registry, explainer CLI — is covered
here too.

Decision fields (tick, kind, subject, aux, rank) compare int-exact;
event VALUES compare float32-close because XLA contracts the serve's
``balance - drain * t`` into an FMA the pure-double oracle doesn't have
(see `repro.obs.ring.assert_event_parity`).
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vecsim
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.simulator import Job
from repro.faults import attach_fault_process
from repro.obs import registry, ring
from repro.obs import trace as obstrace
from repro.obs.oracle import replay_events
from repro.obs.ring import (EV_DEPLETE, EV_PLACE, EV_REGEN, Event,
                            EventCollector, assert_event_parity, decode,
                            record_blocks, ring_init)
from repro.obs.spans import SpanTracer
from repro.traffic import arrivals

TRACE_KEYS = obstrace.TRACE_KEYS
SLOTS = 4096        # retains every event at these scales (no overwrite)


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


# ---------------------------------------------------------------------------
# scenario/config helpers (mirroring tests/test_faults.py scales)
# ---------------------------------------------------------------------------

def _fleet(n=4, slots=3, frac=0.3):
    return make_cluster(n, "t3.large", slots_per_node=slots,
                        cpu_initial_fraction=frac)


def _cpu_jobs(seed, n_jobs=3, tasks_per=5, burst_all=False):
    rng = np.random.default_rng(seed)
    jobs, tid = [], 0
    for j in range(n_jobs):
        tasks = []
        for _ in range(tasks_per):
            ann = (Annotation.BURST_CPU if burst_all or rng.random() < 0.6
                   else Annotation.NONE)
            tasks.append(Task(tid=tid, job=f"j{j}", vertex="map",
                              work_cpu=float(rng.uniform(20, 80)),
                              demand_cpu=float(rng.uniform(0.4, 1.0)),
                              annotation=ann))
            tid += 1
        jobs.append(Job(name=f"j{j}", tasks=tasks))
    return jobs


def _closed_scenario(faults, seed=11):
    nodes = make_cluster(3, "t3.large", slots_per_node=2,
                         cpu_initial_fraction=0.3)
    sc = vecsim.build_scenario(nodes, _cpu_jobs(seed), submit="parallel")
    if faults != "none":
        sc = attach_fault_process(sc, mode=faults, dt=5.0,
                                  kill_rate=1 / 600.0,
                                  restore_rate=1 / 900.0)
    return sc


def _closed_cfg(faults, scheduler="cash", **kw):
    base = dict(n_ticks=400, dt=5.0, scheduler=scheduler,
                telemetry="predicted", trace_slots=SLOTS)
    if faults != "none":
        base.update(faults=faults, max_retries=2,
                    blacklist_horizon_s=120.0, preempt_notice_s=20.0)
    base.update(kw)
    return vecsim.VecSimConfig(**base)


def _traffic_scenario(faults, rng_seed=7, **fkw):
    tmpl = arrivals.make_template(6, seed=3)
    sc = arrivals.build_traffic_scenario(_fleet(), tmpl, mode="poisson",
                                         rate=0.05, rng_seed=rng_seed)
    if faults != "none":
        sc = attach_fault_process(sc, mode=faults, dt=5.0,
                                  **{**dict(kill_rate=1 / 300.0,
                                            restore_rate=1 / 900.0), **fkw})
    return sc


def _traffic_cfg(faults, scheduler="cash", **kw):
    base = dict(n_ticks=300, dt=5.0, scheduler=scheduler,
                telemetry="predicted", traffic="poisson", table_slots=24,
                slo_bins=16, trace_slots=SLOTS)
    if faults != "none":
        base.update(faults=faults, max_retries=2,
                    blacklist_horizon_s=120.0, preempt_notice_s=20.0)
    base.update(kw)
    return vecsim.VecSimConfig(**base)


def _run_and_decode(sc, cfg):
    out = vecsim.run_scenarios([sc], cfg)
    events = obstrace.decode_trace(out, 0)
    head = int(np.asarray(out["trace_head"])[0])
    return out, events, head


def _bitwise_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f":
        return np.array_equal(a, b, equal_nan=True)
    return np.array_equal(a, b)


# ---------------------------------------------------------------------------
# ring unit semantics (pure, no engine)
# ---------------------------------------------------------------------------

def test_ring_record_decode_overwrite_oldest():
    """Events scatter in canonical block order; once head > S only the
    last S survive, and `decode` rotates them back chronologically."""
    S = 5
    ev_i, ev_f, head = ring_init(S)
    ids = jnp.arange(3, dtype=jnp.int32)
    for t in range(4):
        # per tick: nodes t%3 and (t+1)%3 emit EV_DEPLETE, value 10t+n
        valid = (ids == t % 3) | (ids == (t + 1) % 3)
        blocks = [(valid, EV_DEPLETE, ids, -1, -1,
                   10.0 * t + ids.astype(jnp.float32))]
        ev_i, ev_f, head = record_blocks(ev_i, ev_f, head, t, blocks)
    events = decode(np.asarray(ev_i), np.asarray(ev_f), int(head))
    assert int(head) == 8                    # 2 events x 4 ticks
    assert len(events) == S                  # ring kept the last 5
    assert [e.seq for e in events] == [3, 4, 5, 6, 7]
    assert [(e.tick, e.subject) for e in events] == \
        [(1, 2), (2, 0), (2, 2), (3, 0), (3, 1)]
    for e in events:
        assert e.kind == EV_DEPLETE
        assert e.value == pytest.approx(10.0 * e.tick + e.subject)


def test_ring_capacity_guard():
    """S < per-tick block width would collide scatter indices — a
    static trace-time error, not silent corruption."""
    ev_i, ev_f, head = ring_init(2)
    ids = jnp.arange(4, dtype=jnp.int32)
    with pytest.raises(ValueError, match="capacity"):
        record_blocks(ev_i, ev_f, head, 0,
                      [(ids >= 0, EV_REGEN, ids, -1, -1, 0.0)])


def test_assert_event_parity_semantics():
    """Decision fields are int-exact (a rank flip fails), values are
    f32-close (an FMA-sized residue passes; a real delta fails)."""
    col = EventCollector()
    col.emit(3, EV_PLACE, 0, 1, 0, 5.0)
    engine = [Event(seq=0, tick=3, kind=EV_PLACE, subject=0, aux=1,
                    rank=0, value=5.0 + 1e-17)]
    assert_event_parity(engine, col.events, total=1)        # residue ok
    with pytest.raises(AssertionError, match="totals"):
        assert_event_parity(engine, col.events, total=2)
    bad_rank = [Event(seq=0, tick=3, kind=EV_PLACE, subject=0, aux=1,
                      rank=1, value=5.0)]
    with pytest.raises(AssertionError):
        assert_event_parity(bad_rank, col.events)
    bad_val = [Event(seq=0, tick=3, kind=EV_PLACE, subject=0, aux=1,
                     rank=0, value=5.1)]
    with pytest.raises(AssertionError, match="value"):
        assert_event_parity(bad_val, col.events)


# ---------------------------------------------------------------------------
# zero-overhead contract: disabled => bitwise-equal + no extra carry
# ---------------------------------------------------------------------------

def test_trace_disabled_is_bitwise_free():
    """Enabling the trace must not perturb ANY engine output — and with
    `trace_slots=0` the outputs carry no trace keys at all."""
    for sc, on, off in (
        (_closed_scenario("spot"), _closed_cfg("spot"),
         _closed_cfg("spot", trace_slots=0)),
        (_traffic_scenario("spot"), _traffic_cfg("spot"),
         _traffic_cfg("spot", trace_slots=0)),
    ):
        a = vecsim.run_scenarios([sc], off)
        b = vecsim.run_scenarios([sc], on)
        assert not any(k in a for k in TRACE_KEYS)
        assert all(k in b for k in TRACE_KEYS)
        for k, va in a.items():
            if isinstance(va, dict):
                continue
            assert _bitwise_equal(va, b[k]), k


def test_untraced_scan_carries_no_ring_state(monkeypatch):
    """With `trace_slots=0` the tick scan's carry must not contain the
    ring (`ev_i`/`ev_f`/`ev_head`) — statically absent, not zero-sized;
    and the same keys DO appear once tracing is on."""
    captured = []
    orig = jax.lax.scan

    def spy(f, init, xs=None, **kw):
        if isinstance(init, dict):
            captured.append(set(init.keys()))
        return orig(f, init, xs, **kw)

    monkeypatch.setattr(jax.lax, "scan", spy)
    ring_keys = {"ev_i", "ev_f", "ev_head"}

    # unique n_ticks force fresh traces so the spy sees the carry
    tsc = _traffic_scenario("none")
    vecsim.run_scenarios([tsc], _traffic_cfg("none", n_ticks=307,
                                             trace_slots=0))
    csc = _closed_scenario("none")
    vecsim.run_scenarios([csc], _closed_cfg("none", n_ticks=309,
                                            trace_slots=0))
    assert captured, "spy saw no dict-carry scans (stale jit cache?)"
    for keys in captured:
        assert not (keys & ring_keys), keys & ring_keys

    captured.clear()
    vecsim.run_scenarios([tsc], _traffic_cfg("none", n_ticks=307))
    vecsim.run_scenarios([csc], _closed_cfg("none", n_ticks=309))
    assert any(keys & ring_keys for keys in captured)


# ---------------------------------------------------------------------------
# ring vs numpy replay oracle: scheduler x {path, faults} grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ("cash", "stock"))
@pytest.mark.parametrize("faults", ("none", "spot"))
def test_closed_trace_parity(scheduler, faults):
    sc = _closed_scenario(faults)
    cfg = _closed_cfg(faults, scheduler)
    _, events, head = _run_and_decode(sc, cfg)
    oracle_events, _, _ = replay_events(sc, cfg)
    assert head > 0 and any(e.kind == EV_PLACE for e in events)
    assert_event_parity(events, oracle_events, total=head)


@pytest.mark.parametrize("scheduler", ("cash", "stock"))
@pytest.mark.parametrize("faults", ("none", "spot"))
def test_traffic_trace_parity(scheduler, faults):
    sc = _traffic_scenario(faults)
    cfg = _traffic_cfg(faults, scheduler)
    _, events, head = _run_and_decode(sc, cfg)
    oracle_events, _, _ = replay_events(sc, cfg)
    assert head > 0 and any(e.kind == EV_PLACE for e in events)
    if faults == "spot":
        kinds = {e.kind for e in oracle_events}
        assert ring.EV_PREEMPT in kinds     # the faults actually bite
        if scheduler == "cash":
            assert ring.EV_BLACKLIST in kinds
    assert_event_parity(events, oracle_events, total=head)


def test_trace_overwrite_tail_parity():
    """An undersized ring (slots < total events) keeps exactly the LAST
    `S` events — and that tail still matches the oracle replay's tail."""
    sc = _traffic_scenario("none")
    big = _traffic_cfg("none")
    _, all_events, head = _run_and_decode(sc, big)
    assert head > 0, "scenario recorded nothing"
    small = _traffic_cfg("none", trace_slots=1)    # engine pads to width
    out, tail_events, head2 = _run_and_decode(sc, small)
    S = np.asarray(out["trace_ev_i"]).shape[1]
    # the undersized ring really overflowed (else this test is vacuous)
    assert head2 == head and len(tail_events) == min(head, S) < head
    oracle_events, _, _ = replay_events(sc, small)
    assert_event_parity(tail_events, oracle_events, total=head2)
    # the retained tail is literally the end of the full stream
    assert [e.key() for e in tail_events] == \
        [e.key() for e in all_events[head - len(tail_events):]]


@pytest.mark.parametrize("unroll", (2, 4))
def test_traced_unroll_ring_bitwise(unroll):
    """The k-unrolled tick scan records a bitwise-identical ring."""
    sc = _traffic_scenario("spot")
    a = vecsim.run_scenarios([sc], _traffic_cfg("spot", unroll=1))
    b = vecsim.run_scenarios([sc], _traffic_cfg("spot", unroll=unroll))
    for k, va in a.items():
        if isinstance(va, dict):
            continue
        assert _bitwise_equal(va, b[k]), k


def test_fused_unfused_trace_agree():
    """The fused megatick threads the ring too: fused and unfused runs
    produce the same decision stream, and both match the oracle."""
    nodes = make_cluster(3, "t3.large", slots_per_node=2,
                         cpu_initial_fraction=0.05)
    sc = vecsim.build_scenario(nodes, _cpu_jobs(5, burst_all=True),
                               submit="parallel")
    evs = {}
    for fusion in ("unfused", "fused"):
        cfg = _closed_cfg("none", telemetry="oracle", fusion=fusion)
        _, events, head = _run_and_decode(sc, cfg)
        oracle_events, _, _ = replay_events(sc, cfg)
        assert_event_parity(events, oracle_events, total=head)
        evs[fusion] = events
    assert [e.key() for e in evs["fused"]] == \
        [e.key() for e in evs["unfused"]]


# ---------------------------------------------------------------------------
# shard_map bitwise parity (forced devices need a fresh process)
# ---------------------------------------------------------------------------

_TRACE_SHARD_SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro import sweep
    from repro.core import vecsim
    from repro.core.cluster import make_cluster
    from repro.traffic import arrivals

    tmpl = arrivals.make_template(6, seed=3)

    def builder(rng_seed):
        fleet = make_cluster(4, "t3.large", slots_per_node=3,
                             cpu_initial_fraction=0.3)
        return arrivals.build_traffic_scenario(fleet, tmpl, mode="poisson",
                                               rate=0.05,
                                               rng_seed=rng_seed)

    spec = sweep.SweepSpec(builder, axes={"rng_seed": list(range(4))},
                           base=vecsim.VecSimConfig(
                               n_ticks=300, dt=5.0, traffic="poisson",
                               table_slots=24, slo_bins=16,
                               trace_slots=4096))
    a = sweep.run_sweep(spec.groups(), shards=1)
    b = sweep.run_sweep(spec.groups(), shards=2)
    for key in ("trace_ev_i", "trace_ev_f", "trace_head"):
        ka = np.asarray(a.groups[0].outputs[key])
        kb = np.asarray(b.groups[0].outputs[key])
        assert np.array_equal(ka, kb), key
    assert np.asarray(a.groups[0].outputs["trace_head"]).min() > 0
    sa, sb = a.scalars(), b.scalars()
    for k in sa:
        ka, kb = np.asarray(sa[k]), np.asarray(sb[k])
        eq = (np.array_equal(ka, kb, equal_nan=True)
              if ka.dtype.kind == "f" else np.array_equal(ka, kb))
        assert eq, k
    print("BITWISE_OK")
""")


def test_traced_shard_map_bitwise_subprocess():
    """A traced sweep sharded 2-way over the scenario axis reproduces
    the unsharded rings bit for bit (the ring is just more carried
    per-scenario state — shard_map must not reorder or renumber it)."""
    proc = subprocess.run([sys.executable, "-c", _TRACE_SHARD_SCRIPT],
                          capture_output=True, text=True,
                          env=_subprocess_env(2), timeout=300)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "BITWISE_OK" in proc.stdout


def _subprocess_env(n_devices: int) -> dict:
    env = dict(os.environ)
    flags = " ".join(f for f in env.get("XLA_FLAGS", "").split()
                     if "xla_force_host_platform_device_count" not in f)
    env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count="
                        + str(n_devices)).strip()
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


# ---------------------------------------------------------------------------
# host side: bundle round-trip, Perfetto/JSONL export, runner spans
# ---------------------------------------------------------------------------

def test_trace_bundle_and_exports(tmp_path):
    sc = _traffic_scenario("none")
    cfg = _traffic_cfg("none")
    out, events, head = _run_and_decode(sc, cfg)
    bundle = obstrace.save_trace(tmp_path / "t.npz", cfg, sc, out)
    cfg2, sc2, events2, head2 = obstrace.load_trace(bundle)
    assert cfg2 == cfg and head2 == head
    assert [dataclass_tuple(e) for e in events2] == \
        [dataclass_tuple(e) for e in events]
    assert set(sc2) == set(sc)

    # runner spans + device events on one Perfetto timeline
    tr = SpanTracer()
    with tr.span("chunk-compute", group=0, chunk=1):
        tr.instant("lease-renew", renewed=2)
    pf = obstrace.export_perfetto(tmp_path / "t.json", events=events,
                                  dt=cfg.dt, spans=tr.snapshot())
    doc = json.loads(pf.read_text())
    rows = doc["traceEvents"]
    dev = [r for r in rows if r.get("cat") == "device"]
    run = [r for r in rows if r.get("cat") == "runner"]
    assert len(dev) == len(events) and dev[0]["pid"] == 1
    assert {r["name"] for r in run} == {"chunk-compute", "lease-renew"}
    assert all(r["pid"] == 2 for r in run)
    x = next(r for r in run if r["name"] == "chunk-compute")
    assert x["ph"] == "X" and x["dur"] >= 0
    # sim-time instants land at tick * dt microseconds
    e0 = events[0]
    assert any(r["ts"] == pytest.approx(e0.tick * cfg.dt * 1e6)
               for r in dev)

    jl = obstrace.export_jsonl(tmp_path / "t.jsonl", events=events,
                               dt=cfg.dt, spans=tr.snapshot())
    lines = [json.loads(x) for x in jl.read_text().splitlines()]
    assert sum(x["src"] == "device" for x in lines) == len(events)
    assert sum(x["src"] == "runner" for x in lines) == 2


def dataclass_tuple(e):
    return (e.seq, e.tick, e.kind, e.subject, e.aux, e.rank,
            np.float32(e.value))


def test_runner_emits_spans(tmp_path):
    """`run_sweep` with a tracer lands claim / chunk-compute /
    chunk-write spans (checkpointed path) that export cleanly."""
    from repro import sweep as sweeplib

    def builder(seed):
        nodes = make_cluster(2, "t3.large", slots_per_node=2,
                             cpu_initial_fraction=0.3)
        return vecsim.build_scenario(nodes, _cpu_jobs(seed, n_jobs=1),
                                     submit="parallel")

    tr = SpanTracer()
    spec = sweeplib.SweepSpec(builder, axes={"seed": [0, 1]},
                              base=vecsim.VecSimConfig(n_ticks=150,
                                                       dt=5.0))
    res = sweeplib.run_sweep(
        spec, sweeplib.RunnerOptions(tracer=tr, chunk_size=1,
                                     checkpoint_dir=str(tmp_path / "ck")))
    assert bool(res.scalars()["all_done"].all())
    names = {s.name for s in tr.snapshot()}
    assert {"claim", "chunk-compute", "chunk-write"} <= names
    pf = obstrace.export_perfetto(tmp_path / "spans.json",
                                  spans=tr.snapshot())
    assert json.loads(pf.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# explainer CLI
# ---------------------------------------------------------------------------

def test_explain_cli(tmp_path, capsys):
    from repro.obs import explain

    sc = _traffic_scenario("none")
    cfg = _traffic_cfg("none")
    out, events, _ = _run_and_decode(sc, cfg)
    bundle = obstrace.save_trace(tmp_path / "t.npz", cfg, sc, out)
    tick = next(e.tick for e in events if e.kind == EV_PLACE)

    rc = explain.main([str(bundle), "--tick", str(tick)])
    got = capsys.readouterr().out
    assert rc == 0
    assert "agreement" in got and "place:" in got
    assert "placement order" in got         # pre-placement snapshot

    assert explain.main([str(bundle), "--tick",
                         str(cfg.n_ticks + 5)]) == 1


# ---------------------------------------------------------------------------
# metrics registry + poisoned-row accounting (sweep/results.py)
# ---------------------------------------------------------------------------

def test_registry_validates_engine_outputs():
    """Every engine output — closed and traffic, traced — is a declared
    metric with a matching dtype kind; unknown keys are rejected."""
    tout = vecsim.run_scenarios([_traffic_scenario("none")],
                                _traffic_cfg("none"))
    cout = vecsim.run_scenarios([_closed_scenario("none")],
                                _closed_cfg("none"))
    for out in (tout, cout):
        registry.validate_outputs(out)
        with pytest.raises(ValueError, match="undeclared"):
            registry.validate_outputs({**out, "bogus": np.zeros(1)})
    with pytest.raises(ValueError, match="kind"):
        registry.validate_outputs({"makespan": np.zeros(1, np.int32)})
    spec = registry.spec("trace_head")
    assert spec.unit == "events"
    assert "makespan" in registry.scalar_names()
    assert "trace_head" not in registry.scalar_names()


def test_poisoned_rows_warn_and_flag(tmp_path):
    """NaN-filled quarantined rows surface as a load-time warning, a
    `poisoned` flag per tidy row, and `n_poisoned` in the meta."""
    from repro import sweep as sweeplib
    from repro.sweep.results import SweepResult

    def builder(seed):
        nodes = make_cluster(2, "t3.large", slots_per_node=2,
                             cpu_initial_fraction=0.3)
        return vecsim.build_scenario(nodes, _cpu_jobs(seed, n_jobs=1),
                                     submit="parallel")

    spec = sweeplib.SweepSpec(builder, axes={"seed": [0, 1]},
                              base=vecsim.VecSimConfig(n_ticks=150,
                                                       dt=5.0))
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # clean sweep: no warning
        res = sweeplib.run_sweep(spec)
    assert res.n_poisoned == 0
    tidy = res.to_tidy()
    assert tidy["meta"]["n_poisoned"] == 0
    assert not any(r["poisoned"] for r in tidy["points"])

    g = res.groups[0]
    g.outputs["makespan"] = np.asarray(g.outputs["makespan"],
                                       float).copy()
    g.outputs["makespan"][0] = np.nan
    with pytest.warns(UserWarning, match="poisoned"):
        res2 = SweepResult(res.axes, res.groups, res.meta)
    assert res2.n_poisoned == 1
    res2.save(str(tmp_path / "sweep"))
    with pytest.warns(UserWarning, match="poisoned"):
        res3 = SweepResult.load(str(tmp_path / "sweep"))
    t3 = res3.to_tidy()
    assert t3["meta"]["n_poisoned"] == 1
    assert sum(r["poisoned"] for r in t3["points"]) == 1
