"""Token-bucket mechanics vs the provider-published numbers (paper Table 1,
SS2.1-2.2), plus hypothesis invariants."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.token_bucket import (
    INSTANCE_TYPES,
    TokenBucket,
    ebs_gp2_bucket,
    network_dual_bucket,
)


class TestTable1:
    """The AWS T3 credit table reproduced by the bucket constructors."""

    @pytest.mark.parametrize("name,vcpus,baseline,credits_hr", [
        ("t3.large", 2, 0.30, 36.0),
        ("t3.xlarge", 4, 0.40, 96.0),
        ("t3.2xlarge", 8, 0.40, 192.0),
    ])
    def test_specs(self, name, vcpus, baseline, credits_hr):
        spec = INSTANCE_TYPES[name]
        assert spec.vcpus == vcpus
        assert spec.baseline_per_vcpu == baseline
        assert spec.credits_per_hour == credits_hr

    def test_earn_rate_equals_baseline(self):
        # 1 credit = 1 vCPU-minute; earn rate == baseline service rate
        b = INSTANCE_TYPES["t3.2xlarge"].cpu_bucket()
        assert b.baseline == pytest.approx(8 * 0.40)
        assert b.burst == 8.0
        # 24h accrual cap
        assert b.capacity == pytest.approx(192.0 * 24 * 60)

    def test_one_hour_idle_accrues_one_hour_of_credits(self):
        b = INSTANCE_TYPES["t3.2xlarge"].cpu_bucket()
        b.serve(0.0, 3600.0)
        # 192 credits/hr * 60 vCPU-sec per credit
        assert b.balance == pytest.approx(192 * 60.0)


class TestEBS:
    def test_baseline_3_iops_per_gb(self):
        assert ebs_gp2_bucket(200.0).baseline == pytest.approx(600.0)
        assert ebs_gp2_bucket(10.0).baseline == pytest.approx(100.0)   # floor
        assert ebs_gp2_bucket(6000.0).baseline == pytest.approx(16000.0)  # cap

    def test_burst_3000_and_startup_credits(self):
        b = ebs_gp2_bucket(200.0)
        assert b.burst == 3000.0
        assert b.balance == pytest.approx(5.4e6)

    def test_burst_duration_formula(self):
        # Figure 2: a full 100GB volume bursts 3000 IOPS for
        # 5.4M / (3000 - 300) = 2000 s
        b = ebs_gp2_bucket(100.0)
        assert b.time_to_deplete(3000.0) == pytest.approx(2000.0)

    def test_large_volume_never_throttles(self):
        b = ebs_gp2_bucket(2000.0)  # baseline 6000 > burst floor
        assert b.max_rate() >= 6000.0
        assert b.time_to_deplete(6000.0) == math.inf


class TestServeSemantics:
    def test_throttle_to_baseline_when_empty(self):
        b = TokenBucket(baseline=3.2, burst=8.0, capacity=1000.0, balance=0.0)
        work = b.serve(8.0, 10.0)
        assert work == pytest.approx(3.2 * 10.0)

    def test_burst_until_depleted_then_throttle(self):
        b = TokenBucket(baseline=3.2, burst=8.0, capacity=1000.0, balance=48.0)
        # drain rate 4.8/s -> 10 s of burst, then baseline
        work = b.serve(8.0, 20.0)
        assert work == pytest.approx(8.0 * 10 + 3.2 * 10)
        assert b.balance == pytest.approx(0.0)

    def test_unlimited_books_surplus(self):
        b = TokenBucket(baseline=3.2, burst=8.0, capacity=1000.0, balance=0.0,
                        unlimited=True)
        work = b.serve(8.0, 10.0)
        assert work == pytest.approx(80.0)
        assert b.surplus_used == pytest.approx((8.0 - 3.2) * 10.0)

    def test_dual_bucket_network(self):
        nb = network_dual_bucket()
        assert nb.peak.burst > nb.peak.baseline

    def test_dual_bucket_charges_sustained_for_delivered_work_only(self):
        """Regression: when the peak bucket throttles, the sustained bucket
        must be charged for the work actually delivered, not for the full
        demand (which drained it for work never done)."""
        from repro.core.token_bucket import DualTokenBucket, TokenBucket
        peak = TokenBucket(baseline=1.0, burst=10.0, capacity=10.0,
                           balance=0.0)      # empty: throttles to 1.0/s
        sustained = TokenBucket(baseline=1.0, burst=10.0, capacity=1000.0,
                                balance=500.0)
        dual = DualTokenBucket(sustained=sustained, peak=peak)
        work = dual.serve(10.0, 4.0)
        # peak is empty -> delivers baseline 1.0/s for 4s
        assert work == pytest.approx(4.0)
        # sustained saw a 1.0/s delivered rate == its earn rate: no drain
        assert sustained.balance == pytest.approx(500.0)

    def test_dual_bucket_zero_dt(self):
        nb = network_dual_bucket()
        assert nb.serve(1e9, 0.0) == 0.0


@given(
    baseline=st.floats(0.5, 10.0),
    headroom=st.floats(0.0, 10.0),
    balance_frac=st.floats(0.0, 1.0),
    demand=st.floats(0.0, 30.0),
    dt=st.floats(0.1, 1000.0),
)
@settings(max_examples=200, deadline=None)
def test_bucket_invariants(baseline, headroom, balance_frac, demand, dt):
    cap = 10_000.0
    b = TokenBucket(baseline=baseline, burst=baseline + headroom,
                    capacity=cap, balance=cap * balance_frac)
    before = b.balance
    work = b.serve(demand, dt)
    # balance stays in [0, cap]
    assert 0.0 <= b.balance <= cap + 1e-6
    # served work bounded by burst and by demand
    assert work <= min(demand, b.burst) * dt + 1e-6
    # work at least baseline-limited service when demand exceeds baseline
    if demand >= baseline:
        assert work >= min(demand, baseline) * dt - 1e-6
    # credit conservation: spend = servedwork - earned, equals balance drop
    earned = baseline * dt
    spent = work
    expected = min(cap, before + earned - spent)
    if expected >= 0:
        assert b.balance == pytest.approx(expected, abs=1e-3)
