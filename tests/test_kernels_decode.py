"""Pallas decode attention vs oracle: shapes, GQA groups, partial lengths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    # (b, hq, hkv, s_max, d, block_k)
    (2, 4, 2, 512, 64, 128),
    (1, 8, 1, 256, 128, 128),        # MQA
    (4, 8, 8, 1024, 64, 256),        # MHA long cache
    (2, 6, 2, 384, 64, 128),         # group=3 (odd)
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_decode_matches_oracle(case, dtype, tol):
    b, hq, hkv, s_max, d, bk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s_max, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s_max, d), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, s_max + 1)
    want = ref.decode_attention_ref(q, k, v, lengths)
    got = ops.decode_attention(q, k, v, lengths, impl="interpret", block_k=bk)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_decode_length_one():
    b, hq, hkv, s_max, d = 2, 4, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, s_max, d))
    v = jax.random.normal(ks[2], (b, hkv, s_max, d))
    lengths = jnp.array([1, 1])
    want = ref.decode_attention_ref(q, k, v, lengths)
    got = ops.decode_attention(q, k, v, lengths, impl="interpret", block_k=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_ignores_garbage_beyond_length():
    b, hq, hkv, s_max, d = 1, 2, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, s_max, d))
    v = jax.random.normal(ks[2], (b, hkv, s_max, d))
    lengths = jnp.array([100])
    base = ops.decode_attention(q, k, v, lengths, impl="interpret", block_k=64)
    k2 = k.at[:, :, 100:].set(1e6)            # poison the unused region
    v2 = v.at[:, :, 100:].set(-1e6)
    got = ops.decode_attention(q, k2, v2, lengths, impl="interpret", block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-5)
