"""CASH-in-the-runtime: train scheduler, serve admission, straggler monitor,
elastic recovery plans."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.annotations import Annotation
from repro.sched.elastic import plan
from repro.sched.serve_scheduler import CashServeScheduler, Request, make_replicas
from repro.sched.straggler import StragglerMonitor
from repro.sched.train_scheduler import CashTrainScheduler, make_hosts


class TestTrainScheduler:
    def test_initial_assignment_covers_all_shards(self):
        hosts = make_hosts(4)
        sched = CashTrainScheduler(hosts, num_shards=16)
        got = sorted(s for h in hosts for s in h.assigned_shards)
        assert got == list(range(16))

    def test_rebalance_prefers_credit_rich_hosts(self):
        hosts = make_hosts(4, cpu_initial_fraction=0.0)
        hosts[2].node.cpu.balance = hosts[2].node.cpu.capacity    # rich host
        sched = CashTrainScheduler(hosts, num_shards=4)
        for t in range(301):      # let telemetry publish actuals
            sched.observe(float(t), {h.host_id: 0.0 for h in hosts})
        out = sched.rebalance(301.0)
        # the rich host gets packed first (4 slots)
        assert len(out[2]) == 4

    def test_rebalance_covers_all_shards_always(self):
        hosts = make_hosts(3, slots=2)
        sched = CashTrainScheduler(hosts, num_shards=10)   # > total slots
        out = sched.rebalance(0.0)
        got = sorted(s for ss in out.values() for s in ss)
        assert got == list(range(10))

    def test_microbatch_weights_penalize_throttled(self):
        hosts = make_hosts(2, cpu_initial_fraction=0.0)
        hosts[1].node.cpu.balance = hosts[1].node.cpu.capacity
        sched = CashTrainScheduler(hosts, num_shards=2)
        for t in range(301):
            sched.observe(float(t), {0: 8.0, 1: 0.0})
        w = sched.microbatch_weights(301.0)
        assert w[1] > w[0]          # throttled host gets less work

    def test_split_rows_sums_exactly(self):
        hosts = make_hosts(3, cpu_initial_fraction=0.0)
        hosts[0].node.cpu.balance = hosts[0].node.cpu.capacity
        sched = CashTrainScheduler(hosts, num_shards=3)
        for t in range(301):
            sched.observe(float(t), {h.host_id: 0.0 for h in hosts})
        split = sched.split_rows(17, 301.0)
        assert sum(split.values()) == 17
        assert all(v >= 0 for v in split.values())


class TestServeScheduler:
    def test_prefill_to_rich_decode_to_poor(self):
        reps = make_replicas(2, cpu_initial_fraction=0.0)
        reps[1].node.cpu.balance = reps[1].node.cpu.capacity
        cash = CashServeScheduler(reps)
        for t in range(301):
            cash.observe(float(t), {0: 0.0, 1: 0.0})
        pf, dc = cash.admit(301.0, [Request(0, 512, 32)], decode_batches=1)
        assert len(pf[1]) == 1       # prefill -> credit-rich replica
        assert dc[0] == 1            # decode -> credit-poor replica

    def test_all_requests_routed(self):
        reps = make_replicas(3, slots=2)
        cash = CashServeScheduler(reps)
        reqs = [Request(i, 128, 8) for i in range(5)]
        pf, dc = cash.admit(0.0, reqs, decode_batches=1)
        assert sum(len(v) for v in pf.values()) + sum(dc.values()) == 6


class TestStraggler:
    def test_reactive_flags_slow_host(self):
        mon = StragglerMonitor(4)
        for h in range(4):
            for _ in range(5):
                mon.record_step(h, 1.0 if h != 2 else 3.0)
        assert mon.reactive_stragglers() == [2]

    def test_predictive_flags_depleting_bucket(self):
        from repro.core.token_bucket import TokenBucket
        mon = StragglerMonitor(2, horizon_s=100.0)
        rich = TokenBucket(baseline=1.0, burst=2.0, capacity=1e5, balance=1e5)
        poor = TokenBucket(baseline=1.0, burst=2.0, capacity=1e5, balance=10.0)
        flags = mon.predictive_stragglers({0: rich, 1: poor},
                                          {0: 2.0, 1: 2.0})
        assert flags == [1]          # depletes in 10 s < horizon


class TestElasticPlan:
    def test_plan_shrinks_cleanly(self):
        p8 = plan(8, devices_per_host=4, num_shards=32, model_parallel=4)
        assert p8.mesh_shape == (8, 4)
        p5 = plan(5, devices_per_host=4, num_shards=32, model_parallel=4)
        assert p5.mesh_shape == (5, 4)
        # every shard still owned exactly once
        got = sorted(s for ss in p5.shard_map.values() for s in ss)
        assert got == list(range(32))

    def test_plan_rejects_impossible(self):
        with pytest.raises(ValueError):
            plan(0, 4, 8)
        with pytest.raises(ValueError):
            plan(3, 1, 8, model_parallel=2)
