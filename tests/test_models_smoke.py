"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode-path consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import (
    decode_step,
    encode,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
)
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.step import make_train_step

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq_len, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend.num_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg)
    logits, aux = forward(cfg, params, batch, impl="xla")
    assert logits.shape == (2, 32, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nan(arch):
    cfg = reduced_config(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = make_optimizer(OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, impl="xla", remat=False))
    params2, opt_state2, metrics = step(params, opt_state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_steps_finite_and_lengths_advance(arch):
    cfg = reduced_config(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    enc_out = None
    if cfg.family == "encdec":
        frames = _batch(cfg)["frames"]
        enc_out = encode(cfg, params, frames, impl="xla")
    cache = init_decode_cache(cfg, 2, 64, jnp.float32, enc_out=enc_out)
    toks = jnp.array([1, 2], jnp.int32)
    for i in range(3):
        logits, cache = decode_step(cfg, params, cache, toks, impl="xla")
        assert logits.shape == (2, cfg.padded_vocab_size)
        assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert int(cache["lengths"][0]) == 3


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-130m", "yi-34b"])
def test_prefill_decode_consistency(arch):
    """Greedy continuation from a prompt: token-by-token decode must match
    the forward pass's next-token prediction at the prompt end.

    (MoE archs are excluded: capacity-based token dropping makes prefill and
    decode routing legitimately differ — inherent to dropping MoE.)"""
    cfg = reduced_config(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    prompt = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    logits_fwd, _ = forward(cfg, params, {"tokens": prompt}, impl="xla")
    want_next = int(jnp.argmax(logits_fwd[0, -1, :cfg.vocab_size]))
    cache = init_decode_cache(cfg, 1, 32, jnp.float32)
    logits = None
    for t in range(8):
        logits, cache = decode_step(cfg, params, cache, prompt[:, t], impl="xla")
    got_next = int(jnp.argmax(logits[0, :cfg.vocab_size]))
    assert got_next == want_next


def test_padded_vocab_region_masked():
    cfg = reduced_config(ARCHS["granite-3-2b"])  # vocab 257 -> padded 384
    assert cfg.padded_vocab_size > cfg.vocab_size
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    logits, _ = forward(cfg, params, _batch(cfg), impl="xla")
    pad = logits[..., cfg.vocab_size:]
    assert bool(jnp.all(pad <= -1e29))
