"""Algorithm 1 semantics + hypothesis invariants."""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.annotations import Annotation, Task, annotate_task
from repro.core.cluster import make_cluster
from repro.core.scheduler import CashScheduler, JointCashScheduler, StockScheduler


def mk_task(tid, annotation=Annotation.NONE, deps=()):
    return Task(tid=tid, job="j", vertex="v", work_cpu=10.0,
                annotation=annotation, depends_on=deps)


def fresh_nodes(n=4, slots=2):
    return make_cluster(n, "t3.2xlarge", slots_per_node=slots)


class TestPhase1:
    def test_burst_tasks_go_to_highest_credit_node_first(self):
        nodes = fresh_nodes(3, slots=2)
        credits = {0: 10.0, 1: 100.0, 2: 50.0}
        q = [mk_task(i, Annotation.BURST_CPU) for i in range(2)]
        CashScheduler().schedule(q, nodes, credits, 0.0)
        assert len(nodes[1].running) == 2       # packed on the richest
        assert not q

    def test_packing_spills_to_next_richest(self):
        nodes = fresh_nodes(3, slots=2)
        credits = {0: 10.0, 1: 100.0, 2: 50.0}
        q = [mk_task(i, Annotation.BURST_CPU) for i in range(3)]
        CashScheduler().schedule(q, nodes, credits, 0.0)
        assert len(nodes[1].running) == 2
        assert len(nodes[2].running) == 1
        assert len(nodes[0].running) == 0


class TestPhase2:
    def test_network_tasks_ascend_and_round_robin(self):
        nodes = fresh_nodes(3, slots=3)
        credits = {0: 10.0, 1: 100.0, 2: 50.0}
        q = [mk_task(i, Annotation.NETWORK) for i in range(4)]
        CashScheduler().schedule(q, nodes, credits, 0.0)
        # one per node per round ascending (0, 2, 1), second round -> node 0
        assert len(nodes[0].running) == 2
        assert len(nodes[2].running) == 1
        assert len(nodes[1].running) == 1

    def test_burst_before_network(self):
        nodes = fresh_nodes(2, slots=1)
        credits = {0: 10.0, 1: 100.0}
        burst = mk_task(0, Annotation.BURST_CPU)
        net = mk_task(1, Annotation.NETWORK)
        q = [net, burst]   # queue order must not matter for phase priority
        CashScheduler().schedule(q, nodes, credits, 0.0)
        assert burst in nodes[1].running        # burst -> richest
        assert net in nodes[0].running          # network -> poorest


class TestDependencies:
    def test_blocked_tasks_stay_queued(self):
        nodes = fresh_nodes(2, slots=2)
        q = [mk_task(1), mk_task(2, deps=(1,))]
        CashScheduler().schedule(q, nodes, {0: 0.0, 1: 0.0}, 0.0,
                                 ready_ids=set())
        assert len(q) == 1 and q[0].tid == 2

    def test_ready_set_releases(self):
        nodes = fresh_nodes(2, slots=2)
        t2 = mk_task(2, deps=(1,))
        q = [t2]
        CashScheduler().schedule(q, nodes, {0: 0.0, 1: 0.0}, 0.0,
                                 ready_ids={2})
        assert not q


class TestJoint:
    def test_joint_min_normalized(self):
        nodes = fresh_nodes(2, slots=1)
        # node 0: rich cpu, poor disk; node 1: balanced
        ccpu = {0: nodes[0].cpu.capacity, 1: nodes[1].cpu.capacity * 0.5}
        cdisk = {0: 0.0, 1: nodes[1].disk.capacity * 0.5}
        t = mk_task(0, Annotation.BURST_CPU)
        JointCashScheduler().schedule([t], nodes, {}, 0.0,
                                      credits_cpu=ccpu, credits_disk=cdisk)
        assert t in nodes[1].running


@given(
    n_nodes=st.integers(1, 6),
    slots=st.integers(1, 4),
    n_burst=st.integers(0, 12),
    n_net=st.integers(0, 12),
    n_plain=st.integers(0, 12),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=150, deadline=None)
def test_scheduler_invariants(n_nodes, slots, n_burst, n_net, n_plain, seed):
    rng = random.Random(seed)
    nodes = make_cluster(n_nodes, "t3.2xlarge", slots_per_node=slots)
    credits = {n.nid: rng.uniform(0, 1000) for n in nodes}
    tid = [0]

    def nt(ann):
        tid[0] += 1
        return mk_task(tid[0], ann)

    q = ([nt(Annotation.BURST_CPU) for _ in range(n_burst)]
         + [nt(Annotation.NETWORK) for _ in range(n_net)]
         + [nt(Annotation.NONE) for _ in range(n_plain)])
    rng.shuffle(q)
    total = len(q)
    sched = CashScheduler(random.Random(seed))
    assigned = sched.schedule(q, nodes, credits, 0.0)

    # no node over capacity
    for n in nodes:
        assert len(n.running) <= slots
    # work conserved: every task is either running or still queued
    assert len(assigned) + len(q) == total
    # all slots used if tasks were plentiful
    if total >= n_nodes * slots:
        assert all(n.free_slots == 0 for n in nodes)
    # a queued burst task may only remain if no free slot anywhere
    if any(t.burst_intensive for t in q):
        assert all(n.free_slots == 0 for n in nodes)
