"""Pallas flash attention vs jnp oracle: shape/dtype sweep in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # (b, hq, hkv, sq, skv, d)
    (1, 2, 2, 128, 128, 64),          # MHA
    (2, 4, 2, 256, 256, 64),          # GQA 2x
    (1, 8, 1, 128, 128, 128),         # MQA
    (1, 4, 4, 128, 384, 64),          # cross/history: skv > sq
    (2, 2, 2, 384, 384, 32),          # non-pow2 blocks (384 = 3x128)
]


def _mk(b, hq, hkv, sq, skv, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_pallas_matches_oracle(shape, dtype, tol, causal):
    q, k, v = _mk(*shape, dtype)
    want = ref.attention_ref(q, k, v, causal=causal)
    got = ops.attention(q, k, v, causal=causal, impl="interpret")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_xla_matches_oracle(shape, causal):
    q, k, v = _mk(*shape, jnp.float32)
    want = ref.attention_ref(q, k, v, causal=causal)
    got = ref.flash_attention_xla(q, k, v, causal=causal, block_k=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_xla_non_divisible_block():
    q, k, v = _mk(1, 2, 2, 100, 100, 32, jnp.float32)
    want = ref.attention_ref(q, k, v, causal=False)
    got = ref.flash_attention_xla(q, k, v, causal=False, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_xla_grad_matches_oracle():
    q, k, v = _mk(1, 2, 2, 128, 128, 32, jnp.float32)

    def f_ref(q):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    def f_flash(q):
        return jnp.sum(ref.flash_attention_xla(q, k, v, causal=True,
                                               block_k=64) ** 2)

    g1 = jax.grad(f_ref)(q)
    g2 = jax.grad(f_flash)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)


def test_flash_scale_parameter():
    q, k, v = _mk(1, 2, 2, 128, 128, 64, jnp.float32)
    want = ref.attention_ref(q, k, v, causal=True, scale=0.3)
    got = ops.attention(q, k, v, causal=True, scale=0.3, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
