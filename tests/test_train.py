"""Optimizer math, data determinism/resume, checkpoint/restart, trainer
fault tolerance (failure injection -> restore -> identical continuation)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.train import checkpoint as CKPT
from repro.train.data import DataConfig, ShardedLoader, global_batch, synth_batch
from repro.train.optimizer import (
    OptimizerConfig,
    adamw,
    adafactor,
    clip_by_global_norm,
    make_optimizer,
    schedule,
    sgd,
)
from repro.train.trainer import TrainConfig, Trainer


class TestOptimizer:
    def test_adamw_matches_reference_math(self):
        cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=10**9,
                              b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                              grad_clip=1e9, min_lr_ratio=1.0)
        opt = adamw(cfg)
        p = {"w": jnp.array([1.0, -2.0])}
        g = {"w": jnp.array([0.1, 0.2])}
        state = opt.init(p)
        new_p, state, _ = opt.update(p, g, state)
        # hand-computed adam step 1: m=0.1g*... mu=(1-b1)g, nu=(1-b2)g^2,
        # mhat=g, vhat=g^2 -> step = lr * g/(|g|+eps) = lr * sign(g)
        want = p["w"] - 1e-2 * np.sign(np.array([0.1, 0.2]))
        np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-5)

    def test_no_decay_on_norm_params(self):
        cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, weight_decay=0.5,
                              grad_clip=1e9, min_lr_ratio=1.0)
        opt = adamw(cfg)
        p = {"scale": jnp.ones((4,)), "w1": jnp.ones((4,))}
        g = {"scale": jnp.zeros((4,)), "w1": jnp.zeros((4,))}
        state = opt.init(p)
        new_p, *_ = opt.update(p, g, state)
        np.testing.assert_allclose(np.asarray(new_p["scale"]), 1.0)   # no wd
        assert float(new_p["w1"][0]) < 1.0                            # wd applied

    def test_schedule_warmup_cosine(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                              min_lr_ratio=0.1)
        assert float(schedule(cfg, jnp.float32(0))) == 0.0
        assert float(schedule(cfg, jnp.float32(10))) == pytest.approx(1.0)
        assert float(schedule(cfg, jnp.float32(110))) == pytest.approx(0.1)

    def test_grad_clip(self):
        g = {"w": jnp.array([3.0, 4.0])}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(5.0)
        n2 = float(jnp.sqrt(jnp.sum(clipped["w"] ** 2)))
        assert n2 == pytest.approx(1.0, rel=1e-5)

    @pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
    def test_all_optimizers_descend_quadratic(self, name):
        cfg = OptimizerConfig(name=name, lr=0.1, warmup_steps=0,
                              total_steps=10**9, weight_decay=0.0,
                              min_lr_ratio=1.0)
        opt = make_optimizer(cfg)
        p = {"w": jnp.array([5.0])}
        state = opt.init(p)
        loss0 = float(p["w"][0] ** 2)
        for _ in range(50):
            g = {"w": 2 * p["w"]}
            p, state, _ = opt.update(p, g, state)
        assert float(p["w"][0] ** 2) < loss0 * 0.05

    def test_adafactor_memory_factored(self):
        opt = adafactor(OptimizerConfig(name="adafactor"))
        p = {"w": jnp.zeros((64, 32))}
        st = opt.init(p)
        assert st["v"]["w"]["vr"].shape == (64,)
        assert st["v"]["w"]["vc"].shape == (32,)


class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, num_shards=2)
        a = global_batch(cfg, 7)
        b = global_batch(cfg, 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
        assert not np.array_equal(global_batch(cfg, 0)["tokens"],
                                  global_batch(cfg, 1)["tokens"])

    def test_shards_partition_batch(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, num_shards=4)
        full = global_batch(cfg, 3)
        parts = [synth_batch(cfg, s, 3) for s in range(4)]
        np.testing.assert_array_equal(
            full["tokens"], np.concatenate([p["tokens"] for p in parts]))

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        b = global_batch(cfg, 0)
        # same underlying stream: labels[t] == tokens[t+1]
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_loader_resume_exact(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4, num_shards=2)
        l1 = ShardedLoader(cfg, [0], start_step=0)
        batches = [next(l1) for _ in range(4)]
        l1.close()
        l2 = ShardedLoader(cfg, [0], start_step=2)
        resumed = next(l2)
        l2.close()
        np.testing.assert_array_equal(resumed["tokens"], batches[2]["tokens"])


class TestCheckpoint:
    def test_save_restore_bitwise(self, tmp_path):
        state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
                 "opt": {"mu": {"w": jnp.ones((2, 3), jnp.bfloat16)},
                         "step": jnp.int32(7)}}
        CKPT.save(str(tmp_path), 7, state, extra={"data_step": 7})
        restored, step, extra = CKPT.restore(str(tmp_path), state)
        assert step == 7 and extra["data_step"] == 7
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        assert restored["opt"]["mu"]["w"].dtype == np.dtype(jnp.bfloat16)

    def test_latest_wins_and_gc(self, tmp_path):
        state = {"w": jnp.zeros((2,))}
        ck = CKPT.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3):
            ck.save(s, {"w": jnp.full((2,), float(s))})
        ck.wait()
        assert CKPT.list_steps(str(tmp_path)) == [2, 3]
        restored, step, _ = CKPT.restore(str(tmp_path), state)
        assert step == 3 and float(restored["w"][0]) == 3.0


class TestTrainerFaultTolerance:
    def _mk(self, tmp_path, fail_at=None):
        cfg = reduced_config(ARCHS["granite-3-2b"])
        data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                              global_batch=4)
        return Trainer(cfg, data_cfg,
                       train_cfg=TrainConfig(steps=6, log_every=100,
                                             ckpt_every=2,
                                             ckpt_dir=str(tmp_path),
                                             fail_at_step=fail_at))

    def test_loss_decreases(self, tmp_path):
        t = self._mk(tmp_path)
        hist = t.run()
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_crash_restore_identical_continuation(self, tmp_path):
        # uninterrupted reference run
        ref = self._mk(tmp_path / "ref")
        ref_hist = ref.run()

        # crashed run
        t1 = self._mk(tmp_path / "crash", fail_at=4)
        with pytest.raises(RuntimeError, match="injected failure"):
            t1.run()
        if t1._ckpt:
            t1._ckpt.wait()
        # restart from checkpoint, continue to the end
        t2 = self._mk(tmp_path / "crash")
        assert t2.maybe_restore()
        assert t2.step == 4
        hist2 = t2.run(steps=2)
        # the recovered trajectory matches the uninterrupted one
        ref_tail = [h["loss"] for h in ref_hist if h["step"] >= 4]
        got_tail = [h["loss"] for h in hist2]
        np.testing.assert_allclose(got_tail, ref_tail, rtol=1e-5)
