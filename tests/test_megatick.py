"""Whole-tick megakernel + k-unrolled scan + pipelined runner (ISSUE 7).

Everything here is a BITWISE claim under float64: the fused tick
(`ops.megatick`) against the unfused packed-cumsum tick, the Pallas
interpret path against the XLA reference, k ticks unrolled per scan step
against k=1 (including non-divisible tick counts and sample-period
alignment), and the double-buffered sweep runner against the synchronous
one. No tolerances — these are the same math re-scheduled, and any drift
is a bug (the one historical offender, FMA contraction in the timeline
std, is kept out of the scan body for exactly this reason — see
`vecsim._moments`).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sweep
from repro.core import vecsim
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.simulator import Job
from repro.kernels import ops
from repro.traffic import arrivals


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


# ---------------------------------------------------------------------------
# scenario builders
# ---------------------------------------------------------------------------

def _cluster(n_nodes: int = 4):
    return make_cluster(n_nodes, "t3.large", cpu_initial_fraction=0.3)


def _one_class_jobs(seed: int, n_nodes: int,
                    ann: Annotation = Annotation.BURST_CPU):
    """Single-class CPU jobs — the fused tick's eligibility envelope
    (exactly one placement phase)."""
    rng = np.random.RandomState(seed)
    tid = [10_000 * (seed + 1)]
    jobs = []
    for j in range(2):
        tasks = []
        for _ in range(n_nodes * 3):
            tid[0] += 1
            tasks.append(Task(
                tid=tid[0], job=f"j{j}", vertex="map",
                work_cpu=float(rng.uniform(30, 90)),
                demand_cpu=float(rng.uniform(0.3, 0.95)),
                annotation=ann))
        jobs.append(Job(name=f"j{j}", tasks=tasks))
    return jobs


def _closed_scens(ann=Annotation.BURST_CPU, n_scen: int = 3):
    return [vecsim.build_scenario(_cluster(), _one_class_jobs(s, 4, ann))
            for s in range(n_scen)]


def _traffic_scens(burst_fraction: float, n_scen: int = 2):
    tmpl = arrivals.make_template(6, seed=3, burst_fraction=burst_fraction)
    return [arrivals.build_traffic_scenario(
        make_cluster(3, "t3.large", slots_per_node=4,
                     cpu_initial_fraction=0.5),
        tmpl, mode="poisson", rate=0.05, rng_seed=s)
        for s in range(n_scen)]


def _assert_bitwise(a, b, path: str = ""):
    """Recursive exact equality over the (possibly nested) output dicts."""
    assert set(a) == set(b), f"{path}: keys {set(a) ^ set(b)}"
    for k in a:
        va, vb = a[k], b[k]
        if isinstance(va, dict):
            _assert_bitwise(va, vb, f"{path}{k}.")
        else:
            np.testing.assert_array_equal(
                np.asarray(va), np.asarray(vb), err_msg=f"{path}{k}")


def _assert_close(a, b, path: str = ""):
    """Like `_assert_bitwise` but float leaves get a 1-ULP-scale
    tolerance: the Pallas path lane-pads the task axis, which re-blocks
    the demand dot-reduction — same terms, different association. Integer
    outputs (placement, counts, histograms) must still match exactly."""
    if isinstance(a, dict):
        assert set(a) == set(b), f"{path}: keys {set(a) ^ set(b)}"
        for k in a:
            _assert_close(a[k], b[k], f"{path}{k}.")
        return
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind in "fc":
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12,
                                   err_msg=path)
    else:
        np.testing.assert_array_equal(a, b, err_msg=path)


# ---------------------------------------------------------------------------
# op level: pallas interpret vs XLA reference
# ---------------------------------------------------------------------------

def _op_inputs(seed: int, t: int, n: int, carried_rank: bool):
    rng = np.random.RandomState(seed)
    m_pend = rng.uniform(size=t) < 0.5
    if carried_rank:
        # valid carried-FIFO state: pending slots hold contiguous ranks
        rank = (np.cumsum(m_pend) - 1).astype(np.int32)
        rank[~m_pend] = 0
        n_pend = np.int32(m_pend.sum())
    else:
        rank = np.zeros(t, np.int32)
        n_pend = np.int32(0)
    node_prev = np.where(m_pend, -1,
                         rng.randint(0, n, t)).astype(np.int32)
    alive = rng.uniform(size=t) < 0.9
    dem_task = rng.uniform(0.1, 0.95, t)
    live = rng.uniform(size=t) < 0.8
    balance = rng.uniform(0.0, 200.0, n)
    baseline = np.full(n, 0.4)
    burst = np.full(n, 8.0)
    capacity = np.full(n, 576.0)
    unlimited = (rng.uniform(size=n) < 0.3).astype(np.float64)
    free = rng.randint(0, 4, n).astype(np.int32)
    tel = vecsim._fresh_telemetry(n, jnp.float64)
    return (m_pend, rank, n_pend, node_prev, alive, dem_task, live,
            balance, baseline, burst, capacity, unlimited, free, tel,
            jnp.asarray(37.0, jnp.float64))


@pytest.mark.parametrize("carried_rank", [False, True])
@pytest.mark.parametrize("tel_mode", ["predicted", "oracle"])
def test_megatick_interpret_matches_ref(carried_rank, tel_mode):
    """ops.megatick: the Pallas kernel (interpret mode on CPU) must agree
    with the XLA reference — placement/count integers exactly, float
    outputs to 1-ULP scale (the kernel lane-pads the task axis, which
    re-blocks the demand reduction), ragged shapes included."""
    args = _op_inputs(0, 150, 7, carried_rank)   # ragged vs the 128 lanes
    kw = dict(dt=1.0, actual_period=60.0, usage_period=300.0,
              tel_mode=tel_mode, by_credit=True, carried_rank=carried_rank)
    out_x = ops.megatick(*args, impl="xla", **kw)
    out_i = ops.megatick(*args, impl="interpret", **kw)
    for i, (a, b) in enumerate(zip(out_x, out_i)):
        if a is None or b is None:
            assert a is None and b is None      # new_tel in oracle mode
        else:
            _assert_close(a, b, f"out[{i}]")


# ---------------------------------------------------------------------------
# engine level: fused tick == unfused tick, closed and open loop
# ---------------------------------------------------------------------------

def _run_closed(scens, fusion, *, scheduler="cash", telemetry="predicted",
                impl="xla", n_ticks=500, unroll=1, sample_period=25.0):
    cfg = vecsim.VecSimConfig(
        n_ticks=n_ticks, scheduler=scheduler, telemetry=telemetry,
        impl=impl, fusion=fusion, unroll=unroll, sample_period=sample_period)
    return vecsim.run_scenarios(scens, cfg)


@pytest.mark.parametrize("scheduler,telemetry,ann", [
    ("cash", "predicted", Annotation.BURST_CPU),
    ("cash", "stale", Annotation.BURST_CPU),
    ("cash", "oracle", Annotation.BURST_CPU),
    ("cash", "predicted", Annotation.NONE),
    ("stock", "predicted", Annotation.BURST_CPU),
])
def test_closed_fused_matches_unfused(scheduler, telemetry, ann):
    """The whole-tick megakernel must reproduce the unfused tick bitwise
    on the closed-loop path — every scalar, per-task times, and the
    sampled timeline (credit moments included)."""
    scens = _closed_scens(ann)
    unf = _run_closed(scens, "unfused", scheduler=scheduler,
                      telemetry=telemetry)
    fus = _run_closed(scens, "fused", scheduler=scheduler,
                      telemetry=telemetry)
    assert bool(np.asarray(unf["all_done"]).all())
    _assert_bitwise(unf, fus)


def test_closed_fused_interpret_matches_xla():
    """The fused engine with the Pallas kernel in interpret mode == the
    fused engine on the XLA reference (scan-context kernel parity; float
    outputs to 1-ULP scale — see `_assert_close`)."""
    scens = _closed_scens(n_scen=1)
    x = _run_closed(scens, "fused", n_ticks=200, sample_period=0.0)
    i = _run_closed(scens, "fused", impl="interpret", n_ticks=200,
                    sample_period=0.0)
    _assert_close(x, i)


@pytest.mark.parametrize("scheduler,telemetry,burst_fraction", [
    ("cash", "predicted", 1.0),
    ("cash", "stale", 1.0),
    ("cash", "predicted", 0.0),
    ("stock", "predicted", 1.0),
])
def test_traffic_fused_matches_unfused(scheduler, telemetry, burst_fraction):
    """Open-loop ring-buffer path: the fused tick consumes the CARRIED
    FIFO ranks and must reproduce the unfused tick bitwise — streaming
    SLO histogram carries (and so every percentile) included."""
    scens = _traffic_scens(burst_fraction)
    outs = {}
    for fusion in ("unfused", "fused"):
        cfg = vecsim.VecSimConfig(
            n_ticks=400, dt=5.0, scheduler=scheduler, telemetry=telemetry,
            traffic="poisson", table_slots=20, slo_bins=32, fusion=fusion)
        outs[fusion] = vecsim.run_scenarios(scens, cfg)
    assert int(np.asarray(outs["unfused"]["n_completed"]).sum()) > 0
    _assert_bitwise(outs["unfused"], outs["fused"])


def test_fusion_auto_is_platform_aware(monkeypatch):
    """``fusion="auto"`` resolves per backend: the megakernel loses to
    the packed-cumsum tick on CPU (BENCH tick_phases), so auto fuses on
    TPU only — forced modes ignore the platform entirely."""
    cfg = vecsim.VecSimConfig(n_ticks=10, scheduler="cash", fusion="auto")
    one_phase = (False, False, True, False, False)
    assert vecsim.fusion_eligible(cfg, one_phase)
    assert vecsim.fusion_choice(cfg, one_phase, platform="cpu") == "unfused"
    assert vecsim.fusion_choice(cfg, one_phase, platform="tpu") == "fused"
    # ineligible statics stay unfused even where fusion would win
    two_phase = (False, False, True, False, True)
    assert vecsim.fusion_choice(cfg, two_phase, platform="tpu") == "unfused"
    # platform=None consults the live backend
    monkeypatch.setattr(vecsim.jax, "default_backend", lambda: "tpu")
    assert vecsim.fusion_choice(cfg, one_phase) == "fused"
    monkeypatch.setattr(vecsim.jax, "default_backend", lambda: "cpu")
    assert vecsim.fusion_choice(cfg, one_phase) == "unfused"
    # forced modes never consult it
    forced = dataclasses.replace(cfg, fusion="unfused")
    assert vecsim.fusion_choice(forced, one_phase, platform="tpu") == \
        "unfused"


def test_fused_on_ineligible_config_raises():
    """``fusion="fused"`` on a two-phase workload (burst + plain classes)
    must raise instead of silently running a diverging tick."""
    rng = np.random.RandomState(0)
    tasks = [Task(tid=100 + k, job="j0", vertex="map",
                  work_cpu=float(rng.uniform(30, 90)),
                  demand_cpu=0.5,
                  annotation=Annotation.BURST_CPU if k % 2
                  else Annotation.NONE)
             for k in range(8)]
    sc = vecsim.build_scenario(_cluster(), [Job(name="j0", tasks=tasks)])
    cfg = vecsim.VecSimConfig(n_ticks=100, scheduler="cash", fusion="fused")
    with pytest.raises(ValueError, match="fusion"):
        vecsim.run_scenarios([sc], cfg)


# ---------------------------------------------------------------------------
# k-unrolled scan: bitwise parity with k=1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 4])
def test_unroll_closed_bitwise_parity(k):
    """k tick bodies per scan step == k=1, bitwise, at a tick count that
    divides by neither k (405 = 4*101 + 1 — lax.scan's remainder steps)
    and a sample period whose ticks don't align with the unroll factor
    (every 7th tick)."""
    scens = _closed_scens()
    base = _run_closed(scens, "auto", n_ticks=405, sample_period=7.0)
    unrolled = _run_closed(scens, "auto", n_ticks=405, sample_period=7.0,
                           unroll=k)
    # one scenario intentionally overruns the horizon: parity must hold
    # for truncated scans too (the remainder steps still execute)
    assert np.asarray(base["all_done"]).any()
    _assert_bitwise(base, unrolled)


def test_unroll_fused_bitwise_parity():
    """unroll composes with the fused tick: fused k=4 == fused k=1."""
    scens = _closed_scens(n_scen=2)
    base = _run_closed(scens, "fused", n_ticks=403, sample_period=7.0)
    unrolled = _run_closed(scens, "fused", n_ticks=403, sample_period=7.0,
                           unroll=4)
    _assert_bitwise(base, unrolled)


@pytest.mark.parametrize("k", [2, 4])
def test_unroll_traffic_bitwise_parity(k):
    """Open-loop path under unroll: the streaming histogram/latency
    carries accumulate across unrolled tick bodies exactly as at k=1
    (203 ticks: non-divisible; samples every 7th tick)."""
    scens = _traffic_scens(0.7)
    outs = []
    for u in (1, k):
        cfg = vecsim.VecSimConfig(
            n_ticks=203, dt=5.0, scheduler="cash", traffic="poisson",
            table_slots=20, slo_bins=16, sample_period=35.0, unroll=u)
        outs.append(vecsim.run_scenarios(scens, cfg))
    assert int(np.asarray(outs[0]["n_completed"]).sum()) > 0
    _assert_bitwise(outs[0], outs[1])


# ---------------------------------------------------------------------------
# pipelined (double-buffered) sweep runner == synchronous runner
# ---------------------------------------------------------------------------

def test_pipelined_runner_matches_sync():
    """`RunnerOptions.pipeline` moves finalize/save to a writer thread and
    overlaps it with the next chunk's dispatch; results — scalars, group
    outputs, timelines — must equal the synchronous path bitwise."""
    spec = sweep.SweepSpec(
        lambda seed: vecsim.build_scenario(_cluster(3),
                                           _one_class_jobs(seed, 3)),
        axes={"scheduler": ["cash", "stock"], "seed": [1, 2, 3, 4, 5]},
        base=vecsim.VecSimConfig(n_ticks=400, sample_period=50.0),
    )
    piped = sweep.run_sweep(spec, sweep.RunnerOptions(pipeline=True),
                            shards=1, chunk_size=2)
    synced = sweep.run_sweep(spec, sweep.RunnerOptions(pipeline=False),
                             shards=1, chunk_size=2)
    assert piped.meta["pipeline"] and not synced.meta["pipeline"]
    for k, v in piped.scalars().items():
        np.testing.assert_array_equal(v, synced.scalars()[k], err_msg=k)
    for g_p, g_s in zip(piped.groups, synced.groups):
        _assert_bitwise(g_p.outputs, g_s.outputs)
