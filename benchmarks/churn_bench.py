"""Preemption-churn benchmark: CASH vs credit-blind placement on
IDENTICAL fault streams.

A preemptible (spot-style) fleet under open-loop Poisson load: every
node runs a two-state Markov on/off chain (`repro.faults`), and the same
``(seed, rng_seed, fl_*)``-keyed kill sequence hits both schedulers —
the scheduler axis changes only the static config, never the fault
stream, so any goodput/wasted-work gap is pure placement policy (the
benchmark asserts the kill counts match per seed).

CASH runs with credit-aware blacklisting ON: nodes whose *estimated*
bucket depletes within ``blacklist_horizon_s`` at current demand, and
nodes inside the ``preempt_notice_s`` warning window (the spot
two-minute notice), take no new placements. Stock is credit- and
notice-blind. The headline metric is the **wasted-work ratio**: CASH's
lost-work fraction over stock's — under churn, dodging
predicted-to-throttle and soon-to-preempt nodes must not waste MORE
work than credit-blind placement (fast-mode acceptance: ratio <= 1.0).

Emits per-scheduler goodput, lost work, re-executions, sheds, and SLO
tails under churn; lands in ``BENCH_vecsim.json`` under the ``"churn"``
section (benchmarks/run.py).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro import sweep as sweeplib
from repro.core import vecsim
from repro.core.cluster import make_cluster
from repro.faults import attach_fault_process
from repro.traffic import arrivals

SLOTS = 4


def run(fast: bool = False) -> dict:
    n_nodes, n_seeds, n_ticks = (6, 4, 800) if fast else (16, 8, 4_000)
    dt = 5.0
    # short tasks (the cluster-trace norm): the preemption notice window
    # then covers a meaningful fraction of a job's lifetime, which is
    # where notice-aware placement can actually dodge lost work
    tmpl = arrivals.make_template(8, seed=1, work=(30.0, 90.0),
                                  burst_fraction=0.75)
    rate = n_nodes * SLOTS / 300.0    # busy fleet, bounded backlog

    def builder(rng_seed):
        fleet = make_cluster(n_nodes, "t3.large", slots_per_node=SLOTS,
                             cpu_initial_fraction=0.3)
        sc = arrivals.build_traffic_scenario(fleet, tmpl, mode="poisson",
                                             rate=rate, rng_seed=rng_seed)
        # ~1 kill per node per 2000 simulated seconds, minute-scale
        # outages: enough churn that lost work is a first-order effect
        return attach_fault_process(sc, mode="spot", dt=dt,
                                    kill_rate=1 / 2000.0,
                                    restore_rate=1 / 400.0)

    spec = sweeplib.SweepSpec(
        builder,
        axes={"scheduler": ("cash", "stock"),
              "rng_seed": list(range(n_seeds))},
        base=vecsim.VecSimConfig(
            n_ticks=n_ticks, dt=dt, traffic="poisson", faults="spot",
            max_retries=3, blacklist_horizon_s=120.0,
            preempt_notice_s=120.0, table_slots=2 * n_nodes * SLOTS,
            slo_bins=32),
    )
    res = sweeplib.run_sweep(spec, shards=1)
    cols = res.scalars()
    sched = np.array([p.coord_dict["scheduler"] for p in res.points])
    seeds = np.array([p.coord_dict["rng_seed"] for p in res.points])

    # identical-stream sanity: the kill sequence must not depend on the
    # scheduler axis (fault streams key off seed + rng_seed + fl_* only)
    for s in range(n_seeds):
        kills = cols["n_kill_events"][seeds == s]
        assert len(set(kills.astype(int))) == 1, (
            f"fault stream differs across schedulers for rng_seed={s}: "
            f"{kills}")

    stats = {}
    for s in ("cash", "stock"):
        m = sched == s
        goodput = float(cols["goodput"][m].sum())
        lost = float(cols["work_lost"][m].sum())
        stats[s] = {
            "goodput_vcpu_s": goodput,
            "work_lost_vcpu_s": lost,
            "wasted_frac": lost / max(goodput + lost, 1e-12),
            "n_preempted": int(cols["n_preempted"][m].sum()),
            "n_reexec": int(cols["n_reexec"][m].sum()),
            "n_shed": int(cols["n_shed"][m].sum()),
            "n_completed": int(cols["n_completed"][m].sum()),
            "lat_p99_s": float(np.nanmean(cols["lat_p99"][m])),
            "wait_p99_s": float(np.nanmean(cols["wait_p99"][m])),
        }
        emit(f"churn/{s}/goodput_vcpu_s", 0.0, f"{goodput:.0f}")
        emit(f"churn/{s}/work_lost_vcpu_s", 0.0, f"{lost:.0f}")
        emit(f"churn/{s}/wasted_frac", 0.0,
             f"{stats[s]['wasted_frac']:.4f}")
        emit(f"churn/{s}/reexecutions", 0.0, str(stats[s]["n_reexec"]))
        emit(f"churn/{s}/shed", 0.0, str(stats[s]["n_shed"]))
        emit(f"churn/{s}/lat_p99_s", 0.0, f"{stats[s]['lat_p99_s']:.1f}")

    cash_f, stock_f = stats["cash"]["wasted_frac"], \
        stats["stock"]["wasted_frac"]
    ratio = cash_f / stock_f if stock_f > 0 else (1.0 if cash_f == 0
                                                  else float("inf"))
    kills = int(cols["n_kill_events"][sched == "cash"].sum())
    down = int(cols["node_down_ticks"][sched == "cash"].sum())
    emit("churn/kill_events", 0.0, str(kills))
    emit("churn/node_down_ticks", 0.0, str(down))
    emit("churn/wasted_work_ratio_cash_vs_stock", 0.0, f"{ratio:.3f}")
    assert kills > 0, "churn benchmark produced no preemptions"
    if fast:
        ok = ratio <= 1.0
        emit("churn/check/cash_wastes_no_more_than_stock", 0.0,
             "PASS" if ok else "FAIL")
        assert ok, (f"CASH wasted-work fraction {cash_f:.4f} exceeds "
                    f"stock's {stock_f:.4f} (ratio {ratio:.3f} > 1.0) on "
                    "identical fault streams")

    return {
        "mode": "fast" if fast else "full",
        "shape": {"n_nodes": n_nodes, "slots": SLOTS, "n_seeds": n_seeds,
                  "n_ticks": n_ticks, "dt": dt},
        "kill_events": kills,
        "node_down_ticks": down,
        "wasted_work_ratio_cash_vs_stock": ratio,
        "schedulers": stats,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(fast=args.fast)
