"""Open-loop traffic benchmark: CASH vs stock SLO tails under identical
arrival streams, plus ring-buffer engine throughput vs the closed-batch
path.

Two parts:

1. **SLO comparison** — the same Poisson arrival scenarios (shared
   per-scenario rng streams, so both schedulers see the SAME arrival
   sequence) run under CASH and stock; emits p95/p99 latency, queue-wait
   tails and drop counts per scheduler. This is the paper's story under
   open-loop load: credit-aware placement trims the latency tail on a
   credit-starved fleet. Full 64-bin SLO histograms — untimed.
2. **throughput** — an open-loop saturation run against the closed-batch
   fast-mode shape (same scenarios x nodes x ticks figure of merit).
   Acceptance: the open-loop engine stays within 20% of the closed-batch
   throughput measured in the SAME process (self-measured baseline —
   machine-independent), despite recycling slots and streaming SLO
   histograms. Timed interleaved (closed / traffic alternating samples)
   so background load hits both sides equally.

Returned stats land in ``BENCH_vecsim.json`` under the ``"traffic"``
section (benchmarks/run.py).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro import sweep as sweeplib
from repro.core import vecsim
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.simulator import Job
from repro.traffic import arrivals

SLOTS = 8


def _fleet(n_nodes: int, frac: float = 0.15):
    return make_cluster(n_nodes, "t3.2xlarge", slots_per_node=SLOTS,
                        cpu_initial_fraction=frac)


def _closed_jobs(seed: int, n_nodes: int, scale: float):
    """The vecsim_bench saturation shape: CPU-burst waves that drain
    inside the tick budget."""
    rng = np.random.RandomState(seed)
    tid = [500_000 * (seed + 1)]
    jobs = []
    for j in range(4):
        tasks = []
        for _ in range(n_nodes * SLOTS // 2):
            tid[0] += 1
            tasks.append(Task(
                tid=tid[0], job=f"j{j}", vertex="map",
                work_cpu=float(rng.uniform(800, 2400)) * scale,
                demand_cpu=float(rng.uniform(0.3, 0.95)),
                annotation=Annotation.BURST_CPU))
        jobs.append(Job(name=f"j{j}", tasks=tasks))
    return jobs


def _interleaved_times(runners, n_rounds: int = 4):
    """Best-of-rounds steady-state wall time per runner, with the
    runners interleaved round-robin so a background-load phase cannot
    hit only one of them. ``runners`` is a list of ``(fn, calls)``;
    each sample times ``calls`` back-to-back dispatches so every
    runner's sample covers a comparable wall-clock mass."""
    outs = [r() for r, _ in runners]            # warm/compile
    best = [float("inf")] * len(runners)
    for _ in range(n_rounds):
        for i, (r, calls) in enumerate(runners):
            t0 = time.perf_counter()
            for _ in range(calls):
                outs[i] = r()
            best[i] = min(best[i], (time.perf_counter() - t0) / calls)
    return best, outs


def run(fast: bool = False) -> dict:
    n_scen, n_nodes, n_ticks = (8, 8, 1_000) if fast else (16, 16, 10_000)
    tmpl = arrivals.make_template(8, seed=0, work=(60.0, 240.0),
                                  burst_fraction=0.75)
    # arrival rate sized to keep the fleet busy without unbounded backlog
    rate = n_nodes * SLOTS / 300.0

    # ---- 1) CASH vs stock on identical arrival streams ------------------
    def slo_spec(dt=5.0):
        def builder(rng_seed):
            return arrivals.build_traffic_scenario(
                _fleet(n_nodes), tmpl, mode="poisson", rate=rate,
                rng_seed=rng_seed)
        return sweeplib.SweepSpec(
            builder,
            axes={"scheduler": ("cash", "stock"),
                  "rng_seed": list(range(max(4, n_scen // 2)))},
            base=vecsim.VecSimConfig(n_ticks=n_ticks, dt=dt,
                                     traffic="poisson",
                                     table_slots=2 * n_nodes * SLOTS,
                                     slo_bins=64),
        )

    res = sweeplib.run_sweep(slo_spec(), shards=1)
    cols = res.scalars()
    sched = np.array([p.coord_dict["scheduler"] for p in res.points])
    slo_stats = {}
    for s in ("cash", "stock"):
        m = sched == s
        slo_stats[s] = {
            "lat_p95_s": float(np.nanmean(cols["lat_p95"][m])),
            "lat_p99_s": float(np.nanmean(cols["lat_p99"][m])),
            "wait_p95_s": float(np.nanmean(cols["wait_p95"][m])),
            "n_completed": int(cols["n_completed"][m].sum()),
            "n_dropped": int(cols["n_dropped"][m].sum()),
        }
        emit(f"traffic/{s}/lat_p95_s", 0.0,
             f"{slo_stats[s]['lat_p95_s']:.1f}")
        emit(f"traffic/{s}/lat_p99_s", 0.0,
             f"{slo_stats[s]['lat_p99_s']:.1f}")
        emit(f"traffic/{s}/wait_p95_s", 0.0,
             f"{slo_stats[s]['wait_p95_s']:.1f}")
        emit(f"traffic/{s}/completed", 0.0,
             str(slo_stats[s]["n_completed"]))
        emit(f"traffic/{s}/dropped", 0.0, str(slo_stats[s]["n_dropped"]))

    # ---- 2) throughput vs the closed-batch path at matched shape --------
    scale = 0.08 if fast else 0.75
    closed = [vecsim.build_scenario(_fleet(n_nodes, 0.2),
                                    _closed_jobs(s, n_nodes, scale))
              for s in range(n_scen)]
    closed_cfg = vecsim.VecSimConfig(n_ticks=n_ticks, scheduler="cash",
                                     impl="xla", unroll=4)
    closed_batch = vecsim.stack_scenarios(closed)

    # the traffic run is an all-burst saturation stream, matching the
    # closed baseline's all-BURST_CPU workload. The ring is sized to the
    # fleet's run-slot capacity (C = nodes x slots) — the natural
    # open-loop operating point: slots recycle at the service rate and
    # arrivals beyond a full table shed (disclosed via n_dropped below).
    # The timed mode carries a compact 8-bin streaming histogram; SLO
    # fidelity at 64 bins is part 1's job, untimed.
    tmpl_b = arrivals.make_template(8, seed=0, work=(60.0, 240.0),
                                    burst_fraction=1.0)
    # throughput is a per-tick rate, so the open-loop side is free to run
    # a longer scan: 4x the ticks makes each timed sample ~4x the wall
    # clock and squeezes scheduler-noise spikes out of the minima. The
    # closed side keeps the pinned fast-mode shape and instead samples 4
    # back-to-back dispatches, so both sides time a comparable mass.
    tr_ticks = 4 * n_ticks if fast else n_ticks
    tr_cfg = vecsim.VecSimConfig(n_ticks=tr_ticks, dt=5.0, scheduler="cash",
                                 traffic="poisson",
                                 table_slots=n_nodes * SLOTS,
                                 slo_bins=8, impl="xla", unroll=4)
    traffic = [arrivals.build_traffic_scenario(_fleet(n_nodes, 0.2), tmpl_b,
                                               mode="poisson", rate=rate,
                                               rng_seed=s)
               for s in range(n_scen)]
    traffic_batch = vecsim.stack_scenarios(traffic)

    (t_closed, t_traffic), (out_c, out_t) = _interleaved_times([
        (lambda: sweeplib.run_group(closed_batch, closed_cfg, shards=1), 4),
        (lambda: sweeplib.run_group(traffic_batch, tr_cfg, shards=1), 1),
    ])
    assert bool(out_c["all_done"].all()), "closed baseline truncated"
    closed_rate = n_ticks * n_nodes * n_scen / t_closed
    traffic_rate = tr_ticks * n_nodes * n_scen / t_traffic
    ratio = traffic_rate / closed_rate
    served = int(np.asarray(out_t["n_completed"]).sum())
    dropped = int(np.asarray(out_t["n_dropped"]).sum())
    arrived = int(np.asarray(out_t["n_arrived"]).sum())
    assert served > 0, "traffic throughput run completed no jobs"

    emit("traffic/shape", 0.0,
         f"{n_scen}x{n_nodes}x{n_ticks} (open-loop ticks={tr_ticks})")
    emit("traffic/closed_ticks_nodes_scen_per_s", 0.0, f"{closed_rate:.3e}")
    emit("traffic/traffic_ticks_nodes_scen_per_s", 0.0,
         f"{traffic_rate:.3e}")
    emit("traffic/throughput_ratio_vs_closed", 0.0, f"{ratio:.2f}")
    emit("traffic/jobs_shed", 0.0, f"{dropped}/{arrived}")
    if fast:
        # the acceptance check is defined against the closed-batch
        # FAST-mode number; full-mode ratios are reported informationally
        ok = ratio >= 0.8
        emit("traffic/check/within_20pct_of_closed", 0.0,
             "PASS" if ok else "FAIL")
        assert ok, (f"open-loop throughput {traffic_rate:.3e} is "
                    f"{ratio:.2f}x the closed path's {closed_rate:.3e} "
                    "(needs >= 0.8)")

    # execution config of the timed engines (lifted into meta by run.py);
    # fusion resolved for the open-loop all-burst stream
    tr_active = vecsim.batch_statics(traffic_batch)[3]
    engine_info = {"unroll": tr_cfg.unroll,
                   "fusion": vecsim.fusion_choice(tr_cfg, tr_active),
                   "pipelined": sweeplib.RunnerOptions().pipeline}

    return {
        "mode": "fast" if fast else "full",
        "shape": [n_scen, n_nodes, n_ticks],
        "engine": engine_info,
        "traffic_ticks": tr_ticks,
        "table_slots": n_nodes * SLOTS,
        "closed_ticks_nodes_scen_per_s": closed_rate,
        "traffic_ticks_nodes_scen_per_s": traffic_rate,
        "throughput_ratio_vs_closed": ratio,
        "jobs_completed": served,
        "jobs_dropped": dropped,
        "slo": slo_stats,
    }


if __name__ == "__main__":
    run(fast=True)
