"""Paper Fig 9: TPC-DS query completion time, CASH vs stock YARN, at the
three scales (2 VM / 280 GB, 10 VM / 1.2 TB, 20 VM / 2.5 TB).

Claims: improvement grows with I/O intensity — paper: ~5%, ~10.7% (13%
makespan), ~31% (22% makespan). We validate the monotone trend and the
magnitude at scale (bands)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.experiments import run_disk_pair

SETUPS = ("2vm", "10vm", "20vm")


def run() -> dict:
    impr = {}
    for setup in SETUPS:
        pair = run_disk_pair(setup, seeds=(1, 2, 3))
        qct = 1 - pair["cash"]["avg_qct"] / pair["stock"]["avg_qct"]
        mk = 1 - pair["cash"]["makespan"] / pair["stock"]["makespan"]
        impr[setup] = {"qct": qct, "makespan": mk}
        emit(f"fig9/{setup}/stock_avg_qct_s", 0.0, f"{pair['stock']['avg_qct']:.0f}")
        emit(f"fig9/{setup}/cash_avg_qct_s", 0.0, f"{pair['cash']['avg_qct']:.0f}")
        emit(f"fig9/{setup}/qct_improvement", 0.0, f"{qct:+.3f}")
        emit(f"fig9/{setup}/makespan_improvement", 0.0, f"{mk:+.3f}")
    checks = {
        "2vm_modest": impr["2vm"]["qct"] < 0.10,
        "monotone_qct": impr["2vm"]["qct"] < impr["10vm"]["qct"]
                        <= impr["20vm"]["qct"] + 0.02,
        "20vm_qct_large": 0.20 <= impr["20vm"]["qct"] <= 0.45,
        "20vm_makespan_large": 0.15 <= impr["20vm"]["makespan"] <= 0.45,
    }
    for k, ok in checks.items():
        emit(f"fig9/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), (checks, impr)
    return impr


_BATCHED_CACHE: dict = {}


def run_batched(fast: bool = False) -> dict:
    """Vectorized TPC-DS sweep as a `repro.sweep` grid: scheduler (static
    axis -> two compile groups) x setups x seeds, each (setup, seed)
    scenario built once and shared by both groups. fig11's batched path
    reuses these numbers."""
    import time

    import numpy as np

    from repro import sweep
    from repro.core import vecsim
    from repro.core.experiments import build_disk_vec_scenario

    if fast in _BATCHED_CACHE:
        return _BATCHED_CACHE[fast]
    setups = ("2vm",) if fast else SETUPS
    seeds = (1,) if fast else (1, 2, 3)
    n_ticks = 4_000 if fast else 6_000
    t0 = time.time()

    def builder(setup, seed):
        return build_disk_vec_scenario(setup, seed)[0]

    spec = sweep.SweepSpec(
        builder,
        axes={"scheduler": ("stock", "cash"), "setup": setups, "seed": seeds},
        base=vecsim.VecSimConfig(n_ticks=n_ticks, resource="disk"),
    )
    result = sweep.run_sweep(spec)
    assert bool(result.scalars()["all_done"].all()), "sweep did not finish"
    pair: dict = {}
    for sched in ("stock", "cash"):
        per = {}
        for setup in setups:
            pts = result.select(scheduler=sched, setup=setup)
            mks, qcts = [], []
            for p in pts:
                out = result.point_outputs(p.index)
                mks.append(float(out["makespan"]))
                jc = np.where(out["job_mask"], out["job_completion"], np.nan)
                qcts.append(float(np.nanmean(jc)))
            per[setup] = {"makespan": float(np.mean(mks)),
                          "avg_qct": float(np.mean(qcts))}
        pair[sched] = per
    impr = {}
    for setup in setups:
        qct = 1 - pair["cash"][setup]["avg_qct"] / pair["stock"][setup]["avg_qct"]
        mk = 1 - pair["cash"][setup]["makespan"] / pair["stock"][setup]["makespan"]
        impr[setup] = {"qct": qct, "makespan": mk}
        emit(f"fig9/batched/{setup}/qct_improvement", 0.0, f"{qct:+.3f}")
        emit(f"fig9/batched/{setup}/makespan_improvement", 0.0, f"{mk:+.3f}")
    emit("fig9/batched/sweep_wall_s", (time.time() - t0) * 1e6,
         f"{time.time() - t0:.1f}")
    result = {"pair": pair, "impr": impr, "setups": setups}
    _BATCHED_CACHE[fast] = result
    return result


if __name__ == "__main__":
    run()
    run_batched()
