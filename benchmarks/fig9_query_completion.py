"""Paper Fig 9: TPC-DS query completion time, CASH vs stock YARN, at the
three scales (2 VM / 280 GB, 10 VM / 1.2 TB, 20 VM / 2.5 TB).

Claims: improvement grows with I/O intensity — paper: ~5%, ~10.7% (13%
makespan), ~31% (22% makespan). We validate the monotone trend and the
magnitude at scale (bands)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.experiments import run_disk_pair

SETUPS = ("2vm", "10vm", "20vm")


def run() -> dict:
    impr = {}
    for setup in SETUPS:
        pair = run_disk_pair(setup, seeds=(1, 2, 3))
        qct = 1 - pair["cash"]["avg_qct"] / pair["stock"]["avg_qct"]
        mk = 1 - pair["cash"]["makespan"] / pair["stock"]["makespan"]
        impr[setup] = {"qct": qct, "makespan": mk}
        emit(f"fig9/{setup}/stock_avg_qct_s", 0.0, f"{pair['stock']['avg_qct']:.0f}")
        emit(f"fig9/{setup}/cash_avg_qct_s", 0.0, f"{pair['cash']['avg_qct']:.0f}")
        emit(f"fig9/{setup}/qct_improvement", 0.0, f"{qct:+.3f}")
        emit(f"fig9/{setup}/makespan_improvement", 0.0, f"{mk:+.3f}")
    checks = {
        "2vm_modest": impr["2vm"]["qct"] < 0.10,
        "monotone_qct": impr["2vm"]["qct"] < impr["10vm"]["qct"]
                        <= impr["20vm"]["qct"] + 0.02,
        "20vm_qct_large": 0.20 <= impr["20vm"]["qct"] <= 0.45,
        "20vm_makespan_large": 0.15 <= impr["20vm"]["makespan"] <= 0.45,
    }
    for k, ok in checks.items():
        emit(f"fig9/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), (checks, impr)
    return impr


if __name__ == "__main__":
    run()
