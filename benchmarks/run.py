"""Benchmark driver: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

``--fast`` is the CI smoke mode: every figure benchmark runs its *batched*
(core.vecsim) path at reduced scale, plus a reduced vecsim throughput
measurement; the Python-loop figure drivers are skipped. Both modes write
``BENCH_vecsim.json`` (Python-loop vs vectorized throughput) so the perf
trajectory is tracked PR over PR.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--fast", action="store_true",
                        help="reduced-scale smoke run (batched paths only)")
    parser.add_argument("--out", default="BENCH_vecsim.json",
                        help="where to write the vecsim throughput JSON")
    args = parser.parse_args(argv)

    from benchmarks import (
        ablation_joint,
        ablation_telemetry,
        fig7_cpu_burst,
        fig8_utilization,
        fig9_query_completion,
        fig10_iops,
        fig11_cost,
        kernels_bench,
        roofline,
        tables,
        vecsim_bench,
    )
    batched = [
        ("fig7/batched", fig7_cpu_burst.run_batched),
        ("fig8/batched", fig8_utilization.run_batched),
        ("fig9/batched", fig9_query_completion.run_batched),
        ("fig11/batched", fig11_cost.run_batched),
        ("joint/batched", ablation_joint.run_batched),
    ]
    if args.fast:
        mods = [(n, lambda fn=fn: fn(fast=True)) for n, fn in batched]
    else:
        mods = [
            ("tables", tables.run),
            ("fig7", fig7_cpu_burst.run),
            ("fig8", fig8_utilization.run),
            ("fig9", fig9_query_completion.run),
            ("fig10", fig10_iops.run),
            ("fig11", fig11_cost.run),
            ("kernels", kernels_bench.run),
            ("ablation", ablation_telemetry.run),
            ("joint", ablation_joint.run),
            ("roofline", roofline.run),
        ] + batched

    print("name,us_per_call,derived")
    failures = []
    for name, fn in mods:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()

    # vecsim throughput JSON: the tracked perf metric from this PR onward
    try:
        stats = vecsim_bench.run(fast=args.fast)
        stats["mode"] = "fast" if args.fast else "full"
        pathlib.Path(args.out).write_text(json.dumps(stats, indent=2) + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    except Exception as e:  # noqa: BLE001
        failures.append(("vecsim_bench", e))
        traceback.print_exc()

    if failures:
        print(f"FAILED benchmarks: {[n for n, _ in failures]}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
