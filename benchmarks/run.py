"""Benchmark driver: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

``--fast`` is the CI smoke mode: every figure benchmark runs its *batched*
(core.vecsim via repro.sweep) path at reduced scale, plus a reduced vecsim
throughput measurement and the `sweep/smoke` sharded-runner check; the
Python-loop figure drivers are skipped. Unless the caller already forced a
device count, the driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` *before* JAX
initializes so the sweep runner's >= 2-way scenario-axis sharding is
exercised even on single-accelerator CI hosts; it also selects the legacy
CPU runtime (``--xla_cpu_use_thunk_runtime=false``), which the k-unrolled
tick scan needs to pay off (see `_tune_xla_flags`).

Both modes write ``BENCH_vecsim.json`` (Python-loop vs vectorized
throughput). The file keeps one section per mode — ``{"fast": {...},
"full": {...}}`` — so a fast CI run never overwrites the full-mode numbers
and the perf trajectory stays comparable PR over PR. A ``"traffic"``
section (benchmarks/traffic_bench.py) tracks the open-loop ring-buffer
engine: CASH-vs-stock SLO tails plus throughput relative to the
closed-batch path. A ``"churn"`` section (benchmarks/churn_bench.py)
tracks CASH vs credit-blind placement under preemption churn on
identical fault streams (wasted work, goodput, re-executions). A
``"serve"`` section (benchmarks/serve_bench.py) tracks the vectorized
serving fleet: engine throughput vs the Python replay loop, plus
CASH-vs-round-robin admission tails and $/Mtok.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import pathlib
import platform as _platform
import subprocess
import sys
import traceback

_FORCE_DEVICES = "--xla_force_host_platform_device_count=2"
_NO_THUNKS = "--xla_cpu_use_thunk_runtime=false"


def _provenance() -> dict:
    """Where these numbers came from: git SHA (+dirty flag), UTC
    timestamp, jax/jaxlib versions, host platform. Rides at the top
    level of BENCH_vecsim.json so a perf delta PR-over-PR can always be
    tied back to the exact tree and toolchain that produced each side."""
    here = pathlib.Path(__file__).resolve().parent
    sha, dirty = None, None
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=here, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=here,
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip())
    except (OSError, subprocess.SubprocessError):
        pass                      # not a checkout / no git: sha stays None
    import jax
    import jaxlib

    return {
        "git_sha": sha,
        "git_dirty": dirty,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "python": _platform.python_version(),
        "platform": _platform.platform(),
    }


def _tune_xla_flags() -> None:
    """Benchmark-process XLA flags. Must run before JAX initializes its
    backends; respects explicit user settings for either flag.

    * >= 2 host-platform devices, so sweep sharding is exercised even on
      single-accelerator CI hosts.
    * legacy (non-thunk) CPU runtime: a measured ~25% engine-throughput
      win on this XLA version, and the k-unrolled tick scan
      (``VecSimConfig.unroll=4``) is neutral-to-slightly-positive under
      it but a clear ~25% LOSS under the default thunk runtime — the two
      settings ship together (the unrolled scan stays bitwise-identical
      either way; only speed changes).
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        flags = f"{flags} {_FORCE_DEVICES}".strip()
    if "xla_cpu_use_thunk_runtime" not in flags:
        flags = f"{flags} {_NO_THUNKS}".strip()
    os.environ["XLA_FLAGS"] = flags


def _merged_bench(path: pathlib.Path, mode: str, stats: dict) -> dict:
    """Merge this run's stats into the per-mode BENCH layout, migrating the
    pre-PR-4 flat schema (a single run dict with a "mode" field) in place."""
    doc: dict = {}
    if path.exists():
        try:
            prev = json.loads(path.read_text())
        except json.JSONDecodeError:
            prev = {}
        if "sweep" in prev and "mode" in prev:      # old flat schema
            doc[prev["mode"]] = {k: v for k, v in prev.items()
                                 if k != "mode"}
        else:
            doc = {k: v for k, v in prev.items()
                   if k in ("fast", "full", "traffic", "churn", "serve")}
    # mesh topology rides in THIS mode's meta: sharded throughput numbers
    # are only comparable across machines with the same device layout, and
    # the other mode's section may have been written on different hardware.
    # The engine execution config (unroll factor, fusion impl, pipelined
    # runner) rides there too — a perf delta PR-over-PR should name its
    # lever.
    from repro.sweep import mesh_topology

    stats = dict(stats)
    meta = mesh_topology()
    engine = stats.pop("engine", None)
    if engine is not None:
        meta["engine"] = engine
    doc[mode] = dict(stats, meta=meta)
    return doc


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--fast", action="store_true",
                        help="reduced-scale smoke run (batched paths only)")
    parser.add_argument("--out", default="BENCH_vecsim.json",
                        help="where to write the vecsim throughput JSON")
    parser.add_argument("--check", action="store_true",
                        help="fail if a gated throughput metric regresses "
                             ">15%% vs the committed --out baseline "
                             "(benchmarks/check_regression.py)")
    args = parser.parse_args(argv)
    _tune_xla_flags()

    # snapshot the committed baseline BEFORE this run overwrites it —
    # the regression gate compares fresh numbers against this snapshot
    out_path = pathlib.Path(args.out)
    baseline = None
    if args.check and out_path.exists():
        try:
            baseline = json.loads(out_path.read_text())
        except json.JSONDecodeError:
            print(f"--check: unreadable baseline {args.out}; "
                  "gate skipped", file=sys.stderr)

    from benchmarks import (
        ablation_joint,
        ablation_telemetry,
        churn_bench,
        fig7_cpu_burst,
        fig8_utilization,
        fig9_query_completion,
        fig10_iops,
        fig11_cost,
        kernels_bench,
        roofline,
        serve_bench,
        sweep_smoke,
        tables,
        traffic_bench,
        vecsim_bench,
    )
    batched = [
        ("fig7/batched", fig7_cpu_burst.run_batched),
        ("fig8/batched", fig8_utilization.run_batched),
        ("fig9/batched", fig9_query_completion.run_batched),
        ("fig11/batched", fig11_cost.run_batched),
        ("joint/batched", ablation_joint.run_batched),
        ("sweep/smoke", sweep_smoke.run),
    ]
    if args.fast:
        mods = [(n, lambda fn=fn: fn(fast=True)) for n, fn in batched]
    else:
        mods = [
            ("tables", tables.run),
            ("fig7", fig7_cpu_burst.run),
            ("fig8", fig8_utilization.run),
            ("fig9", fig9_query_completion.run),
            ("fig10", fig10_iops.run),
            ("fig11", fig11_cost.run),
            ("kernels", kernels_bench.run),
            ("ablation", ablation_telemetry.run),
            ("joint", ablation_joint.run),
            ("roofline", roofline.run),
        ] + batched

    print("name,us_per_call,derived")
    failures = []
    for name, fn in mods:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()

    # vecsim throughput JSON: the tracked perf metric, one section per mode,
    # plus a "traffic" section for the open-loop ring-buffer engine
    mode = "fast" if args.fast else "full"
    doc = None
    try:
        stats = vecsim_bench.run(fast=args.fast)
        try:
            # tick-phase breakdown (placement/serve/telemetry/histogram,
            # fused vs unfused) rides in the same per-mode section
            stats["tick_phases"] = roofline.vecsim_phases(fast=args.fast)
        except Exception as e:  # noqa: BLE001
            failures.append(("roofline.vecsim_phases", e))
            traceback.print_exc()
        doc = _merged_bench(out_path, mode, stats)
    except Exception as e:  # noqa: BLE001
        failures.append(("vecsim_bench", e))
        traceback.print_exc()
    try:
        tstats = traffic_bench.run(fast=args.fast)
        from repro.sweep import mesh_topology

        if args.fast:
            # the ISSUE-7 acceptance gate, re-checked at the driver level:
            # the fused/unrolled engine must keep the open-loop path
            # within 20% of the closed-batch path (traffic_bench also
            # asserts this internally)
            ratio = float(tstats.get("throughput_ratio_vs_closed", 0.0))
            if ratio < 0.8:
                failures.append(("traffic_ratio", AssertionError(
                    f"traffic/closed throughput ratio {ratio:.2f} < 0.8")))
        if doc is None:
            doc = _merged_bench(out_path, mode, {})
            doc.pop(mode, None)         # vecsim_bench failed: keep prior
        tstats = dict(tstats)
        tmeta = mesh_topology()
        tengine = tstats.pop("engine", None)
        if tengine is not None:
            tmeta["engine"] = tengine
        doc["traffic"] = dict(tstats, meta=tmeta)
    except Exception as e:  # noqa: BLE001
        failures.append(("traffic_bench", e))
        traceback.print_exc()
    try:
        cstats = churn_bench.run(fast=args.fast)
        if args.fast:
            # the ISSUE-8 acceptance gate, re-checked at the driver
            # level: on identical fault streams, credit-aware
            # (blacklisting) placement must not waste more work than
            # credit-blind placement (churn_bench also asserts this)
            cratio = float(cstats.get("wasted_work_ratio_cash_vs_stock",
                                      float("inf")))
            if cratio > 1.0:
                failures.append(("churn_wasted_work", AssertionError(
                    f"CASH/stock wasted-work ratio {cratio:.3f} > 1.0")))
        if doc is None:
            doc = _merged_bench(out_path, mode, {})
            doc.pop(mode, None)
        from repro.sweep import mesh_topology as _topo

        doc["churn"] = dict(cstats, meta=_topo())
    except Exception as e:  # noqa: BLE001
        failures.append(("churn_bench", e))
        traceback.print_exc()
    try:
        sstats = serve_bench.run(fast=args.fast)
        if args.fast:
            # the ISSUE-10 acceptance gate, re-checked at the driver
            # level: the vectorized serving-fleet engine must clear 50x
            # over the Python replay loop (serve_bench also asserts it)
            sp = float(sstats.get("speedup_vs_python_loop", 0.0))
            if sp < serve_bench.SPEEDUP_FLOOR:
                failures.append(("serve_speedup", AssertionError(
                    f"serving engine speedup {sp:.1f}x < "
                    f"{serve_bench.SPEEDUP_FLOOR:.0f}x vs Python loop")))
        if doc is None:
            doc = _merged_bench(out_path, mode, {})
            doc.pop(mode, None)
        from repro.sweep import mesh_topology as _stopo

        sstats = dict(sstats)
        smeta = _stopo()
        sengine = sstats.pop("engine", None)
        if sengine is not None:
            smeta["engine"] = sengine
        doc["serve"] = dict(sstats, meta=smeta)
    except Exception as e:  # noqa: BLE001
        failures.append(("serve_bench", e))
        traceback.print_exc()
    if doc is not None:
        doc["provenance"] = _provenance()
        out_path.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {args.out} [{mode}]", file=sys.stderr)

    if args.check and baseline is not None and doc is not None:
        from benchmarks import check_regression

        if not check_regression.check_docs(baseline, doc):
            failures.append(("regression_gate", AssertionError(
                "throughput regressed vs committed baseline")))
        else:
            print("regression gate: PASS", file=sys.stderr)

    if failures:
        print(f"FAILED benchmarks: {[n for n, _ in failures]}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
