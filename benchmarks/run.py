"""Benchmark driver: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        ablation_joint,
        ablation_telemetry,
        fig7_cpu_burst,
        fig8_utilization,
        fig9_query_completion,
        fig10_iops,
        fig11_cost,
        kernels_bench,
        roofline,
        tables,
    )
    mods = [
        ("tables", tables),
        ("fig7", fig7_cpu_burst),
        ("fig8", fig8_utilization),
        ("fig9", fig9_query_completion),
        ("fig10", fig10_iops),
        ("fig11", fig11_cost),
        ("kernels", kernels_bench),
        ("ablation", ablation_telemetry),
        ("joint", ablation_joint),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, mod in mods:
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED benchmarks: {[n for n, _ in failures]}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
