"""Beyond-paper: JOINT multi-resource CASH — the paper's stated future work
(SS8: "experimenting with joint scheduling of plural credit-based resources")
— reported as an honest NEGATIVE result with analysis.

Mixed workload on burstable T3 instances with wiped EBS buckets: CPU-burst
HiBench jobs AND disk-burst TPC-DS queries run together at full cluster
saturation. Findings (asserted below):

1. The joint scheduler (credit-ranked nodes, burst classes interleaved per
   node toward the richer pool) matches the best single-resource CASH —
   joint awareness costs nothing and removes the need to pick the
   bottleneck resource a priori.
2. ALL CASH variants lose to stock YARN on this *saturated* mixed workload
   (~12-18%). Two mechanisms, both diagnosed in simulation:
   - Algorithm 1's phase priority (all burst tasks before any network task)
     starves the shuffle vertices that gate downstream DAG stages ->
     pipeline stalls that stock's FIFO mixing avoids;
   - class segregation concentrates same-resource demand per node,
     saturating single buckets that mixed placement would share.
   CASH's winning regime is *partial load with placement freedom* (paper
   SS3.1's low-utilization motivation; our Fig 9 reproduction) — this
   experiment maps the boundary of that regime.
"""
from __future__ import annotations

import statistics

from benchmarks.common import emit
from repro.core.cluster import make_cluster
from repro.core.scheduler import CashScheduler, JointCashScheduler, StockScheduler
from repro.core.simulator import SimConfig, Simulation
from repro.core.workloads import make_hibench_workload, make_tpcds_suite, reset_tids

N_NODES = 10


def _run(mode: str, seed: int) -> float:
    reset_tids()
    nodes = make_cluster(N_NODES, "t3.2xlarge", ebs_size_gb=170.0,
                         cpu_initial_fraction=0.3, disk_initial_credits=0.0)
    if mode == "stock":
        sched, cfg = StockScheduler(), SimConfig(resource="cpu")
    elif mode == "cash-cpu":
        sched, cfg = CashScheduler(), SimConfig(resource="cpu")
    elif mode == "cash-disk":
        sched, cfg = CashScheduler(), SimConfig(resource="disk")
    else:
        sched, cfg = JointCashScheduler(), SimConfig(resource="joint")
    sim = Simulation(nodes, sched, cfg)
    # mixed bottlenecks at saturation: disk-burst queries + cpu-burst batch
    jobs = make_tpcds_suite(600.0, N_NODES, 8, seed=seed)
    cpu_jobs = make_hibench_workload("sql_aggregation", N_NODES, 8,
                                     seed=seed + 7)
    sim.submit_parallel(jobs + cpu_jobs[:2])
    r = sim.run()
    return r.makespan


def run() -> dict:
    seeds = (1, 2, 3)
    out = {}
    for mode in ("stock", "cash-cpu", "cash-disk", "cash-joint"):
        out[mode] = statistics.mean(_run(mode, s) for s in seeds)
        emit(f"joint/{mode}/makespan_s", 0.0, f"{out[mode]:.0f}")
    for mode in ("cash-cpu", "cash-disk", "cash-joint"):
        emit(f"joint/{mode}/improvement_vs_stock", 0.0,
             f"{1 - out[mode] / out['stock']:+.3f}")
    checks = {
        # finding 1: joint >= best single-resource variant (within noise)
        "joint_at_least_best_single":
            out["cash-joint"] <= min(out["cash-cpu"], out["cash-disk"]) * 1.05,
        # finding 2 (negative result): at saturation, stock's mixing wins —
        # the documented boundary of Algorithm 1's regime
        "saturation_regime_boundary_observed":
            out["stock"] < min(out["cash-cpu"], out["cash-disk"],
                               out["cash-joint"]),
    }
    for k, ok in checks.items():
        emit(f"joint/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), (checks, out)
    return out


_VEC_MODES = {
    # mode -> (vec scheduler, credit resource) — both compile-time static
    "stock": ("stock", "cpu"),
    "cash-cpu": ("cash", "cpu"),
    "cash-disk": ("cash", "disk"),
    "cash-joint": ("cash-joint", "joint"),
}


def run_batched(fast: bool = False) -> dict:
    """Vectorized mixed-workload sweep as a `repro.sweep` grid: the "mode"
    axis maps through `configure` to (scheduler, resource) — four compile
    groups — while the shared per-seed scenarios are built once and reused
    by every mode (the spec memoizes builders on their parameters)."""
    import statistics
    import time

    from repro import sweep
    from repro.core import vecsim
    from repro.core.cluster import make_cluster as _mk

    seeds = (1,) if fast else (1, 2, 3)
    n_nodes = 6 if fast else N_NODES
    n_ticks = 6_000 if fast else 12_000
    t0 = time.time()

    def builder(seed):
        reset_tids()
        nodes = _mk(n_nodes, "t3.2xlarge", ebs_size_gb=170.0,
                    cpu_initial_fraction=0.3, disk_initial_credits=0.0)
        jobs = make_tpcds_suite(600.0, n_nodes, 8, seed=seed)
        cpu_jobs = make_hibench_workload("sql_aggregation", n_nodes, 8,
                                         seed=seed + 7)
        return vecsim.build_scenario(nodes, jobs + cpu_jobs[:2])

    spec = sweep.SweepSpec(
        builder,
        axes={"mode": list(_VEC_MODES), "seed": seeds},
        base=vecsim.VecSimConfig(n_ticks=n_ticks),
        configure=lambda c: dict(
            zip(("scheduler", "resource"), _VEC_MODES[c["mode"]])),
    )
    result = sweep.run_sweep(spec)
    assert bool(result.scalars()["all_done"].all()), "sweep did not finish"
    out = {}
    for mode in _VEC_MODES:
        out[mode] = statistics.mean(
            float(m) for m in result.metric("makespan", mode=mode))
        emit(f"joint/batched/{mode}/makespan_s", 0.0, f"{out[mode]:.0f}")
    for mode in ("cash-cpu", "cash-disk", "cash-joint"):
        emit(f"joint/batched/{mode}/improvement_vs_stock", 0.0,
             f"{1 - out[mode] / out['stock']:+.3f}")
    emit("joint/batched/sweep_wall_s", (time.time() - t0) * 1e6,
         f"{time.time() - t0:.1f}")
    return out


if __name__ == "__main__":
    run()
    run_batched()
