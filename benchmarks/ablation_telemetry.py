"""Beyond-paper ablation: how much does Algorithm 2's credit *prediction*
matter? The paper argues (SS5.1) that scheduling on CloudWatch's raw 5-minute
actuals would act on stale state, and adds 1-minute utilization-based
prediction. We quantify that choice against two bounds:

  stale     — 5-min actuals only (naive CloudWatch integration)
  predicted — the paper's Algorithm 2 (actuals + 1-min extrapolation)
  oracle    — zero-lag ground-truth credit state (upper bound)

on the 10-VM disk experiment, plus stock YARN as the floor."""
from __future__ import annotations

import statistics

from benchmarks.common import emit
from repro.core.experiments import run_disk_experiment

MODES = ("stale", "predicted", "oracle")


def run() -> dict:
    seeds = (1, 2, 3)
    stock = statistics.mean(
        run_disk_experiment("10vm", "stock", seed=s).result.avg_query_completion()
        for s in seeds)
    emit("ablation/stock/avg_qct_s", 0.0, f"{stock:.0f}")
    out = {}
    for mode in MODES:
        qct = statistics.mean(
            run_disk_experiment("10vm", "cash", seed=s,
                                telemetry=mode).result.avg_query_completion()
            for s in seeds)
        out[mode] = 1 - qct / stock
        emit(f"ablation/cash_{mode}/avg_qct_s", 0.0, f"{qct:.0f}")
        emit(f"ablation/cash_{mode}/improvement_vs_stock", 0.0,
             f"{out[mode]:+.3f}")
    checks = {
        # prediction must recover most of the oracle's advantage over stale
        "all_beat_stock": all(v > 0 for v in out.values()),
        "predicted_not_worse_than_stale":
            out["predicted"] >= out["stale"] - 0.02,
        "predicted_close_to_oracle":
            out["predicted"] >= out["oracle"] - 0.08,
    }
    for k, ok in checks.items():
        emit(f"ablation/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), (checks, out)
    return out


if __name__ == "__main__":
    run()
