"""Shared benchmark utilities: CSV emission + timing + vec-path helpers."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Sequence, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn: Callable, n: int = 1) -> float:
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6   # us


def phase_elapsed_from_vec(order: Sequence, start, finish) -> Dict[str, float]:
    """Per-vertex-kind elapsed sums from a vecsim run's start/finish arrays
    (``order`` from ``vecsim.scenario_task_order``) — the batched analogue of
    ``SimResult.phase_elapsed``."""
    import math
    out: Dict[str, float] = {}
    for (_, t), s, f in zip(order, start, finish):
        if math.isfinite(float(f)) and math.isfinite(float(s)):
            out[t.vertex] = out.get(t.vertex, 0.0) + float(f) - float(s)
    return out
