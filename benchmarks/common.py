"""Shared benchmark utilities: CSV emission + timing."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn: Callable, n: int = 1) -> float:
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6   # us
