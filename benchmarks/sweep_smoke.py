"""`repro.sweep` smoke benchmark (CI `--fast` entry).

Three parts:

1. **multi-group grid** — a scheduler x telemetry x seed grid (4 compile
   groups) with streamed timelines, run end-to-end through
   `sweep.run_sweep` with the scenario axis sharded across all local
   devices (CI forces >= 2 via
   ``XLA_FLAGS=--xla_force_host_platform_device_count=2``).
2. **calibration-scale parity** — a 1024-scenario single-group sweep
   (tiny scenarios, chunked) run through the `shard_map` mesh path at
   every shard count in {2, 4} the host exposes AND on the single-device
   vmap path; per-scenario results must be bitwise equal at each width
   (ISSUE 4/5 acceptance — force widths on CPU with
   ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
3. **open-loop traffic smoke** — a Poisson + trace-replay mode x seed
   grid through the ring-buffer engine (`cfg.traffic`), sharded vs vmap,
   with the streaming SLO histograms (`lat_hist`/`wait_hist`) and all
   per-scenario scalars required bitwise equal across paths (ISSUE 6).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro import sweep as sweeplib
from repro.core import vecsim
from repro.obs import registry
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.simulator import Job
from repro.traffic import arrivals


def _tiny_scenario(seed: int, n_tasks: int = 6, n_nodes: int = 2):
    """A scenario small enough that 1024 of them stack and scan in seconds."""
    rng = np.random.RandomState(seed)
    tid = 1000 * seed + 1
    tasks = []
    for k in range(n_tasks):
        tasks.append(Task(
            tid=tid + k, job="j0", vertex="map",
            work_cpu=float(rng.uniform(8, 32)),
            demand_cpu=float(rng.uniform(0.3, 0.9)),
            annotation=Annotation.BURST_CPU if k % 2 else Annotation.NONE))
    nodes = make_cluster(n_nodes, "t3.large", slots_per_node=2,
                         cpu_initial_fraction=float(rng.uniform(0.1, 0.5)))
    return vecsim.build_scenario(nodes, [Job(name="j0", tasks=tasks)],
                                 rng_seed=seed)


def run(fast: bool = False) -> dict:
    n_dev = sweeplib.device_count()
    # full mode widens the grid's seed axis and deepens the calibration
    # scan; the 1024-scenario count is pinned (ISSUE 4 acceptance)
    grid_seeds, cal_ticks = (4, 256) if fast else (16, 1024)

    # ---- 1) multi-group grid through the sharded runner -----------------
    def builder(seed):
        return _tiny_scenario(seed, n_tasks=8, n_nodes=3)

    grid = sweeplib.SweepSpec(
        builder,
        axes={"scheduler": ("cash", "stock"),
              "telemetry": ("predicted", "stale"),
              "seed": list(range(grid_seeds))},
        base=vecsim.VecSimConfig(n_ticks=512, sample_period=16.0),
    )
    t0 = time.perf_counter()
    res = sweeplib.run_sweep(grid)        # shards = all local devices
    wall = time.perf_counter() - t0
    ok = bool(res.scalars()["all_done"].all())
    emit("sweep/smoke/grid_points", 0.0, str(res.n_points))
    emit("sweep/smoke/grid_groups", 0.0, str(res.meta["n_groups"]))
    emit("sweep/smoke/grid_shards", 0.0, str(res.meta["shards"]))
    emit("sweep/smoke/grid_wall_s", wall * 1e6, f"{wall:.2f}")
    emit("sweep/smoke/grid_all_done", 0.0, "PASS" if ok else "FAIL")
    assert ok, "smoke grid did not finish"
    # every engine output (including the streamed timelines) must be a
    # declared metric — an undeclared key is a registry omission, caught
    # here before it can reach a persisted artifact
    for g in res.groups:
        registry.validate_outputs(g.outputs)
    emit("sweep/smoke/registry_valid", 0.0, "PASS")
    assert res.meta["n_groups"] == 4, res.meta
    # the stock groups never read telemetry, but they are still distinct
    # static configs — the spec must keep them apart
    assert res.n_points == 4 * grid_seeds

    # ---- 2) 1024-scenario sharded-vs-vmap bitwise parity at {2, 4} ------
    n_scen = 1024
    cal = sweeplib.SweepSpec(
        lambda seed: _tiny_scenario(seed),
        axes={"seed": list(range(n_scen))},
        base=vecsim.VecSimConfig(n_ticks=cal_ticks, scheduler="cash"),
    )
    groups = cal.groups()           # build scenarios once, reuse every width
    t0 = time.perf_counter()
    res_vmap = sweeplib.run_sweep(groups, shards=1)
    t_vmap = time.perf_counter() - t0
    s_vmap = res_vmap.scalars()
    emit("sweep/smoke/cal_scenarios", 0.0, str(n_scen))
    emit("sweep/smoke/cal_vmap_wall_s", t_vmap * 1e6, f"{t_vmap:.2f}")

    widths = sorted({d for d in (2, 4, n_dev) if 1 < d <= n_dev})
    if not widths:
        # a parity PASS must mean a sharded run actually executed — on a
        # single-device host say SKIP loudly instead of vacuously passing
        # (benchmarks/run.py forces 2 host devices before JAX init)
        emit("sweep/smoke/cal_parity", 0.0, "SKIP(single-device)")
    t_shard = None
    parity = {}
    for d in widths:
        t0 = time.perf_counter()
        res_shard = sweeplib.run_sweep(groups, shards=d, chunk_size=256)
        t_d = time.perf_counter() - t0
        s_shard = res_shard.scalars()
        bitwise = all(np.array_equal(s_vmap[k], s_shard[k]) for k in s_vmap)
        bitwise &= np.array_equal(res_vmap.groups[0].outputs["finish"],
                                  res_shard.groups[0].outputs["finish"])
        done = bool(s_shard["all_done"].all())
        parity[d] = bitwise and done
        emit(f"sweep/smoke/cal_sharded{d}_wall_s", t_d * 1e6, f"{t_d:.2f}")
        emit(f"sweep/smoke/cal_sharded{d}_bitwise_equal", 0.0,
             "PASS" if parity[d] else "FAIL")
        assert done, f"{d}-way sharded 1024-scenario sweep did not finish"
        assert bitwise, f"{d}-way shard_map diverged from the vmap path"
        if d == n_dev:
            t_shard = t_d

    # ---- 3) open-loop traffic: poisson + replay, sharded parity ---------
    tmpl = arrivals.make_template(6, seed=1)
    horizon = cal_ticks * 5.0

    def traffic_builder(mode, rng_seed):
        nodes = make_cluster(2, "t3.large", slots_per_node=2,
                             cpu_initial_fraction=0.3)
        if mode == "replay":
            # deterministic synthetic trace, fixed length so scenarios
            # stack; front-loaded to 80% of the horizon so late arrivals
            # still finish
            rng = np.random.RandomState(1_000 + rng_seed)
            arr_t = np.sort(rng.uniform(0.0, 0.8 * horizon, size=48))
            arr_k = rng.randint(0, 6, size=48)
            return arrivals.build_traffic_scenario(
                nodes, tmpl, mode="replay", trace_t=arr_t,
                trace_tmpl=arr_k, rng_seed=rng_seed)
        return arrivals.build_traffic_scenario(
            nodes, tmpl, mode="poisson", rate=0.04, rng_seed=rng_seed)

    tr = sweeplib.SweepSpec(
        traffic_builder,
        axes={"mode": ("poisson", "replay"),
              "rng_seed": list(range(grid_seeds))},
        base=vecsim.VecSimConfig(n_ticks=cal_ticks, dt=5.0,
                                 scheduler="cash", table_slots=16,
                                 slo_bins=16),
        configure=lambda c: {"traffic": c["mode"]},
    )
    tr_groups = tr.groups()
    res_tr1 = sweeplib.run_sweep(tr_groups, shards=1)
    s_tr1 = res_tr1.scalars()
    arrived = int(s_tr1["n_arrived"].sum())
    completed = int(s_tr1["n_completed"].sum())
    emit("sweep/smoke/traffic_points", 0.0, str(res_tr1.n_points))
    emit("sweep/smoke/traffic_completed", 0.0, f"{completed}/{arrived}")
    assert completed > 0, "traffic smoke completed no jobs"
    for g in res_tr1.groups:        # traffic outputs (SLO hists) too
        registry.validate_outputs(g.outputs)
    tr_parity = None
    if n_dev > 1:
        res_trd = sweeplib.run_sweep(tr_groups, shards=n_dev)
        s_trd = res_trd.scalars()
        tr_parity = all(np.array_equal(s_tr1[k], s_trd[k],
                                       equal_nan=True) for k in s_tr1)
        for g1, gd in zip(res_tr1.groups, res_trd.groups):
            for key in ("lat_hist", "wait_hist"):
                tr_parity &= np.array_equal(g1.outputs[key],
                                            gd.outputs[key])
        emit("sweep/smoke/traffic_bitwise_equal", 0.0,
             "PASS" if tr_parity else "FAIL")
        assert tr_parity, "sharded traffic sweep diverged from vmap path"
    else:
        emit("sweep/smoke/traffic_bitwise_equal", 0.0,
             "SKIP(single-device)")

    return {
        "grid_points": res.n_points,
        "grid_groups": res.meta["n_groups"],
        "shards": n_dev,
        "cal_scenarios": n_scen,
        "cal_vmap_wall_s": t_vmap,
        "cal_sharded_wall_s": t_shard,
        "cal_bitwise_equal": all(parity.values()) if parity else None,
        "cal_parity_widths": sorted(parity),
        "traffic_points": res_tr1.n_points,
        "traffic_completed": completed,
        "traffic_arrived": arrived,
        "traffic_bitwise_equal": tr_parity,
    }


if __name__ == "__main__":
    run()
