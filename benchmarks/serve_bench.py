"""Serving-fleet benchmark: CASH vs round-robin admission on identical
request streams, $/token billing, and vectorized-engine throughput vs
the Python replay loop.

Three parts:

1. **scheduler comparison** — the same Poisson request streams (shared
   per-scenario rng seeds, so both admission policies see the SAME
   arrivals) run under CASH credit-aware admission and credit-blind
   round-robin; emits p95/p99 end-to-end latency, queue-wait tails and
   completion counts per policy. The fleet runs moderately overloaded
   (a few % of arrivals shed), the regime where admission policy moves
   queue waits and drop counts. Full 64-bin SLO histograms — untimed.
2. **$/token** — `core.cost.BillingLine` over the fleet horizon (T3
   pricing + any unlimited-surplus overdraft from the engine's
   ``surplus_credits``), divided by tokens actually served. Serving
   more tokens inside the same billed wall-clock is the paper's
   cost-equals-duration story applied to inference.
3. **throughput** — the jitted scan engine against the pure-Python
   replay loop (`serve.oracle.ServeFleetOracle`: real `KVCacheManager`
   slot accounting, per-request bookkeeping — the same per-tick
   semantics, see the parity tests). The Python side is timed on a tick
   slice of ONE scenario and extrapolated (it has no cross-scenario
   batching to amortize); the engine is timed end-to-end on the stacked
   batch. Timed at the compact 8-bin streaming histogram — SLO fidelity
   at 64 bins is part 1's job, untimed (the traffic_bench convention).
   Acceptance (fast mode): the vectorized engine clears >= 50x.

Returned stats land in ``BENCH_vecsim.json`` under the ``"serve"``
section (benchmarks/run.py); ``serve_ticks_reps_scen_per_s`` is gated
against the committed baseline by benchmarks/check_regression.py.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import servesim
from repro.core.cost import BillingLine
from repro.serve.oracle import ServeFleetOracle
from repro.traffic import arrivals

INSTANCE = "t3.2xlarge"
SPEEDUP_FLOOR = 50.0
KV_SLOTS = 4


def _scenarios(n_scen: int, n_replicas: int):
    tmpl = arrivals.make_serve_template(8, seed=0)
    # prefill demand far above the sustained rate, balances sized so
    # buckets deplete mid-run, arrival rate past the fleet's drain rate:
    # the regime where admission policy matters (and where the Python
    # loop pays full freight — the request table stays populated)
    return [arrivals.build_serve_scenario(
        tmpl, n_replicas=n_replicas, balance0=400.0, baseline=150.0,
        burst=1500.0, capacity=500.0, rate=0.25 * n_replicas, rng_seed=s)
        for s in range(n_scen)]


def _time_best(fn, rounds: int = 3):
    out = fn()                              # warm-up / compile
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(fast: bool = False) -> dict:
    n_scen, n_reps, n_ticks = (16, 16, 2_000) if fast else (32, 16, 10_000)
    # 1.5x the fleet's KV residency: queue headroom without padding the
    # hot per-tick lane count (shedding, if any, is disclosed below)
    table = 3 * n_reps * KV_SLOTS // 2
    scens = _scenarios(n_scen, n_reps)
    batch = arrivals.stack_serve_scenarios(scens)

    def cfg_for(policy, slo_bins=64):
        return servesim.ServeSimConfig(
            n_ticks=n_ticks, scheduler=policy, traffic="poisson",
            kv_slots=KV_SLOTS, table_slots=table, slo_bins=slo_bins,
            impl="xla", unroll=2)

    # ---- 1+2) CASH vs round-robin on identical streams, with billing ----
    horizon_s = n_ticks * cfg_for("cash").dt
    sched_stats = {}
    for policy in ("cash", "rr"):
        res = servesim.run_batch(batch, cfg_for(policy))
        tokens = float(res["tokens_prefilled"].sum()
                       + res["tokens_decoded"].sum())
        line = BillingLine(
            label=policy, instance_type=INSTANCE,
            n_instances=n_reps * n_scen, wall_clock_s=horizon_s,
            surplus_vcpu_seconds=float(res["surplus_credits"].sum()))
        usd_per_mtok = line.total / tokens * 1e6
        sched_stats[policy] = {
            "lat_p95_s": float(np.nanmean(res["lat_p95"])),
            "lat_p99_s": float(np.nanmean(res["lat_p99"])),
            "wait_p95_s": float(np.nanmean(res["wait_p95"])),
            "n_completed": int(res["n_completed"].sum()),
            "n_dropped": int(res["n_dropped"].sum()),
            "tokens_served": tokens,
            "fleet_usd": line.total,
            "usd_per_mtok": usd_per_mtok,
        }
        emit(f"serve/{policy}/lat_p99_s", 0.0,
             f"{sched_stats[policy]['lat_p99_s']:.1f}")
        emit(f"serve/{policy}/wait_p95_s", 0.0,
             f"{sched_stats[policy]['wait_p95_s']:.1f}")
        emit(f"serve/{policy}/completed", 0.0,
             str(sched_stats[policy]["n_completed"]))
        emit(f"serve/{policy}/dropped", 0.0,
             str(sched_stats[policy]["n_dropped"]))
        emit(f"serve/{policy}/usd_per_mtok", 0.0, f"{usd_per_mtok:.3f}")
    assert sched_stats["cash"]["n_completed"] > 0, "cash run served nothing"

    # ---- 3) engine throughput vs the Python replay loop -----------------
    bench_cfg = cfg_for("cash", slo_bins=8)
    t_eng, out = _time_best(lambda: servesim.run_batch(batch, bench_cfg))
    assert int(np.asarray(out["n_completed"]).sum()) > 0
    engine_rate = n_ticks * n_reps * n_scen / t_eng

    ora_ticks = 500
    ora_cfg = servesim.ServeSimConfig(
        n_ticks=ora_ticks, scheduler="cash", traffic="poisson",
        kv_slots=KV_SLOTS, table_slots=table, slo_bins=8)
    t_py, _ = _time_best(lambda: ServeFleetOracle(scens[0], ora_cfg).run())
    python_rate = ora_ticks * n_reps / t_py
    speedup = engine_rate / python_rate

    emit("serve/shape", 0.0, f"{n_scen}x{n_reps}x{n_ticks}")
    emit("serve/serve_ticks_reps_scen_per_s", 0.0, f"{engine_rate:.3e}")
    emit("serve/python_ticks_reps_per_s", 0.0, f"{python_rate:.3e}")
    emit("serve/speedup_vs_python_loop", 0.0, f"{speedup:.0f}x")
    if fast:
        ok = speedup >= SPEEDUP_FLOOR
        emit("serve/check/speedup_ge_50x", 0.0, "PASS" if ok else "FAIL")
        assert ok, (f"vectorized serving engine {engine_rate:.3e} "
                    f"tick-replicas/s is only {speedup:.1f}x the Python "
                    f"loop's {python_rate:.3e} (needs >= {SPEEDUP_FLOOR}x)")

    engine_info = {"unroll": bench_cfg.unroll,
                   "fusion": servesim.serve_fusion_choice(bench_cfg)}

    return {
        "mode": "fast" if fast else "full",
        "shape": [n_scen, n_reps, n_ticks],
        "engine": engine_info,
        "kv_slots": KV_SLOTS,
        "table_slots": table,
        "serve_ticks_reps_scen_per_s": engine_rate,
        "python_ticks_reps_per_s": python_rate,
        "speedup_vs_python_loop": speedup,
        "schedulers": sched_stats,
    }


if __name__ == "__main__":
    run(fast=True)
