"""Paper Fig 11 + SS6.6: public-cloud billing savings through CASH.

"Any improvement in end-to-end wall-clock time directly translates to cost
savings of equal valuation" — disk experiments' makespan improvements become
billing savings; the CPU side adds the T3-vs-EMR rate discount."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.cost import BillingLine, hourly_rate, savings_fraction
from repro.core.experiments import DISK_SETUPS, run_disk_pair


def run() -> dict:
    out = {}
    for setup, (n_nodes, db, _) in DISK_SETUPS.items():
        pair = run_disk_pair(setup, seeds=(1, 2, 3))
        stock = BillingLine("stock", "m5.2xlarge", n_nodes,
                            pair["stock"]["makespan"])
        cash = BillingLine("cash", "m5.2xlarge", n_nodes,
                           pair["cash"]["makespan"])
        save = savings_fraction(stock, cash)
        out[setup] = save
        emit(f"fig11/{setup}/stock_cost_usd", 0.0, f"{stock.total:.2f}")
        emit(f"fig11/{setup}/cash_cost_usd", 0.0, f"{cash.total:.2f}")
        emit(f"fig11/{setup}/saving", 0.0, f"{save:+.3f}")
    checks = {
        # savings == makespan improvement (duration-proportional billing)
        "saving_tracks_makespan": all(v >= -0.02 for v in out.values()),
        "20vm_saving_large": 0.15 <= out["20vm"] <= 0.45,
    }
    for k, ok in checks.items():
        emit(f"fig11/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), (checks, out)
    return out


def run_batched(fast: bool = False) -> dict:
    """Billing savings from the vectorized fig9 sweep (the shared
    `repro.sweep` grid — its makespans are computed once and reused here)."""
    from benchmarks import fig9_query_completion

    b = fig9_query_completion.run_batched(fast)
    out = {}
    for setup in b["setups"]:
        n_nodes = DISK_SETUPS[setup][0]
        stock = BillingLine("stock", "m5.2xlarge", n_nodes,
                            b["pair"]["stock"][setup]["makespan"])
        cash = BillingLine("cash", "m5.2xlarge", n_nodes,
                           b["pair"]["cash"][setup]["makespan"])
        out[setup] = savings_fraction(stock, cash)
        emit(f"fig11/batched/{setup}/saving", 0.0, f"{out[setup]:+.3f}")
    return out


if __name__ == "__main__":
    run()
    run_batched()
