"""Paper Fig 10: (a) average total IOPS, (b) stddev of disk burst credits,
CASH vs stock, 10-VM / 1.2 TB experiment.

Claims: CASH shows higher average IOPS (opportunistic placement onto
credit-rich volumes -> I/O peaks) and lower burst-credit stddev (balanced
consumption)."""
from __future__ import annotations

import statistics

from benchmarks.common import emit
from repro.core.experiments import run_disk_experiment


def run() -> dict:
    out = {}
    for sched in ("stock", "cash"):
        # average over seeds like the paper's repeated runs
        iops_all, std_all = [], []
        for seed in (1, 2, 3):
            r = run_disk_experiment("10vm", sched, seed=seed).result
            tl = r.timeline
            busy = [x for x in tl["iops"] if x > 0]
            iops_all.append(statistics.mean(busy) if busy else 0.0)
            half = len(tl["disk_credit_std"]) // 2
            std_all.append(statistics.mean(tl["disk_credit_std"][:half]))
        out[sched] = {"iops": statistics.mean(iops_all),
                      "credit_std": statistics.mean(std_all)}
        emit(f"fig10/{sched}/avg_total_iops", 0.0, f"{out[sched]['iops']:.0f}")
        emit(f"fig10/{sched}/disk_credit_std", 0.0,
             f"{out[sched]['credit_std']:.0f}")
    checks = {
        "cash_higher_avg_iops": out["cash"]["iops"] > out["stock"]["iops"],
        "cash_lower_credit_std":
            out["cash"]["credit_std"] < out["stock"]["credit_std"],
    }
    for k, ok in checks.items():
        emit(f"fig10/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), (checks, out)
    return out


if __name__ == "__main__":
    run()
