"""Paper Fig 7 (+ SS6.3 narrative): cumulative map/shuffle/reduce elapsed time
for EMR / naive-T3 / reordered / T3-unlimited / CASH.

Paper claims validated (as bands; original numbers are live-AWS runs):
  - naive ~ +40% cumulative elapsed vs EMR (we land ~+50%)
  - reordered ~ +19% (we land ~+13%)
  - CASH ~ +13% and <= reordered (we land ~+12%)
  - unlimited ~ CASH elapsed, but bills surplus credits -> worse savings
  - T3 hourly rate is 30.7% below EMR (exact, from Table 2 pricing)
"""
from __future__ import annotations

import statistics

from benchmarks.common import emit, phase_elapsed_from_vec, timed
from repro.core.cost import hourly_rate
from repro.core.experiments import CPU_PHASES, run_cpu_experiment

LABELS = ("emr", "naive", "reordered", "unlimited", "cash")


def run() -> dict:
    res = {}
    for label in LABELS:
        t_us = timed(lambda label=label: res.update(
            {label: run_cpu_experiment(label, n_nodes=10, seed=0)}))
        r = res[label]
        emit(f"fig7/{label}/makespan_s", t_us, f"{r.result.makespan:.0f}")
        for ph in CPU_PHASES:
            emit(f"fig7/{label}/cum_{ph}_s", 0.0, f"{r.cumulative(ph):.0f}")
    emr = res["emr"].cumulative_total()
    out = {}
    for label in LABELS[1:]:
        deg = res[label].cumulative_total() / emr - 1.0
        out[label] = deg
        emit(f"fig7/{label}/cum_degradation_vs_emr", 0.0, f"{deg:+.3f}")
        save = 1.0 - res[label].billing.total / res["emr"].billing.total
        emit(f"fig7/{label}/cost_saving_vs_emr", 0.0, f"{save:+.3f}")
    emit("fig7/t3_vs_emr_hourly_rate_discount", 0.0,
         f"{1 - hourly_rate('t3.2xlarge') / hourly_rate('m5.2xlarge', emr=True):.3f}")

    # validation bands
    checks = {
        "naive_deep_degradation": 0.25 <= out["naive"] <= 0.75,
        "reordered_much_better_than_naive": out["reordered"] < out["naive"] * 0.5,
        "cash_best_or_equal_t3": out["cash"] <= out["reordered"] + 0.005,
        "unlimited_close_to_cash_elapsed": abs(out["unlimited"] - out["cash"]) < 0.05,
        "unlimited_bills_surplus": res["unlimited"].billing.surplus_cost > 0,
        "cash_saves_more_than_unlimited":
            res["cash"].billing.total < res["unlimited"].billing.total,
    }
    for k, ok in checks.items():
        emit(f"fig7/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), checks
    return out


_CPU_BATCH_CACHE: dict = {}


def run_cpu_sweep_batched(fast: bool = False) -> dict:
    """Vectorized sweep over the Fig-7 labels, expressed as a `repro.sweep`
    grid: one "label" scenario axis whose `configure` hook routes the four
    stock-scheduled fleets (emr / naive / reordered / unlimited) into ONE
    compile group and cash into another. Runs with per-tick timeline
    emission (`sample_period=10`, the Python simulator's default) so fig8's
    batched path gets its credit/utilization series from the same run.
    Deterministic node order (shuffle="none"), so numbers track — not
    bit-match — the Python path. Cached: fig8 reuses the same sweep."""
    import time

    from repro import sweep
    from repro.core import vecsim
    from repro.core.experiments import build_cpu_vec_scenario

    if fast in _CPU_BATCH_CACHE:
        return _CPU_BATCH_CACHE[fast]
    n_nodes, scale = (6, 0.4) if fast else (10, 1.0)
    n_ticks = 9_000 if fast else 18_000
    t0 = time.time()

    jobs_of: dict = {}

    def builder(label):
        scenario, _, jobs = build_cpu_vec_scenario(label, n_nodes=n_nodes,
                                                   scale=scale)
        jobs_of[label] = jobs
        return scenario

    spec = sweep.SweepSpec(
        builder,
        axes={"label": LABELS},
        base=vecsim.VecSimConfig(n_ticks=n_ticks, sample_period=10.0),
        configure=lambda c: {
            "scheduler": "cash" if c["label"] == "cash" else "stock"},
    )
    result = sweep.run_sweep(spec)
    res = {p.coord_dict["label"]: result.point_outputs(p.index)
           for p in result.points}
    out = {"res": res, "jobs": jobs_of, "n_nodes": n_nodes,
           "wall": time.time() - t0, "result": result}
    _CPU_BATCH_CACHE[fast] = out
    return out


def run_batched(fast: bool = False) -> dict:
    """Fig-7 metrics (cumulative phase elapsed, degradation vs EMR) from the
    shared vectorized CPU sweep."""
    from repro.core import vecsim

    sweep = run_cpu_sweep_batched(fast)
    res, jobs_of, wall = sweep["res"], sweep["jobs"], sweep["wall"]

    cums = {}
    for label in LABELS:
        r = res[label]
        assert bool(r["all_done"]), (label, "did not finish in n_ticks")
        order = vecsim.scenario_task_order(jobs_of[label], "sequential")
        ph = phase_elapsed_from_vec(order, r["start"], r["finish"])
        cums[label] = sum(ph.get(p, 0.0) for p in CPU_PHASES)
        emit(f"fig7/batched/{label}/makespan_s", 0.0,
             f"{float(r['makespan']):.0f}")
        for p in CPU_PHASES:
            emit(f"fig7/batched/{label}/cum_{p}_s", 0.0, f"{ph.get(p, 0):.0f}")
    out_deg = {}
    for label in LABELS[1:]:
        out_deg[label] = cums[label] / cums["emr"] - 1.0
        emit(f"fig7/batched/{label}/cum_degradation_vs_emr", 0.0,
             f"{out_deg[label]:+.3f}")
    emit("fig7/batched/sweep_wall_s", wall * 1e6, f"{wall:.1f}")
    return out_deg


if __name__ == "__main__":
    run()
    run_batched()
