"""Vectorized fleet-simulator throughput: `core.vecsim` (jitted lax.scan,
vmapped over scenarios) vs looping the pure-Python `Simulation`.

Reference sweep (ISSUE 3 acceptance): 32 scenarios x 16 nodes x 10k ticks on
CPU, target >= 50x. The Python side is timed on one full scenario and
extrapolated linearly to the sweep (it has no cross-scenario batching to
amortize — one scenario already takes ~8 s); the vectorized side is timed
end-to-end on the whole stacked batch, steady-state (post-compile).

Figure of merit: ticks * nodes * scenarios / second.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.scheduler import CashScheduler
from repro.core.simulator import Job, SimConfig, Simulation
from repro.core import vecsim

SLOTS = 8


def _sweep_jobs(seed: int, n_nodes: int):
    """CPU-burst fleet near saturation: every tick schedules and serves."""
    rng = np.random.RandomState(seed)
    tid = [100_000 * (seed + 1)]

    def nt(**kw):
        tid[0] += 1
        return Task(tid=tid[0], job=kw.pop("job"), **kw)

    jobs = []
    for j in range(4):
        maps = [nt(job=f"j{j}", vertex="map",
                   work_cpu=float(rng.uniform(800, 2400)),
                   demand_cpu=float(rng.uniform(0.3, 0.95)),
                   annotation=Annotation.BURST_CPU)
                for _ in range(n_nodes * SLOTS // 2)]
        jobs.append(Job(name=f"j{j}", tasks=maps))
    return jobs


def _nodes(n_nodes: int):
    return make_cluster(n_nodes, "t3.2xlarge", slots_per_node=SLOTS,
                        cpu_initial_fraction=0.2)


def run(fast: bool = False) -> dict:
    n_scen, n_nodes, n_ticks = (8, 8, 1_000) if fast else (32, 16, 10_000)
    py_ticks = 300 if fast else 2_000     # Python sample, extrapolated

    # --- Python loop (one scenario, capped ticks, extrapolated) ----------
    sim = Simulation(_nodes(n_nodes), CashScheduler(vecsim.IdentityRng()),
                     SimConfig(max_time=float(py_ticks)))
    sim.submit_parallel(_sweep_jobs(0, n_nodes))
    t0 = time.perf_counter()
    r = sim.run()
    t_py = time.perf_counter() - t0
    ticks_run = max(int(r.makespan), 1)
    t_py_sweep = t_py / ticks_run * n_ticks * n_scen
    py_rate = ticks_run * n_nodes / t_py

    # --- vectorized batch ------------------------------------------------
    scenarios = []
    for s in range(n_scen):
        scenarios.append(vecsim.build_scenario(_nodes(n_nodes),
                                               _sweep_jobs(s, n_nodes)))
    batch = vecsim.stack_scenarios(scenarios)
    cfg = vecsim.VecSimConfig(n_ticks=n_ticks, scheduler="cash", impl="xla")
    t0 = time.perf_counter()
    vecsim.run_batch(batch, cfg)
    t_cold = time.perf_counter() - t0     # includes jit compile
    t0 = time.perf_counter()
    out = vecsim.run_batch(batch, cfg)
    t_vec = time.perf_counter() - t0
    vec_rate = n_ticks * n_nodes * n_scen / t_vec
    speedup = t_py_sweep / t_vec

    emit("vecsim/sweep_shape", 0.0, f"{n_scen}x{n_nodes}x{n_ticks}")
    emit("vecsim/python_ticks_nodes_per_s", t_py / ticks_run * 1e6,
         f"{py_rate:.3e}")
    emit("vecsim/python_sweep_est_s", 0.0, f"{t_py_sweep:.1f}")
    emit("vecsim/vec_compile_s", t_cold * 1e6, f"{t_cold:.2f}")
    emit("vecsim/vec_sweep_s", t_vec * 1e6, f"{t_vec:.2f}")
    emit("vecsim/vec_ticks_nodes_scen_per_s", 0.0, f"{vec_rate:.3e}")
    emit("vecsim/speedup_vs_python_loop", 0.0, f"{speedup:.1f}x")
    if not fast:
        check = speedup >= 50.0
        emit("vecsim/check/speedup_ge_50x", 0.0, "PASS" if check else "FAIL")
        assert check, f"vectorized speedup {speedup:.1f}x < 50x"
    return {
        "sweep": [n_scen, n_nodes, n_ticks],
        "python_est_sweep_s": t_py_sweep,
        "vec_sweep_s": t_vec,
        "vec_compile_s": t_cold,
        "python_ticks_nodes_per_s": py_rate,
        "vec_ticks_nodes_scen_per_s": vec_rate,
        "speedup": speedup,
        "all_done": bool(np.asarray(out["all_done"]).all()),
    }


if __name__ == "__main__":
    run()
