"""Vectorized fleet-simulator throughput: `core.vecsim` via the
`repro.sweep` runner (jitted lax.scan, vmapped over scenarios, optionally
sharded across devices) vs looping the pure-Python `Simulation`.

Reference sweep (ISSUE 3 acceptance): 32 scenarios x 16 nodes x 10k ticks on
CPU, target >= 50x. The Python side is timed on one full scenario and
extrapolated linearly to the sweep (it has no cross-scenario batching to
amortize — one scenario already takes ~8 s); the vectorized side is timed
end-to-end on the whole stacked batch, steady-state (post-compile).

Both modes are sized so the reference workload *finishes* inside the tick
budget, and `all_done` is a hard benchmark error, not a silently-false
field. When >1 local devices are available (CI forces two with
``XLA_FLAGS=--xla_force_host_platform_device_count=2``) a sharded-sweep
throughput entry is measured through `sweep.run_sweep(shards=D)` and its
per-scenario results are asserted bitwise-equal to the single-device vmap
path.

Figure of merit: ticks * nodes * scenarios / second.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.annotations import Annotation, Task
from repro.core.cluster import make_cluster
from repro.core.scheduler import CashScheduler
from repro.core.simulator import Job, SimConfig, Simulation
from repro.core import vecsim
from repro import sweep as sweeplib

SLOTS = 8


def _sweep_jobs(seed: int, n_nodes: int, scale: float = 1.0):
    """CPU-burst fleet near saturation: every tick schedules and serves.
    ``scale`` sizes per-task work so the sweep drains within its tick
    budget (fast mode shrinks ticks 10x but keeps the fleet saturated)."""
    rng = np.random.RandomState(seed)
    tid = [100_000 * (seed + 1)]

    def nt(**kw):
        tid[0] += 1
        return Task(tid=tid[0], job=kw.pop("job"), **kw)

    jobs = []
    for j in range(4):
        maps = [nt(job=f"j{j}", vertex="map",
                   work_cpu=float(rng.uniform(800, 2400)) * scale,
                   demand_cpu=float(rng.uniform(0.3, 0.95)),
                   annotation=Annotation.BURST_CPU)
                for _ in range(n_nodes * SLOTS // 2)]
        jobs.append(Job(name=f"j{j}", tasks=maps))
    return jobs


def _nodes(n_nodes: int):
    return make_cluster(n_nodes, "t3.2xlarge", slots_per_node=SLOTS,
                        cpu_initial_fraction=0.2)


def run(fast: bool = False) -> dict:
    # scale sizes per-task work so every scenario drains inside the tick
    # budget (full: max makespan ~8.5k of 10k; fast: ~0.8k of 1k) — the
    # previous full-scale sweep silently truncated at 10k ticks
    n_scen, n_nodes, n_ticks = (8, 8, 1_000) if fast else (32, 16, 10_000)
    scale = 0.08 if fast else 0.75
    py_ticks = 300 if fast else 2_000     # Python sample, extrapolated

    # --- Python loop (one scenario, capped ticks, extrapolated) ----------
    sim = Simulation(_nodes(n_nodes), CashScheduler(vecsim.IdentityRng()),
                     SimConfig(max_time=float(py_ticks)))
    sim.submit_parallel(_sweep_jobs(0, n_nodes, scale))
    t0 = time.perf_counter()
    r = sim.run()
    t_py = time.perf_counter() - t0
    ticks_run = max(int(r.makespan), 1)
    t_py_sweep = t_py / ticks_run * n_ticks * n_scen
    py_rate = ticks_run * n_nodes / t_py

    # --- vectorized sweep (repro.sweep runner on a pre-stacked batch) ----
    # scenario building/stacking happens once up front (like the Python
    # side's workload setup); the timed region is the engine dispatch,
    # best-of-3 to shed first-call allocator noise
    def _timed(shards: int):
        sweeplib.run_group(batch, cfg, shards=shards)       # warm/compile
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            out = sweeplib.run_group(batch, cfg, shards=shards)
            times.append(time.perf_counter() - t0)
        return min(times), out

    scenarios = [vecsim.build_scenario(_nodes(n_nodes),
                                       _sweep_jobs(s, n_nodes, scale))
                 for s in range(n_scen)]
    batch = vecsim.stack_scenarios(scenarios)
    # unroll=4: k tick bodies per scan step (bitwise-identical to k=1;
    # pays off under the legacy CPU runtime benchmarks/run.py selects).
    # fusion="auto" resolves per backend — the whole-tick megakernel wins
    # on TPU, the unfused packed-cumsum tick wins on CPU (measured).
    cfg = vecsim.VecSimConfig(n_ticks=n_ticks, scheduler="cash", impl="xla",
                              unroll=4)
    active = vecsim.batch_statics(batch)[3]
    engine_info = {"unroll": cfg.unroll,
                   "fusion": vecsim.fusion_choice(cfg, active),
                   "pipelined": sweeplib.RunnerOptions().pipeline}
    emit("vecsim/engine", 0.0,
         f"unroll={engine_info['unroll']} fusion={engine_info['fusion']} "
         f"pipelined={engine_info['pipelined']}")
    t0 = time.perf_counter()
    sweeplib.run_group(batch, cfg, shards=1)
    t_cold = time.perf_counter() - t0     # includes jit compile
    t_vec, res = _timed(1)
    vec_rate = n_ticks * n_nodes * n_scen / t_vec
    speedup = t_py_sweep / t_vec

    emit("vecsim/sweep_shape", 0.0, f"{n_scen}x{n_nodes}x{n_ticks}")
    emit("vecsim/python_ticks_nodes_per_s", t_py / ticks_run * 1e6,
         f"{py_rate:.3e}")
    emit("vecsim/python_sweep_est_s", 0.0, f"{t_py_sweep:.1f}")
    emit("vecsim/vec_compile_s", t_cold * 1e6, f"{t_cold:.2f}")
    emit("vecsim/vec_sweep_s", t_vec * 1e6, f"{t_vec:.2f}")
    emit("vecsim/vec_ticks_nodes_scen_per_s", 0.0, f"{vec_rate:.3e}")
    emit("vecsim/speedup_vs_python_loop", 0.0, f"{speedup:.1f}x")
    if not fast:
        check = speedup >= 50.0
        emit("vecsim/check/speedup_ge_50x", 0.0, "PASS" if check else "FAIL")
        assert check, f"vectorized speedup {speedup:.1f}x < 50x"

    # the reference sweep must drain inside its tick budget — a truncated
    # run would silently misreport throughput of unfinished work
    all_done = bool(res["all_done"].all())
    emit("vecsim/check/all_done", 0.0, "PASS" if all_done else "FAIL")
    assert all_done, ("reference sweep did not finish within "
                      f"{n_ticks} ticks — resize the scenario")

    stats = {
        "sweep": [n_scen, n_nodes, n_ticks],
        # measurement environment: run.py forces 2 host-platform devices
        # before JAX init, so single-device numbers are taken on a split
        # CPU — comparable only against entries with the same device count
        "local_devices": sweeplib.device_count(),
        "python_est_sweep_s": t_py_sweep,
        "vec_sweep_s": t_vec,
        "vec_compile_s": t_cold,
        "python_ticks_nodes_per_s": py_rate,
        "vec_ticks_nodes_scen_per_s": vec_rate,
        "speedup": speedup,
        "all_done": all_done,
        "engine": engine_info,   # lifted into meta by benchmarks/run.py
    }

    # --- sharded sweep (scenario axis across local devices) --------------
    n_dev = sweeplib.device_count()
    if n_dev > 1:
        t_sh, res_sh = _timed(n_dev)
        sh_rate = n_ticks * n_nodes * n_scen / t_sh
        bitwise = all(
            np.array_equal(res[k], res_sh[k])
            for k in ("makespan", "surplus_credits", "total_cpu_work",
                      "finish"))
        emit(f"vecsim/sharded{n_dev}/vec_sweep_s", t_sh * 1e6, f"{t_sh:.2f}")
        emit(f"vecsim/sharded{n_dev}/ticks_nodes_scen_per_s", 0.0,
             f"{sh_rate:.3e}")
        emit(f"vecsim/sharded{n_dev}/bitwise_equal_vmap", 0.0,
             "PASS" if bitwise else "FAIL")
        assert bitwise, "sharded sweep diverged from the vmap path"
        stats["sharded"] = {
            "shards": n_dev,
            "vec_sweep_s": t_sh,
            "ticks_nodes_scen_per_s": sh_rate,
            "bitwise_equal_vmap": bitwise,
        }
    return stats


if __name__ == "__main__":
    run()
