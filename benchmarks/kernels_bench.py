"""Kernel-layer micro-benchmarks (CPU wall-clock of the XLA reference path;
TPU perf is assessed structurally via the roofline — see DESIGN.md).

Besides the attention/SSD kernels, this covers the simulator's tick
kernels: `ops.bucket_serve_distribute` (token-bucket serve + pro-rata
distribution) and the whole-tick megakernel `ops.megatick`, the latter
timed against an honest 4-dispatch unfused pipeline (telemetry estimate,
placement, serve, observe) at several pool shapes. Read the speedup
column carefully: standalone the fused kernel wins (one dispatch vs
four — dispatch overhead dominates at these sizes), but INSIDE the
jitted tick scan, where XLA already fuses the unfused phases and no
per-phase dispatch exists, the megakernel's (T, N) interval matrix
loses to the packed cumsum on CPU (see ``tick_phases`` in
BENCH_vecsim.json) — which is why ``VecSimConfig.fusion="auto"`` keeps
the unfused tick there and fuses on TPU. A k-unroll section times the
full engine at unroll 1/2/4 (the unroll win needs the legacy CPU
runtime flag benchmarks/run.py sets; standalone this module may show
parity).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import megatick as mk
from repro.kernels import ops

# (tasks, nodes) pool shapes: a small fleet tick, the full-bench fleet
# tick, and a traffic-table-sized one
POOL_SHAPES = ((64, 8), (512, 16), (4096, 32))
DT, ACTUAL_PERIOD, USAGE_PERIOD = 1.0, 60.0, 300.0


def _pool_inputs(key, t: int, n: int):
    """Synthetic mid-scan tick state: ~half the tasks pending placement,
    the rest already running on a node; credit balances mid-range."""
    ks = jax.random.split(key, 6)
    m_pend = jax.random.uniform(ks[0], (t,)) < 0.5
    node_prev = jnp.where(
        m_pend, -1, jax.random.randint(ks[1], (t,), 0, n, jnp.int32))
    dem_task = jax.random.uniform(ks[2], (t,), minval=0.1, maxval=0.95)
    live = jnp.ones((t,), bool)
    balance = jax.random.uniform(ks[3], (n,), minval=0.0, maxval=200.0)
    baseline = jnp.full((n,), 0.4)
    burst = jnp.full((n,), 8.0)
    capacity = jnp.full((n,), 576.0)
    unlimited = jnp.zeros((n,))
    free = jax.random.randint(ks[4], (n,), 0, 9, jnp.int32)
    from repro.core import vecsim

    tel = vecsim._fresh_telemetry(n, balance.dtype)
    return (m_pend, node_prev, dem_task, live, balance, baseline, burst,
            capacity, unlimited, free, tel)


def _unfused_tick(t: int, n: int):
    """The unfused comparator: the same estimate -> placement -> serve ->
    observe tick as FOUR separate jitted dispatches (the phase structure
    `core.vecsim` uses when ``fusion="unfused"``)."""
    from repro.core import vecsim

    est = jax.jit(lambda tel, bal, base, cap, now: mk.telemetry_estimate(
        tel, bal, base, cap, now, "predicted"))

    @jax.jit
    def place(credits, m_pend, free):
        order, _ = vecsim._node_orders(credits)
        (r,) = vecsim._packed_ranks(m_pend)
        n_all = r[-1] + 1
        cum, taken = vecsim._pack_counts(order, free, n_all)
        assign = vecsim._gather_phase_nodes(
            [vecsim._pack_table(order, cum, t)], [cum[-1]], [m_pend], [r], t)
        return assign, taken

    @jax.jit
    def serve(assign, node_prev, live, dem_task, balance, baseline, burst,
              capacity, unlimited):
        nidx = jnp.where(assign >= 0, assign, node_prev)
        ids = jnp.arange(n, dtype=jnp.int32)
        hot = (nidx[None, :] == ids[:, None]) & live[None, :]
        demand = hot.astype(dem_task.dtype) @ dem_task
        return ops.bucket_serve_distribute(
            balance, demand, baseline, burst, capacity, unlimited, nidx,
            dem_task, dt=DT, impl="xla")

    observe = jax.jit(lambda tel, bal, rate, now: mk.telemetry_observe(
        tel, bal, rate, now, actual_period=ACTUAL_PERIOD,
        usage_period=USAGE_PERIOD))

    def tick(inputs, now):
        (m_pend, node_prev, dem_task, live, balance, baseline, burst,
         capacity, unlimited, free, tel) = inputs
        credits = est(tel, balance, baseline, capacity, now)
        assign, taken = place(credits, m_pend, free)
        share, work, new_bal, sur = serve(
            assign, node_prev, live, dem_task, balance, baseline, burst,
            capacity, unlimited)
        new_tel = observe(tel, new_bal, work / DT, now)
        return share, new_bal, new_tel

    return tick


def _bench_tick_kernels() -> None:
    """bucket_serve_distribute + megatick vs the unfused 4-dispatch tick,
    per pool shape."""
    for t, n in POOL_SHAPES:
        key = jax.random.PRNGKey(t + n)
        inputs = _pool_inputs(key, t, n)
        (m_pend, node_prev, dem_task, live, balance, baseline, burst,
         capacity, unlimited, free, tel) = inputs
        now = jnp.asarray(37.0, balance.dtype)

        # -- serve kernel alone ------------------------------------------
        nidx = jnp.where(m_pend, jnp.int32(0), node_prev)
        demand = jnp.bincount(jnp.clip(nidx, 0, n - 1), dem_task, length=n)
        sfn = lambda: ops.bucket_serve_distribute_jit(   # noqa: E731
            balance, demand, baseline, burst, capacity, unlimited, nidx,
            dem_task, dt=DT, impl="xla")
        jax.block_until_ready(sfn())
        us = timed(lambda: jax.block_until_ready(sfn()), n=5)
        emit(f"kernels/bucket_serve_{t}x{n}", us,
             f"{t / (us * 1e-6) / 1e6:.1f}Mtask/s")

        # -- whole-tick megakernel vs unfused 4-dispatch tick ------------
        mfn = jax.jit(lambda inp, now: ops.megatick(
            inp[0], jnp.zeros(t, jnp.int32), jnp.int32(0), inp[1],
            jnp.ones(t, bool), inp[2], inp[3], inp[4], inp[5], inp[6],
            inp[7], inp[8], inp[9], inp[10], now, dt=DT,
            actual_period=ACTUAL_PERIOD, usage_period=USAGE_PERIOD,
            tel_mode="predicted", by_credit=True, carried_rank=False,
            impl="xla"))
        jax.block_until_ready(mfn(inputs, now))
        us_f = timed(lambda: jax.block_until_ready(mfn(inputs, now)), n=5)

        unf = _unfused_tick(t, n)
        jax.block_until_ready(unf(inputs, now))
        us_u = timed(lambda: jax.block_until_ready(unf(inputs, now)), n=5)

        emit(f"kernels/megatick_fused_{t}x{n}", us_f,
             f"{t / (us_f * 1e-6) / 1e6:.1f}Mtask/s")
        emit(f"kernels/megatick_unfused_{t}x{n}", us_u,
             f"{t / (us_u * 1e-6) / 1e6:.1f}Mtask/s")
        emit(f"kernels/megatick_speedup_{t}x{n}", 0.0,
             f"{us_u / us_f:.2f}x")


def _bench_engine_unroll() -> None:
    """Full engine throughput at k ticks unrolled per scan step (the
    engine-level lever `benchmarks/run.py` ships at unroll=4 together with
    the legacy CPU runtime flag — see `_tune_xla_flags` there)."""
    from benchmarks import vecsim_bench as vb
    from repro import sweep as sweeplib
    from repro.core import vecsim

    n_scen, n_nodes, n_ticks = 4, 8, 500
    scen = [vecsim.build_scenario(vb._nodes(n_nodes),
                                  vb._sweep_jobs(s, n_nodes, 0.04))
            for s in range(n_scen)]
    batch = vecsim.stack_scenarios(scen)
    for k in (1, 2, 4):
        cfg = vecsim.VecSimConfig(n_ticks=n_ticks, scheduler="cash",
                                  impl="xla", unroll=k)
        sweeplib.run_group(batch, cfg, shards=1)        # warm/compile
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            sweeplib.run_group(batch, cfg, shards=1)
            best = min(best, time.perf_counter() - t0)
        rate = n_ticks * n_nodes * n_scen / best
        emit(f"kernels/engine_unroll{k}", best * 1e6, f"{rate:.3e}")


def run() -> None:
    key = jax.random.PRNGKey(0)
    # flash attention (xla path) at a train-like shape
    b, hq, hkv, s, d = 1, 8, 2, 2048, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="xla"))
    f(q, k, v).block_until_ready()
    us = timed(lambda: f(q, k, v).block_until_ready(), n=3)
    flops = 4 * b * hq * s * s * d
    emit("kernels/flash_xla_2k", us, f"{flops / (us * 1e-6) / 1e9:.1f}GFLOP/s")

    # decode attention over a 32k cache
    s_max = 32768
    q1 = jax.random.normal(ks[0], (4, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (4, hkv, s_max, d), jnp.float32)
    vc = jax.random.normal(ks[2], (4, hkv, s_max, d), jnp.float32)
    lengths = jnp.full((4,), s_max, jnp.int32)
    g = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l, impl="xla"))
    g(q1, kc, vc, lengths).block_until_ready()
    us = timed(lambda: g(q1, kc, vc, lengths).block_until_ready(), n=3)
    bytes_ = 2 * 4 * hkv * s_max * d * 4
    emit("kernels/decode_xla_32k", us, f"{bytes_ / (us * 1e-6) / 1e9:.1f}GB/s")

    # ssd scan
    b2, l2, h2, p2, n2 = 2, 2048, 8, 64, 64
    x = jax.random.normal(ks[0], (b2, l2, h2, p2)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b2, l2, h2))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h2,)))
    Bm = jax.random.normal(ks[0], (b2, l2, n2)) * 0.3
    Cm = jax.random.normal(ks[1], (b2, l2, n2)) * 0.3
    h = jax.jit(lambda *a: ops.ssd(*a, chunk=256, impl="xla"))
    h(x, dt, A, Bm, Cm).block_until_ready()
    us = timed(lambda: h(x, dt, A, Bm, Cm).block_until_ready(), n=3)
    emit("kernels/ssd_xla_2k", us, f"{b2 * l2 / (us * 1e-6) / 1e6:.2f}Mtok/s")

    # simulator tick kernels + engine unroll variants
    _bench_tick_kernels()
    _bench_engine_unroll()


if __name__ == "__main__":
    run()
