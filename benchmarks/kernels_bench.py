"""Kernel-layer micro-benchmarks (CPU wall-clock of the XLA reference path;
TPU perf is assessed structurally via the roofline — see DESIGN.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import ops


def run() -> None:
    key = jax.random.PRNGKey(0)
    # flash attention (xla path) at a train-like shape
    b, hq, hkv, s, d = 1, 8, 2, 2048, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="xla"))
    f(q, k, v).block_until_ready()
    us = timed(lambda: f(q, k, v).block_until_ready(), n=3)
    flops = 4 * b * hq * s * s * d
    emit("kernels/flash_xla_2k", us, f"{flops / (us * 1e-6) / 1e9:.1f}GFLOP/s")

    # decode attention over a 32k cache
    s_max = 32768
    q1 = jax.random.normal(ks[0], (4, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (4, hkv, s_max, d), jnp.float32)
    vc = jax.random.normal(ks[2], (4, hkv, s_max, d), jnp.float32)
    lengths = jnp.full((4,), s_max, jnp.int32)
    g = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l, impl="xla"))
    g(q1, kc, vc, lengths).block_until_ready()
    us = timed(lambda: g(q1, kc, vc, lengths).block_until_ready(), n=3)
    bytes_ = 2 * 4 * hkv * s_max * d * 4
    emit("kernels/decode_xla_32k", us, f"{bytes_ / (us * 1e-6) / 1e9:.1f}GB/s")

    # ssd scan
    b2, l2, h2, p2, n2 = 2, 2048, 8, 64, 64
    x = jax.random.normal(ks[0], (b2, l2, h2, p2)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b2, l2, h2))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h2,)))
    Bm = jax.random.normal(ks[0], (b2, l2, n2)) * 0.3
    Cm = jax.random.normal(ks[1], (b2, l2, n2)) * 0.3
    h = jax.jit(lambda *a: ops.ssd(*a, chunk=256, impl="xla"))
    h(x, dt, A, Bm, Cm).block_until_ready()
    us = timed(lambda: h(x, dt, A, Bm, Cm).block_until_ready(), n=3)
    emit("kernels/ssd_xla_2k", us, f"{b2 * l2 / (us * 1e-6) / 1e6:.2f}Mtok/s")


if __name__ == "__main__":
    run()
