"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md SS Roofline)
plus the vecsim tick-phase breakdown (`vecsim_phases`).

Reads results/dryrun/*.json (written by repro.launch.dryrun), prints the
per-(arch x shape) three-term table for the single-pod mesh, and flags the
dominant bottleneck per cell. Run the sweep first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

`vecsim_phases` measures where the fleet simulator's tick actually spends
its time — placement / serve / telemetry (closed loop) and the streaming
SLO histogram (open loop), for the unfused engine and the whole-tick
megakernel — by **stub ablation**: re-jit the SAME engine with one phase's
functions replaced by shape/dtype-correct constant stubs and attribute the
wall-clock delta to that phase. Results feed ``BENCH_vecsim.json``
(``tick_phases``) via benchmarks/run.py. The numbers are estimates, not
exact: removing a phase also removes whatever XLA fused around it, so a
phase's cost includes its share of neighboring fusion clusters.
"""
from __future__ import annotations

import contextlib
import glob
import json
import time
from pathlib import Path
from typing import Dict, List

from benchmarks.common import emit

RESULTS = Path("results/dryrun")


def load(mesh: str = "pod16x16") -> List[dict]:
    recs = []
    for fn in sorted(glob.glob(str(RESULTS / f"*__{mesh}.json"))):
        recs.append(json.loads(Path(fn).read_text()))
    return recs


# --------------------------------------------------------------------------
# vecsim tick-phase breakdown (stub ablation)
# --------------------------------------------------------------------------

@contextlib.contextmanager
def _patched(obj, name, repl):
    """Temporarily replace ``obj.name`` (module-level function) so a fresh
    jit trace picks up the stub. The engine resolves these names through
    module globals at trace time, so patch + re-jit is a clean ablation."""
    orig = getattr(obj, name)
    setattr(obj, name, repl)
    try:
        yield
    finally:
        setattr(obj, name, orig)


def _time_engine_ms(cfg, statics, arrays, patches=(), reps: int = 3) -> float:
    """Best-of-``reps`` steady-state wall time (ms) of a FRESH jit of
    `vecsim.batched_engine` with ``patches`` active during trace. A fresh
    `jax.jit` (not `vecsim._run_batch_jit`) bypasses the engine's lru
    cache, which would otherwise hand back the unpatched executable."""
    import jax

    from repro.core import vecsim

    with contextlib.ExitStack() as es:
        for obj, name, repl in patches:
            es.enter_context(_patched(obj, name, repl))
        fn = jax.jit(vecsim.batched_engine(cfg, *statics))
        jax.block_until_ready(fn(arrays))           # warm/compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(arrays))
            best = min(best, time.perf_counter() - t0)
    return best * 1e3


def vecsim_phases(fast: bool = True) -> Dict[str, dict]:
    """Where the simulated tick spends its time, by stub ablation.

    Re-jits the SAME engine with one phase's functions replaced by
    shape/dtype-correct constant stubs; the phase's cost is the wall-clock
    delta vs the intact engine (floored at 0 — XLA re-fuses around the
    hole, so small phases can vanish into neighboring clusters). Three
    engines are profiled:

    * ``unfused``  — closed-loop, packed-cumsum tick: placement / serve /
      telemetry / other (residual).
    * ``fused``    — closed-loop with ``fusion="fused"`` (ops.megatick):
      the whole-tick megakernel as one ablatable unit.
    * ``traffic``  — open-loop ring-buffer tick: the streaming SLO
      histogram's share.

    Estimates, not exact microbenchmarks — see the module docstring.
    """
    import jax.numpy as jnp

    from benchmarks import traffic_bench as tb
    from benchmarks import vecsim_bench as vb
    from repro.core import vecsim
    from repro.kernels import ops
    from repro.traffic import arrivals

    n_scen, n_nodes, n_ticks = (8, 8, 1_000) if fast else (16, 16, 2_500)
    scale = 0.08 if fast else 0.75

    # ---- workloads (the bench builders' saturation shapes) ---------------
    closed = [vecsim.build_scenario(vb._nodes(n_nodes),
                                    vb._sweep_jobs(s, n_nodes, scale))
              for s in range(n_scen)]
    stacked = vecsim.stack_scenarios(closed)
    statics = vecsim.batch_statics(stacked)
    batch = vecsim.batch_arrays(stacked)
    # unroll=1: phase *proportions* are what this measures, and 8 fresh jit
    # traces at unroll=4 would quadruple compile time for no extra signal
    cfg = vecsim.VecSimConfig(n_ticks=n_ticks, scheduler="cash", impl="xla")

    tmpl = arrivals.make_template(8, seed=0, work=(60.0, 240.0),
                                  burst_fraction=1.0)
    rate = n_nodes * vb.SLOTS / 300.0
    traffic = [arrivals.build_traffic_scenario(tb._fleet(n_nodes, 0.2), tmpl,
                                               mode="poisson", rate=rate,
                                               rng_seed=s)
               for s in range(n_scen)]
    tstacked = vecsim.stack_scenarios(traffic)
    tstatics = vecsim.batch_statics(tstacked)
    tbatch = vecsim.batch_arrays(tstacked)
    tcfg = vecsim.VecSimConfig(n_ticks=n_ticks, dt=5.0, scheduler="cash",
                               traffic="poisson",
                               table_slots=n_nodes * vb.SLOTS,
                               slo_bins=8, impl="xla")

    # ---- phase stubs (shape/dtype-correct constants) ---------------------
    def stub_orders(kv):
        ids = jnp.arange(kv.shape[0], dtype=jnp.int32)
        return ids, ids

    placement = [
        (vecsim, "_node_orders", stub_orders),
        (vecsim, "_pack_counts",
         lambda order_ids, free, n_pend: (jnp.zeros_like(free),
                                          jnp.zeros_like(free))),
        (vecsim, "_pack_table",
         lambda order_ids, cum, ls: jnp.zeros((ls,), jnp.int32)),
        (vecsim, "_packed_ranks",
         lambda *masks: [jnp.zeros(m.shape, jnp.int32) for m in masks]),
        (vecsim, "_gather_phase_nodes",
         lambda tables, totals, masks, ranks, ls:
             jnp.full(masks[0].shape, -1, jnp.int32)),
    ]

    def stub_serve(balance, demand, baseline, burst, capacity, unlimited,
                   nidx, dem_task, *, dt, impl="auto", dist_demand=None):
        return (jnp.zeros_like(dem_task), jnp.zeros_like(balance),
                balance, jnp.zeros_like(balance))

    serve = [(ops, "bucket_serve_distribute", stub_serve)]

    telemetry = [
        (vecsim, "_telemetry_estimate",
         lambda cfg_, tel, balance, baseline, capacity, now, mode: capacity),
        (vecsim, "_telemetry_observe",
         lambda cfg_, tel, balance, rate_, now: tel),
    ]

    def stub_megatick(m_pend, rank, n_pend, node_prev, alive, dem_task,
                      live, balance, baseline, burst, capacity, unlimited,
                      free, tel, now, **kw):
        t = m_pend.shape[0]
        return (jnp.full((t,), -1, jnp.int32), jnp.zeros_like(free),
                jnp.zeros((t,), balance.dtype),
                jnp.zeros((t,), balance.dtype),
                balance, jnp.zeros_like(balance), tel)

    megatick = [(ops, "megatick", stub_megatick)]

    def stub_hist(edges, nfin, fin_now, now, tb_start, tb_submit):
        b = edges.shape[0] - 1
        return (jnp.zeros((2 * b,), jnp.int32),
                jnp.zeros((2,), tb_submit.dtype),
                jnp.zeros((2,), tb_submit.dtype))

    histogram = [(vecsim, "_slo_hist_update", stub_hist)]

    # ---- measure ---------------------------------------------------------
    import dataclasses

    t_unf = _time_engine_ms(cfg, statics, batch)
    t_no_place = _time_engine_ms(cfg, statics, batch, placement)
    t_no_serve = _time_engine_ms(cfg, statics, batch, serve)
    t_no_tel = _time_engine_ms(cfg, statics, batch, telemetry)

    fcfg = dataclasses.replace(cfg, fusion="fused")
    t_fused = _time_engine_ms(fcfg, statics, batch)
    t_no_mk = _time_engine_ms(fcfg, statics, batch, megatick)

    t_tr = _time_engine_ms(tcfg, tstatics, tbatch)
    t_no_hist = _time_engine_ms(tcfg, tstatics, tbatch, histogram)

    amt = lambda full, ablated: max(0.0, full - ablated)    # noqa: E731
    place_ms = amt(t_unf, t_no_place)
    serve_ms = amt(t_unf, t_no_serve)
    tel_ms = amt(t_unf, t_no_tel)
    out = {
        "shape": [n_scen, n_nodes, n_ticks],
        "method": "stub-ablation estimate (re-jit with phase stubbed)",
        "unfused": {
            "total_ms": t_unf,
            "placement_ms": place_ms,
            "serve_ms": serve_ms,
            "telemetry_ms": tel_ms,
            "other_ms": max(0.0, t_unf - place_ms - serve_ms - tel_ms),
        },
        "fused": {"total_ms": t_fused, "megatick_ms": amt(t_fused, t_no_mk)},
        "traffic": {"total_ms": t_tr, "histogram_ms": amt(t_tr, t_no_hist)},
    }
    emit("tick_phases/shape", 0.0, f"{n_scen}x{n_nodes}x{n_ticks}")
    for eng in ("unfused", "fused", "traffic"):
        for k, v in out[eng].items():
            emit(f"tick_phases/{eng}/{k}", v * 1e3, f"{v:.1f}ms")
    return out


def run() -> Dict[str, dict]:
    recs = load("pod16x16")
    if not recs:
        emit("roofline/status", 0.0, "NO_DRYRUN_RESULTS")
        return {}
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    fail = [r for r in recs if r["status"] == "fail"]
    emit("roofline/cells_ok", 0.0, str(len(ok)))
    emit("roofline/cells_skip", 0.0, str(len(skip)))
    emit("roofline/cells_fail", 0.0, str(len(fail)))
    out = {}
    for r in ok:
        rf = r["roofline"]
        cell = f"{r['arch']}__{r['shape']}"
        out[cell] = rf
        t_dom = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        frac = rf["t_compute_s"] / max(t_dom, 1e-30)
        emit(f"roofline/{cell}/t_compute_s", 0.0, f"{rf['t_compute_s']:.3e}")
        emit(f"roofline/{cell}/t_memory_s", 0.0, f"{rf['t_memory_s']:.3e}")
        emit(f"roofline/{cell}/t_collective_s", 0.0, f"{rf['t_collective_s']:.3e}")
        emit(f"roofline/{cell}/dominant", 0.0, rf["dominant"])
        emit(f"roofline/{cell}/compute_fraction_of_bound", 0.0, f"{frac:.3f}")
        emit(f"roofline/{cell}/useful_flops_ratio", 0.0,
             f"{rf['useful_flops_ratio']:.3f}")
    # multi-pod compile proof
    mp = load("pod2x16x16")
    mp_ok = sum(1 for r in mp if r["status"] == "ok")
    mp_skip = sum(1 for r in mp if r["status"] == "skip")
    emit("roofline/multipod_cells_ok", 0.0, str(mp_ok))
    emit("roofline/multipod_cells_skip", 0.0, str(mp_skip))
    return out


if __name__ == "__main__":
    run()
