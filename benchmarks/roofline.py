"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md SS Roofline).

Reads results/dryrun/*.json (written by repro.launch.dryrun), prints the
per-(arch x shape) three-term table for the single-pod mesh, and flags the
dominant bottleneck per cell. Run the sweep first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
from __future__ import annotations

import glob
import json
from pathlib import Path
from typing import Dict, List

from benchmarks.common import emit

RESULTS = Path("results/dryrun")


def load(mesh: str = "pod16x16") -> List[dict]:
    recs = []
    for fn in sorted(glob.glob(str(RESULTS / f"*__{mesh}.json"))):
        recs.append(json.loads(Path(fn).read_text()))
    return recs


def run() -> Dict[str, dict]:
    recs = load("pod16x16")
    if not recs:
        emit("roofline/status", 0.0, "NO_DRYRUN_RESULTS")
        return {}
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    fail = [r for r in recs if r["status"] == "fail"]
    emit("roofline/cells_ok", 0.0, str(len(ok)))
    emit("roofline/cells_skip", 0.0, str(len(skip)))
    emit("roofline/cells_fail", 0.0, str(len(fail)))
    out = {}
    for r in ok:
        rf = r["roofline"]
        cell = f"{r['arch']}__{r['shape']}"
        out[cell] = rf
        t_dom = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        frac = rf["t_compute_s"] / max(t_dom, 1e-30)
        emit(f"roofline/{cell}/t_compute_s", 0.0, f"{rf['t_compute_s']:.3e}")
        emit(f"roofline/{cell}/t_memory_s", 0.0, f"{rf['t_memory_s']:.3e}")
        emit(f"roofline/{cell}/t_collective_s", 0.0, f"{rf['t_collective_s']:.3e}")
        emit(f"roofline/{cell}/dominant", 0.0, rf["dominant"])
        emit(f"roofline/{cell}/compute_fraction_of_bound", 0.0, f"{frac:.3f}")
        emit(f"roofline/{cell}/useful_flops_ratio", 0.0,
             f"{rf['useful_flops_ratio']:.3f}")
    # multi-pod compile proof
    mp = load("pod2x16x16")
    mp_ok = sum(1 for r in mp if r["status"] == "ok")
    mp_skip = sum(1 for r in mp if r["status"] == "skip")
    emit("roofline/multipod_cells_ok", 0.0, str(mp_ok))
    emit("roofline/multipod_cells_skip", 0.0, str(mp_skip))
    return out


if __name__ == "__main__":
    run()
