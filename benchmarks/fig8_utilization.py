"""Paper Fig 8: (a) average CPU-utilization timeline, (b) standard deviation
of CPU credit balance across the cluster's VMs.

Claims: CASH shows better load balancing than plain reordering (8a) and a
LOWER credit-balance stddev, while T3-unlimited's per-instance averaging
yields a high stddev — tenants billed for surplus while cluster-wide
surplus credits exist (8b)."""
from __future__ import annotations

import statistics

from benchmarks.common import emit
from repro.core.experiments import run_cpu_experiment

LABELS = ("emr", "reordered", "unlimited", "cash")


def run() -> dict:
    stds, utils = {}, {}
    for label in LABELS:
        r = run_cpu_experiment(label, n_nodes=10, seed=0)
        tl = r.result.timeline
        half = len(tl["cpu_credit_std"]) // 2
        stds[label] = statistics.mean(tl["cpu_credit_std"][half:])
        utils[label] = statistics.mean(tl["cpu_util"])
        emit(f"fig8/{label}/avg_cpu_util", 0.0, f"{utils[label]:.3f}")
        emit(f"fig8/{label}/credit_std_late", 0.0, f"{stds[label]:.0f}")
    checks = {
        # 8(b): CASH keeps credit consumption even; unlimited/reordered do not
        "cash_lowest_credit_std": stds["cash"] <= min(stds["reordered"],
                                                      stds["unlimited"]),
        "unlimited_high_std": stds["unlimited"] > stds["cash"] * 1.5,
        # 8(a): CASH utilization >= reordered (better load balancing)
        "cash_util_not_worse": utils["cash"] >= utils["reordered"] - 0.01,
    }
    for k, ok in checks.items():
        emit(f"fig8/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), checks
    return stds


def run_batched(fast: bool = False) -> dict:
    """Vectorized Fig-8 from the engine's *streamed timeline* (scan ys
    sampled at `sample_period`, same cadence as `Simulation.run`): average
    CPU utilization from the sampled utilization series and the late-run
    credit-balance stddev of Fig 8(b) from the sampled cluster credit
    series — the same assertions `run()` makes on the Python timeline,
    now on the batched path. Reuses fig7's shared CPU sweep (one compile +
    run for both figures)."""
    from benchmarks.fig7_cpu_burst import run_cpu_sweep_batched

    sweep = run_cpu_sweep_batched(fast)
    stds, utils = {}, {}
    for label in LABELS:
        r = sweep["res"][label]
        assert bool(r["all_done"]), (label, "did not finish")
        # the Python loop stops sampling once the workload drains; mask the
        # vec timeline the same way so the series align sample-for-sample
        live = r["timeline_t"] < float(r["makespan"])
        std_series = [float(v) for v in r["timeline"]["cpu_credit_std"][live]]
        util_series = [float(v) for v in r["timeline"]["cpu_util"][live]]
        half = len(std_series) // 2
        stds[label] = statistics.mean(std_series[half:])
        utils[label] = statistics.mean(util_series)
        emit(f"fig8/batched/{label}/avg_cpu_util", 0.0, f"{utils[label]:.3f}")
        emit(f"fig8/batched/{label}/credit_std_late", 0.0,
             f"{stds[label]:.0f}")
        emit(f"fig8/batched/{label}/surplus_credits", 0.0,
             f"{float(r['surplus_credits']):.0f}")
    checks = {
        # 8(b): CASH keeps credit consumption even; unlimited/reordered do not
        "cash_lowest_credit_std": stds["cash"] <= min(stds["reordered"],
                                                      stds["unlimited"]),
        "unlimited_high_std": stds["unlimited"] > stds["cash"] * 1.5,
        # 8(a): CASH utilization >= reordered (better load balancing)
        "cash_util_not_worse": utils["cash"] >= utils["reordered"] - 0.01,
    }
    for k, ok in checks.items():
        emit(f"fig8/batched/check/{k}", 0.0, "PASS" if ok else "FAIL")
    assert all(checks.values()), (checks, stds, utils)
    return {"stds": stds, "utils": utils}


if __name__ == "__main__":
    run()
    run_batched()
