"""Throughput regression gate over ``BENCH_vecsim.json``.

Compares a candidate benchmark document against the committed baseline
and fails when any *gated* per-mode throughput metric drops by more than
the threshold (default 15%). Gated keys are the tracked engine numbers —
one per execution path:

    fast / full : vec_ticks_nodes_scen_per_s        (vmap batch path)
                  sharded.ticks_nodes_scen_per_s    (shard_map mesh path)
    traffic     : traffic_ticks_nodes_scen_per_s    (open-loop ring path)
    serve       : serve_ticks_reps_scen_per_s       (serving-fleet path)
    churn       : schedulers.{cash,stock}.goodput_vcpu_s

The churn keys are not wall-clock rates — they are DETERMINISTIC
simulation outcomes (useful vCPU-seconds delivered under identical
fault streams), so the 15% threshold there catches semantic
regressions in placement/recovery, never timing noise. Everything else
in the document (SLO tails, churn ratios, phase breakdowns) is
informational: those have their own acceptance asserts in the
benchmarks that produce them, and gating them on wall-clock-noise
thresholds would only flake. A section missing from either document is
skipped — a fast CI run never gates the full-mode numbers and vice
versa.

Use standalone::

    python -m benchmarks.check_regression BENCH_vecsim.json new.json

or let the driver do it: ``python -m benchmarks.run --fast --check``
snapshots the committed baseline *before* overwriting it and compares
the fresh numbers against the snapshot.

Faster-is-better is assumed for every gated key; improvements never
fail. Exit status: 0 when no gated metric regressed, 1 otherwise
(also 1 for unreadable inputs).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
from typing import Dict, List, Optional, Sequence, Tuple

THRESHOLD = 0.15

# section -> dotted key paths into that section (gated, higher-is-better)
GATED: Dict[str, Tuple[str, ...]] = {
    "fast": ("vec_ticks_nodes_scen_per_s",
             "sharded.ticks_nodes_scen_per_s"),
    "full": ("vec_ticks_nodes_scen_per_s",
             "sharded.ticks_nodes_scen_per_s"),
    "traffic": ("traffic_ticks_nodes_scen_per_s",),
    "serve": ("serve_ticks_reps_scen_per_s",),
    "churn": ("schedulers.cash.goodput_vcpu_s",
              "schedulers.stock.goodput_vcpu_s"),
}


@dataclasses.dataclass(frozen=True)
class Regression:
    section: str
    key: str
    baseline: float
    candidate: float

    @property
    def drop(self) -> float:
        return (self.baseline - self.candidate) / self.baseline

    def __str__(self) -> str:
        return (f"{self.section}/{self.key}: {self.candidate:,.0f} "
                f"vs baseline {self.baseline:,.0f} "
                f"({self.drop:+.1%} drop)")


def _lookup(section: dict, dotted: str) -> Optional[float]:
    cur = section
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    try:
        v = float(cur)
    except (TypeError, ValueError):
        return None
    return v


def compare(baseline: dict, candidate: dict,
            threshold: float = THRESHOLD) -> List[Regression]:
    """Gated metrics that regressed past ``threshold``, in section order.

    A key absent (or non-numeric, or non-positive) on either side is
    skipped: a first run against an empty baseline, or a baseline written
    before a section existed, must not fail the gate.
    """
    regs: List[Regression] = []
    for section, keys in GATED.items():
        old_sec = baseline.get(section)
        new_sec = candidate.get(section)
        if not isinstance(old_sec, dict) or not isinstance(new_sec, dict):
            continue
        for key in keys:
            old = _lookup(old_sec, key)
            new = _lookup(new_sec, key)
            if old is None or new is None or old <= 0.0:
                continue
            if (old - new) / old > threshold:
                regs.append(Regression(section, key, old, new))
    return regs


def check_docs(baseline: dict, candidate: dict,
               threshold: float = THRESHOLD,
               out=None) -> bool:
    """Print a verdict for each regression; True when the gate passes."""
    out = sys.stderr if out is None else out    # late-bound: respect redirects
    regs = compare(baseline, candidate, threshold)
    for r in regs:
        print(f"PERF REGRESSION {r}", file=out)
    if regs:
        print(f"{len(regs)} gated metric(s) regressed more than "
              f"{threshold:.0%}", file=out)
    return not regs


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m benchmarks.check_regression",
        description="Fail when a gated BENCH_vecsim.json throughput "
                    "metric drops more than --threshold vs the baseline.")
    p.add_argument("baseline", help="committed BENCH_vecsim.json")
    p.add_argument("candidate", help="freshly measured BENCH_vecsim.json")
    p.add_argument("--threshold", type=float, default=THRESHOLD,
                   help="max tolerated fractional drop (default 0.15)")
    args = p.parse_args(argv)
    try:
        baseline = json.loads(pathlib.Path(args.baseline).read_text())
        candidate = json.loads(pathlib.Path(args.candidate).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    if check_docs(baseline, candidate, args.threshold):
        print("regression gate: PASS", file=sys.stderr)
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
