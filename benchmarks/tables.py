"""Paper Tables 1-2: T3 credit mechanics and pricing, validated exactly."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.cost import hourly_rate
from repro.core.token_bucket import INSTANCE_TYPES


def run() -> None:
    # Table 1
    for name in ("t3.large", "t3.xlarge", "t3.2xlarge"):
        s = INSTANCE_TYPES[name]
        emit(f"table1/{name}/vcpus", 0.0, str(s.vcpus))
        emit(f"table1/{name}/baseline_per_vcpu", 0.0, f"{s.baseline_per_vcpu:.2f}")
        emit(f"table1/{name}/credits_per_hour", 0.0, f"{s.credits_per_hour:.0f}")
    assert INSTANCE_TYPES["t3.2xlarge"].credits_per_hour == 192.0
    # Table 2
    rows = {
        ("t3.xlarge", False): 0.1664, ("t3.2xlarge", False): 0.3328,
        ("m5.xlarge", False): 0.192, ("m5.2xlarge", False): 0.384,
        ("m5.xlarge", True): 0.24, ("m5.2xlarge", True): 0.48,
    }
    for (inst, emr), want in rows.items():
        got = hourly_rate(inst, emr=emr)
        tag = f"{inst}{'+emr' if emr else ''}"
        emit(f"table2/{tag}/usd_per_hour", 0.0, f"{got:.4f}")
        assert abs(got - want) < 1e-9, (tag, got, want)
    # the paper's headline rate comparisons
    emit("table2/m5_premium_over_t3", 0.0,
         f"{hourly_rate('m5.2xlarge') / hourly_rate('t3.2xlarge') - 1:.3f}")
    emit("table2/emr_premium_over_t3", 0.0,
         f"{hourly_rate('m5.2xlarge', True) / hourly_rate('t3.2xlarge') - 1:.3f}")


if __name__ == "__main__":
    run()
