"""Minimal, dependency-free stand-in for the ``hypothesis`` package.

The container this repo targets does not ship ``hypothesis``; rather than
skip the property tests entirely we provide the tiny subset the test-suite
uses — ``@given`` with keyword strategies, ``@settings(max_examples=...,
deadline=...)`` and ``strategies.integers/floats/booleans/sampled_from`` —
backed by a deterministic PRNG seeded from the test name, so failures are
reproducible run-to-run. If the real hypothesis is ever installed, remove
this shim from ``src/`` (it shadows the package on PYTHONPATH).
"""
from __future__ import annotations

import functools
import random
from typing import Any, Callable, Sequence

__version__ = "0.0-repro-shim"

_DEFAULT_MAX_EXAMPLES = 50


class Strategy:
    def __init__(self, draw: Callable[[random.Random], Any], name: str = "strategy"):
        self._draw = draw
        self._name = name

    def example_from(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<{self._name}>"


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (imported ``as st``)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(lambda rng: rng.randint(min_value, max_value),
                        f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value: float, max_value: float, **_: Any) -> Strategy:
        def draw(rng: random.Random) -> float:
            # bias some mass onto the endpoints — they are where bucket /
            # scheduler edge cases live and what real hypothesis shrinks to
            r = rng.random()
            if r < 0.05:
                return float(min_value)
            if r < 0.10:
                return float(max_value)
            return rng.uniform(min_value, max_value)
        return Strategy(draw, f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> Strategy:
        elements = list(elements)
        return Strategy(lambda rng: rng.choice(elements), "sampled_from(...)")


st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline: Any = None,
             **_: Any) -> Callable:
    def deco(fn: Callable) -> Callable:
        fn._shim_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*args: Strategy, **kwargs: Strategy) -> Callable:
    if args:
        raise TypeError("the hypothesis shim supports keyword strategies only")

    def deco(fn: Callable) -> Callable:
        max_examples = getattr(fn, "_shim_settings",
                               {}).get("max_examples", _DEFAULT_MAX_EXAMPLES)

        # NB: no functools.wraps — it sets __wrapped__ and pytest would then
        # see the original signature and demand fixtures for every strategy
        # parameter. The wrapper must present a zero-argument signature.
        def wrapper(*wargs: Any) -> None:
            seed = f"{fn.__module__}.{fn.__qualname__}"
            for i in range(max_examples):
                rng = random.Random(f"{seed}:{i}")
                drawn = {k: s.example_from(rng) for k, s in kwargs.items()}
                try:
                    fn(*wargs, **drawn)
                except _Rejected:
                    continue  # assume() failed: drop the example
                except Exception as e:  # re-raise with the failing example
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{max_examples}): "
                        f"{fn.__name__}({drawn!r})") from e

        # NB: do not set a ``hypothesis`` attribute here — pytest's bundled
        # hypothesis integration probes it and expects the real object.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def assume(condition: bool) -> None:
    """``assume(False)`` rejects the current example: ``given()`` catches
    the raise and moves on to the next draw. Rejected draws still count
    toward ``max_examples`` (no resampling), so assume-heavy tests run
    fewer effective examples than configured."""
    if not condition:
        raise _Rejected()


class _Rejected(Exception):
    pass


__all__ = ["given", "settings", "strategies", "st", "assume", "Strategy"]
