"""Credit-state telemetry + prediction (paper SS5.1, Algorithm 2).

CloudWatch populates burst-credit balances at a 5-minute granularity; acting
on that alone would mean scheduling against stale state. CASH therefore pulls
1-minute utilization metrics and *predicts* the balance between the 5-minute
ground-truth refreshes using the provider's published accrual formulas
(balance' = earn - use, clamped to [0, capacity]).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.cluster import Node


@dataclasses.dataclass
class CloudWatchSample:
    t: float
    balance: float          # credits (as last *published* by the provider)
    usage_rate: float       # avg service rate over the last metric period


class CloudWatchEmulator:
    """Quantizes simulator ground truth to CloudWatch's reporting periods.

    ``actual_period`` (default 300 s) gates balance freshness; ``usage_period``
    (default 60 s) gates utilization freshness — exactly the paper's 5 min /
    1 min split.
    """

    def __init__(self, resource: str, actual_period: float = 300.0,
                 usage_period: float = 60.0):
        assert resource in ("cpu", "disk")
        self.resource = resource
        self.actual_period = actual_period
        self.usage_period = usage_period
        self._last_actual: Dict[int, CloudWatchSample] = {}
        self._last_usage: Dict[int, CloudWatchSample] = {}
        self._usage_accum: Dict[int, float] = {}
        self._usage_window_start: Dict[int, float] = {}

    def observe(self, now: float, nodes: Sequence[Node],
                usage_rates: Dict[int, float]) -> None:
        """Called every simulator tick with ground truth; publishes samples
        only when a reporting period boundary has passed."""
        for n in nodes:
            nid = n.nid
            self._usage_accum[nid] = self._usage_accum.get(nid, 0.0)
            self._usage_window_start.setdefault(nid, now)
            self._usage_accum[nid] += usage_rates.get(nid, 0.0)
            last_a = self._last_actual.get(nid)
            if last_a is None or now - last_a.t >= self.actual_period:
                bal = n.credit(self.resource)
                self._last_actual[nid] = CloudWatchSample(now, bal, usage_rates.get(nid, 0.0))
            last_u = self._last_usage.get(nid)
            if last_u is None or now - last_u.t >= self.usage_period:
                span = max(now - self._usage_window_start[nid], 1e-9)
                ticks = max(1.0, span)  # accum is per-tick(1s) rates
                avg = self._usage_accum[nid] / ticks
                self._last_usage[nid] = CloudWatchSample(now, float("nan"), avg)
                self._usage_accum[nid] = 0.0
                self._usage_window_start[nid] = now

    def latest_actual(self, nid: int) -> Optional[CloudWatchSample]:
        return self._last_actual.get(nid)

    def latest_usage(self, nid: int) -> Optional[CloudWatchSample]:
        return self._last_usage.get(nid)


class CreditPredictor:
    """Algorithm 2: every 5 min adopt the provider's actual balance; every
    1 min extrapolate from utilization using the published formula."""

    def __init__(self, watcher: CloudWatchEmulator):
        self.watcher = watcher
        self._estimates: Dict[int, float] = {}

    def update(self, now: float, nodes: Sequence[Node]) -> Dict[int, float]:
        for n in nodes:
            bucket = n.cpu if self.watcher.resource == "cpu" else n.disk
            actual = self.watcher.latest_actual(n.nid)
            usage = self.watcher.latest_usage(n.nid)
            if actual is None:
                self._estimates[n.nid] = bucket.capacity
                continue
            est = actual.balance
            if usage is not None and usage.t >= actual.t:
                # provider formula: balance' = baseline(earn) - avg usage
                dt = now - actual.t
                est = est + (bucket.baseline - usage.usage_rate) * dt
            est = min(max(est, 0.0), bucket.capacity)
            self._estimates[n.nid] = est
        return dict(self._estimates)

    def estimate(self, nid: int) -> float:
        return self._estimates.get(nid, 0.0)


class OracleCredits:
    """Ablation: scheduler sees exact, zero-lag credit state."""

    def __init__(self, resource: str):
        assert resource in ("cpu", "disk")
        self.resource = resource

    def update(self, now: float, nodes: Sequence[Node]) -> Dict[int, float]:
        return {n.nid: n.credit(self.resource) for n in nodes}


class StaleCredits:
    """Ablation: only the 5-minute actuals, no prediction (what a naive
    CloudWatch integration would do)."""

    def __init__(self, watcher: CloudWatchEmulator):
        self.watcher = watcher

    def update(self, now: float, nodes: Sequence[Node]) -> Dict[int, float]:
        out = {}
        for n in nodes:
            s = self.watcher.latest_actual(n.nid)
            bucket = n.cpu if self.watcher.resource == "cpu" else n.disk
            out[n.nid] = s.balance if s is not None else bucket.capacity
        return out
