"""Discrete-time cluster simulator for burstable-cloud scheduling (paper SS6).

Time-stepped (default 1 s ticks). Each tick:
  1. finished tasks release slots;
  2. job sequencing / DAG readiness updates the pending queue;
  3. the scheduler (CASH / stock) places runnable tasks onto free slots using
     the telemetry-estimated credit state (Algorithm 2 predictor by default);
  4. every node's token buckets serve the aggregate demand of its running
     tasks; completed work is distributed pro-rata to task demands;
  5. CloudWatch emulation observes ground truth at its reporting periods.

The simulator is deterministic given (workload, scheduler rng, config).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.annotations import Annotation, Task
from repro.core.cluster import Node, cluster_stats
from repro.core.credits import CloudWatchEmulator, CreditPredictor, OracleCredits, StaleCredits
from repro.core.scheduler import SchedulerBase


@dataclasses.dataclass
class Job:
    name: str
    tasks: List[Task]
    # fraction of a task's dependencies that must be *finished* before it may
    # start (paper: reduce starts once ~5% of map output is available)
    dep_threshold: float = 1.0

    def finished(self) -> bool:
        return all(t.finished() for t in self.tasks)


@dataclasses.dataclass
class SimConfig:
    dt: float = 1.0
    max_time: float = 200_000.0
    resource: str = "cpu"              # credit pool driving the scheduler
    telemetry: str = "predicted"       # predicted | stale | oracle
    actual_period: float = 300.0
    usage_period: float = 60.0
    sample_period: float = 10.0        # timeline sampling


@dataclasses.dataclass
class SimResult:
    makespan: float
    job_completion: Dict[str, float]                  # job -> completion time
    phase_elapsed: Dict[str, float]                   # vertex kind -> sum of task elapsed
    phase_count: Dict[str, int]
    timeline: Dict[str, List[float]]                  # sampled series
    surplus_credits: float                            # T3-unlimited overdraft (vCPU-sec)
    node_busy_seconds: float
    total_cpu_work: float
    tasks: List[Task]

    def cumulative_elapsed(self, kinds: Sequence[str]) -> float:
        return sum(self.phase_elapsed.get(k, 0.0) for k in kinds)

    def avg_query_completion(self) -> float:
        vals = list(self.job_completion.values())
        return sum(vals) / max(len(vals), 1)


class Simulation:
    def __init__(self, nodes: List[Node], scheduler: SchedulerBase,
                 config: Optional[SimConfig] = None):
        self.nodes = nodes
        self.scheduler = scheduler
        self.cfg = config or SimConfig()
        self.queue: List[Task] = []
        self.jobs: List[Job] = []
        self._sequential: List[Job] = []   # jobs gated on the previous finishing
        self.finished_tasks: List[Task] = []
        self._done_ids: set = set()
        # incremental DAG-readiness tracking (O(edges) total)
        self._dependents: Dict[int, List[Task]] = {}
        self._dep_done: Dict[int, int] = {}
        self._ready: set = set()
        self.now = 0.0
        self.joint = self.cfg.resource == "joint"
        if self.joint:
            # paper SS8 future work: two credit pools tracked side by side
            self.watcher_cpu = CloudWatchEmulator(
                "cpu", self.cfg.actual_period, self.cfg.usage_period)
            self.watcher_disk = CloudWatchEmulator(
                "disk", self.cfg.actual_period, self.cfg.usage_period)
            self.telemetry_cpu = CreditPredictor(self.watcher_cpu)
            self.telemetry_disk = CreditPredictor(self.watcher_disk)
            self.watcher = self.watcher_cpu
            self.telemetry = self.telemetry_cpu
        else:
            watcher = CloudWatchEmulator(self.cfg.resource,
                                         self.cfg.actual_period,
                                         self.cfg.usage_period)
            self.watcher = watcher
            if self.cfg.telemetry == "predicted":
                self.telemetry = CreditPredictor(watcher)
            elif self.cfg.telemetry == "stale":
                self.telemetry = StaleCredits(watcher)
            elif self.cfg.telemetry == "oracle":
                self.telemetry = OracleCredits(self.cfg.resource)
            else:
                raise ValueError(self.cfg.telemetry)

    # ----------------------------------------------------------- submission
    def submit_parallel(self, jobs: Sequence[Job]) -> None:
        """All jobs eligible immediately (streaming queries, SS6.5). Tasks are
        interleaved round-robin across jobs — the capacity scheduler's fair
        sharing between parallel query queues."""
        for j in jobs:
            self.jobs.append(j)
            self._register_job(j)
            for t in j.tasks:
                t.submit_time = self.now
        # round-robin interleave in O(total tasks): wave w takes the w-th task
        # of every job that still has one (list.pop(0) per element is O(n^2))
        lists = [j.tasks for j in jobs]
        for wave in range(max((len(l) for l in lists), default=0)):
            for lst in lists:
                if wave < len(lst):
                    self.queue.append(lst[wave])

    def submit_sequential(self, jobs: Sequence[Job]) -> None:
        """Jobs gated: job k+1 enters the queue when job k finishes (SS6.1:
        HiBench jobs are submitted sequentially)."""
        self._sequential.extend(jobs)

    # ------------------------------------------------------------- internals
    def _admit_sequential(self) -> None:
        while self._sequential:
            if self.jobs and not all(j.finished() for j in self.jobs):
                break
            j = self._sequential.pop(0)
            self.jobs.append(j)
            self._register_job(j)
            for t in j.tasks:
                t.submit_time = self.now
            self.queue.extend(j.tasks)

    def _register_job(self, job: Job) -> None:
        """Index DAG edges for incremental readiness tracking."""
        for t in job.tasks:
            if not t.depends_on:
                continue
            if t.dep_threshold is None:
                t.dep_threshold = job.dep_threshold
            done = sum(1 for d in t.depends_on if d in self._done_ids)
            self._dep_done[t.tid] = done
            if done / len(t.depends_on) + 1e-12 >= t.dep_threshold:
                self._ready.add(t.tid)
            for d in t.depends_on:
                if d not in self._done_ids:
                    self._dependents.setdefault(d, []).append(t)

    def _mark_done(self, task: Task) -> None:
        self._done_ids.add(task.tid)
        for dep_task in self._dependents.pop(task.tid, ()):  # type: ignore[arg-type]
            self._dep_done[dep_task.tid] = self._dep_done.get(dep_task.tid, 0) + 1
            th = dep_task.dep_threshold if dep_task.dep_threshold is not None else 1.0
            if self._dep_done[dep_task.tid] / len(dep_task.depends_on) + 1e-12 >= th:
                self._ready.add(dep_task.tid)

    def _runnable_ids(self) -> set:
        return self._ready

    def _serve_tick(self) -> Dict[str, Dict[int, float]]:
        """Serve all running tasks for one dt; returns per-node usage rates
        for both credit resources (for CloudWatch)."""
        dt = self.cfg.dt
        usage: Dict[str, Dict[int, float]] = {"cpu": {}, "disk": {}}
        for node in self.nodes:
            run = node.running
            dem_cpu = sum(min(t.demand_cpu, 1.0) for t in run if t.remaining()["cpu"] > 0)
            dem_disk = sum(t.demand_disk for t in run if t.remaining()["disk"] > 0)
            dem_net = sum(t.demand_net for t in run if t.remaining()["net"] > 0)
            w_cpu = node.cpu.serve(dem_cpu, dt)
            w_disk = node.disk.serve(dem_disk, dt)
            w_net = node.net.serve(dem_net, dt)
            for t in run:
                rem = t.remaining()
                if dem_cpu > 0 and rem["cpu"] > 0:
                    t.done_cpu = min(t.work_cpu,
                                     t.done_cpu + w_cpu * min(t.demand_cpu, 1.0) / dem_cpu)
                if dem_disk > 0 and rem["disk"] > 0:
                    t.done_disk = min(t.work_disk,
                                      t.done_disk + w_disk * t.demand_disk / dem_disk)
                if dem_net > 0 and rem["net"] > 0:
                    t.done_net = min(t.work_net,
                                     t.done_net + w_net * t.demand_net / dem_net)
            usage["cpu"][node.nid] = w_cpu / dt
            usage["disk"][node.nid] = w_disk / dt
        return usage

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        cfg = self.cfg
        timeline: Dict[str, List[float]] = {
            "t": [], "cpu_util": [], "cpu_credit_std": [], "cpu_credit_mean": [],
            "disk_credit_std": [], "disk_credit_mean": [], "iops": [],
        }
        next_sample = 0.0
        busy_seconds = 0.0
        iops_acc: List[float] = []
        util_acc: List[float] = []

        while self.now < cfg.max_time:
            self._admit_sequential()
            # release finished
            for node in self.nodes:
                for t in node.release_finished(self.now):
                    self.finished_tasks.append(t)
                    self._mark_done(t)
            self._admit_sequential()

            done = not self.queue and not self._sequential and \
                all(not n.running for n in self.nodes)
            if done:
                break

            # schedule
            ready = self._runnable_ids()
            if self.joint:
                ccpu = self.telemetry_cpu.update(self.now, self.nodes)
                cdisk = self.telemetry_disk.update(self.now, self.nodes)
                self.scheduler.schedule(self.queue, self.nodes, ccpu, self.now,
                                        ready_ids=ready, credits_cpu=ccpu,
                                        credits_disk=cdisk)
            else:
                credits = self.telemetry.update(self.now, self.nodes)
                self.scheduler.schedule(self.queue, self.nodes, credits,
                                        self.now, ready_ids=ready)

            # serve
            usage = self._serve_tick()
            if self.joint:
                self.watcher_cpu.observe(self.now, self.nodes, usage["cpu"])
                self.watcher_disk.observe(self.now, self.nodes, usage["disk"])
            else:
                self.watcher.observe(self.now, self.nodes,
                                     usage[self.cfg.resource])

            # metrics
            total_vcpus = sum(n.spec.vcpus for n in self.nodes)
            util = sum(usage["cpu"].values()) / total_vcpus
            busy_seconds += sum(1.0 for n in self.nodes if n.running) * cfg.dt
            if cfg.resource == "disk":
                iops_acc.append(sum(usage["disk"].values()) / len(self.nodes))
            else:
                util_acc.append(util)
            if self.now >= next_sample:
                st = cluster_stats(self.nodes)
                timeline["t"].append(self.now)
                timeline["cpu_util"].append(util)
                timeline["cpu_credit_std"].append(st["cpu_credit_std"])
                timeline["cpu_credit_mean"].append(st["cpu_credit_mean"])
                timeline["disk_credit_std"].append(st["disk_credit_std"])
                timeline["disk_credit_mean"].append(st["disk_credit_mean"])
                timeline["iops"].append(
                    sum(usage["disk"].values()) / len(self.nodes))
                next_sample += cfg.sample_period
            self.now += cfg.dt

        # aggregate
        phase_elapsed: Dict[str, float] = {}
        phase_count: Dict[str, int] = {}
        for t in self.finished_tasks:
            e = t.elapsed()
            if not math.isnan(e):
                phase_elapsed[t.vertex] = phase_elapsed.get(t.vertex, 0.0) + e
                phase_count[t.vertex] = phase_count.get(t.vertex, 0) + 1
        job_completion = {}
        for j in self.jobs:
            ends = [t.finish_time for t in j.tasks if t.finish_time is not None]
            starts = [t.submit_time for t in j.tasks]
            if ends:
                job_completion[j.name] = max(ends) - min(starts)
        surplus = sum(n.cpu.surplus_used for n in self.nodes)
        return SimResult(
            makespan=self.now,
            job_completion=job_completion,
            phase_elapsed=phase_elapsed,
            phase_count=phase_count,
            timeline=timeline,
            surplus_credits=surplus,
            node_busy_seconds=busy_seconds,
            total_cpu_work=sum(t.done_cpu for t in self.finished_tasks),
            tasks=self.finished_tasks,
        )
