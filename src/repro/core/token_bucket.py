"""Token-bucket models of variable-service-rate cloud resources (paper SS2).

A unified bucket covers AWS T3 CPU credits (SS2.1), EBS gp2 I/O credits (SS2.2)
and the dual-bucket network regulator of burstable instances (paper footnote 3,
reverse-engineered in Wang et al., SIGMETRICS'17).

Unit convention: credits are measured in *service-unit x seconds* so the earn
rate numerically equals the baseline service rate. For T3 this is equivalent to
AWS's books (1 CPU credit = 1 vCPU-minute = 60 of our credit units); for EBS it
matches AWS exactly (1 I/O credit = 1 IOPS x second).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass
class TokenBucket:
    """baseline: sustained service rate == credit earn rate (units/sec).
    burst: max service rate while credits remain (units/sec).
    capacity: bucket cap in credit units (service-unit-seconds).
    unlimited: T3-unlimited semantics — never throttle, account surplus.
    """
    baseline: float
    burst: float
    capacity: float
    balance: float = 0.0
    unlimited: bool = False
    surplus_used: float = 0.0      # credit units consumed beyond the bucket

    def __post_init__(self) -> None:
        if self.burst < self.baseline:
            raise ValueError("burst rate must be >= baseline rate")
        self.balance = min(max(self.balance, 0.0), self.capacity)

    # ------------------------------------------------------------------
    def max_rate(self) -> float:
        """Service rate available *right now* (used by schedulers)."""
        if self.unlimited or self.balance > 0.0:
            return self.burst
        return self.baseline

    def serve(self, demand: float, dt: float) -> float:
        """Serve ``demand`` (units/sec) for ``dt`` seconds.

        Returns work completed (units x sec). Credits accrue at ``baseline``
        and drain at the served rate; when the bucket empties the rate is
        throttled to ``baseline`` (unless ``unlimited``, which books surplus
        credits instead — AWS bills those, see core.cost).
        """
        if dt <= 0.0 or demand <= 0.0:
            # idle: pure accrual
            self.balance = min(self.capacity, self.balance + self.baseline * max(dt, 0.0))
            return 0.0
        rate = min(demand, self.burst)
        drain = rate - self.baseline               # net credit flow (negative = accrue)
        if drain <= 0.0:
            self.balance = min(self.capacity, self.balance - drain * dt)
            return rate * dt
        # bursting: spend credits until the bucket empties
        t_burst = dt if self.unlimited else min(dt, self.balance / drain)
        work = rate * t_burst
        spent = drain * t_burst
        if self.unlimited:
            over = max(0.0, spent - self.balance)
            self.surplus_used += over
            self.balance = max(0.0, self.balance - spent)
        else:
            self.balance = max(0.0, self.balance - spent)
        rest = dt - t_burst
        if rest > 0.0:
            # throttled remainder at baseline (balance pinned at ~0 while
            # demand exceeds baseline: earn == drain)
            work += min(demand, self.baseline) * rest
        return work

    def time_to_deplete(self, demand: float) -> float:
        """Seconds of ``demand`` service until throttling (inf if never)."""
        rate = min(demand, self.burst)
        drain = rate - self.baseline
        if drain <= 0.0 or self.unlimited:
            return float("inf")
        return self.balance / drain

    def snapshot(self) -> Tuple[float, float]:
        return self.balance, self.surplus_used


# ---------------------------------------------------------------------------
# AWS instance / volume catalogs (paper Table 1, SS2.1-2.2, Table 2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InstanceSpec:
    name: str
    vcpus: int
    memory_gib: int
    baseline_per_vcpu: float       # fraction of a core (Table 1)
    credits_per_hour: float        # CPU credits (vCPU-minutes) per hour
    price_per_hour: float          # on-demand USD (Table 2 / AWS pricing)
    burstable: bool

    def cpu_bucket(self, initial_fraction: float = 0.0, unlimited: bool = False) -> TokenBucket:
        if not self.burstable:
            # fixed-rate instance: baseline == burst == all vCPUs, bucket inert
            full = float(self.vcpus)
            return TokenBucket(baseline=full, burst=full, capacity=0.0)
        baseline = self.vcpus * self.baseline_per_vcpu          # vCPU units
        cap = self.credits_per_hour * 24 * 60.0                 # 24h accrual, in vCPU-sec
        # credits/hour in vCPU-min -> earn rate in vCPU-sec/sec == vCPU:
        earn = self.credits_per_hour * 60.0 / 3600.0
        assert abs(earn - baseline) < 1e-6, (self.name, earn, baseline)
        return TokenBucket(
            baseline=baseline, burst=float(self.vcpus), capacity=cap,
            balance=cap * initial_fraction, unlimited=unlimited)


# Table 1 (+ t3/m5 xlarge, 2xlarge pricing from Table 2; m5.2xl memory 32GiB)
INSTANCE_TYPES = {
    "t3.large":    InstanceSpec("t3.large", 2, 8, 0.30, 36.0, 0.0832, True),
    "t3.xlarge":   InstanceSpec("t3.xlarge", 4, 16, 0.40, 96.0, 0.1664, True),
    "t3.2xlarge":  InstanceSpec("t3.2xlarge", 8, 32, 0.40, 192.0, 0.3328, True),
    "m5.xlarge":   InstanceSpec("m5.xlarge", 4, 16, 1.00, 0.0, 0.192, False),
    "m5.2xlarge":  InstanceSpec("m5.2xlarge", 8, 32, 1.00, 0.0, 0.384, False),
}

# EMR premium on top of the EC2 instance price (Table 2: M5+EMR = 0.24 / 0.48)
EMR_SURCHARGE = {"m5.xlarge": 0.048, "m5.2xlarge": 0.096}

EBS_STARTUP_CREDITS = 5_400_000.0   # paper SS6.5: 5.4M initial I/O credits
EBS_MAX_BURST_IOPS = 3000.0
EBS_MIN_BASELINE_IOPS = 100.0
EBS_MAX_BASELINE_IOPS = 16000.0


def ebs_gp2_bucket(size_gb: float, initial_credits: Optional[float] = None) -> TokenBucket:
    """EBS gp2 bucket (Figure 2): baseline 3 IOPS/GB in [100, 16000], burst 3000.

    Volumes whose baseline exceeds 3000 IOPS never need credits (bucket inert).
    """
    baseline = min(max(3.0 * size_gb, EBS_MIN_BASELINE_IOPS), EBS_MAX_BASELINE_IOPS)
    burst = max(EBS_MAX_BURST_IOPS, baseline)
    cap = EBS_STARTUP_CREDITS
    bal = cap if initial_credits is None else initial_credits
    return TokenBucket(baseline=baseline, burst=burst, capacity=cap, balance=bal)


@dataclasses.dataclass
class DualTokenBucket:
    """Network regulator of burstable instances (paper footnote 3 / Wang'17):
    a small *peak* bucket refilled from a large *sustained* bucket; service is
    limited by the peak bucket's state, long-run rate by the sustained one.
    """
    sustained: TokenBucket
    peak: TokenBucket

    def max_rate(self) -> float:
        return min(self.peak.max_rate(),
                   self.sustained.max_rate() if self.sustained.balance <= 0 else self.peak.burst)

    def serve(self, demand: float, dt: float) -> float:
        """Serve through both regulators: the peak bucket shapes the burst,
        then the sustained bucket is charged only for the work the peak
        bucket actually delivered (charging both by the full demand would
        drain the non-binding bucket for work never done)."""
        w1 = self.peak.serve(demand, dt)
        if dt <= 0.0:
            return 0.0
        # long-run envelope: the sustained bucket sees the delivered rate
        return self.sustained.serve(w1 / dt, dt)


def network_dual_bucket(gbps_peak: float = 10.0, gbps_sustained: float = 2.5) -> DualTokenBucket:
    to_units = 1e9 / 8.0  # bytes/sec
    peak = TokenBucket(baseline=gbps_sustained * to_units, burst=gbps_peak * to_units,
                       capacity=gbps_peak * to_units * 60.0,
                       balance=gbps_peak * to_units * 60.0)
    sustained = TokenBucket(baseline=gbps_sustained * to_units, burst=gbps_peak * to_units,
                            capacity=gbps_peak * to_units * 3600.0,
                            balance=gbps_peak * to_units * 3600.0)
    return DualTokenBucket(sustained=sustained, peak=peak)
