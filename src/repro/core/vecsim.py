"""Vectorized batched fleet simulator: a `lax.scan` tick engine, vmapped
over a leading scenario axis, for CASH scenario sweeps.

The pure-Python `Simulation` (core.simulator) advances one scenario at a
time through Python dicts — every paper figure and ablation is wall-clock
bound by the interpreter. This module represents the whole cluster as
arrays and advances *hundreds of scenarios at once*:

  per-node bucket state   (balance, surplus, baseline, burst, capacity)
                          for the CPU pool, the EBS pool, and the two
                          halves of the network dual regulator;
  per-task state          (work/done per resource, demand, node, status);
  telemetry state         (CloudWatch actual/usage samples per node).

One tick = release -> sequential-wave admission -> telemetry estimate ->
three-phase placement (credit-sorted argsort + masked scatter of slot
assignments) -> fused token-bucket serve + pro-rata work distribution
(kernels.ops.bucket_serve_distribute, the Pallas / XLA kernel: one kernel
per pool instead of serve-then-gather) -> CloudWatch observe. The
semantics mirror `Simulation.run` tick-for-tick; under float64
(`jax_enable_x64`) the engine reproduces the Python oracle's makespan,
per-job completion times and surplus credits exactly (see
tests/test_vecsim.py). One caveat: the engine computes time as ``t * dt``
while the Python loop accumulates ``now += dt``, so exact parity holds for
``dt`` values whose products are exact in binary (1.0, 0.5, 2.0, ... — all
in-repo configs); a drifting dt like 0.1 can land telemetry publish
boundaries one tick apart. (`sample_tick_indices` deliberately reproduces
the accumulation drift so *timeline sampling* stays aligned regardless.) The single deliberate deviation: the Python
schedulers shuffle node order with a Mersenne-Twister rng in stock /
phase-3 placement; the vectorized engine offers `shuffle="none"`
(deterministic nid order — pass the Python scheduler an identity-shuffle
rng to compare) or `shuffle="random"` (counter-based `jax.random`
permutation per tick).

Scenario sweeps batch over (credit seeds x fleet mixes x scheduler modes x
telemetry modes): build one `Scenario` per configuration with
`build_scenario`, group them by static `VecSimConfig` — every field is
compile-time static — `stack_scenarios`, and `run_batch` jit-compiles one
scan for the whole group. `repro.sweep` orchestrates all of that for grids
(spec -> compile groups -> sharded/chunked/resumable execution -> tidy
artifacts); with `sample_period > 0` the scan also streams per-tick
timeline ys (credit mean/std, utilization, queue depth) sampled exactly
where `Simulation.run` records its timeline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.annotations import Annotation, Task
from repro.core.cluster import Node
from repro.core.simulator import Job
from repro.kernels import megatick as _mk
from repro.kernels import ops

# annotation codes in the task class array
CLS_PAD, CLS_NONE, CLS_BURST_CPU, CLS_BURST_DISK, CLS_NET = -1, 0, 1, 2, 3

_ANN_CODE = {
    Annotation.NONE: CLS_NONE,
    Annotation.BURST_CPU: CLS_BURST_CPU,
    Annotation.BURST_DISK: CLS_BURST_DISK,
    Annotation.NETWORK: CLS_NET,
}

_NEVER = _mk.NEVER        # "no telemetry sample yet" timestamp sentinel
_INF = np.float64(np.inf)


@dataclasses.dataclass(frozen=True)
class VecSimConfig:
    """Static (compile-time) sweep configuration. One `run_batch` call
    covers scenarios sharing these; sweep over the rest via the batch axis."""
    dt: float = 1.0
    n_ticks: int = 4096
    resource: str = "cpu"            # cpu | disk | joint (credit pool driving CASH)
    scheduler: str = "cash"          # cash | stock | cash-joint
    telemetry: str = "predicted"     # predicted | stale | oracle
    shuffle: str = "none"            # none | random (stock / phase-3 node order)
    actual_period: float = 300.0     # CloudWatch 5-min actuals
    usage_period: float = 60.0       # CloudWatch 1-min utilization
    impl: str = "auto"               # bucket-serve kernel path (ops.bucket_serve)
    seed: int = 0                    # base key for shuffle="random"
    sample_period: float = 0.0       # timeline ys emission period (0 = off)
    joint_anti_affinity: bool = True  # cash-joint: interleave burst classes
    joint_cpu_weight: float = 0.5    # cash-joint pool weight (0.5 = min-rule)
    # open-loop traffic (repro.traffic): none | poisson | diurnal | replay
    traffic: str = "none"
    table_slots: int = 0             # ring-buffer capacity (0 = 2 x fleet slots)
    slo_bins: int = 64               # latency/queue-wait histogram bins
    slo_max_s: float = 0.0           # histogram upper edge (0 = the horizon)
    emit_task_times: bool = True     # closed batch: carry per-task start/finish
    # whole-tick megakernel (ops.megatick): auto | fused | unfused.
    # "auto" fuses only where eligible AND the backend is TPU — on CPU the
    # kernel's (T, N) interval matrix loses to the packed cumsum + table
    # gather (measured), so "auto" keeps the unfused tick there.
    fusion: str = "auto"
    unroll: int = 1                  # ticks unrolled per lax.scan step
    # fault injection (repro.faults): none | spot | crash | degrade
    faults: str = "none"
    max_retries: int = 3             # node kills a task survives before shed
    # CASH placement blacklisting: skip nodes whose ESTIMATED credits
    # deplete within the horizon at their current demand (the
    # sched.straggler time-to-deplete contract) and, under mortal fault
    # modes, nodes due to preempt inside the notice window (the spot
    # two-minute warning). 0 disables either term.
    blacklist_horizon_s: float = 0.0
    preempt_notice_s: float = 0.0
    # decision-trace event ring (repro.obs.ring) carried through the scan:
    # ring capacity in events (grown to one per-tick candidate block when
    # smaller). 0 disables tracing entirely — the scan carries ZERO trace
    # state and compiles to the identical program (the same contract as
    # faults/traffic; asserted by tests/test_obs.py).
    trace_slots: int = 0


def sample_tick_indices(n_ticks: int, dt: float,
                        sample_period: float) -> Tuple[int, ...]:
    """Tick indices at which `Simulation.run` records a timeline sample:
    greedy `now >= next_sample` with `next_sample += sample_period` per hit.
    Static (host-side) — the engine gathers its per-tick scan ys at exactly
    these positions so the batched timeline aligns sample-for-sample with
    the Python simulator's. ``now`` is *accumulated* (`now += dt`), not
    computed as `t * dt`, to reproduce the Python loop's float drift for dt
    values that are not exactly representable (e.g. 0.1)."""
    idx: List[int] = []
    next_sample = 0.0
    now = 0.0
    for t in range(n_ticks):
        if now >= next_sample:
            idx.append(t)
            next_sample += sample_period
        now += dt
    return tuple(idx)


# ---------------------------------------------------------------------------
# scenario construction: Python Node/Job objects -> arrays
# ---------------------------------------------------------------------------

def _bucket_fields(bucket) -> Tuple[float, float, float, float]:
    return (float(bucket.baseline), float(bucket.burst),
            float(bucket.capacity), float(bucket.balance))


# every per-node array a scenario carries (shared by the closed-batch and
# traffic builders/stackers)
NODE_ARRAY_KEYS = ("slots", "vcpus", "cpu_unlimited", "node_pad") + tuple(
    f"{name}_{fld}" for name in ("cpu", "disk", "peak", "sus")
    for fld in ("baseline", "burst", "capacity", "balance0"))


def node_arrays(nodes: Sequence[Node]) -> Dict[str, np.ndarray]:
    """Freeze a cluster's nodes into the per-node scenario arrays."""
    f = np.float64
    sc: Dict[str, np.ndarray] = {
        "slots": np.array([n.slots for n in nodes], np.int32),
        "vcpus": np.array([n.spec.vcpus for n in nodes], f),
        "cpu_unlimited": np.array([1.0 if n.cpu.unlimited else 0.0
                                   for n in nodes], f),
        "node_pad": np.zeros(len(nodes), bool),
    }
    for name, get in (("cpu", lambda n: n.cpu), ("disk", lambda n: n.disk),
                      ("peak", lambda n: n.net.peak),
                      ("sus", lambda n: n.net.sustained)):
        cols = np.array([_bucket_fields(get(n)) for n in nodes], f).reshape(
            len(nodes), 4) if nodes else np.zeros((0, 4), f)
        sc[f"{name}_baseline"] = cols[:, 0]
        sc[f"{name}_burst"] = cols[:, 1]
        sc[f"{name}_capacity"] = cols[:, 2]
        sc[f"{name}_balance0"] = cols[:, 3]
    return sc


def scenario_task_order(jobs: Sequence[Job],
                        submit: str = "parallel") -> List[Tuple[int, Task]]:
    """(job index, task) pairs in scenario array order — the queue order the
    engine schedules in. Use this to map the per-task ``start``/``finish``
    output arrays back to Task objects (e.g. per-vertex phase sums)."""
    if submit == "parallel":
        order: List[Tuple[int, Task]] = []
        lists = [list(j.tasks) for j in jobs]
        for wave in range(max((len(l) for l in lists), default=0)):
            for ji, lst in enumerate(lists):
                if wave < len(lst):
                    order.append((ji, lst[wave]))
        return order
    if submit == "sequential":
        return [(ji, t) for ji, j in enumerate(jobs) for t in j.tasks]
    raise ValueError(submit)


def build_scenario(nodes: Sequence[Node], jobs: Sequence[Job], *,
                   submit: str = "parallel",
                   rng_seed: int = 0) -> Dict[str, np.ndarray]:
    """Freeze one scenario (a cluster + workload) into arrays.

    ``submit="parallel"`` interleaves tasks round-robin across jobs exactly
    like ``Simulation.submit_parallel`` (all jobs wave 0);
    ``submit="sequential"`` gates job k+1 on job k finishing (wave = job
    index), like ``Simulation.submit_sequential``. Task array order IS the
    queue order, so schedulers index it directly. Only static task fields
    are read — the same Job objects can still be run through the Python
    oracle afterwards.

    ``rng_seed`` is a *per-scenario* stream id for ``shuffle="random"``:
    the engine folds it into ``PRNGKey(cfg.seed)``, so a seed sweep batches
    into ONE compile instead of one per VecSimConfig.seed value.
    """
    order = scenario_task_order(jobs, submit)
    if submit == "parallel":
        waves = np.zeros(len(order), np.int32)
        n_waves = 1
    else:
        waves = np.array([ji for ji, _ in order], np.int32)
        n_waves = max(len(jobs), 1)

    tasks = [t for _, t in order]
    T = len(tasks)
    tid_to_idx = {t.tid: i for i, t in enumerate(tasks)}

    # dependency groups: unique dep-sets -> one released-counter each.
    # Both workload generators attach whole-stage dep sets, so G << T and
    # readiness is two O(G x T) ops per tick instead of a T x T matmul.
    group_of: Dict[frozenset, int] = {}
    dep_group = np.full(T, -1, np.int32)
    thresholds = np.ones(T, np.float64)
    for i, (ji, t) in enumerate(order):
        if t.depends_on:
            key = frozenset(t.depends_on)
            dep_group[i] = group_of.setdefault(key, len(group_of))
            th = t.dep_threshold
            thresholds[i] = jobs[ji].dep_threshold if th is None else th
    G = len(group_of)
    member = np.zeros((G, T), np.float64)
    group_size = np.ones(G, np.float64)
    for key, g in group_of.items():
        idxs = [tid_to_idx[d] for d in key if d in tid_to_idx]
        member[g, idxs] = 1.0
        group_size[g] = float(len(key))

    f = np.float64
    sc: Dict[str, np.ndarray] = {
        # --- tasks (T,) in queue order -------------------------------------
        "work_cpu": np.array([t.work_cpu for t in tasks], f),
        "work_disk": np.array([t.work_disk for t in tasks], f),
        "work_net": np.array([t.work_net for t in tasks], f),
        # the simulator caps per-slot CPU demand at one core
        "dem_cpu": np.array([min(t.demand_cpu, 1.0) for t in tasks], f),
        "dem_disk": np.array([t.demand_disk for t in tasks], f),
        "dem_net": np.array([t.demand_net for t in tasks], f),
        "cls": np.array([_ANN_CODE[t.annotation] for t in tasks], np.int32),
        "wave": waves,
        "job": np.array([ji for ji, _ in order], np.int32),
        "dep_group": dep_group,
        "dep_threshold": thresholds,
        "task_pad": np.zeros(T, bool),
        # --- dependency groups (G, T) / (G,) -------------------------------
        "member": member,
        "group_size": group_size,
        # --- per-scenario scalars -------------------------------------------
        "rng_seed": np.int32(rng_seed),
    }
    sc.update(node_arrays(nodes))
    sc["n_waves"] = np.int32(n_waves)
    sc["n_jobs"] = np.int32(len(jobs))
    return sc


def stack_scenarios(scenarios: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    """Pad every scenario to the sweep's max (tasks, nodes, groups, waves,
    jobs) and stack on a leading axis. Padded tasks are born released with
    class CLS_PAD; padded nodes have zero slots and inert buckets.

    Open-loop traffic scenarios (built by `repro.traffic.arrivals`, marked
    by their template table) dispatch to the traffic stacker."""
    if scenarios and "tmpl_work" in scenarios[0]:
        from repro.traffic.arrivals import stack_traffic_scenarios
        return stack_traffic_scenarios(scenarios)
    Ts = [len(s["work_cpu"]) for s in scenarios]
    Ns = [len(s["slots"]) for s in scenarios]
    Gs = [s["member"].shape[0] for s in scenarios]
    T, N, G = max(Ts), max(Ns), max(Gs)
    W = max(int(s["n_waves"]) for s in scenarios)
    J = max(int(s["n_jobs"]) for s in scenarios)
    # fault-process scalars (repro.faults.attach_fault_process) ride
    # through per-scenario; presence must be uniform — a half-faulty
    # group has no consistent static `cfg.faults`
    has_fl = any("fl_p_kill" in s for s in scenarios)
    if has_fl and not all("fl_p_kill" in s for s in scenarios):
        raise ValueError("scenarios in one group must uniformly carry "
                         "fault parameters (attach_fault_process on all "
                         "or none)")

    out: Dict[str, List[np.ndarray]] = {}
    for s in scenarios:
        t_pad, n_pad, g_pad = T - len(s["work_cpu"]), N - len(s["slots"]), \
            G - s["member"].shape[0]

        def pt(key, fill=0.0):
            a = s[key]
            return np.concatenate([a, np.full(t_pad, fill, a.dtype)]) if t_pad else a

        def pn(key, fill=0.0):
            a = s[key]
            return np.concatenate([a, np.full(n_pad, fill, a.dtype)]) if n_pad else a

        row = {k: pt(k) for k in ("work_cpu", "work_disk", "work_net",
                                  "dem_cpu", "dem_disk", "dem_net",
                                  "dep_threshold")}
        row["cls"] = pt("cls", CLS_PAD)
        row["wave"] = pt("wave", 0)
        row["job"] = pt("job", J)            # padded tasks -> overflow segment
        row["dep_group"] = pt("dep_group", -1)
        row["task_pad"] = pt("task_pad", True)
        mem = s["member"]
        mem = np.pad(mem, ((0, g_pad), (0, t_pad)))
        row["member"] = mem
        row["group_size"] = np.concatenate(
            [s["group_size"], np.ones(g_pad, s["group_size"].dtype)])
        for k in ("slots", "vcpus", "cpu_unlimited"):
            row[k] = pn(k)
        row["node_pad"] = pn("node_pad", True)
        for name in ("cpu", "disk", "peak", "sus"):
            for fld in ("baseline", "burst", "capacity", "balance0"):
                row[f"{name}_{fld}"] = pn(f"{name}_{fld}")
        row["n_waves"] = np.int32(W)
        row["n_jobs"] = s["n_jobs"]
        row["rng_seed"] = s.get("rng_seed", np.int32(0))
        if has_fl:
            for k in s:
                if k.startswith("fl_"):
                    row[k] = s[k]
        for k, v in row.items():
            out.setdefault(k, []).append(np.asarray(v))
    batch = {k: np.stack(v) for k, v in out.items()}
    batch["_meta"] = np.array([T, N, G, W, J])  # static dims (host side)
    return batch


# ---------------------------------------------------------------------------
# placement primitives (Algorithm 1 in array form)
# ---------------------------------------------------------------------------

# Scheduling must re-rank nodes and queue prefixes every tick. The
# formulations below deliberately avoid argsort / searchsorted / scatter —
# under vmap those serialize per scenario on XLA:CPU and dominated the
# sweep's wall clock. Everything task-sized stays O(T) elementwise (plus
# ONE packed cumsum and one small matmul per tick); per-node bookkeeping is
# (N, N) / (N, S) comparison matrices — N is a handful of nodes.

def _bucket_rank(cum: jnp.ndarray, rank: jnp.ndarray) -> jnp.ndarray:
    """searchsorted(cum, rank, side='right') as a comparison-sum."""
    return jnp.sum(cum[None, :] <= rank[:, None], axis=1, dtype=jnp.int32)


def _node_orders(key_vals: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Node visit orders (descending, ascending) by credit key with nid
    tie-break — `sorted(nodes, key=(+-credit, nid))` as comparison counts."""
    n = key_vals.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    ck, cj = key_vals[None, :], key_vals[:, None]
    tie = (ck == cj) & (ids[None, :] < ids[:, None])
    rank_desc = jnp.sum((ck > cj) | tie, axis=1, dtype=jnp.int32)
    rank_asc = jnp.sum((ck < cj) | tie, axis=1, dtype=jnp.int32)

    def invert(rank):
        m = rank[None, :] == ids[:, None]
        return jnp.sum(jnp.where(m, ids[None, :], 0), axis=1).astype(jnp.int32)

    return invert(rank_desc), invert(rank_asc)


def _rank_desc(key_vals: jnp.ndarray) -> jnp.ndarray:
    """Per-node position in the descending credit visit order (the
    uninverted first half of `_node_orders`): rank_desc[n] = rank of node
    n in ``sorted(nodes, key=(-credit, nid))``. The decision trace records
    it on placement events as "the credit rank that won the slot"."""
    n = key_vals.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    ck, cj = key_vals[None, :], key_vals[:, None]
    tie = (ck == cj) & (ids[None, :] < ids[:, None])
    return jnp.sum((ck > cj) | tie, axis=1, dtype=jnp.int32)


def _unpermute(order_ids: jnp.ndarray, vals_sorted: jnp.ndarray) -> jnp.ndarray:
    """vals[order_ids[i]] = vals_sorted[i] without scatter: (N, N) one-hot."""
    n = order_ids.shape[0]
    m = order_ids[:, None] == jnp.arange(n, dtype=order_ids.dtype)[None, :]
    return jnp.sum(jnp.where(m, vals_sorted[:, None], 0),
                   axis=0).astype(vals_sorted.dtype)


def _packed_ranks(*masks: jnp.ndarray) -> List[jnp.ndarray]:
    """In-class queue ranks (cumsum of each mask, minus one). Per-tick (T,)
    cumsums are the scan's costliest CPU primitive, so up to three masks are
    packed into bit fields of a single int32 cumsum when T allows."""
    t = masks[0].shape[0]
    if t < 1024 and len(masks) <= 3:
        combined = masks[0].astype(jnp.int32)
        for i, m in enumerate(masks[1:], start=1):
            combined = combined + (m.astype(jnp.int32) << (10 * i))
        cum = jnp.cumsum(combined)
        return [((cum >> (10 * i)) & 1023) - 1 for i in range(len(masks))]
    stacked = jnp.stack(masks).astype(jnp.int32)
    cum = jnp.cumsum(stacked, axis=-1) - 1
    return [cum[i] for i in range(len(masks))]


# Each placement phase is factored into (a) tiny per-node bookkeeping in
# (N,)- / (N*smax,)-space and (b) a rank -> node LOOKUP TABLE over the slot
# rank space (at most N*smax entries). The per-task work of a whole tick
# then collapses to ONE packed cumsum plus ONE stacked table gather — on
# CPU every unfused (T,)-wide op costs ~0.1 ms x ticks x sweeps, so the
# breaker-op count is the figure of merit here, not FLOPs.

def _pack_counts(order_ids: jnp.ndarray, free: jnp.ndarray,
                 n_pend: jnp.ndarray):
    """Phase 1/3 slot-fill bookkeeping: nodes visited in ``order_ids``
    order, each packed before moving on. Returns (cumulative capacity in
    visit order, per-node assigned count)."""
    cap = free[order_ids]
    cum = jnp.cumsum(cap)
    taken_sorted = jnp.clip(n_pend - (cum - cap), 0, cap)
    return cum, _unpermute(order_ids, taken_sorted)


def _pack_table(order_ids: jnp.ndarray, cum: jnp.ndarray, ls: int) -> jnp.ndarray:
    """rank -> node table for a slot-fill phase (rank r lands on the node
    whose cumulative-capacity range covers r)."""
    r = jnp.arange(ls, dtype=jnp.int32)
    slot = _bucket_rank(cum, r)
    return order_ids[jnp.clip(slot, 0, order_ids.shape[0] - 1)]


def _rr_table(order_ids: jnp.ndarray, free: jnp.ndarray, n_pend: jnp.ndarray,
              smax: int, ls: int):
    """Phase 2 (at most one task per node per round, nodes visited in
    ``order_ids`` order each round) as a rank -> node table: cell (j, s) of
    the (node, round) grid has global rank `rounds-before + nodes-earlier-
    this-round`; inverting that over the <= N*smax cells yields the table.
    Returns (total assignable, table, per-node assigned count)."""
    n = order_ids.shape[0]
    cap = free[order_ids]                                   # (N,)
    s_idx = jnp.arange(smax, dtype=cap.dtype)               # (S,)
    gti = (cap[:, None] > s_idx[None, :]).astype(jnp.int32)  # (N, S)
    c_s = jnp.sum(gti, axis=0, dtype=jnp.int32)             # (S,) round sizes
    cumc = jnp.cumsum(c_s)
    prior = jnp.cumsum(gti, axis=0) - gti                   # exclusive (N, S)
    # invpos[p, s] = visit-order position of the p-th participant of round s
    pp = jnp.arange(n, dtype=jnp.int32)
    hit = (prior[None, :, :] == pp[:, None, None]) & (gti[None, :, :] > 0)
    invpos = jnp.sum(jnp.where(hit, pp[None, :, None], 0), axis=1,
                     dtype=jnp.int32)                       # (N, S)
    r = jnp.arange(ls, dtype=jnp.int32)
    s_r = jnp.clip(_bucket_rank(cumc, r), 0, smax - 1)
    p_r = jnp.clip(r - (cumc[s_r] - c_s[s_r]), 0, n - 1)
    table = order_ids[invpos[p_r, s_r]]
    taken_sorted = jnp.sum((gti > 0) & ((cumc - c_s)[None, :] + prior < n_pend),
                           axis=1, dtype=jnp.int32)
    return cumc[-1], table, _unpermute(order_ids, taken_sorted)


def _gather_phase_nodes(tables, totals, masks, ranks, ls: int):
    """The single per-task placement op: stacked rank -> node gather over
    all phase tables, masked to each phase's class and assignable range."""
    if len(tables) == 1:
        node = tables[0][jnp.clip(ranks[0], 0, ls - 1)]
        ok = masks[0] & (ranks[0] < totals[0])
        return jnp.where(ok, node, -1)
    tabs = jnp.stack(tables)                                # (P, LS)
    rk = jnp.stack(ranks)                                   # (P, T)
    mk = jnp.stack(masks)
    tot = jnp.stack(totals)
    nodes = jnp.take_along_axis(tabs, jnp.clip(rk, 0, ls - 1), axis=1)
    ok = mk & (rk < tot[:, None])
    anodes = jnp.where(ok, nodes, -1)
    assign = anodes[0]
    for p in range(1, len(tables)):
        assign = jnp.where(assign >= 0, assign, anodes[p])
    return assign


def _joint_split(free_sorted: jnp.ndarray, prefer_cpu: jnp.ndarray,
                 n_cpu: jnp.ndarray, n_disk: jnp.ndarray,
                 alternate: bool = True):
    """JointCashScheduler phase 1: per node (visited in joint-credit
    descending order) alternate the two burst classes starting from the
    richer pool. ``alternate=False`` (the anti-affinity ablation) packs the
    preferred class exhaustively before the other, like running Algorithm 1
    phase 1 per class. Returns per-node (cpu_take, disk_take)."""
    def body(carry, inp):
        rc, rd = carry
        f, pref = inp
        t = jnp.minimum(f, rc + rd)
        if alternate:
            ceil_h, floor_h = (t + 1) // 2, t // 2
            want_cpu = jnp.where(pref, ceil_h, floor_h)
        else:
            want_cpu = jnp.where(pref, t, jnp.zeros_like(t))
        cpu_take = jnp.minimum(rc, jnp.maximum(want_cpu, t - rd))
        disk_take = t - cpu_take
        return (rc - cpu_take, rd - disk_take), (cpu_take, disk_take)

    (_, _), (cpu_take, disk_take) = jax.lax.scan(
        body, (n_cpu, n_disk), (free_sorted, prefer_cpu))
    return cpu_take, disk_take


# ---------------------------------------------------------------------------
# the scan engine
# ---------------------------------------------------------------------------

def _telemetry_estimate(cfg: VecSimConfig, tel: Dict[str, jnp.ndarray],
                        balance: jnp.ndarray, baseline: jnp.ndarray,
                        capacity: jnp.ndarray, now: jnp.ndarray,
                        mode: str) -> jnp.ndarray:
    """Algorithm 2 / ablations, array form (mirrors core.credits). The
    math lives in kernels.megatick so the fused whole-tick kernel and this
    unfused path share one source of truth."""
    return _mk.telemetry_estimate(tel, balance, baseline, capacity, now,
                                  mode)


def _telemetry_observe(cfg: VecSimConfig, tel: Dict[str, jnp.ndarray],
                       balance: jnp.ndarray, rate: jnp.ndarray,
                       now: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """CloudWatch emulation: publish actuals / windowed usage on period
    boundaries (mirrors core.credits.CloudWatchEmulator.observe; math in
    kernels.megatick, shared with the fused whole-tick kernel)."""
    return _mk.telemetry_observe(tel, balance, rate, now,
                                 actual_period=cfg.actual_period,
                                 usage_period=cfg.usage_period)


def fusion_eligible(cfg: VecSimConfig,
                    active: Tuple[bool, bool, bool, bool, bool]) -> bool:
    """Whether (cfg, batch statics) fit the whole-tick megakernel: a
    single placement phase over the cpu pool alone, deterministic node
    order. The round-robin network phase and multi-phase ticks keep the
    unfused path."""
    if cfg.resource != "cpu" or cfg.shuffle != "none":
        return False
    if cfg.scheduler not in ("cash", "stock"):
        return False
    # fault injection / placement blacklisting thread through the unfused
    # tick only — the megakernel has no liveness plumbing
    if cfg.faults != "none" or cfg.blacklist_horizon_s > 0.0:
        return False
    if active[0] or active[1]:          # disk / network pools in play
        return False
    if cfg.scheduler == "stock":
        return True
    # cash: exactly one placement phase, and never the round-robin one
    return (int(active[2]) + int(active[3]) + int(active[4]) == 1
            and not active[3])


def fusion_choice(cfg: VecSimConfig,
                  active: Tuple[bool, bool, bool, bool, bool],
                  platform: Optional[str] = None) -> str:
    """Resolve ``cfg.fusion`` to the tick implementation that will run:
    ``"fused"`` (ops.megatick) or ``"unfused"``. ``fusion="fused"`` on an
    ineligible configuration raises rather than silently diverging.

    ``platform`` overrides the backend the ``"auto"`` policy consults
    (``jax.default_backend()`` when None) — BENCH_vecsim.json
    ``tick_phases`` measured the fused megatick ~1.9x SLOWER than the
    unfused tick on CPU (the (T, N) interval matrix loses to the packed
    cumsum + table gather there), so auto fuses on TPU only; the
    parameter exists so that decision is unit-testable per platform."""
    if cfg.fusion == "unfused":
        return "unfused"
    eligible = fusion_eligible(cfg, active)
    if cfg.fusion == "fused":
        if not eligible:
            raise ValueError(
                "fusion='fused' needs a single-phase cpu-pool cash|stock "
                f"configuration with shuffle='none'; got scheduler="
                f"{cfg.scheduler!r} resource={cfg.resource!r} "
                f"shuffle={cfg.shuffle!r} active={active}")
        return "fused"
    if cfg.fusion != "auto":
        raise ValueError(f"fusion must be auto|fused|unfused, "
                         f"got {cfg.fusion!r}")
    plat = jax.default_backend() if platform is None else platform
    return "fused" if (eligible and plat == "tpu") else "unfused"


def _fresh_telemetry(n: int, dtype) -> Dict[str, jnp.ndarray]:
    z = jnp.zeros(n, dtype)
    return {"act_bal": z, "act_t": jnp.full(n, _NEVER, dtype),
            "use_rate": z, "use_t": jnp.full(n, _NEVER, dtype),
            "accum": z, "win_start": z}


def _moments(x: jnp.ndarray, nmask: jnp.ndarray, n_real: jnp.ndarray):
    """Masked first/second timeline moments of a per-node series. The tick
    emits RAW moments; `batched_engine` turns them into the std AFTER the
    scan — the `m2 - m*m` subtraction is FMA-contraction-sensitive, and
    keeping it out of the loop body makes the timeline bitwise-stable
    across `cfg.unroll` codegen variants."""
    m = jnp.sum(jnp.where(nmask, x, 0.0)) / n_real
    m2 = jnp.sum(jnp.where(nmask, x * x, 0.0)) / n_real
    return m, m2


def _timeline_std(tl: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Replace the streamed `_<pool>_credit_m2` moments with the public
    `<pool>_credit_std` series (see `_moments`)."""
    out = {}
    for k, v in tl.items():
        if k.startswith("_") and k.endswith("_credit_m2"):
            m = tl[k[1:-3] + "_mean"]
            out[k[1:-3] + "_std"] = jnp.sqrt(jnp.maximum(0.0, v - m * m))
        else:
            out[k] = v
    return out


def _simulate_one(cfg: VecSimConfig, smax: int, n_waves: int, n_jobs: int,
                  active: Tuple[bool, bool, bool, bool, bool],
                  sc: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """One scenario end-to-end; vmapped over the batch by `run_batch`.

    ``active`` = (disk, net, burst-class, network-class, plain-class):
    compile-time flags letting sweeps skip untouched buckets' serve paths
    and statically empty scheduling phases entirely.
    """
    T = sc["work_cpu"].shape[0]
    N = sc["slots"].shape[0]
    G = sc["member"].shape[0]
    dtype = sc["work_cpu"].dtype
    dt = cfg.dt
    joint = cfg.resource == "joint"
    tel_mode = "predicted" if joint else cfg.telemetry
    # stock never reads credits: skip telemetry state + estimates entirely
    need_credits = cfg.scheduler != "stock"
    act_disk = active[0] or cfg.resource in ("disk", "joint")
    act_net = active[1]
    p_burst, p_netcls, p_plain = active[2], active[3], active[4]
    # whole-tick megakernel (ops.megatick) vs the unfused tick — resolved
    # at trace time; bitwise-identical either way (tests/test_megatick.py)
    fused = fusion_choice(cfg, active) == "fused"

    # ---- fault injection statics (repro.faults) -----------------------
    # mortal modes kill nodes (tasks requeue); degrade only sags burst.
    # Streams are derived OUTSIDE the tick scan and fed as xs, so the
    # fault-free path carries nothing and compiles identically.
    faulty = cfg.faults != "none"
    mortal = cfg.faults in ("spot", "crash")
    degrading = cfg.faults == "degrade"
    use_black = (cfg.scheduler == "cash" and cfg.resource == "cpu"
                 and (cfg.blacklist_horizon_s > 0.0
                      or (mortal and cfg.preempt_notice_s > 0.0)))
    ev = None
    if faulty:
        from repro.faults import processes as _faults
        ev = _faults.fault_events(cfg, sc, dtype)
    if use_black:
        from repro.sched import straggler as _straggler

    is_burst = (sc["cls"] == CLS_BURST_CPU) | (sc["cls"] == CLS_BURST_DISK)
    is_net = sc["cls"] == CLS_NET
    is_plain = sc["cls"] == CLS_NONE
    ids = jnp.arange(N, dtype=jnp.int32)
    zero_t = jnp.zeros(T, dtype)
    zero_n = jnp.zeros(N, dtype)

    # the scan carry holds only what this configuration can touch — an
    # untouched (T,)-wide passenger costs a copy per tick per scenario
    state = {
        "done_cpu": zero_t,
        "node_of": jnp.full(T, -1, jnp.int32),
        "released": sc["task_pad"],
        # incremental per-node occupancy: running count after placement and
        # the pending releases booked during last tick's serve — recomputing
        # them from node_of would cost a (T, N) reduction every tick
        "run_cnt": jnp.zeros(N, jnp.int32),
        "rel_cnt": jnp.zeros(N, jnp.int32),
        "cpu_bal": sc["cpu_balance0"], "cpu_sur": zero_n,
        "cpu_work_total": jnp.zeros((), dtype),
        "busy_seconds": jnp.zeros((), dtype),
    }
    if cfg.emit_task_times:
        state["start"] = jnp.full(T, _INF, dtype)
        state["finish"] = jnp.full(T, _INF, dtype)
    else:
        # scalar-metric sweeps drop the two (T,)-wide timestamp carries;
        # makespan only needs the time of the LAST release
        state["last_rel"] = jnp.full((), -jnp.inf, dtype)
    if act_disk:
        state["done_disk"] = zero_t
        state["disk_bal"] = sc["disk_balance0"]
    if act_net:
        state["done_net"] = zero_t
        state["peak_bal"] = sc["peak_balance0"]
        state["sus_bal"] = sc["sus_balance0"]
    if n_waves > 1:
        state["wave_adm"] = jnp.int32(0)
        state["wave_t"] = jnp.zeros(n_waves, dtype).at[1:].set(jnp.inf)
    if tel_mode != "oracle" and need_credits:
        if joint or cfg.resource == "cpu":
            state["tel_cpu"] = _fresh_telemetry(N, dtype)
        if joint or cfg.resource == "disk":
            state["tel_disk"] = _fresh_telemetry(N, dtype)
    if cfg.shuffle == "random":
        # per-scenario stream: fold the batched rng_seed into the static
        # base key, so a seed sweep is ONE compile (cfg stays constant)
        state["key"] = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                          sc["rng_seed"])
    if mortal:
        # per-task retry counts + lost work are the ONLY fault carries;
        # kill-event totals reduce over the precomputed xs streams free
        state["retry"] = jnp.zeros(T, jnp.int32)
        state["work_lost"] = jnp.zeros((), dtype)

    # ---- decision trace (repro.obs.ring): carried event ring ----------
    # trace_slots == 0 adds NO carries and NO ops: the compiled program is
    # identical to an untraced run (tests/test_obs.py asserts bitwise).
    tracing = cfg.trace_slots > 0
    if tracing:
        if (cfg.resource != "cpu" or cfg.scheduler not in ("cash", "stock")
                or cfg.shuffle != "none" or act_disk or act_net or p_netcls):
            raise NotImplementedError(
                "trace_slots > 0 mirrors the replay-oracle scope: cpu pool "
                "only, cash|stock, shuffle='none', no disk/net work")
        from repro.obs import ring as _obsring
        # per-tick candidate block width: PLACE(T) + DEPLETE/REGEN(2N),
        # plus PREEMPT/SHED(2T) under mortal faults and BLACKLIST(N) when
        # blacklisting is on — scatter-index uniqueness needs one block
        width = T + 2 * N + (2 * T if mortal else 0) \
            + (N if use_black else 0)
        state["ev_i"], state["ev_f"], state["ev_head"] = \
            _obsring.ring_init(max(cfg.trace_slots, width))

    emit_tl = cfg.sample_period > 0.0

    def tick(st, inp):
        if faulty:
            t, fx = inp
        else:
            t = inp
        now = t.astype(dtype) * dt

        # ---- 1) release finished tasks (work completed last tick) --------
        rem_cpu = sc["work_cpu"] - st["done_cpu"]
        rem_disk = sc["work_disk"] - st["done_disk"] if act_disk else zero_t
        rem_net = sc["work_net"] - st["done_net"] if act_net else zero_t
        started = st["node_of"] >= 0
        finished = rem_cpu <= 1e-9
        if act_disk:
            finished &= rem_disk <= 1e-9
        if act_net:
            finished &= rem_net <= 1e-9
        newly = finished & started & ~st["released"]
        released = st["released"] | newly
        if cfg.emit_task_times:
            finish = jnp.where(newly, now, st["finish"])
            last_rel = None
        else:
            finish = None
            last_rel = jnp.where(jnp.any(newly), now, st["last_rel"])
        run_cnt = st["run_cnt"] - st["rel_cnt"]     # occupancy after release

        # ---- 1b) fault step (repro.faults): kill/restore nodes -----------
        # Runs AFTER release — work that completed last tick on a node
        # dying now still counts — and BEFORE admission/placement, so
        # requeued tasks compete for slots again this very tick.
        alive_t = notice_t = scale_t = None
        retry = work_lost = None
        if degrading:
            scale_t = fx["scale"]
        if mortal:
            alive_t, died_t = fx["alive"], fx["died"]
            notice_t = fx.get("notice")
            st = dict(st)
            if cfg.faults == "crash":
                # the replacement arrives FRESH: bucket + telemetry reset
                # before this tick's estimate/serve read them (cumulative
                # surplus is fleet accounting and survives the swap)
                fresh_t = fx["fresh"]
                st["cpu_bal"] = jnp.where(fresh_t, sc["cpu_balance0"],
                                          st["cpu_bal"])
                if act_disk:
                    st["disk_bal"] = jnp.where(fresh_t, sc["disk_balance0"],
                                               st["disk_bal"])
                if act_net:
                    st["peak_bal"] = jnp.where(fresh_t, sc["peak_balance0"],
                                               st["peak_bal"])
                    st["sus_bal"] = jnp.where(fresh_t, sc["sus_balance0"],
                                              st["sus_bal"])
                for tk in ("tel_cpu", "tel_disk"):
                    if tk in st:
                        blank = _fresh_telemetry(N, dtype)
                        st[tk] = {k: jnp.where(fresh_t, blank[k], v)
                                  for k, v in st[tk].items()}
            # tasks resident on a node that died this tick requeue with a
            # retry count; this attempt's partial work is lost. Past
            # max_retries the task is SHED: released without finishing,
            # excluded from makespan, its dependents unblocked (lost-work
            # accounting, not failure propagation).
            resident = (st["node_of"] >= 0) & ~released
            hit = resident & died_t[jnp.clip(st["node_of"], 0, N - 1)]
            retry = st["retry"] + hit.astype(jnp.int32)
            shed_now = hit & (retry > cfg.max_retries)
            lost = st["done_cpu"]
            if act_disk:
                lost = lost + st["done_disk"]
            if act_net:
                lost = lost + st["done_net"]
            work_lost = st["work_lost"] + jnp.sum(jnp.where(hit, lost, 0.0))
            st["done_cpu"] = jnp.where(hit, 0.0, st["done_cpu"])
            rem_cpu = sc["work_cpu"] - st["done_cpu"]
            if act_disk:
                st["done_disk"] = jnp.where(hit, 0.0, st["done_disk"])
                rem_disk = sc["work_disk"] - st["done_disk"]
            if act_net:
                st["done_net"] = jnp.where(hit, 0.0, st["done_net"])
                rem_net = sc["work_net"] - st["done_net"]
            node_pre = st["node_of"]        # trace: node before the clear
            st["node_of"] = jnp.where(hit, -1, st["node_of"])
            started = st["node_of"] >= 0
            released = released | shed_now
            run_cnt = jnp.where(alive_t, run_cnt, 0)

        # ---- 2) sequential wave admission --------------------------------
        wave_adm = wave_t = None
        if n_waves > 1:
            wave_adm, wave_t = st["wave_adm"], st["wave_t"]
            pending = (~released) & (sc["wave"] <= wave_adm)
            adv = (~jnp.any(pending)) & (wave_adm < n_waves - 1)
            wave_adm = wave_adm + adv.astype(jnp.int32)
            wave_t = jnp.where(adv & (jnp.arange(n_waves) == wave_adm),
                               now, wave_t)

        # ---- 3) telemetry estimates (pre-observe state, like Algorithm 2)
        # (the fused path's estimate happens inside ops.megatick)
        est_cpu = est_disk = None
        if need_credits and not fused and (joint or cfg.resource == "cpu"):
            est_cpu = _telemetry_estimate(cfg, st.get("tel_cpu"),
                                          st["cpu_bal"], sc["cpu_baseline"],
                                          sc["cpu_capacity"], now, tel_mode)
        if need_credits and (joint or cfg.resource == "disk"):
            est_disk = _telemetry_estimate(cfg, st.get("tel_disk"),
                                           st["disk_bal"],
                                           sc["disk_baseline"],
                                           sc["disk_capacity"], now, tel_mode)
        credits = est_disk if cfg.resource == "disk" else est_cpu

        # ---- 4) placement ------------------------------------------------
        dep_ok = jnp.ones(T, bool)
        if G > 0:
            done_cnt = sc["member"] @ released.astype(dtype)
            g = jnp.clip(sc["dep_group"], 0, G - 1)
            frac = done_cnt[g] / sc["group_size"][g]
            dep_ok = (sc["dep_group"] < 0) | \
                (frac + 1e-12 >= sc["dep_threshold"])
        ready = (~started) & (~released) & dep_ok & (sc["cls"] != CLS_PAD)
        if n_waves > 1:
            ready &= sc["wave"] <= wave_adm

        free = sc["slots"] - run_cnt
        if mortal:
            free = jnp.where(alive_t, free, 0)
        if use_black:
            # CASH blacklisting: skip nodes whose ESTIMATED bucket drains
            # within the horizon at the demand they are ALREADY serving
            # (sched.straggler contract) and nodes inside the preemption
            # notice window
            black = jnp.zeros(N, bool)
            tdep = jnp.full(N, jnp.inf, dtype)
            if cfg.blacklist_horizon_s > 0.0:
                running0 = (st["node_of"] >= 0) & ~released
                col0 = jnp.where(running0 & (rem_cpu > 0.0),
                                 sc["dem_cpu"], 0.0)
                oh0 = jnp.where((st["node_of"][:, None] == ids[None, :])
                                & running0[:, None],
                                jnp.ones((), dtype), 0.0)
                dem_pre = jax.lax.dot_general(
                    col0[None, :], oh0, (((1,), (0,)), ((), ())),
                    preferred_element_type=dtype)[0]
                burst_eff = (sc["cpu_burst"] * scale_t if degrading
                             else sc["cpu_burst"])
                # predictive_blacklist IS `time_to_deplete < horizon`;
                # computed in two steps so the trace's blacklist events
                # can carry the predicted time-to-deplete itself
                tdep = _straggler.time_to_deplete_vec(
                    est_cpu, dem_pre, sc["cpu_baseline"], burst_eff,
                    sc["cpu_unlimited"])
                black = tdep < cfg.blacklist_horizon_s
            if notice_t is not None:
                black = black | notice_t
            # deadlock guard: when every free slot is blacklisted the
            # blacklist is void (CASH prefers slow progress to none)
            ok = jnp.any((~black) & (free > 0))
            free = jnp.where(black & ok, 0, free)

        if cfg.shuffle == "random":
            key, sub = jax.random.split(st["key"])
            order3 = jax.random.permutation(sub, ids)
        else:
            key = None
            order3 = ids

        ls = N * smax                      # slot rank space (static)
        tel_fused = None
        if fused:
            # ---- fused 3-6: estimate + placement + serve + observe -------
            if cfg.scheduler == "stock":
                m_pend, by_credit, mk_mode = ready, False, "none"
            elif p_burst:
                m_pend, by_credit, mk_mode = ready & is_burst, True, tel_mode
            else:
                m_pend, by_credit, mk_mode = ready & is_plain, False, tel_mode
            (assign, taken, share_cpu, w_cpu, cpu_bal, sur_add,
             tel_fused) = ops.megatick(
                m_pend, jnp.zeros(T, jnp.int32), jnp.int32(0),
                st["node_of"], ~released, sc["dem_cpu"], rem_cpu > 0.0,
                st["cpu_bal"], sc["cpu_baseline"], sc["cpu_burst"],
                sc["cpu_capacity"], sc["cpu_unlimited"], free,
                st.get("tel_cpu"), now, dt=dt,
                actual_period=cfg.actual_period,
                usage_period=cfg.usage_period, tel_mode=mk_mode,
                by_credit=by_credit, carried_rank=False, impl=cfg.impl)
        elif cfg.scheduler == "stock":
            (r_all,) = _packed_ranks(ready)
            n_all = r_all[-1] + 1
            cum, taken = _pack_counts(order3, free, n_all)
            assign = _gather_phase_nodes(
                [_pack_table(order3, cum, ls)], [cum[-1]], [ready], [r_all], ls)
        elif cfg.scheduler == "cash-joint" and joint:
            cap_cpu = jnp.maximum(sc["cpu_capacity"], 1e-9)
            cap_disk = jnp.maximum(sc["disk_capacity"], 1e-9)
            norm_cpu, norm_disk = est_cpu / cap_cpu, est_disk / cap_disk
            if cfg.joint_cpu_weight != 0.5:
                # weighted min-rule; w = 0.5 reduces to the plain min
                norm_cpu = norm_cpu * (2.0 * cfg.joint_cpu_weight)
                norm_disk = norm_disk * (2.0 * (1.0 - cfg.joint_cpu_weight))
            jcred = jnp.minimum(norm_cpu, norm_disk)
            desc, asc = _node_orders(jcred)
            prefer = (norm_cpu >= norm_disk)[desc]
            m_cpu = ready & (sc["cls"] == CLS_BURST_CPU)
            m_disk = ready & (sc["cls"] == CLS_BURST_DISK)
            m_net, m_plain = ready & is_net, ready & is_plain
            r_cpu, r_disk, r_net = _packed_ranks(m_cpu, m_disk, m_net)
            (r_plain,) = _packed_ranks(m_plain)
            ct, dtk = _joint_split(free[desc], prefer, r_cpu[-1] + 1,
                                   r_disk[-1] + 1,
                                   alternate=cfg.joint_anti_affinity)
            cum_c, cum_d = jnp.cumsum(ct), jnp.cumsum(dtk)
            t1 = _unpermute(desc, ct) + _unpermute(desc, dtk)
            free1 = free - t1
            tot2, rrtab, t2 = _rr_table(asc, free1, r_net[-1] + 1, smax, ls)
            free2 = free1 - t2
            cum3, t3 = _pack_counts(order3, free2, r_plain[-1] + 1)
            assign = _gather_phase_nodes(
                [_pack_table(desc, cum_c, ls), _pack_table(desc, cum_d, ls),
                 rrtab, _pack_table(order3, cum3, ls)],
                [cum_c[-1], cum_d[-1], tot2, cum3[-1]],
                [m_cpu, m_disk, m_net, m_plain],
                [r_cpu, r_disk, r_net, r_plain], ls)
            taken = t1 + t2 + t3
        else:  # cash (single resource; also joint fleets under one pool)
            desc, asc = _node_orders(credits)
            # classes statically absent from the whole batch contribute no
            # phase — a fleet sweep of pure burst tasks runs phase 1 only
            phase_masks = []
            if p_burst:
                phase_masks.append(ready & is_burst)
            if p_netcls:
                phase_masks.append(ready & is_net)
            if p_plain:
                phase_masks.append(ready & is_plain)
            pranks = _packed_ranks(*phase_masks) if phase_masks else []
            tables, totals = [], []
            cur_free, taken, i = free, jnp.zeros(N, jnp.int32), 0
            if p_burst:
                cum, tk = _pack_counts(desc, cur_free, pranks[i][-1] + 1)
                tables.append(_pack_table(desc, cum, ls))
                totals.append(cum[-1])
                cur_free, taken, i = cur_free - tk, taken + tk, i + 1
            if p_netcls:
                tot2, rrtab, tk = _rr_table(asc, cur_free, pranks[i][-1] + 1,
                                            smax, ls)
                tables.append(rrtab)
                totals.append(tot2)
                cur_free, taken, i = cur_free - tk, taken + tk, i + 1
            if p_plain:
                cum, tk = _pack_counts(order3, cur_free, pranks[i][-1] + 1)
                tables.append(_pack_table(order3, cum, ls))
                totals.append(cum[-1])
                taken = taken + tk
            if tables:
                assign = _gather_phase_nodes(tables, totals, phase_masks,
                                             pranks, ls)
            else:
                assign = jnp.full(T, -1, jnp.int32)

        placed = assign >= 0
        tr_place = None
        if tracing:
            if cfg.scheduler == "cash":
                # fused path: recompute the kernel's internal Algorithm-2
                # estimate via the SAME dispatch-layer function — bitwise-
                # identical to what megatick ranked nodes by
                est_tr = est_cpu if not fused else ops.megatick_estimate(
                    st.get("tel_cpu"), st["cpu_bal"], sc["cpu_baseline"],
                    sc["cpu_capacity"], now, tel_mode=tel_mode)
                nsel = jnp.clip(assign, 0, N - 1)
                tr_place = (_rank_desc(est_tr)[nsel], est_tr[nsel])
            else:        # stock never consults credits: rank = node id
                tr_place = (assign, jnp.zeros(T, dtype))
        node_of = jnp.where(placed, assign, st["node_of"])
        start = (jnp.where(placed, now, st["start"])
                 if cfg.emit_task_times else None)
        running = (node_of >= 0) & ~released
        run_cnt = run_cnt + taken
        nidx = jnp.clip(node_of, 0, N - 1)

        # ---- 5) serve + distribute: aggregate demand -> fused kernel -----
        # per-node reductions as ONE small matmul over a started-task
        # one-hot; masks live in the matrix columns (vmapped scatters /
        # where-sums here dominated the sweep before). Each active pool
        # then runs ops.bucket_serve_distribute — the token-bucket serve
        # AND the per-task pro-rata share gather fused into one kernel, so
        # nothing round-trips through a serve-then-gather pair
        onehot = jnp.where((node_of[:, None] == ids[None, :]) &
                           running[:, None], jnp.ones((), dtype), 0.0)
        if not fused:
            cols = [jnp.where(running & (rem_cpu > 0.0), sc["dem_cpu"], 0.0)]
            if act_disk:
                cols.append(jnp.where(running & (rem_disk > 0.0),
                                      sc["dem_disk"], 0.0))
            if act_net:
                cols.append(jnp.where(running & (rem_net > 0.0),
                                      sc["dem_net"], 0.0))
            per_node = jax.lax.dot_general(
                jnp.stack(cols), onehot, (((1,), (0,)), ((), ())),
                preferred_element_type=dtype)                # (C, N)
            dem_cpu = per_node[0]

            # degradation windows sag the burst ceiling only — baseline
            # accrual and capacity are untouched (a slow disk still earns)
            cpu_burst_t = (sc["cpu_burst"] * scale_t if degrading
                           else sc["cpu_burst"])
            share_cpu, w_cpu, cpu_bal, sur_add = ops.bucket_serve_distribute(
                st["cpu_bal"], dem_cpu, sc["cpu_baseline"], cpu_burst_t,
                sc["cpu_capacity"], sc["cpu_unlimited"], nidx,
                sc["dem_cpu"], dt=dt, impl=cfg.impl)
            if mortal:
                # down nodes' buckets FREEZE (instance paused): no spend —
                # their demand is zero — and no regeneration either
                cpu_bal = jnp.where(alive_t, cpu_bal, st["cpu_bal"])

        disk_bal = peak_bal = sus_bal = done_disk = done_net = None
        w_disk = w_net = zero_n
        share_disk = share_net = None
        if act_disk:
            done_disk = st["done_disk"]
            dem_disk = per_node[1]
            disk_burst_t = (sc["disk_burst"] * scale_t if degrading
                            else sc["disk_burst"])
            share_disk, w_disk, disk_bal, _ = ops.bucket_serve_distribute(
                st["disk_bal"], dem_disk, sc["disk_baseline"],
                disk_burst_t, sc["disk_capacity"], zero_n, nidx,
                sc["dem_disk"], dt=dt, impl=cfg.impl)
            if mortal:
                disk_bal = jnp.where(alive_t, disk_bal, st["disk_bal"])
        if act_net:
            done_net = st["done_net"]
            dem_net = per_node[-1]
            # dual network regulator: shape by the peak bucket, then charge
            # the sustained bucket for the work actually delivered; shares
            # pro-rate against the ORIGINAL aggregate demand, not the
            # peak-shaped rate the sustained bucket is served at
            w_pk, peak_bal, _ = ops.bucket_serve(
                st["peak_bal"], dem_net, sc["peak_baseline"],
                sc["peak_burst"], sc["peak_capacity"], zero_n, dt=dt,
                impl=cfg.impl)
            share_net, w_net, sus_bal, _ = ops.bucket_serve_distribute(
                st["sus_bal"], w_pk / dt, sc["sus_baseline"],
                sc["sus_burst"], sc["sus_capacity"], zero_n, nidx,
                sc["dem_net"], dt=dt, impl=cfg.impl, dist_demand=dem_net)
            if mortal:
                peak_bal = jnp.where(alive_t, peak_bal, st["peak_bal"])
                sus_bal = jnp.where(alive_t, sus_bal, st["sus_bal"])

        # fold each pool's fused share into the done counters. The share is
        # already zero wherever the node's aggregate demand is — and done is
        # capped at work_tot every step — so gating on the task's own
        # liveness alone reproduces the old dem>0-masked update bit for bit
        def apply_share(done, work_tot, rem, share):
            upd = running & (rem > 0.0)
            return jnp.where(upd, jnp.minimum(work_tot, done + share), done)

        done_cpu = apply_share(st["done_cpu"], sc["work_cpu"], rem_cpu,
                               share_cpu)
        fin = rem_cpu - (done_cpu - st["done_cpu"]) <= 1e-9
        if act_disk:
            done_disk = apply_share(done_disk, sc["work_disk"], rem_disk,
                                    share_disk)
            fin &= rem_disk - (done_disk - st["done_disk"]) <= 1e-9
        if act_net:
            done_net = apply_share(done_net, sc["work_net"], rem_net,
                                   share_net)
            fin &= rem_net - (done_net - st["done_net"]) <= 1e-9

        # tasks finishing this serve release (and free their slot) next tick
        fin = fin & running
        rel_cnt = jax.lax.dot_general(
            jnp.where(fin, jnp.ones((), dtype), 0.0), onehot,
            (((0,), (0,)), ((), ())),
            preferred_element_type=dtype).astype(jnp.int32)

        # ---- 6) CloudWatch observe (fused: rides in the megakernel) ------
        tel_cpu, tel_disk = st.get("tel_cpu"), st.get("tel_disk")
        if fused:
            tel_cpu = tel_fused
        elif tel_cpu is not None:
            tel_cpu = _telemetry_observe(cfg, tel_cpu, cpu_bal, w_cpu / dt, now)
            if mortal:
                # a paused instance publishes nothing: freeze its metrics
                tel_cpu = {k: jnp.where(alive_t, v, st["tel_cpu"][k])
                           for k, v in tel_cpu.items()}
        if tel_disk is not None:
            tel_disk = _telemetry_observe(cfg, tel_disk, disk_bal,
                                          w_disk / dt, now)
            if mortal:
                tel_disk = {k: jnp.where(alive_t, v, st["tel_disk"][k])
                            for k, v in tel_disk.items()}

        # mirror the initial carry exactly — inactive features stay out
        new_st = {
            "done_cpu": done_cpu,
            "node_of": node_of,
            "released": released, "run_cnt": run_cnt, "rel_cnt": rel_cnt,
            "cpu_bal": cpu_bal, "cpu_sur": st["cpu_sur"] + sur_add,
            "cpu_work_total": st["cpu_work_total"] + jnp.sum(w_cpu),
            "busy_seconds": st["busy_seconds"]
            + jnp.sum((run_cnt > 0).astype(dtype)) * dt,
        }
        if cfg.emit_task_times:
            new_st["start"] = start
            new_st["finish"] = finish
        else:
            new_st["last_rel"] = last_rel
        if act_disk:
            new_st["done_disk"] = done_disk
            new_st["disk_bal"] = disk_bal
        if act_net:
            new_st["done_net"] = done_net
            new_st["peak_bal"] = peak_bal
            new_st["sus_bal"] = sus_bal
        if n_waves > 1:
            new_st["wave_adm"] = wave_adm
            new_st["wave_t"] = wave_t
        if tel_cpu is not None:
            new_st["tel_cpu"] = tel_cpu
        if tel_disk is not None:
            new_st["tel_disk"] = tel_disk
        if cfg.shuffle == "random":
            new_st["key"] = key
        if mortal:
            new_st["retry"] = retry
            new_st["work_lost"] = work_lost

        # ---- 6b) decision trace: one masked ring scatter per tick --------
        if tracing:
            nmask_tr = ~sc["node_pad"]
            # bucket crossings measured serve-input -> post-freeze balance
            dep = (st["cpu_bal"] > 1e-9) & (cpu_bal <= 1e-9) & nmask_tr
            reg = (st["cpu_bal"] <= 1e-9) & (cpu_bal > 1e-9) & nmask_tr
            tidx = jnp.arange(T, dtype=jnp.int32)
            blocks = []
            if mortal:
                blocks.append((hit, _obsring.EV_PREEMPT, tidx, node_pre,
                               retry, lost))
                blocks.append((shed_now, _obsring.EV_SHED, tidx, node_pre,
                               retry, jnp.zeros(T, dtype)))
            if use_black:
                notice_i = (notice_t.astype(jnp.int32)
                            if notice_t is not None
                            else jnp.zeros(N, jnp.int32))
                blocks.append((black & ok, _obsring.EV_BLACKLIST, ids,
                               notice_i, -1, tdep))
            blocks.append((placed, _obsring.EV_PLACE, tidx, assign,
                           tr_place[0], tr_place[1]))
            blocks.append((dep, _obsring.EV_DEPLETE, ids, -1, -1, cpu_bal))
            blocks.append((reg, _obsring.EV_REGEN, ids, -1, -1, cpu_bal))
            (new_st["ev_i"], new_st["ev_f"],
             new_st["ev_head"]) = _obsring.record_blocks(
                st["ev_i"], st["ev_f"], st["ev_head"], t, blocks)

        # ---- 7) streaming timeline ys (static switch: off -> zero cost) --
        ys = None
        if emit_tl:
            # sampled AFTER serve+observe, exactly where Simulation.run
            # records its timeline row (cluster_stats on post-serve state)
            nmask = ~sc["node_pad"]
            n_real = jnp.maximum(
                jnp.sum(jnp.where(nmask, jnp.ones((), dtype), 0.0)), 1.0)
            total_vcpus = jnp.maximum(jnp.sum(sc["vcpus"]), 1e-9)

            # effective balance: unlimited overdraft counts negative (Fig 8b)
            cm, c2 = _moments(cpu_bal - new_st["cpu_sur"], nmask, n_real)
            ys = {
                "cpu_util": jnp.sum(w_cpu) / dt / total_vcpus,
                "cpu_credit_mean": cm, "_cpu_credit_m2": c2,
                "queue_depth": jnp.sum(
                    (ready & (assign < 0)).astype(jnp.int32)),
            }
            if act_disk:
                dm, d2 = _moments(disk_bal, nmask, n_real)
                ys["disk_credit_mean"] = dm
                ys["_disk_credit_m2"] = d2
                ys["iops"] = jnp.sum(w_disk) / dt / n_real
        return new_st, ys

    # unroll k tick bodies per scan step to amortize per-iteration dispatch
    # (lax.scan handles the non-divisible remainder natively; bitwise-
    # identical to k=1, asserted by tests/test_megatick.py)
    xs_t = jnp.arange(cfg.n_ticks, dtype=jnp.int32)
    st, ys = jax.lax.scan(tick, state, (xs_t, ev) if faulty else xs_t,
                          unroll=max(1, cfg.unroll))

    real = ~sc["task_pad"]
    all_done = jnp.all(st["released"] | ~real)
    out = {
        "all_done": all_done,
        "surplus_credits": jnp.sum(st["cpu_sur"]),
        "total_cpu_work": jnp.sum(jnp.where(real, st["done_cpu"], 0.0)),
        "cpu_work_served": st["cpu_work_total"],
        "node_busy_seconds": st["busy_seconds"],
    }
    if faulty:
        # stream-level event counts are reductions over the xs — free
        out.update(_faults.event_totals(ev))
        if mortal:
            retry_r = jnp.where(real, st["retry"], 0)
            out["n_preempted"] = jnp.sum(retry_r, dtype=jnp.int32)
            out["n_reexec"] = jnp.sum(
                jnp.minimum(retry_r, cfg.max_retries), dtype=jnp.int32)
            out["n_shed"] = jnp.sum(real & (st["retry"] > cfg.max_retries),
                                    dtype=jnp.int32)
            out["work_lost"] = st["work_lost"]
        else:
            out["n_preempted"] = jnp.zeros((), jnp.int32)
            out["n_reexec"] = jnp.zeros((), jnp.int32)
            out["n_shed"] = jnp.zeros((), jnp.int32)
            out["work_lost"] = jnp.zeros((), dtype)
        # closed-path done counters are zeroed on kill, so total_cpu_work
        # is already goodput (lost work lives in work_lost alone)
        out["goodput"] = out["total_cpu_work"]
    # a task finishing work at tick k is released (and timestamped) at k+1 —
    # exactly the Python loop, whose makespan is `now` at the break check
    if cfg.emit_task_times:
        if mortal:
            # shed tasks never finish: drop them from the makespan (all
            # shed -> 0.0, mirroring the traffic drained convention)
            fin_ok = real & (st["retry"] <= cfg.max_retries)
            mk = jnp.max(jnp.where(fin_ok, st["finish"], -jnp.inf))
            mk = jnp.where(jnp.any(fin_ok), mk, 0.0)
            makespan = jnp.where(all_done, mk, cfg.n_ticks * dt)
        else:
            makespan = jnp.where(
                all_done,
                jnp.max(jnp.where(real, st["finish"], -jnp.inf)),
                cfg.n_ticks * dt)
        if n_waves > 1:
            submit = st["wave_t"][jnp.clip(sc["wave"], 0, n_waves - 1)]
        else:
            submit = jnp.zeros(T, dtype)
        seg = jnp.where(real, sc["job"], n_jobs)
        j_end = jax.ops.segment_max(jnp.where(real, st["finish"], -jnp.inf),
                                    seg, num_segments=n_jobs + 1)[:n_jobs]
        j_sub = jax.ops.segment_min(jnp.where(real, submit, jnp.inf), seg,
                                    num_segments=n_jobs + 1)[:n_jobs]
        j_cnt = jax.ops.segment_sum(real.astype(jnp.int32), seg,
                                    num_segments=n_jobs + 1)[:n_jobs]
        out.update({
            "makespan": makespan,
            "job_completion": j_end - j_sub,
            "job_mask": j_cnt > 0,
            "finish": st["finish"],
            "start": st["start"],
        })
    else:
        # without timestamps the last release time IS max(finish)
        last_rel = st["last_rel"]
        if mortal:
            # shed never updates last_rel; all-shed runs report 0.0
            last_rel = jnp.maximum(last_rel, 0.0)
        out["makespan"] = jnp.where(all_done, last_rel,
                                    cfg.n_ticks * dt)
    if tracing:
        out["trace_ev_i"] = st["ev_i"]
        out["trace_ev_f"] = st["ev_f"]
        out["trace_head"] = st["ev_head"]
    if emit_tl:
        # full per-tick series: `batched_engine` gathers the sample ticks
        # ONCE per batch (still inside the compiled/sharded program)
        out["timeline"] = ys
    return out


def _slo_hist_update(edges: jnp.ndarray, nfin: jnp.ndarray,
                     fin_now: jnp.ndarray, now: jnp.ndarray,
                     tb_start: jnp.ndarray, tb_submit: jnp.ndarray):
    """Streaming SLO histogram increment for the jobs released this tick.

    bin = count of upper edges <= value, overflow into the last bin (the
    oracle mirrors this comparison in slo.bucket_index). The histogram
    increments fall out of CUMULATIVE counts: with c[j] = #finished jobs
    whose value >= edges[1 + j], h[0] = nfin - c[0], h[b] = c[b-1] - c[b],
    and the last bin absorbs the c[B-2] tail — one fused (2, C, B-1)
    comparison tensor per tick, no scatter (batched scatters serialize
    horribly on CPU) and no per-value one-hot.

    lat/wait are >= 0 for finished jobs, so ONE zero-masked copy feeds the
    sums, the (zero-initialised) running maxima, AND the cumulative
    counts: a masked zero can never reach the first upper edge
    (edges[1] > 0), so no explicit fin_now AND is needed inside the
    comparison tensor. The (B-1, 2, C) layout reduces over the trailing
    contiguous axis (~20% whole-scan speedup over a middle axis), and the
    accumulator narrows to uint8 where the table width C bounds per-tick
    counts below 256 — exact, and it quarters the bytes this memory-bound
    reduction moves.

    Returns ``(hadd (2B,), sums (2,), maxs (2,))`` — the histogram
    increment and the latency/wait sum and max over this tick's releases.
    """
    b = edges.shape[0] - 1
    c = fin_now.shape[0]
    vals2 = jnp.stack([jnp.broadcast_to(now, (c,)), tb_start]) \
        - tb_submit[None, :]                                 # (2, C) lat/wait
    mv = jnp.where(fin_now[None, :], vals2, 0.0)
    acc_dt = jnp.uint8 if c < 256 else jnp.int32
    cum = jnp.sum(edges[1:b, None, None] <= mv[None, :, :],
                  axis=2, dtype=acc_dt).astype(jnp.int32).T  # (2, B-1)
    hadd = jnp.concatenate(
        [nfin[None] - cum[:, :1].T, (cum[:, :-1] - cum[:, 1:]).T,
         cum[:, -1:].T]).T                                   # (2, B)
    return hadd.reshape(-1), jnp.sum(mv, axis=1), jnp.max(mv, axis=1)


def _simulate_traffic(cfg: VecSimConfig, smax: int, n_waves: int,
                      n_jobs: int,
                      active: Tuple[bool, bool, bool, bool, bool],
                      sc: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Open-loop variant of `_simulate_one`: jobs arrive mid-scan from an
    arrival process (`repro.traffic.arrivals`) into a RING-BUFFER task
    table of fixed capacity C — slots recycle on completion, so multi-day
    horizons carry O(C) task state instead of O(total arrivals).

    Invariants (documented in DESIGN.md "Open-loop traffic"):
      * a slot is free iff its class is CLS_PAD (node -1);
      * arrivals fill free slots lowest-index first, in arrival order;
        when fewer free slots than arrivals remain, the excess is DROPPED
        (counted, never retried) — open-loop load shedding;
      * placement serves each phase's queue FIFO by global arrival order
        (slot index order would be unfair across recycled slots). Because
        placement always consumes a RANK PREFIX of each queue, in-phase
        FIFO ranks are carried incrementally (`tb_rank` + per-phase
        `qlen`): arrivals append at rank `qlen`, placement of k jobs
        shifts the survivors down by k — every queue stays contiguous
        from 0, and no per-tick (C, C) seq comparison is needed;
      * a job finishing its work at tick k releases (and timestamps its
        latency/queue-wait histograms) at tick k+1, like the closed path.

    Completed jobs stream into fixed-bin latency / queue-wait histograms
    (`repro.traffic.slo`) rather than per-job timestamp arrays."""
    from repro.traffic import arrivals as _arrivals
    from repro.traffic import slo as _slo

    if cfg.resource != "cpu":
        raise NotImplementedError(
            f"traffic mode drives the cpu pool only, got {cfg.resource!r}")
    if cfg.scheduler not in ("cash", "stock"):
        raise NotImplementedError(
            f"traffic mode supports cash|stock, got {cfg.scheduler!r}")

    N = sc["slots"].shape[0]
    dtype = sc["tmpl_work"].dtype
    dt = cfg.dt
    C = cfg.table_slots if cfg.table_slots > 0 else 2 * N * smax
    B = cfg.slo_bins
    need_credits = cfg.scheduler != "stock"
    tel_mode = cfg.telemetry
    p_burst, p_plain = active[2], active[4]
    # placement phases, in queue order (stock: one class-blind queue)
    P = 1 if cfg.scheduler == "stock" else int(p_burst) + int(p_plain)
    # whole-tick megakernel vs the unfused tick (see _simulate_one); the
    # traffic path feeds the kernel its CARRIED FIFO ranks — no per-tick
    # placement cumsum either way
    fused = fusion_choice(cfg, active) == "fused"

    # ---- fault injection statics (see _simulate_one) ------------------
    faulty = cfg.faults != "none"
    mortal = cfg.faults in ("spot", "crash")
    degrading = cfg.faults == "degrade"
    use_black = (cfg.scheduler == "cash"
                 and (cfg.blacklist_horizon_s > 0.0
                      or (mortal and cfg.preempt_notice_s > 0.0)))
    ev = None
    if faulty:
        from repro.faults import processes as _faults
        ev = _faults.fault_events(cfg, sc, dtype)
    if use_black:
        from repro.sched import straggler as _straggler

    edges = jnp.asarray(_slo.edges_for(cfg), dtype)       # (B + 1,) static
    ids = jnp.arange(N, dtype=jnp.int32)
    zero_n = jnp.zeros(N, dtype)
    zero_s = jnp.zeros((), dtype)

    # the whole admission-count stream is derived inside the compiled
    # program (one vectorized draw / searchsorted per scenario) and fed to
    # the scan as xs — nothing stochastic lives in the carry
    counts = _arrivals.arrival_counts(cfg, sc, dtype)

    state = {
        # --- ring-buffer task table (C,) ----------------------------------
        "tb_rem": jnp.zeros(C, dtype),          # remaining cpu work
        "tb_dem": jnp.zeros(C, dtype),
        "tb_cls": jnp.full(C, CLS_PAD, jnp.int32),
        "tb_rank": jnp.zeros(C, jnp.int32),     # in-phase FIFO queue rank
        "tb_submit": jnp.zeros(C, dtype),
        "tb_start": jnp.full(C, _INF, dtype),
        "tb_node": jnp.full(C, -1, jnp.int32),
        # --- nodes / pools (as the closed path) ---------------------------
        "run_cnt": jnp.zeros(N, jnp.int32),
        "rel_cnt": jnp.zeros(N, jnp.int32),
        "cpu_bal": sc["cpu_balance0"], "cpu_sur": zero_n,
        "cpu_work_total": zero_s,
        "work_done": zero_s,
        "busy_seconds": zero_s,
        # --- stream counters + SLO histograms -----------------------------
        "n_seen": jnp.int32(0), "n_adm": jnp.int32(0), "n_done": jnp.int32(0),
        "hist2": jnp.zeros(2 * B, jnp.int32),   # [lat_hist; wait_hist]
        "lat_sum": zero_s, "wait_sum": zero_s,
        "lat_max": zero_s, "wait_max": zero_s,
        "last_rel": jnp.full((), -jnp.inf, dtype),
    }
    if P:
        state["qlen"] = jnp.zeros(P, jnp.int32)   # per-phase queue length
    if tel_mode != "oracle" and need_credits:
        state["tel_cpu"] = _fresh_telemetry(N, dtype)
    if cfg.shuffle == "random":
        state["key"] = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                          sc["rng_seed"])
    if mortal:
        # ring slots recycle, so per-job fault state rides in the table:
        # full work (requeue resets rem to it; lost work = work - rem)
        # and the retry count; stream counters are plain scalars
        state["tb_work"] = jnp.zeros(C, dtype)
        state["tb_retry"] = jnp.zeros(C, jnp.int32)
        state["n_preempt"] = jnp.int32(0)
        state["n_reexec"] = jnp.int32(0)
        state["n_shed"] = jnp.int32(0)
        state["work_lost"] = zero_s

    # ---- decision trace (repro.obs.ring): see _simulate_one -----------
    tracing = cfg.trace_slots > 0
    if tracing:
        if cfg.shuffle != "none":
            raise NotImplementedError(
                "trace_slots > 0 mirrors the replay-oracle scope: "
                "shuffle='none' only")
        from repro.obs import ring as _obsring
        # SLO_OVER(C) + DROP(1) + PLACE(C) + DEPLETE/REGEN(2N), plus
        # PREEMPT/SHED(2C) under mortal faults and BLACKLIST(N)
        width = 2 * C + 1 + 2 * N + (2 * C if mortal else 0) \
            + (N if use_black else 0)
        state["ev_i"], state["ev_f"], state["ev_head"] = \
            _obsring.ring_init(max(cfg.trace_slots, width))

    emit_tl = cfg.sample_period > 0.0
    # stacked float template columns — ONE (2, C) gather per tick at
    # admission instead of two (C,) gathers
    tmplf = jnp.stack([sc["tmpl_work"], sc["tmpl_dem"]])

    def tick(st, inp):
        if faulty:
            t, k_t, fx = inp
        else:
            t, k_t = inp
        now = t.astype(dtype) * dt

        # ---- 1) release finished jobs, bucket their SLOs, free slots -----
        occupied = st["tb_cls"] != CLS_PAD
        fin_now = occupied & (st["tb_node"] >= 0) & (st["tb_rem"] <= 1e-9)
        nfin = jnp.sum(fin_now, dtype=jnp.int32)
        if tracing:
            # SLO-bucket overflow: released latency beyond the top edge
            lat_all = now - st["tb_submit"]
            slo_over = fin_now & (lat_all >= edges[-1])

        hadd, sums, maxs = _slo_hist_update(edges, nfin, fin_now, now,
                                            st["tb_start"], st["tb_submit"])
        hist2 = st["hist2"] + hadd                           # (2B,) carried
        n_done = st["n_done"] + nfin
        lat_sum = st["lat_sum"] + sums[0]
        wait_sum = st["wait_sum"] + sums[1]
        lat_max = jnp.maximum(st["lat_max"], maxs[0])
        wait_max = jnp.maximum(st["wait_max"], maxs[1])
        last_rel = jnp.where(nfin > 0, now, st["last_rel"])
        tb_cls = jnp.where(fin_now, CLS_PAD, st["tb_cls"])
        tb_node = jnp.where(fin_now, -1, st["tb_node"])
        run_cnt = st["run_cnt"] - st["rel_cnt"]

        # ---- 1b) fault step (repro.faults): kill/restore nodes -----------
        # AFTER release (work finished last tick on a dying node still
        # counts), BEFORE arrivals: requeued jobs rejoin their queue's
        # tail AHEAD of this tick's arrivals.
        alive_t = notice_t = scale_t = None
        tb_rem0, tb_rank0, qlen0 = st["tb_rem"], st["tb_rank"], st.get("qlen")
        tb_work = tb_retry = None
        if degrading:
            scale_t = fx["scale"]
        if mortal:
            alive_t, died_t = fx["alive"], fx["died"]
            notice_t = fx.get("notice")
            st = dict(st)
            if cfg.faults == "crash":
                fresh_t = fx["fresh"]
                st["cpu_bal"] = jnp.where(fresh_t, sc["cpu_balance0"],
                                          st["cpu_bal"])
                if "tel_cpu" in st:
                    blank = _fresh_telemetry(N, dtype)
                    st["tel_cpu"] = {k: jnp.where(fresh_t, blank[k], v)
                                     for k, v in st["tel_cpu"].items()}
            tb_work = st["tb_work"]
            resident = (tb_cls != CLS_PAD) & (tb_node >= 0)
            hit = resident & died_t[jnp.clip(tb_node, 0, N - 1)]
            tb_retry = st["tb_retry"] + hit.astype(jnp.int32)
            shed_now = hit & (tb_retry > cfg.max_retries)
            requeue = hit & ~shed_now
            work_lost = st["work_lost"] + jnp.sum(
                jnp.where(hit, tb_work - tb_rem0, 0.0))
            n_hit = jnp.sum(hit, dtype=jnp.int32)
            n_shed_t = jnp.sum(shed_now, dtype=jnp.int32)
            n_preempt = st["n_preempt"] + n_hit
            n_reexec = st["n_reexec"] + (n_hit - n_shed_t)
            n_shed_c = st["n_shed"] + n_shed_t
            if tracing:
                # captured BEFORE the clears below — and retry before the
                # admission-time reset, which can recycle a shed slot
                # within this same tick
                node_pre = tb_node
                retry_tr = tb_retry
                lost_tr = tb_work - tb_rem0
            tb_node = jnp.where(hit, -1, tb_node)
            tb_rem0 = jnp.where(requeue, tb_work, tb_rem0)
            run_cnt = jnp.where(alive_t, run_cnt, 0)
            # requeued jobs keep FIFO order by slot index within the
            # batch and append at their phase queue's current tail
            if cfg.scheduler == "stock":
                rq = [requeue]
            else:
                rq = []
                if p_burst:
                    rq.append(requeue & ((tb_cls == CLS_BURST_CPU)
                                         | (tb_cls == CLS_BURST_DISK)))
                if p_plain:
                    rq.append(requeue & (tb_cls == CLS_NONE))
            if rq:
                rr = _packed_ranks(*rq)
                for i, (m, r) in enumerate(zip(rq, rr)):
                    tb_rank0 = jnp.where(m, qlen0[i] + r, tb_rank0)
                qlen0 = qlen0 + jnp.stack([r[-1] + 1 for r in rr])
            # shed LAST: a shed job leaves the table entirely
            tb_cls = jnp.where(shed_now, CLS_PAD, tb_cls)

        # ---- 2) open-loop arrivals into recycled slots -------------------
        free_slot = tb_cls == CLS_PAD
        frank = jnp.cumsum(free_slot.astype(jnp.int32)) - 1
        n_free = frank[-1] + 1
        adm = free_slot & (frank < k_t)
        aidx = st["n_seen"] + frank             # global arrival index
        if cfg.traffic == "replay":
            j = jnp.clip(aidx, 0, sc["arr_t"].shape[0] - 1)
            trow = sc["arr_tmpl"][j]
            sub_t = sc["arr_t"][j].astype(dtype)
        else:
            trow = jnp.mod(aidx, jnp.maximum(sc["tmpl_n"], 1))
            sub_t = jnp.broadcast_to(now, (C,))
        cls_new = sc["tmpl_cls"][trow]
        wd = tmplf[:, trow]                     # (2, C): work, demand
        tb_rem = jnp.where(adm, wd[0], tb_rem0)
        tb_dem = jnp.where(adm, wd[1], st["tb_dem"])
        tb_cls = jnp.where(adm, cls_new, tb_cls)
        tb_submit = jnp.where(adm, sub_t, st["tb_submit"])
        if mortal:
            # a recycled slot must not inherit the previous job's fault
            # bookkeeping
            tb_work = jnp.where(adm, wd[0], tb_work)
            tb_retry = jnp.where(adm, 0, tb_retry)
        # NOTE: tb_start is NOT reset on admission — a recycled slot keeps
        # the previous job's start until placement overwrites it, and the
        # only read (wait at release) always happens after placement
        tb_start = st["tb_start"]
        n_new = jnp.minimum(k_t, n_free)
        n_seen = st["n_seen"] + k_t
        n_adm = st["n_adm"] + n_new

        # append arrivals at the tail of their phase's FIFO queue: rank =
        # queue length + in-tick position (admission is lowest-free-slot
        # first in arrival order, so `frank` IS that position when every
        # admitted job lands in one queue; a two-phase split needs one
        # extra packed cumsum)
        tb_rank, qlen = tb_rank0, qlen0
        if P == 1 and (cfg.scheduler == "stock" or not active[3]):
            adm_pos = [(adm, frank, n_new)]
        elif P:
            am = []
            if p_burst:
                am.append(adm & ((cls_new == CLS_BURST_CPU)
                                 | (cls_new == CLS_BURST_DISK)))
            if p_plain:
                am.append(adm & (cls_new == CLS_NONE))
            rs = _packed_ranks(*am)
            adm_pos = [(m, r, r[-1] + 1) for m, r in zip(am, rs)]
        else:
            adm_pos = []
        for i, (m, r, _) in enumerate(adm_pos):
            tb_rank = jnp.where(m, qlen[i] + r, tb_rank)
        if adm_pos:
            qlen = qlen + jnp.stack([cnt for _, _, cnt in adm_pos])

        # ---- 3) telemetry estimates (Algorithm 2, as the closed path) ----
        est_cpu = None
        if need_credits and not fused:
            est_cpu = _telemetry_estimate(cfg, st.get("tel_cpu"),
                                          st["cpu_bal"], sc["cpu_baseline"],
                                          sc["cpu_capacity"], now, tel_mode)

        # ---- 4) placement: FIFO by arrival seq within each phase ---------
        occupied = tb_cls != CLS_PAD
        ready = occupied & (tb_node < 0)
        free = sc["slots"] - run_cnt
        if mortal:
            free = jnp.where(alive_t, free, 0)
        if use_black:
            # CASH blacklisting (see _simulate_one): estimated credits +
            # currently-running demand -> time-to-deplete, plus the
            # preemption notice window; void when nothing else is free
            black = jnp.zeros(N, bool)
            tdep = jnp.full(N, jnp.inf, dtype)
            if cfg.blacklist_horizon_s > 0.0:
                running0 = tb_node >= 0
                col0 = jnp.where(running0 & (tb_rem > 0.0), tb_dem, 0.0)
                oh0 = jnp.where((tb_node[:, None] == ids[None, :])
                                & running0[:, None],
                                jnp.ones((), dtype), 0.0)
                dem_pre = jax.lax.dot_general(
                    col0[None, :], oh0, (((1,), (0,)), ((), ())),
                    preferred_element_type=dtype)[0]
                burst_eff = (sc["cpu_burst"] * scale_t if degrading
                             else sc["cpu_burst"])
                # predictive_blacklist IS tdep < horizon — keep tdep so
                # the trace can record the predicted time-to-deplete
                tdep = _straggler.time_to_deplete_vec(
                    est_cpu, dem_pre, sc["cpu_baseline"], burst_eff,
                    sc["cpu_unlimited"])
                black = tdep < cfg.blacklist_horizon_s
            if notice_t is not None:
                black = black | notice_t
            ok = jnp.any((~black) & (free > 0))
            free = jnp.where(black & ok, 0, free)
        if cfg.shuffle == "random":
            key, sub = jax.random.split(st["key"])
            order3 = jax.random.permutation(sub, ids)
        else:
            key = None
            order3 = ids
        ls = N * smax
        if cfg.scheduler == "stock":
            masks = [ready]
        else:
            masks = []
            if p_burst:
                masks.append(ready & ((tb_cls == CLS_BURST_CPU)
                                      | (tb_cls == CLS_BURST_DISK)))
            if p_plain:
                masks.append(ready & (tb_cls == CLS_NONE))
        # the carried ranks ARE each phase's FIFO ranks (contiguous from
        # 0), and the carried queue lengths replace per-tick mask reduces
        pranks = [tb_rank] * len(masks)
        pcounts = [qlen[i] for i in range(len(masks))]
        tel_fused = None
        if fused:
            # ---- fused 3-6: estimate + placement + serve + observe -------
            # (eligibility guarantees exactly one placement phase, so the
            # carried ranks/length of queue 0 are the whole pending set)
            by_credit = cfg.scheduler == "cash" and bool(p_burst)
            mk_mode = "none" if cfg.scheduler == "stock" else tel_mode
            (assign, taken, share_cpu, w_cpu, cpu_bal, sur_add,
             tel_fused) = ops.megatick(
                masks[0], tb_rank, pcounts[0], tb_node,
                jnp.ones(C, bool), tb_dem, tb_rem > 0.0,
                st["cpu_bal"], sc["cpu_baseline"], sc["cpu_burst"],
                sc["cpu_capacity"], sc["cpu_unlimited"], free,
                st.get("tel_cpu"), now, dt=dt,
                actual_period=cfg.actual_period,
                usage_period=cfg.usage_period, tel_mode=mk_mode,
                by_credit=by_credit, carried_rank=True, impl=cfg.impl)
            # rank-prefix consumed = full free capacity (== cum[-1] of the
            # unfused packed cumsum), clipped against qlen below
            totals = [jnp.sum(free, dtype=jnp.int32)]
        elif cfg.scheduler == "stock":
            cum, taken = _pack_counts(order3, free, pcounts[0])
            assign = _gather_phase_nodes([_pack_table(order3, cum, ls)],
                                         [cum[-1]], masks, pranks, ls)
            totals = [cum[-1]]
        else:
            desc, _ = _node_orders(est_cpu)
            tables, totals = [], []
            cur_free, taken, i = free, jnp.zeros(N, jnp.int32), 0
            if p_burst:
                cum, tk = _pack_counts(desc, cur_free, pcounts[i])
                tables.append(_pack_table(desc, cum, ls))
                totals.append(cum[-1])
                cur_free, taken, i = cur_free - tk, taken + tk, i + 1
            if p_plain:
                cum, tk = _pack_counts(order3, cur_free, pcounts[i])
                tables.append(_pack_table(order3, cum, ls))
                totals.append(cum[-1])
                taken = taken + tk
            if tables:
                assign = _gather_phase_nodes(tables, totals, masks,
                                             pranks, ls)
            else:
                assign = jnp.full(C, -1, jnp.int32)

        placed = assign >= 0
        tr_place = None
        if tracing:
            if cfg.scheduler == "cash":
                # fused path: recompute the kernel's internal Algorithm-2
                # estimate (bitwise-identical standalone form)
                est_tr = est_cpu if not fused else ops.megatick_estimate(
                    st.get("tel_cpu"), st["cpu_bal"], sc["cpu_baseline"],
                    sc["cpu_capacity"], now, tel_mode=tel_mode)
                nsel = jnp.clip(assign, 0, N - 1)
                tr_place = (_rank_desc(est_tr)[nsel], est_tr[nsel])
            else:        # stock never consults credits: rank = node id
                tr_place = (assign, jnp.zeros(C, dtype))
        tb_node = jnp.where(placed, assign, tb_node)
        tb_start = jnp.where(placed, now, tb_start)
        running = tb_node >= 0
        run_cnt = run_cnt + taken
        nidx = jnp.clip(tb_node, 0, N - 1)

        # placement consumed ranks [0, n_placed) of each queue — shift the
        # survivors down so every queue stays contiguous from 0 (placed
        # slots keep a stale rank, which is never read while running)
        n_placed = [jnp.minimum(t, c) for t, c in zip(totals, pcounts)] \
            if masks else []
        for m, npl in zip(masks, n_placed):
            tb_rank = jnp.where(m, tb_rank - npl, tb_rank)
        if masks:
            qlen = qlen - jnp.stack(n_placed)

        # ---- 5) serve + distribute (cpu pool, fused kernel) --------------
        # the onehot stays outside the fusion boundary: rel_cnt (next
        # tick's slot frees) needs it either way
        onehot = jnp.where((tb_node[:, None] == ids[None, :])
                           & running[:, None], jnp.ones((), dtype), 0.0)
        if not fused:
            col = jnp.where(running & (tb_rem > 0.0), tb_dem, 0.0)
            dem_cpu = jax.lax.dot_general(
                col[None, :], onehot, (((1,), (0,)), ((), ())),
                preferred_element_type=dtype)[0]
            cpu_burst_t = (sc["cpu_burst"] * scale_t if degrading
                           else sc["cpu_burst"])
            share_cpu, w_cpu, cpu_bal, sur_add = ops.bucket_serve_distribute(
                st["cpu_bal"], dem_cpu, sc["cpu_baseline"], cpu_burst_t,
                sc["cpu_capacity"], sc["cpu_unlimited"], nidx, tb_dem,
                dt=dt, impl=cfg.impl)
            if mortal:
                # down nodes' buckets FREEZE: no spend, no regeneration
                cpu_bal = jnp.where(alive_t, cpu_bal, st["cpu_bal"])
        upd = running & (tb_rem > 0.0)
        inc = jnp.where(upd, jnp.minimum(share_cpu, tb_rem), 0.0)
        tb_rem = tb_rem - inc
        fin = upd & (tb_rem <= 1e-9)      # releases (frees its slot) at k+1
        rel_cnt = jax.lax.dot_general(
            jnp.where(fin, jnp.ones((), dtype), 0.0), onehot,
            (((0,), (0,)), ((), ())),
            preferred_element_type=dtype).astype(jnp.int32)

        # ---- 6) CloudWatch observe --------------------------------------
        tel_cpu = st.get("tel_cpu")
        if fused:
            tel_cpu = tel_fused
        elif tel_cpu is not None:
            tel_cpu = _telemetry_observe(cfg, tel_cpu, cpu_bal, w_cpu / dt,
                                         now)
            if mortal:
                tel_cpu = {k: jnp.where(alive_t, v, st["tel_cpu"][k])
                           for k, v in tel_cpu.items()}

        new_st = {
            "tb_rem": tb_rem, "tb_dem": tb_dem, "tb_cls": tb_cls,
            "tb_rank": tb_rank, "tb_submit": tb_submit,
            "tb_start": tb_start, "tb_node": tb_node,
            "run_cnt": run_cnt, "rel_cnt": rel_cnt,
            "cpu_bal": cpu_bal, "cpu_sur": st["cpu_sur"] + sur_add,
            "cpu_work_total": st["cpu_work_total"] + jnp.sum(w_cpu),
            "work_done": st["work_done"] + jnp.sum(inc),
            "busy_seconds": st["busy_seconds"]
            + jnp.sum((run_cnt > 0).astype(dtype)) * dt,
            "n_seen": n_seen, "n_adm": n_adm, "n_done": n_done,
            "hist2": hist2,
            "lat_sum": lat_sum, "wait_sum": wait_sum,
            "lat_max": lat_max, "wait_max": wait_max,
            "last_rel": last_rel,
        }
        if tel_cpu is not None:
            new_st["tel_cpu"] = tel_cpu
        if P:
            new_st["qlen"] = qlen
        if cfg.shuffle == "random":
            new_st["key"] = key
        if mortal:
            new_st["tb_work"] = tb_work
            new_st["tb_retry"] = tb_retry
            new_st["n_preempt"] = n_preempt
            new_st["n_reexec"] = n_reexec
            new_st["n_shed"] = n_shed_c
            new_st["work_lost"] = work_lost

        # ---- 6b) decision trace: one masked scatter per tick -------------
        if tracing:
            nmask_tr = ~sc["node_pad"]
            dep = (st["cpu_bal"] > 1e-9) & (cpu_bal <= 1e-9) & nmask_tr
            reg = (st["cpu_bal"] <= 1e-9) & (cpu_bal > 1e-9) & nmask_tr
            cidx = jnp.arange(C, dtype=jnp.int32)
            blocks = [(slo_over, _obsring.EV_SLO_OVER, cidx, -1, -1,
                       lat_all)]
            if mortal:
                blocks.append((hit, _obsring.EV_PREEMPT, cidx, node_pre,
                               retry_tr, lost_tr))
                blocks.append((shed_now, _obsring.EV_SHED, cidx, node_pre,
                               retry_tr, jnp.zeros(C, dtype)))
            dropped_tr = (k_t - n_new).astype(jnp.int32)
            blocks.append(((dropped_tr > 0)[None], _obsring.EV_DROP, -1,
                           dropped_tr, -1, 0.0))
            if use_black:
                notice_i = (notice_t.astype(jnp.int32)
                            if notice_t is not None
                            else jnp.zeros(N, jnp.int32))
                blocks.append((black & ok, _obsring.EV_BLACKLIST, ids,
                               notice_i, -1, tdep))
            blocks.append((placed, _obsring.EV_PLACE, cidx, assign,
                           tr_place[0], tr_place[1]))
            blocks.append((dep, _obsring.EV_DEPLETE, ids, -1, -1, cpu_bal))
            blocks.append((reg, _obsring.EV_REGEN, ids, -1, -1, cpu_bal))
            (new_st["ev_i"], new_st["ev_f"],
             new_st["ev_head"]) = _obsring.record_blocks(
                st["ev_i"], st["ev_f"], st["ev_head"], t, blocks)

        # ---- 7) streaming timeline ys ------------------------------------
        ys = None
        if emit_tl:
            nmask = ~sc["node_pad"]
            n_real = jnp.maximum(
                jnp.sum(jnp.where(nmask, jnp.ones((), dtype), 0.0)), 1.0)
            total_vcpus = jnp.maximum(jnp.sum(sc["vcpus"]), 1e-9)

            cm, c2 = _moments(cpu_bal - new_st["cpu_sur"], nmask, n_real)
            ys = {
                "cpu_util": jnp.sum(w_cpu) / dt / total_vcpus,
                "cpu_credit_mean": cm, "_cpu_credit_m2": c2,
                "queue_depth": jnp.sum(
                    (ready & (assign < 0)).astype(jnp.int32)),
                "occupancy": jnp.sum(occupied.astype(jnp.int32)),
                "completed_cum": n_done,
                "dropped_cum": n_seen - n_adm,
                # cumulative surplus series — what the 24 h billing-window
                # reduction (core.cost.window_surplus_bills) consumes
                "surplus_cum": jnp.sum(new_st["cpu_sur"]),
            }
        return new_st, ys

    xs_t = jnp.arange(cfg.n_ticks, dtype=jnp.int32)
    st, ys = jax.lax.scan(tick, state,
                          (xs_t, counts, ev) if faulty else (xs_t, counts))

    # shed jobs left the table without completing — they still drain
    drained = (st["n_done"] + st["n_shed"] == st["n_adm"]) if mortal \
        else (st["n_done"] == st["n_adm"])
    if cfg.traffic == "replay":
        n_trace = jnp.sum(jnp.isfinite(sc["arr_t"]), dtype=jnp.int32)
        all_done = drained & (st["n_seen"] >= n_trace)
    else:
        all_done = drained          # open-ended stream: drained at horizon
    makespan = jnp.where(all_done,
                         jnp.where(st["n_done"] > 0, st["last_rel"], 0.0),
                         cfg.n_ticks * dt)
    out = {
        "makespan": makespan,
        "all_done": all_done,
        "surplus_credits": jnp.sum(st["cpu_sur"]),
        "total_cpu_work": st["work_done"],
        "cpu_work_served": st["cpu_work_total"],
        "node_busy_seconds": st["busy_seconds"],
        "n_arrived": st["n_seen"],
        "n_admitted": st["n_adm"],
        "n_dropped": st["n_seen"] - st["n_adm"],
        "n_completed": st["n_done"],
        "lat_hist": st["hist2"][:B], "wait_hist": st["hist2"][B:],
        "lat_sum": st["lat_sum"], "wait_sum": st["wait_sum"],
        "lat_max": st["lat_max"], "wait_max": st["wait_max"],
        "last_finish": st["last_rel"],
    }
    if tracing:
        out["trace_ev_i"] = st["ev_i"]
        out["trace_ev_f"] = st["ev_f"]
        out["trace_head"] = st["ev_head"]
    if faulty:
        out.update(_faults.event_totals(ev))
        if mortal:
            out["n_preempted"] = st["n_preempt"]
            out["n_reexec"] = st["n_reexec"]
            out["n_shed"] = st["n_shed"]
            out["work_lost"] = st["work_lost"]
        else:
            out["n_preempted"] = jnp.zeros((), jnp.int32)
            out["n_reexec"] = jnp.zeros((), jnp.int32)
            out["n_shed"] = jnp.zeros((), jnp.int32)
            out["work_lost"] = zero_s
        # work_done counts every unit applied to job progress, including
        # units later thrown away by a kill — goodput subtracts the waste
        out["goodput"] = out["total_cpu_work"] - out["work_lost"]
    if emit_tl:
        out["timeline"] = ys
    return out


def batched_engine(cfg: VecSimConfig, smax: int, n_waves: int, n_jobs: int,
                   active: Tuple[bool, bool, bool, bool, bool]):
    """The whole-batch device program: the vmapped tick engine plus every
    batch-level reduction that used to live host-side — the timeline's
    sample-tick gather happens here, on the batch, so a sharded dispatch
    (`repro.sweep.mesh` wraps this SAME callable in `shard_map`) keeps
    sampled sweeps device-resident end to end. Both the single-device jit
    path and the mesh path execute this one function — their bitwise
    parity is structural, not coincidental."""
    sim_fn = _simulate_traffic if cfg.traffic != "none" else _simulate_one
    sim = functools.partial(sim_fn, cfg, smax, n_waves, n_jobs, active)

    def engine(arrays: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        out = jax.vmap(sim)(arrays)
        if cfg.sample_period > 0.0:
            sidx = jnp.asarray(sample_tick_indices(cfg.n_ticks, cfg.dt,
                                                   cfg.sample_period),
                               dtype=jnp.int32)
            out["timeline"] = _timeline_std(
                {k: v[:, sidx] for k, v in out["timeline"].items()})
        return out

    return engine


@functools.lru_cache(maxsize=None)
def _jitted_engine(cfg: VecSimConfig, smax: int, n_waves: int, n_jobs: int,
                   active: Tuple[bool, bool, bool, bool, bool]):
    return jax.jit(batched_engine(cfg, smax, n_waves, n_jobs, active))


def _run_batch_jit(cfg: VecSimConfig, smax: int, n_waves: int, n_jobs: int,
                   active: Tuple[bool, bool, bool, bool, bool],
                   arrays: Dict[str, jnp.ndarray]):
    return _jitted_engine(cfg, smax, n_waves, n_jobs, active)(arrays)


def batch_statics(batch: Dict[str, np.ndarray]):
    """Compile-time statics a stacked batch implies: ``(smax, n_waves,
    n_jobs, active)`` — the extra static arguments of the jitted engine.
    Exposed for external runners (repro.sweep) that shard the scenario axis
    themselves."""
    if "tmpl_work" in batch:       # open-loop traffic batch: no waves/jobs
        smax = int(batch["slots"].max()) if batch["slots"].size else 1
        cls = batch["tmpl_cls"]
        active = (False, False,
                  bool(((cls == CLS_BURST_CPU)
                        | (cls == CLS_BURST_DISK)).any()),
                  False,
                  bool((cls == CLS_NONE).any()))
        return max(smax, 1), 1, 1, active
    _, _, _, W, J = (int(x) for x in batch["_meta"])
    smax = int(batch["slots"].max()) if batch["slots"].size else 1
    cls = batch["cls"]
    active = (bool(batch["work_disk"].any() or batch["dem_disk"].any()),
              bool(batch["work_net"].any() or batch["dem_net"].any()),
              bool(((cls == CLS_BURST_CPU) | (cls == CLS_BURST_DISK)).any()),
              bool((cls == CLS_NET).any()),
              bool((cls == CLS_NONE).any()))
    return max(smax, 1), W, J, active


def batch_arrays(batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """The batch entries the engine actually maps over (host-side metadata
    stripped)."""
    return {k: v for k, v in batch.items()
            if k not in ("_meta", "n_waves", "n_jobs")}


def finalize_outputs(out, cfg: VecSimConfig) -> Dict[str, np.ndarray]:
    """Device outputs -> numpy, plus the host-side timeline time axis and
    (traffic mode) the SLO percentile reductions over the histograms."""
    res = jax.tree_util.tree_map(np.asarray, out)
    if cfg.sample_period > 0.0:
        res["timeline_t"] = np.asarray(
            sample_tick_indices(cfg.n_ticks, cfg.dt, cfg.sample_period),
            dtype=np.float64) * cfg.dt
    if cfg.traffic != "none":
        from repro.traffic import slo as _slo
        _slo.attach_percentiles(res, cfg)
    return res


def run_batch(batch: Dict[str, np.ndarray],
              cfg: VecSimConfig) -> Dict[str, np.ndarray]:
    """Run a stacked scenario batch under one static config. Returns arrays
    with a leading scenario axis: makespan, all_done, job_completion /
    job_mask, surplus_credits, per-task start/finish times, aggregate
    cpu-work and busy-seconds counters, and (when ``cfg.sample_period > 0``)
    a ``timeline`` dict of sampled per-tick series plus its ``timeline_t``
    time axis."""
    smax, W, J, active = batch_statics(batch)
    arrays = {k: jnp.asarray(v) for k, v in batch_arrays(batch).items()}
    out = _run_batch_jit(cfg, smax, W, J, active, arrays)
    return finalize_outputs(out, cfg)


def run_scenarios(scenarios: Sequence[Dict[str, np.ndarray]],
                  cfg: VecSimConfig) -> Dict[str, np.ndarray]:
    """Convenience: stack + run in one call."""
    return run_batch(stack_scenarios(scenarios), cfg)


class IdentityRng:
    """Drop-in for the schedulers' ``random.Random``: keeps node order
    deterministic (nid ascending) so the Python oracle matches the
    vectorized engine's ``shuffle="none"`` placement."""

    def shuffle(self, x: list) -> None:  # noqa: D401 - rng protocol
        return None
