"""Cluster / node / slot model (paper SS4.2: "Each node has a number of slots
... one task per slot").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.annotations import Task
from repro.core.token_bucket import (
    DualTokenBucket,
    InstanceSpec,
    INSTANCE_TYPES,
    TokenBucket,
    ebs_gp2_bucket,
    network_dual_bucket,
)


@dataclasses.dataclass
class Node:
    nid: int
    spec: InstanceSpec
    cpu: TokenBucket
    disk: TokenBucket
    net: DualTokenBucket
    slots: int
    running: List[Task] = dataclasses.field(default_factory=list)

    @property
    def free_slots(self) -> int:
        return self.slots - len(self.running)

    def assign(self, task: Task, now: float) -> None:
        if self.free_slots <= 0:
            raise RuntimeError(f"node {self.nid} has no free slot")
        task.node = self.nid
        task.start_time = now
        self.running.append(task)

    def release_finished(self, now: float) -> List[Task]:
        done = [t for t in self.running if t.finished()]
        for t in done:
            t.finish_time = now
            self.running.remove(t)
        return done

    # credit views used by schedulers -----------------------------------
    def credit(self, resource: str) -> float:
        if resource == "cpu":
            return self.cpu.balance
        if resource == "disk":
            return self.disk.balance
        raise KeyError(resource)


def make_cluster(
    n_nodes: int,
    instance_type: str = "t3.2xlarge",
    ebs_size_gb: float = 200.0,
    slots_per_node: Optional[int] = None,
    cpu_initial_fraction: float = 0.0,
    disk_initial_credits: Optional[float] = None,
    unlimited: bool = False,
) -> List[Node]:
    """Build a homogeneous cluster (the paper's experimental setups).

    ``disk_initial_credits=0.0`` reproduces SS6.5's wiped burst buckets.
    """
    spec = INSTANCE_TYPES[instance_type]
    slots = slots_per_node if slots_per_node is not None else spec.vcpus
    nodes = []
    for i in range(n_nodes):
        nodes.append(Node(
            nid=i,
            spec=spec,
            cpu=spec.cpu_bucket(initial_fraction=cpu_initial_fraction, unlimited=unlimited),
            disk=ebs_gp2_bucket(ebs_size_gb, initial_credits=disk_initial_credits),
            net=network_dual_bucket(),
            slots=slots,
        ))
    return nodes


def cluster_stats(nodes: List[Node]) -> Dict[str, float]:
    import math
    # effective balance: unlimited instances overdraft into billed surplus
    # credits (negative effective balance), cf. Fig 8(b)
    cpu = [n.cpu.balance - n.cpu.surplus_used for n in nodes]
    disk = [n.disk.balance for n in nodes]
    mean = lambda xs: sum(xs) / len(xs)
    std = lambda xs: math.sqrt(max(0.0, mean([x * x for x in xs]) - mean(xs) ** 2))
    return {
        "cpu_credit_mean": mean(cpu), "cpu_credit_std": std(cpu),
        "disk_credit_mean": mean(disk), "disk_credit_std": std(disk),
        "free_slots": float(sum(n.free_slots for n in nodes)),
    }
