"""Vectorized credit-aware serving fleet: the jitted `lax.scan` engine.

`core.vecsim` vectorized the batch-scheduling simulator; this module does
the same for the SERVING-FLEET scenario (`sched.serve_scheduler` /
`serve.engine`): R inference replicas, each a token bucket in token/s
units (burstable hosts — decode throughput throttles when credits run
dry), serving an open-loop request stream under continuous batching.

The mapping onto the vecsim machinery, piece for piece:

  * **replicas = credit nodes.** A replica's sustained decode rate is its
    bucket ``baseline``; prefill bursts drain the balance at up to
    ``burst`` tokens/s (`kernels.bucket_serve._serve_math`, the exact
    arithmetic of `core.token_bucket.TokenBucket.serve`).
  * **KV slots = the slot resource.** Each replica holds ``cfg.kv_slots``
    KV-cache slots (`serve.kv_cache.KVCacheManager`'s accounting,
    collapsed to an occupancy counter); a request occupies one slot from
    placement to release, and slots recycle exactly like vecsim's
    ring-buffer table slots.
  * **requests = two-phase jobs.** A request carries prefill tokens (its
    prompt) then decode tokens; while prefill remains it demands
    ``dpre`` tokens/s (compute-dense, the paper's map-like burst
    annotation), afterwards ``ddec`` (the steady decode trickle). A
    request whose prefill AND decode both hit zero releases — and frees
    its KV slot — at the NEXT tick, vecsim's release-at-k+1 contract.
  * **CASH admission = Algorithm 1 on the fleet.** Queued requests admit
    to the credit-richest replica first (`sched.serve_scheduler
    .admission_order`, replica-id tie-break) — prefill is the burst, so
    it lands where headroom lives. The credit-blind baseline is
    round-robin: one KV slot per replica per rotation pass, origin
    carried in ``rr_ptr``, advanced by the number placed. The scheduler
    is a STATIC axis (``cfg.scheduler``: ``"cash" | "rr"``), so a sweep
    compares both on the identical arrival stream.
  * **open-loop traffic** reuses `traffic.arrivals` unchanged: the
    poisson / diurnal admission-count stream is drawn inside the
    compiled program and fed to the scan as xs; excess arrivals beyond
    the free request-table slots are dropped (load shedding).

The per-tick hot path — admission rank + KV-slot assign + bucket-
throttled serve + release detection — exists twice, bitwise-equal:

  * **unfused**: the vecsim packed-cumsum placement (`_pack_counts` /
    `_rr_table` rank->replica tables) + the `ops.bucket_serve_distribute`
    fused serve, the fast formulation on CPU;
  * **fused**: ONE `ops.serve_admit` kernel call (`kernels.serve_admit`,
    a single `pl.pallas_call` on TPU with the XLA reference behind the
    same dispatcher) covering all of it, the `ops.megatick` pattern.

``cfg.fusion="auto"`` picks unfused on CPU and fused on TPU (same
measured rationale as `vecsim.fusion_choice`); `serve_fusion_choice`
takes an explicit ``platform`` so the decision is unit-testable.

Correctness is anchored three ways (tests/test_servesim.py):
`serve.oracle.ServeFleetOracle` — a plain-Python replay over real
`KVCacheManager` instances and `admission_order` — matches float64-
exactly; fused matches unfused bitwise; and the decision trace
(`repro.obs.ring`, admission / release / throttle events) matches the
oracle's `EventCollector` event-for-event.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vecsim import (
    _INF,
    _gather_phase_nodes,
    _node_orders,
    _pack_counts,
    _pack_table,
    _rank_desc,
    _rr_table,
    _slo_hist_update,
)
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class ServeSimConfig:
    """Static (compile-time) serving-fleet configuration. One `run_batch`
    covers scenarios sharing these; sweep the rest via the batch axis.
    Field names are duck-compatible with `traffic.arrivals.arrival_counts`
    and `traffic.slo.edges_for`."""
    dt: float = 1.0
    n_ticks: int = 4096
    scheduler: str = "cash"          # cash | rr (credit-blind round-robin)
    traffic: str = "poisson"         # poisson | diurnal (stochastic only)
    kv_slots: int = 4                # KV-cache slots per replica
    table_slots: int = 0             # request ring capacity (0 = 2*R*kv)
    slo_bins: int = 64               # latency/queue-wait histogram bins
    slo_max_s: float = 0.0           # histogram upper edge (0 = horizon)
    impl: str = "auto"               # kernel path (ops.*: xla|pallas|...)
    fusion: str = "auto"             # auto | fused | unfused
    unroll: int = 1                  # ticks unrolled per lax.scan step
    seed: int = 0                    # arrival-stream base key
    trace_slots: int = 0             # decision-trace ring (0 = no trace)


def serve_fusion_choice(cfg: ServeSimConfig,
                        platform: Optional[str] = None) -> str:
    """Resolve ``cfg.fusion`` for the serving tick: ``"fused"``
    (ops.serve_admit) or ``"unfused"``. Unlike the vecsim megatick there
    is no eligibility gate — both policies fit the kernel — so the only
    question is the platform: the fused (C, R) interval/one-hot matrices
    lose to the packed cumsum + table gather on CPU (the same measured
    trade as `vecsim.fusion_choice`), so ``"auto"`` fuses on TPU only.
    ``platform`` overrides ``jax.default_backend()`` for unit tests."""
    if cfg.fusion in ("fused", "unfused"):
        return cfg.fusion
    if cfg.fusion != "auto":
        raise ValueError(f"fusion must be auto|fused|unfused, "
                         f"got {cfg.fusion!r}")
    plat = jax.default_backend() if platform is None else platform
    return "fused" if plat == "tpu" else "unfused"


def _simulate_serve(cfg: ServeSimConfig,
                    sc: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """One serving-fleet scenario under `lax.scan` (vmapped by
    `batched_engine`). Mirrors `vecsim._simulate_traffic`'s tick shape:
    release -> arrivals -> admission+serve -> trace."""
    from repro.obs import ring as _obsring
    from repro.traffic import arrivals as _arrivals
    from repro.traffic import slo as _slo

    if cfg.scheduler not in ("cash", "rr"):
        raise NotImplementedError(
            f"serving fleet supports cash|rr, got {cfg.scheduler!r}")
    if cfg.traffic not in ("poisson", "diurnal"):
        raise NotImplementedError(
            "serving-fleet traffic is stochastic only (poisson|diurnal), "
            f"got {cfg.traffic!r}")
    if cfg.kv_slots < 1:
        raise ValueError("kv_slots must be >= 1")

    R = sc["rep_balance0"].shape[0]
    dtype = sc["rep_balance0"].dtype
    dt = cfg.dt
    C = cfg.table_slots if cfg.table_slots > 0 else 2 * R * cfg.kv_slots
    B = cfg.slo_bins
    policy = cfg.scheduler
    fused = serve_fusion_choice(cfg) == "fused"

    edges = jnp.asarray(_slo.edges_for(cfg), dtype)
    rids = jnp.arange(R, dtype=jnp.int32)
    cidx = jnp.arange(C, dtype=jnp.int32)
    zero_s = jnp.zeros((), dtype)

    # the whole admission-count stream, derived inside the compiled
    # program — the SAME stream `traffic` scenarios draw (shared key tag)
    counts = _arrivals.arrival_counts(cfg, sc, dtype)

    state = {
        # --- ring-buffer request table (C,) -------------------------------
        "rq_pre": jnp.zeros(C, dtype),          # remaining prefill tokens
        "rq_dec": jnp.zeros(C, dtype),          # remaining decode tokens
        "rq_dpre": jnp.zeros(C, dtype),         # prefill demand (tok/s)
        "rq_ddec": jnp.zeros(C, dtype),         # decode demand (tok/s)
        "rq_tmpl": jnp.full(C, -1, jnp.int32),  # template row (-1 = free)
        "rq_rank": jnp.zeros(C, jnp.int32),     # FIFO queue rank
        "rq_submit": jnp.zeros(C, dtype),
        "rq_start": jnp.full(C, _INF, dtype),   # first placement time
        "rq_rep": jnp.full(C, -1, jnp.int32),   # resident replica
        # --- replica fleet (R,) -------------------------------------------
        "occ": jnp.zeros(R, jnp.int32),         # occupied KV slots
        "rel_cnt": jnp.zeros(R, jnp.int32),     # slots freeing next tick
        "bal": sc["rep_balance0"],
        "sur": jnp.zeros(R, dtype),
        # --- queue / rotation / stream counters ---------------------------
        "qlen": jnp.int32(0),
        "rr_ptr": jnp.int32(0),
        "n_seen": jnp.int32(0), "n_adm": jnp.int32(0),
        "n_done": jnp.int32(0),
        "tok_pre": zero_s, "tok_dec": zero_s, "busy": zero_s,
        "hist2": jnp.zeros(2 * B, jnp.int32),   # [lat_hist; wait_hist]
        "lat_sum": zero_s, "wait_sum": zero_s,
        "lat_max": zero_s, "wait_max": zero_s,
        "last_rel": jnp.full((), -jnp.inf, dtype),
    }

    tracing = cfg.trace_slots > 0
    if tracing:
        # SLO_OVER(C) + RELEASE(C) + DROP(1) + PLACE(C) + DEPLETE/REGEN(2R)
        width = 3 * C + 1 + 2 * R
        state["ev_i"], state["ev_f"], state["ev_head"] = \
            _obsring.ring_init(max(cfg.trace_slots, width))

    # stacked float template columns: ONE (4, C) gather per tick
    tmplf = jnp.stack([sc["tmpl_pre"], sc["tmpl_dec"],
                       sc["tmpl_dpre"], sc["tmpl_ddec"]])

    def tick(st, inp):
        t, k_t = inp
        now = t.astype(dtype) * dt

        # ---- 1) release: finished requests free their KV slots -----------
        occupied = st["rq_tmpl"] >= 0
        fin_now = occupied & (st["rq_rep"] >= 0) \
            & (st["rq_pre"] <= 1e-9) & (st["rq_dec"] <= 1e-9)
        nfin = jnp.sum(fin_now, dtype=jnp.int32)
        if tracing:
            lat_all = now - st["rq_submit"]
            slo_over = fin_now & (lat_all >= edges[-1])
            node_pre = st["rq_rep"]
        hadd, sums, maxs = _slo_hist_update(edges, nfin, fin_now, now,
                                            st["rq_start"], st["rq_submit"])
        hist2 = st["hist2"] + hadd
        n_done = st["n_done"] + nfin
        lat_sum = st["lat_sum"] + sums[0]
        wait_sum = st["wait_sum"] + sums[1]
        lat_max = jnp.maximum(st["lat_max"], maxs[0])
        wait_max = jnp.maximum(st["wait_max"], maxs[1])
        last_rel = jnp.where(nfin > 0, now, st["last_rel"])
        rq_tmpl = jnp.where(fin_now, -1, st["rq_tmpl"])
        rq_rep = jnp.where(fin_now, -1, st["rq_rep"])
        occ = st["occ"] - st["rel_cnt"]

        # ---- 2) arrivals into free table slots, lowest index first -------
        free_slot = rq_tmpl < 0
        frank = jnp.cumsum(free_slot.astype(jnp.int32)) - 1
        adm = free_slot & (frank < k_t)
        aidx = st["n_seen"] + frank
        trow = jnp.mod(aidx, jnp.maximum(sc["tmpl_n"], 1)).astype(jnp.int32)
        cols = tmplf[:, trow]                                # (4, C)
        rq_pre = jnp.where(adm, cols[0], st["rq_pre"])
        rq_dec = jnp.where(adm, cols[1], st["rq_dec"])
        rq_dpre = jnp.where(adm, cols[2], st["rq_dpre"])
        rq_ddec = jnp.where(adm, cols[3], st["rq_ddec"])
        rq_tmpl = jnp.where(adm, trow, rq_tmpl)
        rq_submit = jnp.where(adm, now, st["rq_submit"])
        n_new = jnp.minimum(k_t, jnp.sum(free_slot, dtype=jnp.int32))
        rq_rank = jnp.where(adm, st["qlen"] + frank, st["rq_rank"])
        qlen = st["qlen"] + n_new
        n_seen = st["n_seen"] + k_t
        n_adm = st["n_adm"] + n_new

        # ---- 3) admission + serve (the fused/unfused hot path) -----------
        pending = (rq_tmpl >= 0) & (rq_rep < 0)
        free = cfg.kv_slots - occ                            # (R,) int32
        bal0 = st["bal"]
        if fused:
            (assign, taken, n_placed, inc_pre, inc_dec, new_pre, new_dec,
             fin, _w, new_bal, sur_add) = ops.serve_admit(
                pending, rq_rank, rq_rep, rq_pre, rq_dec, rq_dpre, rq_ddec,
                bal0, sc["rep_baseline"], sc["rep_burst"],
                sc["rep_capacity"], sc["rep_unlimited"], free, qlen,
                st["rr_ptr"], dt=dt, policy=policy,
                max_rounds=cfg.kv_slots, impl=cfg.impl)
            rq_rep = jnp.where(assign >= 0, assign, rq_rep)
            running = rq_rep >= 0
            onehot = jnp.where((rq_rep[:, None] == rids[None, :])
                               & running[:, None], jnp.ones((), dtype), 0.0)
        else:
            ls = R * cfg.kv_slots
            if policy == "cash":
                desc, _ = _node_orders(bal0)
                cum, taken = _pack_counts(desc, free, qlen)
                total, table = cum[-1], _pack_table(desc, cum, ls)
            else:
                order = jnp.mod(st["rr_ptr"] + rids, R)
                total, table, taken = _rr_table(order, free, qlen,
                                                cfg.kv_slots, ls)
            assign = _gather_phase_nodes([table], [total], [pending],
                                         [rq_rank], ls)
            n_placed = jnp.minimum(total, qlen)
            # serve: phase-dependent demand, bucket throttle, pro-rata —
            # expression-for-expression the kernel's serve_admit_math
            rq_rep = jnp.where(assign >= 0, assign, rq_rep)
            running = rq_rep >= 0
            nidx = jnp.clip(rq_rep, 0, R - 1)
            in_pre = rq_pre > 1e-9
            live = in_pre | (rq_dec > 1e-9)
            dem_i = jnp.where(in_pre, rq_dpre, rq_ddec)
            onehot = jnp.where((rq_rep[:, None] == rids[None, :])
                               & running[:, None], jnp.ones((), dtype), 0.0)
            col = jnp.where(running & live, dem_i, 0.0)
            dem_node = jax.lax.dot_general(
                col[None, :], onehot, (((1,), (0,)), ((), ())),
                preferred_element_type=dtype)[0]
            share, _w, new_bal, sur_add = ops.bucket_serve_distribute(
                bal0, dem_node, sc["rep_baseline"], sc["rep_burst"],
                sc["rep_capacity"], sc["rep_unlimited"], nidx, dem_i,
                dt=dt, impl=cfg.impl)
            # balance snaps to the 2^-10 grid every tick (see
            # kernels.serve_admit): it orders the cash admission sort, so
            # FMA-vs-two-roundings ulps must not accumulate in the carry
            new_bal = jnp.round(new_bal * 1024.0) / 1024.0
            share = jnp.where(running & live, share, 0.0)
            inc_pre = jnp.where(in_pre, jnp.minimum(share, rq_pre), 0.0)
            inc_dec = jnp.where(~in_pre, jnp.minimum(share, rq_dec), 0.0)
            new_pre = rq_pre - inc_pre
            new_dec = rq_dec - inc_dec
            fin = running & (new_pre <= 1e-9) & (new_dec <= 1e-9)

        placed = assign >= 0
        rq_start = jnp.where(placed, now, st["rq_start"])
        # placement consumed ranks [0, n_placed): shift survivors down so
        # the queue stays contiguous from 0 (placed slots keep a stale
        # rank, never read while running)
        rq_rank = jnp.where(pending, rq_rank - n_placed, rq_rank)
        qlen = qlen - n_placed
        rr_ptr = jnp.mod(st["rr_ptr"] + n_placed, R)
        occ = occ + taken
        # next tick's KV-slot frees, by replica (outside the fusion
        # boundary: the onehot is needed for this either way)
        rel_cnt = jax.lax.dot_general(
            jnp.where(fin, jnp.ones((), dtype), 0.0), onehot,
            (((0,), (0,)), ((), ())),
            preferred_element_type=dtype).astype(jnp.int32)

        new_st = {
            "rq_pre": new_pre, "rq_dec": new_dec,
            "rq_dpre": rq_dpre, "rq_ddec": rq_ddec,
            "rq_tmpl": rq_tmpl, "rq_rank": rq_rank,
            "rq_submit": rq_submit, "rq_start": rq_start, "rq_rep": rq_rep,
            "occ": occ, "rel_cnt": rel_cnt,
            "bal": new_bal, "sur": st["sur"] + sur_add,
            "qlen": qlen, "rr_ptr": rr_ptr,
            "n_seen": n_seen, "n_adm": n_adm, "n_done": n_done,
            "tok_pre": st["tok_pre"] + jnp.sum(inc_pre),
            "tok_dec": st["tok_dec"] + jnp.sum(inc_dec),
            "busy": st["busy"] + jnp.sum((occ > 0).astype(dtype)) * dt,
            "hist2": hist2,
            "lat_sum": lat_sum, "wait_sum": wait_sum,
            "lat_max": lat_max, "wait_max": wait_max,
            "last_rel": last_rel,
        }

        # ---- 4) decision trace: one masked scatter per tick --------------
        if tracing:
            dep = (bal0 > 1e-9) & (new_bal <= 1e-9)
            reg = (bal0 <= 1e-9) & (new_bal > 1e-9)
            dropped = (k_t - n_new).astype(jnp.int32)
            if policy == "cash":
                nsel = jnp.clip(assign, 0, R - 1)
                tr_rank, tr_val = _rank_desc(bal0)[nsel], bal0[nsel]
            else:     # round-robin never consults credits: rank = replica
                tr_rank, tr_val = assign, jnp.zeros(C, dtype)
            blocks = [
                (slo_over, _obsring.EV_SLO_OVER, cidx, -1, -1, lat_all),
                (fin_now, _obsring.EV_RELEASE, cidx, node_pre, -1, lat_all),
                ((dropped > 0)[None], _obsring.EV_DROP, -1, dropped, -1,
                 0.0),
                (placed, _obsring.EV_PLACE, cidx, assign, tr_rank, tr_val),
                (dep, _obsring.EV_DEPLETE, rids, -1, -1, new_bal),
                (reg, _obsring.EV_REGEN, rids, -1, -1, new_bal),
            ]
            (new_st["ev_i"], new_st["ev_f"],
             new_st["ev_head"]) = _obsring.record_blocks(
                st["ev_i"], st["ev_f"], st["ev_head"], t, blocks)
        return new_st, None

    xs_t = jnp.arange(cfg.n_ticks, dtype=jnp.int32)
    st, _ = jax.lax.scan(tick, state, (xs_t, counts),
                         unroll=max(1, cfg.unroll))

    all_done = st["n_done"] == st["n_adm"]     # open stream: drained
    makespan = jnp.where(all_done,
                         jnp.where(st["n_done"] > 0, st["last_rel"], 0.0),
                         cfg.n_ticks * dt)
    out = {
        "makespan": makespan,
        "all_done": all_done,
        "surplus_credits": jnp.sum(st["sur"]),
        "node_busy_seconds": st["busy"],
        "n_arrived": st["n_seen"],
        "n_admitted": st["n_adm"],
        "n_dropped": st["n_seen"] - st["n_adm"],
        "n_completed": st["n_done"],
        "lat_hist": st["hist2"][:B], "wait_hist": st["hist2"][B:],
        "lat_sum": st["lat_sum"], "wait_sum": st["wait_sum"],
        "lat_max": st["lat_max"], "wait_max": st["wait_max"],
        "last_finish": st["last_rel"],
        "tokens_prefilled": st["tok_pre"],
        "tokens_decoded": st["tok_dec"],
    }
    if tracing:
        out["trace_ev_i"] = st["ev_i"]
        out["trace_ev_f"] = st["ev_f"]
        out["trace_head"] = st["ev_head"]
    return out


def batched_engine(cfg: ServeSimConfig):
    """The whole-batch device program: the vmapped serving tick engine.
    Both the single-device jit path and the sharded mesh path execute
    this one callable — bitwise parity between them is structural."""
    sim = functools.partial(_simulate_serve, cfg)

    def engine(arrays: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        return jax.vmap(sim)(arrays)

    return engine


@functools.lru_cache(maxsize=None)
def _jitted_engine(cfg: ServeSimConfig):
    return jax.jit(batched_engine(cfg))


@functools.lru_cache(maxsize=None)
def _sharded_engine(cfg: ServeSimConfig, n_shards: int):
    """jit(shard_map(batched_engine)) over the scenario mesh — the
    `sweep.mesh._sharded_engine` construction, on the serving engine."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from repro.sweep import mesh as _mesh
    spec = PartitionSpec(_mesh.SCENARIO_AXIS)
    # check_rep=False for the same reason as sweep.mesh: the replication
    # checker has no rule for jax.random.poisson's while loop, and every
    # input/output is fully partitioned along the scenario axis
    fn = shard_map(batched_engine(cfg), mesh=_mesh.scenario_mesh(n_shards),
                   in_specs=spec, out_specs=spec, check_rep=False)
    return jax.jit(fn)


def batch_arrays(batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """The batch entries the engine maps over (metadata stripped)."""
    return {k: v for k, v in batch.items() if k != "_meta"}


def finalize_outputs(out, cfg: ServeSimConfig) -> Dict[str, np.ndarray]:
    """Device outputs -> numpy, plus the SLO percentile reductions over
    the latency/queue-wait histograms (`traffic.slo`)."""
    from repro.traffic import slo as _slo
    res = jax.tree_util.tree_map(np.asarray, out)
    _slo.attach_percentiles(res, cfg)
    return res


def run_batch(batch: Dict[str, np.ndarray],
              cfg: ServeSimConfig) -> Dict[str, np.ndarray]:
    """Run a stacked serving-fleet batch (`traffic.arrivals
    .stack_serve_scenarios`) under one static config. Returns arrays with
    a leading scenario axis — the registry-declared scalar/histogram keys
    plus lat/wait percentiles."""
    arrays = {k: jnp.asarray(v) for k, v in batch_arrays(batch).items()}
    return finalize_outputs(_jitted_engine(cfg)(arrays), cfg)


def run_batch_sharded(batch: Dict[str, np.ndarray], cfg: ServeSimConfig,
                      n_shards: Optional[int] = None) -> Dict[str, np.ndarray]:
    """`run_batch` dispatched over the ``scenario`` mesh axis: the batch
    pads to a multiple of the shard count (repeating row 0) and each
    device scans its block. Bitwise-equal to `run_batch` per scenario —
    same `batched_engine` callable under `shard_map`."""
    from repro.sweep import mesh as _mesh
    n = _mesh.device_count() if n_shards is None else n_shards
    arrays = {k: np.asarray(v) for k, v in batch_arrays(batch).items()}
    padded, b = _mesh.pad_scenario_axis(arrays, n)
    out = _sharded_engine(cfg, n)(
        {k: jnp.asarray(v) for k, v in padded.items()})
    out = jax.tree_util.tree_map(lambda v: np.asarray(v)[:b], out)
    return finalize_outputs(out, cfg)


def run_scenarios(scenarios: Sequence[Dict[str, np.ndarray]],
                  cfg: ServeSimConfig) -> Dict[str, np.ndarray]:
    """Convenience: stack + run in one call."""
    from repro.traffic import arrivals as _arrivals
    return run_batch(_arrivals.stack_serve_scenarios(scenarios), cfg)
