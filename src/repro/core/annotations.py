"""Task model + framework-side annotation (paper SS4.1, SS5.2-5.3).

The application framework annotates DAG vertices coarsely:
  - map-like vertices ("map", "lambda", "tokenize", "root_input", "scan") are
    *burst-intensive* in the workload's bottleneck resource (CPU or disk —
    one, never both; paper SS4.1);
  - reduce-like vertices ("reduce", "shuffle", "collate") get the *network*
    annotation (attached alongside, but scheduling treats network as its own
    phase-2 class per Algorithm 1);
  - anything else is unannotated.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence


class Annotation(enum.Enum):
    BURST_CPU = "burst_cpu"
    BURST_DISK = "burst_disk"
    NETWORK = "network"
    NONE = "none"


MAP_LIKE = {"map", "lambda", "tokenize", "root_input", "scan", "prefill", "encode"}
REDUCE_LIKE = {"reduce", "shuffle", "collate", "decode_step", "sync"}


@dataclasses.dataclass
class Task:
    """One schedulable unit (a YARN container request in the prototype).

    work_* are total work volumes: cpu in vCPU-seconds, disk in I/O ops,
    net in bytes. demand_* are the per-slot peak demand rates while running.
    """
    tid: int
    job: str
    vertex: str                                # DAG vertex kind
    work_cpu: float = 0.0
    work_disk: float = 0.0
    work_net: float = 0.0
    demand_cpu: float = 1.0                    # vCPUs (<= 1 slot => <= 1.0 typical)
    demand_disk: float = 0.0                   # IOPS
    demand_net: float = 0.0                    # bytes/sec
    annotation: Annotation = Annotation.NONE
    depends_on: Sequence[int] = ()
    # fraction of dependencies that must finish before this task may start
    # (None -> the owning Job's default). Paper: shuffle starts at ~5% of maps.
    dep_threshold: Optional[float] = None
    # runtime bookkeeping (filled by the simulator)
    submit_time: float = 0.0
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    node: Optional[int] = None
    done_cpu: float = 0.0
    done_disk: float = 0.0
    done_net: float = 0.0

    @property
    def burst_intensive(self) -> bool:
        return self.annotation in (Annotation.BURST_CPU, Annotation.BURST_DISK)

    @property
    def network_annotated(self) -> bool:
        return self.annotation == Annotation.NETWORK

    def remaining(self) -> Dict[str, float]:
        return {
            "cpu": max(0.0, self.work_cpu - self.done_cpu),
            "disk": max(0.0, self.work_disk - self.done_disk),
            "net": max(0.0, self.work_net - self.done_net),
        }

    def finished(self) -> bool:
        r = self.remaining()
        return r["cpu"] <= 1e-9 and r["disk"] <= 1e-9 and r["net"] <= 1e-9

    def elapsed(self) -> float:
        if self.start_time is None or self.finish_time is None:
            return float("nan")
        return self.finish_time - self.start_time


def annotate_task(task: Task, bottleneck: Annotation) -> Task:
    """Framework auto-annotation (SS4.1): map-like -> burst(bottleneck),
    reduce-like -> network. ``bottleneck`` is BURST_CPU or BURST_DISK —
    the preliminary CASH uses one resource class per workload, never both.
    """
    if bottleneck not in (Annotation.BURST_CPU, Annotation.BURST_DISK):
        raise ValueError("bottleneck must be BURST_CPU or BURST_DISK")
    v = task.vertex.lower()
    if v in MAP_LIKE or any(v.startswith(p) for p in MAP_LIKE):
        task.annotation = bottleneck
    elif v in REDUCE_LIKE or any(v.startswith(p) for p in REDUCE_LIKE):
        task.annotation = Annotation.NETWORK
    else:
        task.annotation = Annotation.NONE
    return task


def annotate_dag(tasks: List[Task], bottleneck: Annotation) -> List[Task]:
    for t in tasks:
        annotate_task(t, bottleneck)
    return tasks


def user_annotate(task: Task, annotation: Annotation) -> Task:
    """User-defined vertex-manager annotation (SS5.2: users may attach any
    annotation to any vertex of their DAG)."""
    task.annotation = annotation
    return task
