"""CASH core: the paper's contribution (token buckets, Algorithm 1+2, simulator)."""
from repro.core.annotations import Annotation, Task, annotate_dag, annotate_task, user_annotate
from repro.core.cluster import Node, cluster_stats, make_cluster
from repro.core.credits import CloudWatchEmulator, CreditPredictor, OracleCredits, StaleCredits
from repro.core.scheduler import (
    CashScheduler,
    JointCashScheduler,
    SCHEDULERS,
    SchedulerBase,
    StockScheduler,
)
from repro.core.simulator import Job, SimConfig, SimResult, Simulation
from repro.core.token_bucket import (
    DualTokenBucket,
    EMR_SURCHARGE,
    INSTANCE_TYPES,
    InstanceSpec,
    TokenBucket,
    ebs_gp2_bucket,
    network_dual_bucket,
)

__all__ = [
    "Annotation", "Task", "annotate_dag", "annotate_task", "user_annotate",
    "Node", "cluster_stats", "make_cluster",
    "CloudWatchEmulator", "CreditPredictor", "OracleCredits", "StaleCredits",
    "CashScheduler", "JointCashScheduler", "SCHEDULERS", "SchedulerBase", "StockScheduler",
    "Job", "SimConfig", "SimResult", "Simulation",
    "DualTokenBucket", "EMR_SURCHARGE", "INSTANCE_TYPES", "InstanceSpec",
    "TokenBucket", "ebs_gp2_bucket", "network_dual_bucket",
]
