"""Synthetic workload DAGs modeled on the paper's evaluation suites.

CPU-burst side (SS6.1): HiBench PageRank / K-means / Hive SQL-aggregation —
sequential jobs of map + shuffle + reduce waves; SQL aggregation demands more
CPU than the T3 40% baseline, PageRank/K-means less (that asymmetry is what
Experiments 1-4 exploit).

Disk-burst side (SS6.4): hive-testbench TPC-DS queries 66 / 49 / 37 over Tez
— parallel streaming queries whose map-like (root-input) vertices read a hive
warehouse: IOPS demand scales with database size.

All generators are deterministic given their seed.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence

from repro.core.annotations import Annotation, Task, annotate_dag
from repro.core.simulator import Job

_next_tid = [0]


def _tid() -> int:
    _next_tid[0] += 1
    return _next_tid[0]


def reset_tids() -> None:
    _next_tid[0] = 0


def _lognorm(rng: random.Random, mean: float, sigma: float = 0.35) -> float:
    """Heterogeneous work sizes (stragglers emerge naturally)."""
    import math
    mu = math.log(mean) - sigma * sigma / 2.0
    return rng.lognormvariate(mu, sigma)


# --------------------------------------------------------------------------
# HiBench-like CPU workloads (paper SS6.1-6.2)
# --------------------------------------------------------------------------

HIBENCH_PROFILES: Dict[str, Dict[str, float]] = {
    # demand_cpu: per-slot duty cycle (S3 wait keeps it < 1.0; EMR shows ~30%
    # average node utilization, Fig 3) -- sql_aggregation exceeds the 40% T3
    # baseline, pagerank/kmeans sit below it (SS6.2.1-6.2.2).
    # moderate cluster load (paper SS3.1: utilization is low and bursty) --
    # per-job map waves cover ~0.8x of cluster slots, many sequential jobs
    # sql: cpu-dense, deep multi-wave queues (sustained >baseline demand);
    # pagerank/kmeans: partial-load (paper SS3.1's low bursty utilization)
    "sql_aggregation": dict(demand_cpu=0.85, map_work=300.0, red_work=45.0,
                            maps_per_wave=3.0, n_jobs=4, reduces_frac=0.10),
    "pagerank":        dict(demand_cpu=0.26, map_work=130.0, red_work=40.0,
                            maps_per_wave=0.60, n_jobs=3, reduces_frac=0.15),
    "kmeans":          dict(demand_cpu=0.30, map_work=110.0, red_work=35.0,
                            maps_per_wave=0.60, n_jobs=3, reduces_frac=0.15),
}

DUTY_SIGMA = 0.45   # per-task duty-cycle jitter (data skew / S3 latency
                    # variance) — the source of cross-node credit divergence
WORK_SIGMA = 0.12   # task work-size spread (tight: HiBench splits are uniform)


EMR_S3_SPEEDUP = 1.15       # EMR's S3-optimized committers raise the map duty
                            # cycle (paper SS6.2: EMR "is highly optimized to
                            # work with S3"); plain Hadoop-on-EC2 lacks this.


def make_hibench_workload(kind: str, n_nodes: int, slots_per_node: int,
                          seed: int = 0, scale: float = 1.0,
                          emr_optimized: bool = False) -> List[Job]:
    """One HiBench workload = several sequential Hadoop jobs. Each job has the
    three Fig-7 phases: map (CPU-burst), shuffle (network; starts once ~5% of
    maps finished), reduce (CPU; after its shuffle wave)."""
    prof = HIBENCH_PROFILES[kind]
    rng = random.Random(seed)
    jobs: List[Job] = []
    slots = n_nodes * slots_per_node
    n_maps = max(4, int(prof["maps_per_wave"] * slots * scale))
    n_reds = max(2, int(n_maps * prof["reduces_frac"]))
    duty = min(1.0, prof["demand_cpu"] * (EMR_S3_SPEEDUP if emr_optimized else 1.0))
    for j in range(int(prof["n_jobs"])):
        tasks: List[Task] = []
        map_ids = []
        for _ in range(n_maps):
            w = _lognorm(rng, prof["map_work"], WORK_SIGMA)
            d = min(1.0, max(0.5 * duty, _lognorm(rng, duty, DUTY_SIGMA)))
            t = Task(tid=_tid(), job=f"{kind}/job{j}", vertex="map",
                     work_cpu=w, demand_cpu=d,
                     work_disk=w * 2.0, demand_disk=20.0)   # scratch EBS I/O
            tasks.append(t)
            map_ids.append(t.tid)
        shuf_ids = []
        for _ in range(n_reds):
            w = _lognorm(rng, prof["red_work"])
            t = Task(tid=_tid(), job=f"{kind}/job{j}", vertex="shuffle",
                     work_net=w * 3e8, demand_net=6.0e8,    # parallel fetch of map output
                     work_cpu=w * 0.1, demand_cpu=0.15,
                     depends_on=tuple(map_ids), dep_threshold=0.05)
            tasks.append(t)
            shuf_ids.append(t.tid)
        for s in shuf_ids:
            w = _lognorm(rng, prof["red_work"])
            t = Task(tid=_tid(), job=f"{kind}/job{j}", vertex="reduce",
                     work_cpu=w * 0.5, demand_cpu=0.35,
                     work_net=w * 4e6, demand_net=4.0e7,
                     depends_on=(s,), dep_threshold=1.0)
            tasks.append(t)
        annotate_dag(tasks, Annotation.BURST_CPU)
        jobs.append(Job(name=f"{kind}/job{j}", tasks=tasks, dep_threshold=1.0))
    return jobs


CPU_EXPERIMENT_ORDERS = {
    # paper SS6.2.1 (naive): the >baseline workload first, zero accrued credits
    "naive": ["sql_aggregation", "pagerank", "kmeans"],
    # SS6.2.2 (reordered): accrue credits first
    "reordered": ["pagerank", "kmeans", "sql_aggregation"],
}


def make_cpu_suite(order: Sequence[str], n_nodes: int, slots_per_node: int,
                   seed: int = 0, scale: float = 1.0,
                   emr_optimized: bool = False) -> List[Job]:
    jobs: List[Job] = []
    for i, kind in enumerate(order):
        jobs.extend(make_hibench_workload(kind, n_nodes, slots_per_node,
                                          seed=seed + i, scale=scale,
                                          emr_optimized=emr_optimized))
    return jobs


# --------------------------------------------------------------------------
# TPC-DS-like disk workloads (paper SS6.4-6.5)
# --------------------------------------------------------------------------

# Relative scan/IO weight of the three queries (q66: widest scans over
# web/catalog sales; q49: three channels; q37: inventory+catalog). Stage
# counts reflect the multi-vertex Tez DAGs of these queries (cf. Fig 6).
TPCDS_PROFILES: Dict[str, Dict[str, float]] = {
    "q66": dict(scan_frac=0.40, stages=6),
    "q49": dict(scan_frac=0.35, stages=5),
    "q37": dict(scan_frac=0.25, stages=4),
}

IO_PER_GB = 1300.0          # read ops per GB of warehouse touched per query
DEMAND_IOPS = 300.0         # per-scan-task peak IOPS demand
SHUFFLE_BYTES = 3.0e10      # mean bytes moved per shuffle task
SPLIT_GB = 4.0              # input-split size: scan-task count is data-determined


def make_tpcds_query(q: str, db_size_gb: float, n_nodes: int,
                     slots_per_node: int, seed: int = 0) -> Job:
    """A streaming Hive/Tez query: root-input (disk-burst) vertices scanning
    the warehouse, then shuffle (network) vertices, per stage. The number of
    scan tasks follows the data (one per input split), not the cluster."""
    prof = TPCDS_PROFILES[q]
    rng = random.Random(seed)
    slots = n_nodes * slots_per_node
    total_io = db_size_gb * IO_PER_GB * prof["scan_frac"]
    tasks: List[Task] = []
    prev_ids: List[int] = []
    n_stages = int(prof["stages"])
    for s in range(n_stages):
        # stage 0 is the wide warehouse scan; later stages are narrower
        # refinements (join/aggregate inputs) — io split 50% / rest even
        stage_frac = 0.5 if s == 0 else 0.5 / (n_stages - 1)
        stage_io = total_io * stage_frac
        n_scan = max(3, int(db_size_gb * prof["scan_frac"] * stage_frac / SPLIT_GB))
        io_per_task = stage_io / n_scan
        ids = []
        for _ in range(n_scan):
            io = _lognorm(rng, io_per_task)
            t = Task(tid=_tid(), job=q, vertex="root_input",
                     work_disk=io, demand_disk=DEMAND_IOPS,
                     work_cpu=io / 90.0, demand_cpu=0.5,
                     depends_on=tuple(prev_ids),
                     dep_threshold=0.5 if prev_ids else None)
            tasks.append(t)
            ids.append(t.tid)
        n_shuf = max(2, n_scan // 2)
        sids = []
        for _ in range(n_shuf):
            t = Task(tid=_tid(), job=q, vertex="shuffle",
                     work_net=_lognorm(rng, SHUFFLE_BYTES), demand_net=2.0e8,
                     work_cpu=8.0, demand_cpu=0.3,
                     depends_on=tuple(ids),
                     dep_threshold=0.05)
            tasks.append(t)
            sids.append(t.tid)
        prev_ids = sids
    annotate_dag(tasks, Annotation.BURST_DISK)
    return Job(name=q, tasks=tasks, dep_threshold=1.0)


def make_tpcds_suite(db_size_gb: float, n_nodes: int, slots_per_node: int,
                     seed: int = 0,
                     queries: Sequence[str] = ("q66", "q49", "q37")) -> List[Job]:
    """The paper runs all three queries in parallel (SS6.5)."""
    return [make_tpcds_query(q, db_size_gb, n_nodes, slots_per_node,
                             seed=seed + i)
            for i, q in enumerate(queries)]
