"""Schedulers: CASH (paper Algorithm 1) and baselines.

CASH's scheduling thread, per tick:
  Phase 1 — nodes in *descending* (estimated) burst-credit order; pack each
            node with as many burst-intensive tasks as it has free slots.
  Phase 2 — nodes in *ascending* credit order; round-robin at most one
            network-annotated task per node per round (load balancing).
  Phase 3 — remaining (unannotated) tasks to free slots in arbitrary order.

The stock baseline models YARN's default behaviour the paper compares
against: nodes visited in random order, no credit awareness.
"""
from __future__ import annotations

import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.annotations import Annotation, Task
from repro.core.cluster import Node

Assignment = Tuple[Task, Node]


class SchedulerBase:
    name = "base"

    def schedule(self, queue: List[Task], nodes: Sequence[Node],
                 credits: Dict[int, float], now: float) -> List[Assignment]:
        raise NotImplementedError


def _runnable(queue: Sequence[Task], ready_ids: Optional[set] = None) -> List[Task]:
    """Tasks allowed to start: no dependencies, or listed in ``ready_ids``
    (the simulator resolves DAG thresholds and passes the ready set)."""
    if ready_ids is None:
        return [t for t in queue if not t.depends_on]
    return [t for t in queue if not t.depends_on or t.tid in ready_ids]


def _dequeue_assigned(queue: List[Task], assignments: Sequence[Assignment]) -> None:
    """Remove assigned tasks from the queue in one O(queue) rebuild (a
    per-assignment ``queue.remove`` rescan is O(queue x assignments))."""
    if not assignments:
        return
    assigned = {t.tid for t, _ in assignments}
    queue[:] = [t for t in queue if t.tid not in assigned]


class CashScheduler(SchedulerBase):
    """Paper Algorithm 1 (three-phase, credit-ordered)."""

    name = "cash"

    def __init__(self, rng: Optional[random.Random] = None):
        self.rng = rng or random.Random(0)

    def schedule(self, queue: List[Task], nodes: Sequence[Node],
                 credits: Dict[int, float], now: float,
                 ready_ids: Optional[set] = None) -> List[Assignment]:
        assignments: List[Assignment] = []
        pending = _runnable(queue, ready_ids)
        burst = [t for t in pending if t.burst_intensive]
        network = [t for t in pending if t.network_annotated]
        rest = [t for t in pending if not t.burst_intensive and not t.network_annotated]

        # Phase 1: burst-intensive tasks, nodes by descending credits
        node_desc = sorted(nodes, key=lambda n: (-credits.get(n.nid, 0.0), n.nid))
        for node in node_desc:
            while node.free_slots > 0 and burst:
                task = burst.pop(0)
                node.assign(task, now)
                assignments.append((task, node))

        # Phase 2: network tasks, ascending credits, <=1 slot/node/round
        node_asc = sorted(nodes, key=lambda n: (credits.get(n.nid, 0.0), n.nid))
        while network and any(n.free_slots > 0 for n in node_asc):
            progressed = False
            for node in node_asc:
                if not network:
                    break
                if node.free_slots > 0:
                    task = network.pop(0)
                    node.assign(task, now)
                    assignments.append((task, node))
                    progressed = True
            if not progressed:
                break

        # Phase 3: everything else, arbitrary (shuffled) node order
        node_rand = list(nodes)
        self.rng.shuffle(node_rand)
        for node in node_rand:
            while node.free_slots > 0 and rest:
                task = rest.pop(0)
                node.assign(task, now)
                assignments.append((task, node))

        _dequeue_assigned(queue, assignments)
        return assignments


class StockScheduler(SchedulerBase):
    """Stock YARN capacity-scheduler stand-in: random node order, slot-fill,
    credit-oblivious (paper SS3.2: "cluster managers like YARN choose nodes
    for scheduling tasks in random order")."""

    name = "stock"

    def __init__(self, rng: Optional[random.Random] = None):
        self.rng = rng or random.Random(0)

    def schedule(self, queue: List[Task], nodes: Sequence[Node],
                 credits: Dict[int, float], now: float,
                 ready_ids: Optional[set] = None) -> List[Assignment]:
        assignments: List[Assignment] = []
        pending = _runnable(queue, ready_ids)
        node_rand = list(nodes)
        self.rng.shuffle(node_rand)
        for node in node_rand:
            while node.free_slots > 0 and pending:
                task = pending.pop(0)
                node.assign(task, now)
                assignments.append((task, node))
        _dequeue_assigned(queue, assignments)
        return assignments


class JointCashScheduler(SchedulerBase):
    """Beyond-paper extension (the paper's stated future work, SS8): joint
    scheduling over *both* credit pools.

    Design note (from our mixed-workload experiments): naively running
    Algorithm 1 with any single ranking *segregates* task classes — a node
    gets packed with 8 CPU-burst tasks, saturating its CPU bucket, while its
    disk idles. Stock's accidental class-mixing stresses each bucket less
    and wins. The joint policy therefore keeps the credit-descending node
    order but fills each node by ALTERNATING burst classes (anti-affinity of
    complementary demands), steering each class's share toward the node's
    richer pool.

    Ablation knobs (mirrored by ``vecsim.VecSimConfig``):
    ``anti_affinity=False`` packs the preferred class exhaustively before
    the other per node; ``cpu_weight`` skews the min-rule joint credit —
    ``min(2w·cpu, 2(1-w)·disk)`` — with ``w=0.5`` the plain min."""

    name = "cash-joint"

    def __init__(self, rng: Optional[random.Random] = None, *,
                 anti_affinity: bool = True, cpu_weight: float = 0.5):
        self.rng = rng or random.Random(0)
        self.anti_affinity = anti_affinity
        self.cpu_weight = cpu_weight
        self._inner = CashScheduler(self.rng)

    def schedule(self, queue: List[Task], nodes: Sequence[Node],
                 credits: Dict[int, float], now: float,
                 ready_ids: Optional[set] = None,
                 credits_cpu: Optional[Dict[int, float]] = None,
                 credits_disk: Optional[Dict[int, float]] = None) -> List[Assignment]:
        if credits_cpu is None or credits_disk is None:
            return self._inner.schedule(queue, nodes, credits, now, ready_ids)
        assignments: List[Assignment] = []
        pending = _runnable(queue, ready_ids)
        cpu_burst = [t for t in pending if t.annotation == Annotation.BURST_CPU]
        disk_burst = [t for t in pending if t.annotation == Annotation.BURST_DISK]
        network = [t for t in pending if t.network_annotated]
        rest = [t for t in pending
                if not t.burst_intensive and not t.network_annotated]

        w = self.cpu_weight
        wc, wd = (1.0, 1.0) if w == 0.5 else (2.0 * w, 2.0 * (1.0 - w))

        def norm(pool, n, cap):
            return pool.get(n.nid, 0.0) / max(cap, 1e-9)

        joint = {n.nid: min(wc * norm(credits_cpu, n, n.cpu.capacity),
                            wd * norm(credits_disk, n, n.disk.capacity))
                 for n in nodes}

        # Phase 1: descending joint credits; interleave the two burst
        # classes per node, preferring the class whose pool is richer there
        node_desc = sorted(nodes, key=lambda n: (-joint[n.nid], n.nid))
        for node in node_desc:
            prefer_cpu = (wc * norm(credits_cpu, node, node.cpu.capacity)
                          >= wd * norm(credits_disk, node, node.disk.capacity))
            take_cpu = prefer_cpu
            while node.free_slots > 0 and (cpu_burst or disk_burst):
                src = cpu_burst if (take_cpu and cpu_burst) or not disk_burst \
                    else disk_burst
                task = src.pop(0)
                node.assign(task, now)
                assignments.append((task, node))
                if self.anti_affinity:
                    take_cpu = not take_cpu

        # Phase 2: network tasks ascending, <=1 per node per round
        node_asc = sorted(nodes, key=lambda n: (joint[n.nid], n.nid))
        while network and any(n.free_slots > 0 for n in node_asc):
            progressed = False
            for node in node_asc:
                if not network:
                    break
                if node.free_slots > 0:
                    task = network.pop(0)
                    node.assign(task, now)
                    assignments.append((task, node))
                    progressed = True
            if not progressed:
                break

        # Phase 3: the rest, shuffled
        node_rand = list(nodes)
        self.rng.shuffle(node_rand)
        for node in node_rand:
            while node.free_slots > 0 and rest:
                task = rest.pop(0)
                node.assign(task, now)
                assignments.append((task, node))

        _dequeue_assigned(queue, assignments)
        return assignments


SCHEDULERS: Dict[str, Callable[..., SchedulerBase]] = {
    "cash": CashScheduler,
    "stock": StockScheduler,
    "cash-joint": JointCashScheduler,
}
