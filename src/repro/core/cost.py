"""Public-cloud billing model (paper Table 2, SS6.2.3, SS6.6, Fig 11).

- On-demand instance pricing: per-hour x instances x wall-clock hours.
- EMR: SaaS surcharge on top of the EC2 M5 price (Table 2).
- T3 unlimited: surplus credits above the 24 h average are billed at
  $0.05 per vCPU-hour (= 60 CPU credits = 3600 of our vCPU-second units).
- "Any improvement in end-to-end wall-clock time directly translates to cost
  savings of equal valuation" (SS6.6) — billing is duration-proportional.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.token_bucket import EMR_SURCHARGE, INSTANCE_TYPES

UNLIMITED_USD_PER_VCPU_HOUR = 0.05
VCPU_SECONDS_PER_CREDIT_HOUR = 3600.0

# T3 unlimited settles surplus once per rolling 24 h billing period
SURPLUS_WINDOW_S = 86400.0


@dataclasses.dataclass(frozen=True)
class BillingLine:
    label: str
    instance_type: str
    n_instances: int
    wall_clock_s: float
    emr: bool = False
    surplus_vcpu_seconds: float = 0.0     # T3-unlimited overdraft

    @property
    def hours(self) -> float:
        return self.wall_clock_s / 3600.0

    @property
    def instance_cost(self) -> float:
        spec = INSTANCE_TYPES[self.instance_type]
        rate = spec.price_per_hour
        if self.emr:
            rate += EMR_SURCHARGE[self.instance_type]
        return rate * self.n_instances * self.hours

    @property
    def surplus_cost(self) -> float:
        surplus_vcpu_hours = self.surplus_vcpu_seconds / VCPU_SECONDS_PER_CREDIT_HOUR
        return surplus_vcpu_hours * UNLIMITED_USD_PER_VCPU_HOUR

    @property
    def total(self) -> float:
        return self.instance_cost + self.surplus_cost


@dataclasses.dataclass(frozen=True)
class SurplusWindow:
    """Surplus accrued inside one 24 h billing window ``(start_s, end_s]``.

    The half-open-on-the-LEFT convention matches how the bill lands:
    window ``w`` covers ``(w * W, (w + 1) * W]``, so surplus accrued
    exactly AT a rollover instant ``t == (w + 1) * W`` bills into the
    window that ends there, not the one that starts there. (Accrual at
    ``t == 0`` cannot exist — surplus needs elapsed burn — but is folded
    into window 0 for completeness.)"""
    index: int
    start_s: float
    end_s: float
    surplus_vcpu_seconds: float

    @property
    def usd(self) -> float:
        return (self.surplus_vcpu_seconds / VCPU_SECONDS_PER_CREDIT_HOUR
                * UNLIMITED_USD_PER_VCPU_HOUR)


def window_surplus_bills(times: Sequence[float],
                         cum_surplus: Sequence[float], *,
                         window_s: float = SURPLUS_WINDOW_S,
                         horizon_s: float = 0.0) -> List[SurplusWindow]:
    """Split a CUMULATIVE surplus series — e.g. a traffic timeline's
    ``surplus_cum`` samples — into per-24h-window bills.

    ``times`` must be non-decreasing and ``cum_surplus`` non-decreasing
    (cumulative). Returns one `SurplusWindow` per window up to
    ``max(times[-1], horizon_s)``; the sum of all windows' surplus equals
    ``cum_surplus[-1]`` exactly (it is a telescoping difference of the
    series, never a re-accumulation)."""
    t = np.asarray(times, np.float64)
    c = np.asarray(cum_surplus, np.float64)
    if t.shape != c.shape or t.ndim != 1:
        raise ValueError("times and cum_surplus must be matching 1-D series")
    if t.size == 0:
        return []
    if np.any(np.diff(t) < 0):
        raise ValueError("times must be non-decreasing")
    if np.any(np.diff(c) < -1e-9):
        raise ValueError("cum_surplus must be cumulative (non-decreasing)")
    if window_s <= 0.0:
        raise ValueError(f"window_s must be positive, got {window_s}")

    # the window a sample at time x bills into: (w*W, (w+1)*W] => ceil-1,
    # with x == 0 folded into window 0
    w_of = np.maximum(np.ceil(t / window_s).astype(np.int64) - 1, 0)
    end = max(float(t[-1]), float(horizon_s))
    n_w = int(np.maximum(np.ceil(end / window_s), 1))
    # cumulative surplus as of each window's close: the LAST sample in a
    # window or before it. searchsorted over the sorted w_of series gives
    # that sample's index; -1 (window closes before the first sample)
    # reads as zero accrual so far.
    idx = np.searchsorted(w_of, np.arange(n_w), side="right") - 1
    end_cum = np.where(idx >= 0, c[np.maximum(idx, 0)], 0.0)
    start_cum = np.concatenate([[0.0], end_cum[:-1]])
    return [SurplusWindow(index=w, start_s=w * window_s,
                          end_s=(w + 1) * window_s,
                          surplus_vcpu_seconds=float(end_cum[w]
                                                     - start_cum[w]))
            for w in range(n_w)]


def savings_fraction(baseline: BillingLine, other: BillingLine) -> float:
    return (baseline.total - other.total) / baseline.total


def hourly_rate(instance_type: str, emr: bool = False) -> float:
    spec = INSTANCE_TYPES[instance_type]
    rate = spec.price_per_hour
    if emr:
        rate += EMR_SURCHARGE[instance_type]
    return rate
