"""Public-cloud billing model (paper Table 2, SS6.2.3, SS6.6, Fig 11).

- On-demand instance pricing: per-hour x instances x wall-clock hours.
- EMR: SaaS surcharge on top of the EC2 M5 price (Table 2).
- T3 unlimited: surplus credits above the 24 h average are billed at
  $0.05 per vCPU-hour (= 60 CPU credits = 3600 of our vCPU-second units).
- "Any improvement in end-to-end wall-clock time directly translates to cost
  savings of equal valuation" (SS6.6) — billing is duration-proportional.
"""
from __future__ import annotations

import dataclasses

from repro.core.token_bucket import EMR_SURCHARGE, INSTANCE_TYPES

UNLIMITED_USD_PER_VCPU_HOUR = 0.05
VCPU_SECONDS_PER_CREDIT_HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class BillingLine:
    label: str
    instance_type: str
    n_instances: int
    wall_clock_s: float
    emr: bool = False
    surplus_vcpu_seconds: float = 0.0     # T3-unlimited overdraft

    @property
    def hours(self) -> float:
        return self.wall_clock_s / 3600.0

    @property
    def instance_cost(self) -> float:
        spec = INSTANCE_TYPES[self.instance_type]
        rate = spec.price_per_hour
        if self.emr:
            rate += EMR_SURCHARGE[self.instance_type]
        return rate * self.n_instances * self.hours

    @property
    def surplus_cost(self) -> float:
        surplus_vcpu_hours = self.surplus_vcpu_seconds / VCPU_SECONDS_PER_CREDIT_HOUR
        return surplus_vcpu_hours * UNLIMITED_USD_PER_VCPU_HOUR

    @property
    def total(self) -> float:
        return self.instance_cost + self.surplus_cost


def savings_fraction(baseline: BillingLine, other: BillingLine) -> float:
    return (baseline.total - other.total) / baseline.total


def hourly_rate(instance_type: str, emr: bool = False) -> float:
    spec = INSTANCE_TYPES[instance_type]
    rate = spec.price_per_hour
    if emr:
        rate += EMR_SURCHARGE[instance_type]
    return rate
