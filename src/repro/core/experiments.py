"""Paper experiment drivers (SS6.2 CPU-burst Experiments 1-4, SS6.5 disk-burst
Experiments 1-3). Shared by the benchmarks and tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.cluster import make_cluster
from repro.core.cost import BillingLine
from repro.core.scheduler import CashScheduler, StockScheduler
from repro.core.simulator import SimConfig, SimResult, Simulation
from repro.core.workloads import (
    CPU_EXPERIMENT_ORDERS,
    make_cpu_suite,
    make_tpcds_suite,
    reset_tids,
)

CPU_PHASES = ("map", "shuffle", "reduce")


@dataclasses.dataclass
class CpuExperimentResult:
    label: str
    result: SimResult
    billing: BillingLine

    def cumulative(self, phase: str) -> float:
        return self.result.phase_elapsed.get(phase, 0.0)

    def cumulative_total(self) -> float:
        return sum(self.cumulative(p) for p in CPU_PHASES)


def _cpu_setup(label: str, n_nodes: int, seed: int, scale: float):
    """Shared label -> (nodes, jobs, scheduler_name) table for the SS6.2 CPU
    experiments — the single source both the Python driver and the vecsim
    builder read, so the two paths cannot desynchronize."""
    reset_tids()
    slots = 8
    if label == "emr":
        nodes = make_cluster(n_nodes, "m5.2xlarge", ebs_size_gb=200.0)
        jobs = make_cpu_suite(CPU_EXPERIMENT_ORDERS["naive"], n_nodes, slots,
                              seed=seed, scale=scale, emr_optimized=True)
    elif label in ("naive", "unlimited"):
        nodes = make_cluster(n_nodes, "t3.2xlarge", ebs_size_gb=200.0,
                             cpu_initial_fraction=0.0,
                             unlimited=(label == "unlimited"))
        jobs = make_cpu_suite(CPU_EXPERIMENT_ORDERS["naive"], n_nodes, slots,
                              seed=seed, scale=scale)
    elif label in ("reordered", "cash"):
        nodes = make_cluster(n_nodes, "t3.2xlarge", ebs_size_gb=200.0,
                             cpu_initial_fraction=0.0)
        jobs = make_cpu_suite(CPU_EXPERIMENT_ORDERS["reordered"], n_nodes,
                              slots, seed=seed, scale=scale)
    else:
        raise ValueError(label)
    return nodes, jobs, ("cash" if label == "cash" else "stock")


def run_cpu_experiment(label: str, n_nodes: int = 10, seed: int = 0,
                       scale: float = 1.0) -> CpuExperimentResult:
    """labels: emr | naive | reordered | unlimited | cash (paper SS6.2.1-6.2.4)."""
    nodes, jobs, sched_name = _cpu_setup(label, n_nodes, seed, scale)
    sched = CashScheduler() if sched_name == "cash" else StockScheduler()
    sim = Simulation(nodes, sched, SimConfig(resource="cpu"))
    sim.submit_sequential(jobs)
    res = sim.run()
    billing = BillingLine(
        label=label,
        instance_type="m5.2xlarge" if label == "emr" else "t3.2xlarge",
        n_instances=n_nodes,
        wall_clock_s=res.makespan,
        emr=(label == "emr"),
        surplus_vcpu_seconds=res.surplus_credits,
    )
    return CpuExperimentResult(label, res, billing)


@dataclasses.dataclass
class DiskExperimentResult:
    label: str
    n_nodes: int
    db_size_gb: float
    result: SimResult


DISK_SETUPS = {
    # paper SS6.5.1-6.5.3: (n_nodes, db_size_gb, ebs_size_gb)
    "2vm": (2, 280.0, 200.0),
    "10vm": (10, 1200.0, 170.0),
    "20vm": (20, 2500.0, 200.0),
}


def run_disk_experiment(setup: str, scheduler: str, seed: int = 0,
                        telemetry: str = "predicted") -> DiskExperimentResult:
    """telemetry: predicted (Algorithm 2) | stale (5-min actuals only) |
    oracle (zero-lag ground truth) — the SS5.1 ablation."""
    n_nodes, db, ebs = DISK_SETUPS[setup]
    reset_tids()
    nodes = make_cluster(n_nodes, "m5.2xlarge", ebs_size_gb=ebs,
                         disk_initial_credits=0.0)   # SS6.5: wiped buckets
    sched = CashScheduler() if scheduler == "cash" else StockScheduler()
    sim = Simulation(nodes, sched, SimConfig(resource="disk",
                                             telemetry=telemetry))
    sim.submit_parallel(make_tpcds_suite(db, n_nodes, 8, seed=seed))
    return DiskExperimentResult(scheduler, n_nodes, db, sim.run())


# ---------------------------------------------------------------------------
# Vectorized (core.vecsim) scenario builders — same setups as the Python
# drivers above, frozen to arrays for batched sweeps (see `repro.sweep` for
# declaring grids over them and running sharded). The batched paths run
# with shuffle="none" (deterministic node order) whereas the Python drivers
# shuffle with Random(0); results are the same experiment, not bit-equal.
# ``rng_seed`` labels the scenario's shuffle stream for shuffle="random"
# sweeps (folded into the engine key; keeps seed sweeps one compile).
# ---------------------------------------------------------------------------

def build_cpu_vec_scenario(label: str, n_nodes: int = 10, seed: int = 0,
                           scale: float = 1.0, rng_seed: int = 0):
    """vecsim scenario for ``run_cpu_experiment``'s setup (same
    `_cpu_setup` table).

    Returns (scenario, scheduler_name, jobs) — labels using the stock
    scheduler (emr / naive / reordered / unlimited) stack into one batch;
    "cash" compiles separately (the scheduler is compile-time static).
    """
    from repro.core import vecsim

    nodes, jobs, sched = _cpu_setup(label, n_nodes, seed, scale)
    return (vecsim.build_scenario(nodes, jobs, submit="sequential",
                                  rng_seed=rng_seed), sched, jobs)


def build_disk_vec_scenario(setup: str, seed: int = 0, rng_seed: int = 0):
    """vecsim scenario for ``run_disk_experiment``'s setup (scheduler and
    telemetry stay compile-time static — pass them via VecSimConfig)."""
    from repro.core import vecsim

    n_nodes, db, ebs = DISK_SETUPS[setup]
    reset_tids()
    nodes = make_cluster(n_nodes, "m5.2xlarge", ebs_size_gb=ebs,
                         disk_initial_credits=0.0)
    jobs = make_tpcds_suite(db, n_nodes, 8, seed=seed)
    return vecsim.build_scenario(nodes, jobs, rng_seed=rng_seed), jobs


def run_disk_pair(setup: str, seeds: Sequence[int] = (1, 2, 3)) -> Dict[str, Dict[str, float]]:
    """stock-vs-cash averages over seeds: makespan + avg query completion."""
    out: Dict[str, Dict[str, float]] = {}
    for sched in ("stock", "cash"):
        mks, qcts = [], []
        for s in seeds:
            r = run_disk_experiment(setup, sched, seed=s).result
            mks.append(r.makespan)
            qcts.append(r.avg_query_completion())
        out[sched] = {"makespan": sum(mks) / len(mks),
                      "avg_qct": sum(qcts) / len(qcts)}
    return out
