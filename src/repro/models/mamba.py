"""Mamba-2 (SSD) block: in_proj -> causal conv -> selective SSM -> gated out.

Train / prefill uses the chunked SSD (Pallas kernel or XLA ref via
kernels.ops.ssd). Decode keeps per-layer recurrent state:
  conv_state (B, d_conv-1, conv_dim) and ssd_state (B, H, N, P).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import rmsnorm

Params = Dict[str, Any]


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    s = cfg.ssm or SSMConfig()
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return d_in, n_heads, s.d_state, s.head_dim, conv_dim


def init_mamba(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_in, h, n, p_dim, conv_dim = dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * n + h          # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) * d_in ** -0.5).astype(dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_in, h, n, _, _ = dims(cfg)
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + d_in + 2 * n], axis=-1)
    return z, xBC, dt


def mamba_block(cfg: ModelConfig, p: Params, x: jax.Array,
                *, impl: str = "auto") -> jax.Array:
    """Full-sequence SSD. x (B, S, d) -> (B, S, d)."""
    s_cfg = cfg.ssm or SSMConfig()
    b, l, d = x.shape
    d_in, h, n, p_dim, conv_dim = dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)

    # causal depthwise conv over (x, B, C)
    w = p["conv_w"]                                        # (K, conv_dim)
    k_w = w.shape[0]
    pad = jnp.zeros((b, k_w - 1, conv_dim), xBC.dtype)
    xc = jnp.concatenate([pad, xBC], axis=1)
    out = jnp.zeros_like(xBC)
    for i in range(k_w):
        out = out + xc[:, i:i + l, :] * w[i]
    xBC = jax.nn.silu(out + p["conv_b"])

    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B, S, H)
    A = -jnp.exp(p["A_log"])                                        # (H,)
    xh = xs.reshape(b, l, h, p_dim)
    chunk = min(s_cfg.chunk, l)
    if l % chunk != 0:
        chunk = 1
        while l % (chunk * 2) == 0 and chunk * 2 <= s_cfg.chunk:
            chunk *= 2
    from repro.kernels import ops
    y = ops.ssd(xh, dt, A, Bm, Cm, chunk=chunk, impl=impl)          # (B,S,H,P)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, l, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return (y @ p["out_proj"]).astype(x.dtype)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype: Any) -> Params:
    s = cfg.ssm or SSMConfig()
    d_in, h, n, p_dim, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, h, n, p_dim), jnp.float32),
    }


def mamba_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                 cache: Params) -> Tuple[jax.Array, Params]:
    """Single-step recurrence. x (B, 1, d) -> (B, 1, d), new cache."""
    b = x.shape[0]
    d_in, h, n, p_dim, conv_dim = dims(cfg)
    zxbcdt = x[:, 0] @ p["in_proj"]                        # (B, proj)
    z, xBC, dt = _split_proj(cfg, zxbcdt)

    w = p["conv_w"]                                        # (K, conv_dim)
    hist = jnp.concatenate([cache["conv"], xBC[:, None]], axis=1)  # (B, K, conv)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"]
    xBC_act = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:]

    xs, Bm, Cm = jnp.split(xBC_act, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B, H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None])                              # (B, H)
    xh = xs.reshape(b, h, p_dim).astype(jnp.float32)
    upd = dt[..., None, None] * Bm[:, None, :, None].astype(jnp.float32) \
        * xh[:, :, None, :]
    S = a[..., None, None] * cache["ssd"] + upd            # (B,H,N,P)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), S)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(b, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = (y @ p["out_proj"]).astype(x.dtype)[:, None]
    return out, {"conv": new_conv, "ssd": S}
