"""Mixture-of-Experts layer: top-k routing with capacity (GShard-style
einsum dispatch) — SPMD-friendly: with tokens sharded over ``data`` and
experts over ``model``, XLA emits the dispatch/combine all-to-alls.

Group size bounds the dispatch tensor (G, S_g, E, C); C = ceil(S_g*k*cf/E).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

from repro.configs.base import ModelConfig, MoEConfig

Params = Dict[str, Any]

DEFAULT_GROUP = 512


def set_default_group(n: int) -> None:
    """Hillclimb knob: MoE dispatch group size (dispatch volume ~ linear
    in group size at fixed capacity factor)."""
    global DEFAULT_GROUP
    DEFAULT_GROUP = n


def init_moe(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    m = cfg.moe
    assert m is not None
    d, ff, e = cfg.d_model, m.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, ff)) * d ** -0.5).astype(dtype),
        "w2": (jax.random.normal(ks[2], (e, ff, d)) * ff ** -0.5).astype(dtype),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = (jax.random.normal(ks[3], (e, d, ff)) * d ** -0.5).astype(dtype)
    return p


def moe_block(cfg: ModelConfig, p: Params, x: jax.Array,
              *, group_size: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    assert m is not None
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    tokens = b * s
    g_sz = min(group_size if group_size is not None else DEFAULT_GROUP, tokens)
    assert tokens % g_sz == 0, (tokens, g_sz)
    g = tokens // g_sz
    cap = max(k, int(math.ceil(g_sz * k * m.capacity_factor / e)))

    xg = constrain(x.reshape(g, g_sz, d), "dp", None, None)
    logits = (xg.astype(jnp.float32) @ p["router"])            # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (G, S, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load balance aux loss
    me = jnp.mean(probs, axis=1)                               # (G, E)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=2), axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * e

    # capacity positions: for the j-th routing choice of each token, its
    # position within its expert's buffer (GShard cumsum trick)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)    # (G, S, k, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, k * g_sz, e)
    pos_flat = jnp.cumsum(flat, axis=1) - flat                 # (G, k*S, E)
    pos = pos_flat.reshape(g, k, g_sz, e).transpose(0, 2, 1, 3)  # (G, S, k, E)
    pos = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)     # (G, S, k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)       # (G, S, k, C)
    combine = jnp.einsum("gske,gskc->gsec", onehot * gate_vals[..., None], pos_oh)
    dispatch = (combine > 0.0).astype(x.dtype)                 # (G, S, E, C)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)            # (G, E, C, d)
    xe = constrain(xe, "dp", "model", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])              # (G, E, C, d)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(ye.dtype), ye)
    return y.reshape(b, s, d), aux.astype(jnp.float32)
