"""Core transformer layers: norms, rotary embeddings, GQA attention, MLPs.

Functional style: params are plain pytrees (nested dicts of jax.Arrays),
layers are pure functions. Layer stacks carry a leading ``num_layers`` dim
and are driven by ``jax.lax.scan`` (keeps HLO small at 80-layer scale).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops

Params = Dict[str, Any]

KV_WRITE_MODE = "onehot"     # "onehot" | "dus" (hillclimb knob; see dryrun)


def set_kv_write_mode(mode: str) -> None:
    global KV_WRITE_MODE
    assert mode in ("onehot", "dus")
    KV_WRITE_MODE = mode


# ----------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def init_norm(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm != "rmsnorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ----------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D); positions (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                 # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    emb = jnp.zeros((length, dim), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb


# -------------------------------------------------------------- attention
def init_attention(cfg: ModelConfig, key: jax.Array, dtype: Any) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, nq * hd)) * sc).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd)) * sc).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd)) * sc).astype(dtype),
        "wo": (jax.random.normal(ks[3], (nq * hd, d)) * (nq * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def attention_block(cfg: ModelConfig, p: Params, x: jax.Array,
                    positions: jax.Array, *, impl: str = "auto",
                    causal: bool = True,
                    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                    use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill). x (B, S, d).

    ``kv`` overrides keys/values source (cross-attention)."""
    b, s, d = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    if kv is None:
        xk = xv = x
        kpos = positions
    else:
        xk, xv = kv
        kpos = jnp.broadcast_to(jnp.arange(xk.shape[1])[None], (b, xk.shape[1]))
    k = xk @ p["wk"]
    v = xv @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nq, hd)
    k = k.reshape(b, xk.shape[1], nkv, hd)
    v = v.reshape(b, xv.shape[1], nkv, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kpos, cfg.rope_theta)
    qt = constrain(q.transpose(0, 2, 1, 3), "dp", "model", None, None)
    kt = constrain(k.transpose(0, 2, 1, 3), "dp", "model", None, None)
    vt = constrain(v.transpose(0, 2, 1, 3), "dp", "model", None, None)
    o = ops.attention(qt, kt, vt, causal=causal, impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, nq * hd)
    return o @ p["wo"]


def attention_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array,
                     lengths: jax.Array, *, impl: str = "auto",
                     use_rope: bool = True):
    """Single-token decode. x (B, 1, d); cache (B, Hkv, S_max, hd);
    lengths (B,) = tokens already in cache. Returns (out, new_k, new_v)."""
    b, _, d = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, nq, hd)
    k = k.reshape(b, 1, nkv, hd)
    v = v.reshape(b, 1, nkv, hd)
    if use_rope:
        pos = lengths[:, None]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    # write new kv at position `lengths`
    k_t = k.transpose(0, 2, 1, 3)                        # (B, Hkv, 1, hd)
    v_t = v.transpose(0, 2, 1, 3)
    if KV_WRITE_MODE == "dus":
        # per-row dynamic_update_slice along the cache sequence dim
        def _wr(c, u, l):
            return jax.lax.dynamic_update_slice(c, u, (0, l, 0))
        cache_k = jax.vmap(_wr)(cache_k, k_t, lengths)
        cache_v = jax.vmap(_wr)(cache_v, v_t, lengths)
    else:
        idx = lengths[:, None, None, None]
        s_max = cache_k.shape[2]
        onehot = (jnp.arange(s_max)[None, None, :, None] == idx)
        cache_k = jnp.where(onehot, k_t, cache_k)
        cache_v = jnp.where(onehot, v_t, cache_v)
    o = ops.decode_attention(q.reshape(b, nq, hd), cache_k, cache_v,
                             lengths + 1, impl=impl)
    return (o.reshape(b, 1, nq * hd) @ p["wo"]), cache_k, cache_v


def cross_attention_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                           enc_k: jax.Array, enc_v: jax.Array,
                           *, impl: str = "auto"):
    """Decode-time cross attention against fixed encoder K/V
    (B, Hkv, S_enc, hd) — no cache mutation."""
    b = x.shape[0]
    hd, nq = cfg.resolved_head_dim, cfg.num_heads
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    s_enc = enc_k.shape[2]
    lengths = jnp.full((b,), s_enc, jnp.int32)
    o = ops.decode_attention(q.reshape(b, nq, hd), enc_k, enc_v, lengths,
                             impl=impl)
    return o.reshape(b, 1, nq * hd) @ p["wo"]


# -------------------------------------------------------------------- MLP
def init_mlp(cfg: ModelConfig, key: jax.Array, dtype: Any,
             d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = cfg.d_ff if d_ff is None else d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": (jax.random.normal(ks[0], (d, ff)) * d ** -0.5).astype(dtype),
        "w2": (jax.random.normal(ks[1], (ff, d)) * ff ** -0.5).astype(dtype),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = (jax.random.normal(ks[2], (d, ff)) * d ** -0.5).astype(dtype)
    return p


def mlp_block(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = x @ p["w1"]
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(h) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(h)
    return h @ p["w2"]
