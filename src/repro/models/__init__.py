from repro.models import layers, mamba, moe
from repro.models.model import (
    decode_step,
    encode,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
)

__all__ = ["layers", "mamba", "moe", "decode_step", "encode", "forward",
           "init_decode_cache", "init_params", "loss_fn"]
