"""Unified model builder for the 10 assigned architectures.

Families: dense | moe | ssm | hybrid | encdec | vlm | audio (audio==encdec).
Parameters are pytrees with leading layer/group dims; forward passes scan
over the stacks (small HLO even at 80 layers). Every family exposes:

  init_params(cfg, key, dtype)           -> params
  forward(cfg, params, batch, ...)       -> (logits, aux_loss)
  loss_fn(cfg, params, batch, ...)       -> scalar loss
  init_decode_cache(cfg, params, batch_size, s_max, ...) -> cache
  decode_step(cfg, params, cache, tokens, ...) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as _SH
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ===========================================================================
# init
# ===========================================================================

def _init_layer(cfg: ModelConfig, key: jax.Array, layer_idx: int,
                dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": L.init_norm(cfg, cfg.d_model),
                 "ln2": L.init_norm(cfg, cfg.d_model)}
    if cfg.is_attention_layer(layer_idx):
        p["attn"] = L.init_attention(cfg, ks[0], dtype)
    else:
        p["mamba"] = M.init_mamba(cfg, ks[0], dtype)
    if cfg.is_moe_layer(layer_idx):
        p["moe"] = MOE.init_moe(cfg, ks[1], dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = L.init_mlp(cfg, ks[1], dtype)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype: Optional[Any] = None) -> Params:
    dtype = _dtype(cfg) if dtype is None else dtype
    keys = jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 4)
    vp = cfg.padded_vocab_size
    params: Params = {
        "embed": (jax.random.normal(keys[-1], (vp, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "ln_f": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[-2], (cfg.d_model, vp)) * cfg.d_model ** -0.5
        ).astype(dtype)

    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.num_layers // period
        groups = []
        for g in range(n_groups):
            gp: Params = {
                "ln1": _stack([L.init_norm(cfg, cfg.d_model) for _ in range(period)]),
                "ln2": _stack([L.init_norm(cfg, cfg.d_model) for _ in range(period)]),
            }
            mambas, moes, mlps = [], [], []
            for rel in range(period):
                k = keys[g * period + rel]
                ks = jax.random.split(k, 2)
                if cfg.is_attention_layer(rel):
                    gp["attn"] = L.init_attention(cfg, ks[0], dtype)
                else:
                    mambas.append(M.init_mamba(cfg, ks[0], dtype))
                if cfg.is_moe_layer(rel):
                    moes.append(MOE.init_moe(cfg, ks[1], dtype))
                elif cfg.d_ff > 0:
                    mlps.append(L.init_mlp(cfg, ks[1], dtype))
            gp["mamba"] = _stack(mambas)
            if moes:
                gp["moe"] = _stack(moes)
            if mlps:
                gp["mlp"] = _stack(mlps)
            groups.append(gp)
        # groups share structure (period even, fixed attention index)
        params["groups"] = _stack(groups)
    else:
        params["layers"] = _stack([
            _init_layer(cfg, keys[i], i, dtype) for i in range(cfg.num_layers)])

    if cfg.encoder_layers:
        enc_cfg = cfg
        enc = []
        for i in range(cfg.encoder_layers):
            k = keys[cfg.num_layers + i]
            ks = jax.random.split(k, 2)
            enc.append({
                "ln1": L.init_norm(cfg, cfg.d_model),
                "ln2": L.init_norm(cfg, cfg.d_model),
                "attn": L.init_attention(enc_cfg, ks[0], dtype),
                "mlp": L.init_mlp(enc_cfg, ks[1], dtype),
            })
        params["encoder"] = _stack(enc)
        params["enc_ln_f"] = L.init_norm(cfg, cfg.d_model)
        # decoder cross-attention stack (one per decoder layer)
        params["cross"] = _stack([
            L.init_attention(cfg, keys[-3 - i], dtype)
            for i in range(cfg.num_layers)])
        params["ln_x"] = _stack([L.init_norm(cfg, cfg.d_model)
                                 for _ in range(cfg.num_layers)])
    return params


# ===========================================================================
# forward (train / prefill)
# ===========================================================================

def _maybe_remat(fn, remat: bool):
    if not remat:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _decoder_layer(cfg: ModelConfig, lp: Params, x: jax.Array,
                   positions: jax.Array, layer_idx_static: Dict[str, bool],
                   impl: str, enc_out: Optional[jax.Array] = None,
                   cross_p: Optional[Params] = None,
                   ln_x: Optional[Params] = None) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, "dp", None, "model") if _SH.SP_RESIDUALS \
        else constrain(x, "dp", None, None)
    h = L.norm(cfg, lp["ln1"], x)
    if layer_idx_static["attention"]:
        x = x + L.attention_block(cfg, lp["attn"], h, positions, impl=impl,
                                  use_rope=(cfg.family != "encdec"))
    else:
        x = x + M.mamba_block(cfg, lp["mamba"], h, impl=impl)
    if enc_out is not None and cross_p is not None:
        hx = L.norm(cfg, ln_x, x)
        x = x + L.attention_block(cfg, cross_p, hx, positions, impl=impl,
                                  causal=False, kv=(enc_out, enc_out),
                                  use_rope=False)
    h2 = L.norm(cfg, lp["ln2"], x)
    if layer_idx_static["moe"]:
        y, aux = MOE.moe_block(cfg, lp["moe"], h2)
        x = x + y
    elif cfg.d_ff > 0:
        x = x + L.mlp_block(cfg, lp["mlp"], h2)
    return x, aux


def _run_stack(cfg: ModelConfig, params: Params, x: jax.Array,
               positions: jax.Array, impl: str, remat: bool,
               enc_out: Optional[jax.Array] = None,
               unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    if cfg.family == "hybrid":
        period = cfg.hybrid_period

        def group_body(carry, gp):
            x = carry
            aux_total = jnp.zeros((), jnp.float32)
            mamba_i = moe_i = mlp_i = 0
            for rel in range(period):
                h = L.norm(cfg, jax.tree.map(lambda a: a[rel], gp["ln1"]), x)
                if cfg.is_attention_layer(rel):
                    x = x + L.attention_block(cfg, gp["attn"], h, positions,
                                              impl=impl)
                else:
                    mp = jax.tree.map(lambda a: a[mamba_i], gp["mamba"])
                    x = x + M.mamba_block(cfg, mp, h, impl=impl)
                    mamba_i += 1
                h2 = L.norm(cfg, jax.tree.map(lambda a: a[rel], gp["ln2"]), x)
                if cfg.is_moe_layer(rel):
                    ep = jax.tree.map(lambda a: a[moe_i], gp["moe"])
                    y, aux = MOE.moe_block(cfg, ep, h2)
                    x = x + y
                    aux_total = aux_total + aux
                    moe_i += 1
                elif cfg.d_ff > 0:
                    fp = jax.tree.map(lambda a: a[mlp_i], gp["mlp"])
                    x = x + L.mlp_block(cfg, fp, h2)
                    mlp_i += 1
            return x, aux_total

        body = _maybe_remat(group_body, remat)
        x, auxs = jax.lax.scan(body, x, params["groups"], unroll=unroll)
        return x, jnp.sum(auxs)

    # homogeneous stack (dense / moe / ssm / encdec decoder / vlm)
    is_attn = cfg.is_attention_layer(0)
    is_moe = cfg.is_moe_layer(0)
    flags = {"attention": is_attn, "moe": is_moe}
    has_cross = cfg.encoder_layers > 0

    def layer_body(carry, inp):
        x = carry
        if has_cross:
            lp, cross_p, ln_x = inp
            x, aux = _decoder_layer(cfg, lp, x, positions, flags, impl,
                                    enc_out=enc_out, cross_p=cross_p,
                                    ln_x=ln_x)
        else:
            lp = inp
            x, aux = _decoder_layer(cfg, lp, x, positions, flags, impl)
        return x, aux

    body = _maybe_remat(layer_body, remat)
    xs = (params["layers"], params["cross"], params["ln_x"]) if has_cross \
        else params["layers"]
    x, auxs = jax.lax.scan(body, x, xs, unroll=unroll)
    return x, jnp.sum(auxs)


def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           *, impl: str = "auto", remat: bool = False,
           unroll: bool = False) -> jax.Array:
    """Whisper-style encoder over stubbed frame embeddings (B, S_enc, d)."""
    b, s, d = frames.shape
    pos_emb = L.sinusoidal_embedding(s, d).astype(frames.dtype)
    x = frames + pos_emb[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, lp):
        x = carry
        h = L.norm(cfg, lp["ln1"], x)
        x = x + L.attention_block(cfg, lp["attn"], h, positions, impl=impl,
                                  causal=False, use_rope=False)
        h = L.norm(cfg, lp["ln2"], x)
        x = x + L.mlp_block(cfg, lp["mlp"], h)
        return x, ()

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=unroll)
    return L.norm(cfg, params["enc_ln_f"], x)


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, impl: str = "auto", remat: bool = False,
            unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """batch: tokens (B, S) [+ frames (B,S_enc,d) | image_embeds (B,V,d)].

    Returns (logits (B, S, V), aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = constrain(x, "dp", None, None)
    if cfg.family == "vlm" and "image_embeds" in batch:
        v = batch["image_embeds"].shape[1]
        x = jnp.concatenate([batch["image_embeds"].astype(x.dtype),
                             x[:, v:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.family == "encdec":
        x = x + L.sinusoidal_embedding(s, cfg.d_model).astype(x.dtype)[None]
        enc_out = encode(cfg, params, batch["frames"], impl=impl, remat=remat,
                         unroll=unroll)
    else:
        enc_out = None
    x, aux = _run_stack(cfg, params, x, positions, impl, remat, enc_out=enc_out,
                        unroll=unroll)
    x = L.norm(cfg, params["ln_f"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    # mask padded vocab entries (vocab padded for clean model-axis sharding)
    if cfg.padded_vocab_size != cfg.vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, impl: str = "auto", remat: bool = False,
            unroll: bool = False) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(cfg, params, batch, impl=impl, remat=remat,
                          unroll=unroll)
    labels = batch["labels"]
    # sharding-safe cross entropy: logsumexp + one-hot contraction keep the
    # vocab dim model-sharded end-to-end (no all-gather of logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    lab_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - lab_logit
    mask = (labels >= 0).astype(jnp.float32)
    xent = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    m = cfg.moe
    loss = xent + (m.aux_loss_weight * aux if m is not None else 0.0)
    return loss, {"xent": xent, "aux": aux}


# ===========================================================================
# decode (serving)
# ===========================================================================

def init_decode_cache(cfg: ModelConfig, batch: int, s_max: int,
                      dtype: Optional[Any] = None,
                      enc_out: Optional[jax.Array] = None) -> Params:
    """Stacked per-layer KV caches (+ mamba states for ssm/hybrid)."""
    dtype = _dtype(cfg) if dtype is None else dtype
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    cache: Params = {"lengths": jnp.zeros((batch,), jnp.int32)}

    def kv(n_layers):
        return (jnp.zeros((n_layers, batch, nkv, s_max, hd), dtype),
                jnp.zeros((n_layers, batch, nkv, s_max, hd), dtype))

    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.num_layers // period
        n_mamba = period - 1
        cache["k"], cache["v"] = kv(n_groups)
        d_in, h, n, p_dim, conv_dim = M.dims(cfg)
        s = cfg.ssm
        cache["conv"] = jnp.zeros((n_groups, n_mamba, batch, s.d_conv - 1, conv_dim), dtype)
        cache["ssd"] = jnp.zeros((n_groups, n_mamba, batch, h, n, p_dim), jnp.float32)
    elif cfg.family == "ssm":
        d_in, h, n, p_dim, conv_dim = M.dims(cfg)
        s = cfg.ssm
        cache["conv"] = jnp.zeros((cfg.num_layers, batch, s.d_conv - 1, conv_dim), dtype)
        cache["ssd"] = jnp.zeros((cfg.num_layers, batch, h, n, p_dim), jnp.float32)
    else:
        cache["k"], cache["v"] = kv(cfg.num_layers)
    if cfg.encoder_layers:
        assert enc_out is not None, "enc-dec decode needs encoder output"
        # pre-projected cross-attention K/V per decoder layer
        cache["enc_out"] = enc_out
    return cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, *, impl: str = "auto",
                unroll: bool = False) -> Tuple[jax.Array, Params]:
    """One decoding step. tokens (B,) -> logits (B, V), updated cache."""
    b = tokens.shape[0]
    lengths = cache["lengths"]
    x = params["embed"][tokens][:, None]                     # (B, 1, d)
    if cfg.family == "encdec":
        pe = L.sinusoidal_embedding(int(cache["k"].shape[3]), cfg.d_model)
        x = x + pe[lengths[0]][None, None].astype(x.dtype)

    if cfg.family == "hybrid":
        period = cfg.hybrid_period

        def group_body(x, inp):
            gp, ck, cv, conv, ssd = inp
            mamba_i = moe_i = mlp_i = 0
            new_conv, new_ssd = [], []
            nk, nv = ck, cv
            for rel in range(period):
                h = L.norm(cfg, jax.tree.map(lambda a: a[rel], gp["ln1"]), x)
                if cfg.is_attention_layer(rel):
                    o, nk, nv = L.attention_decode(cfg, gp["attn"], h, ck, cv,
                                                   lengths, impl=impl)
                    x = x + o
                else:
                    mp = jax.tree.map(lambda a: a[mamba_i], gp["mamba"])
                    mc = {"conv": conv[mamba_i], "ssd": ssd[mamba_i]}
                    o, mc = M.mamba_decode(cfg, mp, h, mc)
                    new_conv.append(mc["conv"])
                    new_ssd.append(mc["ssd"])
                    mamba_i += 1
                    x = x + o
                h2 = L.norm(cfg, jax.tree.map(lambda a: a[rel], gp["ln2"]), x)
                if cfg.is_moe_layer(rel):
                    ep = jax.tree.map(lambda a: a[moe_i], gp["moe"])
                    y, _ = MOE.moe_block(cfg, ep, h2, group_size=b)
                    x = x + y
                    moe_i += 1
                elif cfg.d_ff > 0:
                    fp = jax.tree.map(lambda a: a[mlp_i], gp["mlp"])
                    x = x + L.mlp_block(cfg, fp, h2)
                    mlp_i += 1
            return x, (nk, nv, jnp.stack(new_conv), jnp.stack(new_ssd))

        x, (nk, nv, nconv, nssd) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["k"], cache["v"], cache["conv"], cache["ssd"]),
            unroll=unroll)
        cache = dict(cache, k=nk, v=nv, conv=nconv, ssd=nssd)

    elif cfg.family == "ssm":
        def body(x, inp):
            lp, conv, ssd = inp
            h = L.norm(cfg, lp["ln1"], x)
            o, mc = M.mamba_decode(cfg, lp["mamba"], h, {"conv": conv, "ssd": ssd})
            x = x + o
            return x, (mc["conv"], mc["ssd"])

        x, (nconv, nssd) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssd"]),
            unroll=unroll)
        cache = dict(cache, conv=nconv, ssd=nssd)

    else:
        has_cross = cfg.encoder_layers > 0
        is_moe = cfg.is_moe_layer(0)

        def body(x, inp):
            if has_cross:
                lp, ck, cv, cross_p, ln_x = inp
            else:
                lp, ck, cv = inp
            h = L.norm(cfg, lp["ln1"], x)
            o, nk, nv = L.attention_decode(
                cfg, lp["attn"], h, ck, cv, lengths, impl=impl,
                use_rope=(cfg.family != "encdec"))
            x = x + o
            if has_cross:
                hx = L.norm(cfg, ln_x, x)
                enc = cache["enc_out"]
                x = x + L.attention_block(cfg, cross_p, hx,
                                          jnp.zeros((b, 1), jnp.int32),
                                          impl=impl, causal=False,
                                          kv=(enc, enc), use_rope=False)
            h2 = L.norm(cfg, lp["ln2"], x)
            if is_moe:
                y, _ = MOE.moe_block(cfg, lp["moe"], h2, group_size=b)
                x = x + y
            elif cfg.d_ff > 0:
                x = x + L.mlp_block(cfg, lp["mlp"], h2)
            return x, (nk, nv)

        xs = (params["layers"], cache["k"], cache["v"])
        if has_cross:
            xs = xs + (params["cross"], params["ln_x"])
        x, (nk, nv) = jax.lax.scan(body, x, xs, unroll=unroll)
        cache = dict(cache, k=nk, v=nv)

    x = L.norm(cfg, params["ln_f"], x[:, 0])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    if cfg.padded_vocab_size != cfg.vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    cache["lengths"] = lengths + 1
    return logits, cache
