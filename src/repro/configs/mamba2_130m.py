"""mamba2-130m — attention-free SSM (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,              # d_inner / head_dim = 1536/64
    num_kv_heads=24,
    d_ff=0,                    # mamba2 block has no separate MLP
    vocab_size=50280,
    tie_embeddings=True,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    source="arXiv:2405.21060",
    notes="SSD; the long_500k cell runs here (O(S) state recurrence)",
)
