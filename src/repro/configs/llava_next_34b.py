"""llava-next-34b — VLM; yi-34b backbone + anyres vision tiling (stubbed).

[hf:llava-hf/llava-v1.6-34b; unverified] ``input_specs`` provides precomputed
patch embeddings for the anyres tile grid (base 576 + up to 4 tiles x 576).
"""
from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=5_000_000.0,
    frontend=FrontendStub(kind="vision", num_tokens=2880, feature_dim=7168),
    source="hf:llava-hf/llava-v1.6-34b",
    notes="anyres tiling is host-side 'map-like' work under CASH annotation",
)
