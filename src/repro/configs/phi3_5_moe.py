"""phi3.5-moe-42b-a6.6b — MoE 16 experts top-2, every layer.

[hf:microsoft/Phi-3.5-MoE-instruct; hf] Analytic ~42B total / ~6.6B active.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,                 # unused (every layer is MoE); kept for reference
    vocab_size=32064,
    head_dim=128,
    act="swiglu",
    norm="layernorm",
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400, every=1),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
