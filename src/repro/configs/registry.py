"""Architecture registry: full configs, reduced smoke configs, shape table."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.configs.base import (
    FrontendStub,
    InputShape,
    ModelConfig,
    MoEConfig,
    SHAPES,
    SHAPES_BY_NAME,
    SSMConfig,
    shape_applicable,
)

from repro.configs.granite_20b import CONFIG as _granite_20b
from repro.configs.qwen1_5_110b import CONFIG as _qwen
from repro.configs.granite_3_2b import CONFIG as _granite_3_2b
from repro.configs.yi_34b import CONFIG as _yi
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.jamba_1_5_large import CONFIG as _jamba
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.phi3_5_moe import CONFIG as _phi
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.llava_next_34b import CONFIG as _llava

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _granite_20b, _qwen, _granite_3_2b, _yi, _whisper,
        _jamba, _mamba2, _phi, _dbrx, _llava,
    )
}

# convenient aliases (CLI friendliness)
ALIASES = {
    "granite-20b": "granite-20b",
    "qwen1.5-110b": "qwen1.5-110b",
    "qwen110b": "qwen1.5-110b",
    "granite-3-2b": "granite-3-2b",
    "yi-34b": "yi-34b",
    "whisper-large-v3": "whisper-large-v3",
    "whisper": "whisper-large-v3",
    "jamba-1.5-large-398b": "jamba-1.5-large-398b",
    "jamba": "jamba-1.5-large-398b",
    "mamba2-130m": "mamba2-130m",
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b-a6.6b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "dbrx-132b": "dbrx-132b",
    "llava-next-34b": "llava-next-34b",
}


def get_config(name: str) -> ModelConfig:
    key = ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a full config to a CPU-smoke-testable config of the same family.

    Keeps the structural features (GQA ratio topology, MoE, hybrid interleave,
    enc-dec, frontend) while dropping widths/depths/vocab to toy scale.
    """
    kv = 1 if cfg.num_kv_heads == 1 else 2        # preserve MQA vs GQA
    updates: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=4 if cfg.family == "hybrid" else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=kv,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=257,
        head_dim=16,
        max_seq_len=128,
    )
    if cfg.moe is not None:
        updates["moe"] = MoEConfig(
            num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff=128,
            every=min(cfg.moe.every, 2), capacity_factor=cfg.moe.capacity_factor)
    if cfg.ssm is not None:
        updates["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16)
    if cfg.family == "hybrid":
        updates["hybrid_period"] = 2
        updates["hybrid_attn_index"] = 1
    if cfg.encoder_layers:
        updates["encoder_layers"] = 2
        updates["encoder_seq_len"] = 8
    if cfg.frontend is not None:
        updates["frontend"] = FrontendStub(
            kind=cfg.frontend.kind, num_tokens=8, feature_dim=64)
    return dataclasses.replace(cfg, **updates)


def all_cells(include_skips: bool = False) -> List[Tuple[ModelConfig, InputShape, bool, str]]:
    """All (arch, shape) dry-run cells; skipped cells flagged with the reason."""
    out = []
    for cfg in ARCHS.values():
        for shape in SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_skips:
                out.append((cfg, shape, ok, reason))
    return out


__all__ = [
    "ARCHS", "ALIASES", "SHAPES", "SHAPES_BY_NAME",
    "get_config", "reduced_config", "all_cells", "shape_applicable",
]
