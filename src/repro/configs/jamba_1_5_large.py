"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] 72 layers in 9 blocks of 8; layer 4 of each block is
attention (1:7 attn:mamba), MoE every other layer. Analytic params ~397B total /
~94B active, matching the published 398B/94B.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    hybrid_period=8,
    hybrid_attn_index=4,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576, every=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    source="arXiv:2403.19887",
)
