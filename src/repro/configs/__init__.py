from repro.configs.base import (
    FrontendStub,
    InputShape,
    ModelConfig,
    MoEConfig,
    SHAPES,
    SHAPES_BY_NAME,
    SSMConfig,
    shape_applicable,
)
from repro.configs.registry import (
    ALIASES,
    ARCHS,
    all_cells,
    get_config,
    reduced_config,
)

__all__ = [
    "FrontendStub", "InputShape", "ModelConfig", "MoEConfig", "SSMConfig",
    "SHAPES", "SHAPES_BY_NAME", "shape_applicable",
    "ALIASES", "ARCHS", "all_cells", "get_config", "reduced_config",
]
