"""whisper-large-v3 — enc-dec audio transformer; conv frontend stubbed.

[arXiv:2212.04356; unverified] — the transformer BACKBONE only; ``input_specs``
provides precomputed log-mel frame embeddings (the 2x conv1d stem is a stub).
"""
from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,             # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,           # full MHA (GQA kv=20 == heads)
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    act="gelu",
    norm="layernorm",
    qkv_bias=True,
    encoder_layers=32,
    encoder_seq_len=1500,      # 30 s of audio at 50 Hz after conv stem
    frontend=FrontendStub(kind="audio", num_tokens=1500, feature_dim=1280),
    source="arXiv:2212.04356",
    notes="enc-dec; decode shapes exercise decoder self-attn KV + cross-attn cache",
)
