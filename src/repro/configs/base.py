"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. Fields cover the
union of the dense / MoE / SSM / hybrid / enc-dec / multimodal families; family-
specific fields are ignored by families that do not use them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    every: int = 1                 # MoE layer every `every` layers (1 = all layers)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128             # N, SSD state size
    d_conv: int = 4                # local conv width
    expand: int = 2                # d_inner = expand * d_model
    head_dim: int = 64             # SSD head dim (P)
    chunk: int = 256               # SSD chunk length


@dataclasses.dataclass(frozen=True)
class FrontendStub:
    """Modality frontend stub: ``input_specs`` provides precomputed embeddings."""
    kind: str                      # "audio" | "vision"
    num_tokens: int                # frames (audio) / patches incl. anyres tiles (vision)
    feature_dim: int               # embedding dim fed into the backbone


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None           # default d_model // num_heads
    max_seq_len: int = 8192
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    act: str = "swiglu"                      # swiglu | gelu | geglu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba-style): within each block of `hybrid_period` layers, layer
    # index `hybrid_attn_index` is attention, the rest are mamba.
    hybrid_period: int = 0
    hybrid_attn_index: int = 0
    # enc-dec
    encoder_layers: int = 0
    encoder_seq_len: int = 0                 # fixed encoder length (whisper: 1500)
    frontend: Optional[FrontendStub] = None
    dtype: str = "bfloat16"
    notes: str = ""
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 128 so the embedding / logits shard
        cleanly over the model axis (Megatron-style padding)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def is_attention_layer(self, layer_idx: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.hybrid_period > 0:
            return (layer_idx % self.hybrid_period) == self.hybrid_attn_index
        return True

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return (layer_idx % self.moe.every) == (self.moe.every - 1)

    def num_attention_layers(self) -> int:
        return sum(1 for i in range(self.num_layers) if self.is_attention_layer(i))

    def num_moe_layers(self) -> int:
        return sum(1 for i in range(self.num_layers) if self.is_moe_layer(i))

    # ---------------- parameter counting (for 6ND roofline) ----------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count. ``active_only`` counts top_k experts only."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = 0
        # embeddings (+ untied output head)
        total += self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        layers = self.num_layers

        def attn_params() -> int:
            p = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            if self.qkv_bias:
                p += (n_q + 2 * n_kv) * hd
            return p

        def dense_mlp_params(dff: int) -> int:
            mults = 3 if self.act in ("swiglu", "geglu") else 2
            return mults * d * dff

        def mamba_params() -> int:
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            n_heads_ssm = d_in // s.head_dim
            p = d * (2 * d_in + 2 * s.d_state + n_heads_ssm)   # in_proj(z,x,B,C,dt)
            p += s.d_conv * (d_in + 2 * s.d_state)             # conv over x,B,C
            p += n_heads_ssm * 2                               # A_log, D
            p += d_in * d                                      # out_proj
            return p

        for i in range(layers):
            total += 2 * d  # norms
            if self.is_attention_layer(i):
                total += attn_params()
            else:
                total += mamba_params()
            if self.is_moe_layer(i):
                m = self.moe
                assert m is not None
                n_e = m.top_k if active_only else m.num_experts
                total += n_e * dense_mlp_params(m.d_ff) + d * m.num_experts  # + router
            else:
                total += dense_mlp_params(self.d_ff)
        # encoder stack (enc-dec): attention + mlp per layer + cross-attn in decoder
        if self.encoder_layers:
            for _ in range(self.encoder_layers):
                total += attn_params() + dense_mlp_params(self.d_ff) + 2 * d
            # decoder cross-attention blocks
            total += self.num_layers * (attn_params() + d)
        total += d  # final norm
        return total


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    InputShape("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    InputShape("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    InputShape("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether a dry-run cell (arch x shape) applies; returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % cfg.name
    return True, ""
