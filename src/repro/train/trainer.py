"""Training loop: grad accumulation, checkpoint/restart, CASH-scheduled data
shards, straggler-aware microbatching, failure injection for tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MD
from repro.sched.train_scheduler import CashTrainScheduler
from repro.train import checkpoint as CKPT
from repro.train.data import DataConfig, global_batch
from repro.train.optimizer import Optimizer, OptimizerConfig, make_optimizer
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    grad_accum: int = 1
    impl: str = "auto"
    remat: bool = False
    seed: int = 0
    rebalance_every: int = 20          # CASH shard-rebalance cadence (steps)
    fail_at_step: Optional[int] = None  # failure injection (tests)


class Trainer:
    """Single-process trainer (multi-host generalizes via the same pjit step;
    the CASH scheduler layer is host-level and identical either way)."""

    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig,
                 opt_cfg: Optional[OptimizerConfig] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 scheduler: Optional[CashTrainScheduler] = None,
                 dtype: Any = jnp.float32):
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.train_cfg = train_cfg or TrainConfig()
        self.opt = make_optimizer(opt_cfg or OptimizerConfig(
            warmup_steps=10, total_steps=self.train_cfg.steps))
        self.scheduler = scheduler
        key = jax.random.PRNGKey(self.train_cfg.seed)
        self.params = MD.init_params(cfg, key, dtype)
        self.opt_state = self.opt.init(self.params)
        self.step_fn = jax.jit(make_train_step(
            cfg, self.opt, impl=self.train_cfg.impl, remat=self.train_cfg.remat))
        self.step = 0
        self.history: List[Dict[str, float]] = []
        self._ckpt = (CKPT.AsyncCheckpointer(self.train_cfg.ckpt_dir,
                                             keep=self.train_cfg.ckpt_keep)
                      if self.train_cfg.ckpt_dir else None)

    # ------------------------------------------------------------------
    def state(self) -> Dict[str, Any]:
        return {"params": self.params, "opt": self.opt_state}

    def maybe_restore(self) -> bool:
        if not self.train_cfg.ckpt_dir:
            return False
        latest = CKPT.latest_step(self.train_cfg.ckpt_dir)
        if latest is None:
            return False
        state, step, extra = CKPT.restore(self.train_cfg.ckpt_dir, self.state())
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = step
        return True

    def _microbatches(self, batch: Dict[str, np.ndarray]):
        ga = self.train_cfg.grad_accum
        if ga == 1:
            yield batch
            return
        rows = batch["tokens"].shape[0]
        per = rows // ga
        for i in range(ga):
            yield {k: v[i * per:(i + 1) * per] for k, v in batch.items()}

    def run(self, steps: Optional[int] = None) -> List[Dict[str, float]]:
        tc = self.train_cfg
        end = self.step + (steps if steps is not None else tc.steps)
        while self.step < end:
            if tc.fail_at_step is not None and self.step == tc.fail_at_step:
                raise RuntimeError(f"injected failure at step {self.step}")
            if (self.scheduler is not None
                    and self.step % tc.rebalance_every == 0):
                self.scheduler.rebalance(now=float(self.step))
            batch_np = global_batch(self.data_cfg, self.step)
            t0 = time.time()
            metrics = None
            for mb in self._microbatches(batch_np):
                mb = {k: jnp.asarray(v) for k, v in mb.items()}
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, mb)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step"] = self.step
            metrics["step_time_s"] = time.time() - t0
            self.history.append(metrics)
            if self.step % tc.log_every == 0:
                print(f"step {self.step:5d} loss={metrics['loss']:.4f} "
                      f"lr={metrics['lr']:.2e} ({metrics['step_time_s']:.2f}s)")
            self.step += 1
            if self._ckpt and self.step % tc.ckpt_every == 0:
                self._ckpt.save(self.step, self.state(),
                                extra={"data_step": self.step})
        if self._ckpt:
            self._ckpt.save(self.step, self.state(),
                            extra={"data_step": self.step})
            self._ckpt.wait()
        return self.history
