"""jit-compiled train / serve step builders (shared by trainer and dry-run)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as SH
from repro.models import model as MD
from repro.train.optimizer import Optimizer


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                    impl: str = "auto", remat: bool = True,
                    unroll: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: MD.loss_fn(cfg, p, batch, impl=impl, remat=remat,
                                 unroll=unroll),
            has_aux=True)(params)
        params, opt_state, opt_metrics = optimizer.update(params, grads, opt_state)
        out = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, out

    return train_step


def make_serve_step(cfg: ModelConfig, *, impl: str = "auto",
                    unroll: bool = False):
    """(params, cache, tokens) -> (logits, cache)."""

    def serve_step(params, cache, tokens):
        return MD.decode_step(cfg, params, cache, tokens, impl=impl,
                              unroll=unroll)

    return serve_step


def jit_train_step(cfg: ModelConfig, optimizer: Optimizer, mesh, *,
                   impl: str = "auto", remat: bool = True, unroll: bool = False,
                   params_struct=None, batch_struct=None):
    """pjit the train step against a mesh with the sharding rules applied."""
    step = make_train_step(cfg, optimizer, impl=impl, remat=remat, unroll=unroll)
    if params_struct is None:
        params_struct = jax.eval_shape(
            lambda: MD.init_params(cfg, jax.random.PRNGKey(0)))
    opt_struct = jax.eval_shape(optimizer.init, params_struct)
    p_sh = SH.param_shardings(params_struct, mesh)
    o_sh = SH.param_shardings(opt_struct, mesh)   # mirrors params; extras -> replicated
    if batch_struct is not None:
        b_sh = SH.batch_shardings(batch_struct, mesh)
    else:
        b_sh = None
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    ), (p_sh, o_sh, b_sh)


SERVE_TP_BUDGET_BYTES = 14e9     # fit bf16 TP-sharded weights in v5e HBM


def serve_params_mode(cfg: ModelConfig, mesh) -> str:
    """"tp" (weights TP-only, data-replicated: no per-step FSDP gathers)
    when the TP shard fits HBM; otherwise "fsdp"."""
    tp = mesh.shape.get("model", 1)
    per_dev = cfg.param_count() * 2.0 / tp
    return "tp" if per_dev <= SERVE_TP_BUDGET_BYTES else "fsdp"


def jit_serve_step(cfg: ModelConfig, mesh, *, impl: str = "auto",
                   unroll: bool = False, params_mode: str = "auto",
                   params_struct=None, cache_struct=None, tokens_struct=None):
    step = make_serve_step(cfg, impl=impl, unroll=unroll)
    if params_struct is None:
        params_struct = jax.eval_shape(
            lambda: MD.init_params(cfg, jax.random.PRNGKey(0)))
    if params_mode == "auto":
        params_mode = serve_params_mode(cfg, mesh)
    p_sh = SH.param_shardings(params_struct, mesh,
                              serve_tp=(params_mode == "tp"))
    c_sh = SH.cache_shardings(cache_struct, mesh) if cache_struct is not None else None
    t_sh = (SH.batch_shardings(tokens_struct, mesh)
            if tokens_struct is not None else None)
    return jax.jit(
        step,
        in_shardings=(p_sh, c_sh, t_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    ), (p_sh, c_sh)
