"""Sharded checkpointing: manifest + per-leaf .npy, async save, exact
restore, and elastic reshard-on-load (a checkpoint written under one mesh
restores under another — leaves are saved unsharded-logical, resharding is
the loader's concern).

Fault-tolerance contract (tested): save is atomic (tmp dir + rename), the
latest complete checkpoint always wins, and (params, opt_state, data step)
restore bitwise so a killed-and-restarted run continues identically.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, state: Dict[str, Any],
         extra: Optional[Dict[str, Any]] = None) -> Path:
    """Atomic synchronous save of a pytree state under ``ckpt_dir/step_N``."""
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f".tmp_step_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten(state)
    index = {}
    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "__") + ".npy"
        np.save(tmp / fn, arr)
        index[key] = {"file": fn, "shape": list(arr.shape),
                      "dtype": str(arr.dtype)}
    manifest = {"step": step, "index": index, "extra": extra or {},
                "time": time.time()}
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    final = root / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


class AsyncCheckpointer:
    """Off-thread saves; ``wait()`` before reading results or exiting."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # materialize on the caller thread (donation safety), write off-thread
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _work():
            save(self.ckpt_dir, step, host_state, extra)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(Path(self.ckpt_dir) / f"step_{s:08d}",
                          ignore_errors=True)


def list_steps(ckpt_dir: str):
    root = Path(ckpt_dir)
    if not root.exists():
        return []
    out = []
    for d in root.iterdir():
        if d.is_dir() and d.name.startswith("step_") and (d / MANIFEST).exists():
            out.append(int(d.name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (optional pytree of NamedSharding)
    re-shards on load — elastic restarts under a different mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / MANIFEST).read_text())
    leaves, treedef = _flatten(target)
    sh_leaves = None
    if shardings is not None:
        sh_leaves, _ = _flatten(shardings)
    restored = {}
    for key in leaves:
        meta = manifest["index"][key]
        arr = np.load(d / meta["file"])
        if str(arr.dtype) != meta["dtype"]:
            # np.save round-trips ml_dtypes (bf16, fp8) as void; the bits are
            # intact — view back to the recorded dtype
            import ml_dtypes  # noqa: F401  (registers the dtypes)
            arr = arr.view(np.dtype(meta["dtype"]))
        if sh_leaves is not None and key in sh_leaves:
            restored[key] = jax.device_put(arr, sh_leaves[key])
        else:
            restored[key] = arr
    ordered = [restored[k] for k in leaves]
    return jax.tree_util.tree_unflatten(treedef, ordered), step, manifest["extra"]
