"""Optimizers built from scratch (no optax in this environment).

AdamW (decoupled weight decay), Adafactor (factored second moment — the
memory-frugal choice for the 398B config), SGD-momentum, plus a
warmup-cosine schedule. Optimizer state mirrors the parameter pytree so the
parameter sharding rules apply verbatim (ZeRO-style sharded optimizer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"                 # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moments_dtype: str = "float32"      # bfloat16 halves optimizer memory
    momentum: float = 0.9               # sgd


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


class Optimizer(NamedTuple):
    init: Callable[[Params], OptState]
    update: Callable[[Params, Params, OptState, jax.Array],
                     Tuple[Params, OptState, Dict[str, jax.Array]]]
    config: OptimizerConfig


def _decay_mask(path_names) -> bool:
    """No weight decay on norms / biases / 1-d params."""
    name = path_names[-1]
    return name not in ("scale", "bias", "norm", "A_log", "D", "dt_bias",
                        "bq", "bk", "bv", "conv_b")


def _paths(tree):
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in kp), tree)


def adamw(cfg: OptimizerConfig) -> Optimizer:
    mdt = jnp.dtype(cfg.moments_dtype)

    def init(params: Params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, step=None):
        step = state["step"] if step is None else step
        count = step + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = schedule(cfg, count.astype(jnp.float32))
        bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

        def upd(p, g, mu, nu, path):
            g = g.astype(jnp.float32)
            mu32 = mu.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
            nu32 = nu.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
            step_ = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
            if _decay_mask(path):
                step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step_
            return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

        flat_p, tdef = jax.tree_util.tree_flatten_with_path(params)
        paths = [tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
                 for kp, _ in flat_p]
        tdef_plain = jax.tree_util.tree_structure(params)
        flat_g = tdef_plain.flatten_up_to(grads)
        flat_mu = tdef_plain.flatten_up_to(state["mu"])
        flat_nu = tdef_plain.flatten_up_to(state["nu"])
        news = [upd(p, g, mu, nu, path)
                for (_, p), g, mu, nu, path
                in zip(flat_p, flat_g, flat_mu, flat_nu, paths)]
        new_p = tdef_plain.unflatten([n[0] for n in news])
        new_mu = tdef_plain.unflatten([n[1] for n in news])
        new_nu = tdef_plain.unflatten([n[2] for n in news])
        return new_p, {"mu": new_mu, "nu": new_nu, "step": count}, \
            {"lr": lr, "grad_norm": gnorm}

    return Optimizer(init, update, cfg)


def adafactor(cfg: OptimizerConfig) -> Optimizer:
    """Factored second moment for matrices (>=2D); full for vectors."""

    def init(params: Params) -> OptState:
        def factored(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(factored, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, step=None):
        step = state["step"] if step is None else step
        count = step + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = schedule(cfg, count.astype(jnp.float32))
        decay = 1.0 - count.astype(jnp.float32) ** -0.8

        def upd(p, g, v):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + 1e-30
            if p.ndim >= 2:
                vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                r = vr / jnp.mean(vr, axis=-1, keepdims=True)
                pre = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                           + cfg.eps)
                new_v = {"vr": vr, "vc": vc}
            else:
                nv = decay * v["v"] + (1 - decay) * g2
                pre = g / (jnp.sqrt(nv) + cfg.eps)
                new_v = {"v": nv}
            upd_ = pre + cfg.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd_).astype(p.dtype)
            return new_p, new_v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        news = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_p = tdef.unflatten([n[0] for n in news])
        new_v = tdef.unflatten([n[1] for n in news])
        return new_p, {"v": new_v, "step": count}, {"lr": lr, "grad_norm": gnorm}

    return Optimizer(init, update, cfg)


def sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, step=None):
        step = state["step"] if step is None else step
        count = step + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = schedule(cfg, count.astype(jnp.float32))

        def upd(p, g, m):
            m32 = cfg.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m32).astype(p.dtype), m32
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["mom"])
        news = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        return (tdef.unflatten([n[0] for n in news]),
                {"mom": tdef.unflatten([n[1] for n in news]), "step": count},
                {"lr": lr, "grad_norm": gnorm})

    return Optimizer(init, update, cfg)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}[cfg.name](cfg)
