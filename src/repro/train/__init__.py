from repro.train import checkpoint, data, optimizer, step
from repro.train.trainer import TrainConfig, Trainer

__all__ = ["checkpoint", "data", "optimizer", "step", "TrainConfig", "Trainer"]
