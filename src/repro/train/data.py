"""Deterministic, shardable synthetic LM data pipeline.

Production-shaped: per-host shard assignment, exact resume (skip-free: data
is a pure function of (seed, shard, step)), background prefetch, and a
CASH hook — shard *reassignment* is driven by the credit-aware scheduler
(see repro.sched.train_scheduler), modeling hosts whose input pipelines run
on burstable CPU.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1            # data-parallel hosts
    markov_order: int = 2          # synthetic structure (learnable signal)


def _shard_rng(cfg: DataConfig, shard: int, step: int) -> np.random.Generator:
    # stable, collision-free stream per (seed, shard, step)
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, shard, step]))


def synth_batch(cfg: DataConfig, shard: int, step: int) -> Dict[str, np.ndarray]:
    """One shard's sub-batch for ``step``: structured token stream (a noisy
    periodic source) so small models show a real learning curve."""
    rng = _shard_rng(cfg, shard, step)
    rows = cfg.global_batch // cfg.num_shards
    v = cfg.vocab_size
    base = rng.integers(0, v, size=(rows, 1), dtype=np.int64)
    pos = np.arange(cfg.seq_len + 1, dtype=np.int64)[None, :]
    period = 3 + (base % 11)
    tok = (base + pos * period) % v
    noise = rng.random((rows, cfg.seq_len + 1)) < 0.05
    tok = np.where(noise, rng.integers(0, v, size=tok.shape), tok)
    return {"tokens": tok[:, :-1].astype(np.int32),
            "labels": tok[:, 1:].astype(np.int32)}


class ShardedLoader:
    """Iterator over this host's batches with prefetch + exact resume.

    ``shard_ids`` may hold several logical shards (credit-aware rebalancing
    moves logical shards between hosts; each host concatenates the rows of
    the shards it currently owns)."""

    def __init__(self, cfg: DataConfig, shard_ids: Sequence[int],
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.shard_ids: List[int] = list(shard_ids)
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _build(self, step: int) -> Dict[str, np.ndarray]:
        parts = [synth_batch(self.cfg, s, step) for s in self.shard_ids]
        return {k: np.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._build(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self) -> None:
        self._stop.set()


def global_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The full global batch (all shards) — single-host training / tests."""
    parts = [synth_batch(cfg, s, step) for s in range(cfg.num_shards)]
    return {k: np.concatenate([p[k] for p in parts], axis=0) for k in parts[0]}
