"""Pure-Python replay oracle for the ring-buffer traffic engine.

`TrafficOracle` interprets ONE (unstacked) traffic scenario under the
same `VecSimConfig` the vectorized engine compiles, mirroring
`vecsim._simulate_traffic` tick-for-tick with plain Python loops over
numpy float64 state:

  * the arrival stream is the IDENTICAL stream — it calls
    `arrivals.arrival_counts` eagerly, so the per-scenario
    ``fold_in(fold_in(PRNGKey(seed), TAG), rng_seed)`` Poisson draws (or
    the trace searchsorted) match integer-for-integer;
  * token-bucket serve mirrors `kernels.ref.bucket_serve_ref`
    branch-for-branch (which itself mirrors `TokenBucket.serve`);
  * telemetry mirrors the engine's `_telemetry_estimate` /
    `_telemetry_observe` array formulas (Algorithm 2);
  * placement packs each phase's FIFO-by-arrival-seq queue over nodes in
    descending-credit order (CASH phase 1) / nid order (plain phase and
    stock), exactly the engine's rank->table formulation.

Latency / queue-wait values are exact float64 products of tick index and
``dt`` on both sides, and both sides bucket with the same comparison
(`slo.bucket_index`), so under ``jax_enable_x64`` the oracle's
histograms — and every percentile derived from them — must equal the
engine's EXACTLY; tests assert that, not a tolerance.

Scope mirrors the engine's traffic support: ``resource="cpu"``,
``scheduler in ("cash", "stock")``, ``shuffle="none"``.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.vecsim import (
    CLS_BURST_CPU,
    CLS_BURST_DISK,
    CLS_NONE,
    CLS_PAD,
    VecSimConfig,
    _NEVER,
)
from repro.traffic import arrivals, slo


def _serve_bucket(balance, demand, baseline, burst, capacity, unlimited, dt):
    """Scalar mirror of `kernels.ref.bucket_serve_ref`. Returns
    (work, new_balance, surplus_add)."""
    rate = min(demand, burst)
    drain = rate - baseline
    if drain > 0.0:                                   # bursting
        t_burst = dt if unlimited else min(dt, balance / drain)
        spent = drain * t_burst
        over = max(0.0, spent - balance) if unlimited else 0.0
        work = rate * t_burst + min(demand, baseline) * (dt - t_burst)
        return work, max(0.0, balance - spent), over
    return rate * dt, min(capacity, balance - drain * dt), 0.0


class TrafficOracle:
    """Interpret one traffic scenario; `run()` returns the engine's
    scalar/histogram output keys as plain numpy values.

    Capacity caveat: with ``table_slots == 0`` the engine sizes the ring
    as ``2 * N * smax`` of the PADDED batch, which the oracle (seeing one
    unstacked scenario) cannot reconstruct for a ragged group — parity
    tests pin ``table_slots`` explicitly or use uniform fleets."""

    def __init__(self, sc: Dict[str, np.ndarray], cfg: VecSimConfig):
        if cfg.traffic not in arrivals.TRAFFIC_MODES:
            raise ValueError(f"not a traffic config: {cfg.traffic!r}")
        if cfg.shuffle != "none":
            raise NotImplementedError("oracle mirrors shuffle='none' only")
        if cfg.resource != "cpu" or cfg.scheduler not in ("cash", "stock"):
            raise NotImplementedError("traffic scope is cpu + cash|stock")
        self.sc = {k: np.asarray(v) for k, v in sc.items()}
        self.cfg = cfg
        self.N = len(self.sc["slots"])
        smax = int(self.sc["slots"].max()) if self.N else 1
        self.C = (cfg.table_slots if cfg.table_slots > 0
                  else 2 * self.N * max(smax, 1))
        self.edges = slo.edges_for(cfg)
        self.counts = np.asarray(arrivals.arrival_counts(cfg, self.sc,
                                                         np.float64))

    # ------------------------------------------------------------------ tick
    def run(self) -> Dict[str, np.ndarray]:
        cfg, sc, N, C = self.cfg, self.sc, self.N, self.C
        dt = cfg.dt
        B = cfg.slo_bins
        need_credits = cfg.scheduler != "stock"

        tb_rem = np.zeros(C)
        tb_dem = np.zeros(C)
        tb_cls = np.full(C, CLS_PAD, np.int64)
        tb_seq = np.full(C, np.iinfo(np.int32).max, np.int64)
        tb_submit = np.zeros(C)
        tb_start = np.full(C, np.inf)
        tb_node = np.full(C, -1, np.int64)

        run_cnt = np.zeros(N, np.int64)
        rel_cnt = np.zeros(N, np.int64)
        bal = sc["cpu_balance0"].astype(np.float64).copy()
        sur = np.zeros(N)
        baseline = sc["cpu_baseline"].astype(np.float64)
        burst = sc["cpu_burst"].astype(np.float64)
        capacity = sc["cpu_capacity"].astype(np.float64)
        unlimited = sc["cpu_unlimited"].astype(np.float64) > 0.0
        slots = sc["slots"].astype(np.int64)

        tel = {"act_bal": np.zeros(N), "act_t": np.full(N, _NEVER),
               "use_rate": np.zeros(N), "use_t": np.full(N, _NEVER),
               "accum": np.zeros(N), "win_start": np.zeros(N)}

        n_seen = n_adm = n_done = 0
        lat_hist = np.zeros(B, np.int64)
        wait_hist = np.zeros(B, np.int64)
        lat_sum = wait_sum = 0.0
        lat_max = wait_max = 0.0
        last_rel = -np.inf
        work_done = work_served = busy_seconds = 0.0

        tmpl_n = max(int(sc["tmpl_n"]), 1)
        replay = cfg.traffic == "replay"

        for t in range(cfg.n_ticks):
            now = float(t) * dt

            # 1) release finished jobs, bucket SLOs, recycle slots
            fin_now = np.flatnonzero((tb_cls != CLS_PAD) & (tb_node >= 0)
                                     & (tb_rem <= 1e-9))
            for i in fin_now:
                lat = now - tb_submit[i]
                wait = tb_start[i] - tb_submit[i]
                lat_hist[slo.bucket_index(lat, self.edges)] += 1
                wait_hist[slo.bucket_index(wait, self.edges)] += 1
                lat_sum += lat
                wait_sum += wait
                lat_max = max(lat_max, lat)
                wait_max = max(wait_max, wait)
                tb_cls[i] = CLS_PAD
                tb_node[i] = -1
                tb_seq[i] = np.iinfo(np.int32).max
            if len(fin_now):
                n_done += len(fin_now)
                last_rel = now
            run_cnt -= rel_cnt
            rel_cnt = np.zeros(N, np.int64)

            # 2) arrivals into free slots, lowest index first, FIFO order
            k = int(self.counts[t])
            free_slots = np.flatnonzero(tb_cls == CLS_PAD)
            admitted = free_slots[:k]
            for r, i in enumerate(admitted):
                aidx = n_seen + r
                if replay:
                    row = int(sc["arr_tmpl"][aidx])
                    tb_submit[i] = float(sc["arr_t"][aidx])
                else:
                    row = aidx % tmpl_n
                    tb_submit[i] = now
                tb_rem[i] = float(sc["tmpl_work"][row])
                tb_dem[i] = float(sc["tmpl_dem"][row])
                tb_cls[i] = int(sc["tmpl_cls"][row])
                tb_seq[i] = aidx
                tb_start[i] = np.inf
            n_seen += k
            n_adm += len(admitted)

            # 3) telemetry estimate (pre-observe, Algorithm 2)
            est = None
            if need_credits:
                if cfg.telemetry == "oracle":
                    est = bal.copy()
                else:
                    has = tel["act_t"] > _NEVER / 2
                    if cfg.telemetry == "stale":
                        est = np.where(has, tel["act_bal"], capacity)
                    else:
                        use_ok = tel["use_t"] >= tel["act_t"]
                        dt_act = now - np.where(has, tel["act_t"], now)
                        e = tel["act_bal"] + np.where(
                            use_ok, (baseline - tel["use_rate"]) * dt_act,
                            0.0)
                        est = np.where(has, np.clip(e, 0.0, capacity),
                                       capacity)

            # 4) placement: FIFO by arrival seq within each phase
            free = slots - run_cnt

            def fifo(mask: np.ndarray) -> List[int]:
                q = np.flatnonzero(mask)
                return list(q[np.argsort(tb_seq[q], kind="stable")])

            def pack(order, queue):
                for n in order:
                    while free[n] > 0 and queue:
                        i = queue.pop(0)
                        tb_node[i] = n
                        tb_start[i] = now
                        free[n] -= 1
                        run_cnt[n] += 1

            ready = (tb_cls != CLS_PAD) & (tb_node < 0)
            if cfg.scheduler == "stock":
                pack(range(N), fifo(ready))
            else:
                desc = sorted(range(N), key=lambda n: (-est[n], n))
                pack(desc, fifo(ready & ((tb_cls == CLS_BURST_CPU)
                                         | (tb_cls == CLS_BURST_DISK))))
                pack(range(N), fifo(ready & (tb_cls == CLS_NONE)))

            # 5) serve + pro-rata distribute (mirrors bucket_serve_ref)
            running = tb_node >= 0
            live = running & (tb_rem > 0.0)
            dem_node = np.zeros(N)
            for i in np.flatnonzero(live):
                dem_node[tb_node[i]] += tb_dem[i]
            w_node = np.zeros(N)
            for n in range(N):
                w, bal[n], over = _serve_bucket(
                    bal[n], dem_node[n], baseline[n], burst[n],
                    capacity[n], unlimited[n], dt)
                w_node[n] = w
                sur[n] += over
                work_served += w
            for i in np.flatnonzero(live):
                n = tb_node[i]
                share = (w_node[n] * tb_dem[i] / dem_node[n]
                         if dem_node[n] > 0.0 else 0.0)
                inc = min(share, tb_rem[i])
                tb_rem[i] -= inc
                work_done += inc
                if tb_rem[i] <= 1e-9:
                    rel_cnt[n] += 1
            busy_seconds += float(np.sum(run_cnt > 0)) * dt

            # 6) CloudWatch observe (post-serve balance, like the engine)
            if need_credits and cfg.telemetry != "oracle":
                tel["accum"] = tel["accum"] + w_node / dt
                pub_a = now - tel["act_t"] >= cfg.actual_period
                pub_u = now - tel["use_t"] >= cfg.usage_period
                span = np.maximum(now - tel["win_start"], 1e-9)
                avg = tel["accum"] / np.maximum(1.0, span)
                tel["act_bal"] = np.where(pub_a, bal, tel["act_bal"])
                tel["act_t"] = np.where(pub_a, now, tel["act_t"])
                tel["use_rate"] = np.where(pub_u, avg, tel["use_rate"])
                tel["use_t"] = np.where(pub_u, now, tel["use_t"])
                tel["accum"] = np.where(pub_u, 0.0, tel["accum"])
                tel["win_start"] = np.where(pub_u, now, tel["win_start"])

        drained = n_done == n_adm
        if replay:
            all_done = drained and n_seen >= int(
                np.sum(np.isfinite(sc["arr_t"])))
        else:
            all_done = drained
        makespan = ((last_rel if n_done > 0 else 0.0) if all_done
                    else cfg.n_ticks * dt)
        out = {
            "makespan": makespan, "all_done": all_done,
            "surplus_credits": float(np.sum(sur)),
            "total_cpu_work": work_done, "cpu_work_served": work_served,
            "node_busy_seconds": busy_seconds,
            "n_arrived": n_seen, "n_admitted": n_adm,
            "n_dropped": n_seen - n_adm, "n_completed": n_done,
            "lat_hist": lat_hist, "wait_hist": wait_hist,
            "lat_sum": lat_sum, "wait_sum": wait_sum,
            "lat_max": lat_max, "wait_max": wait_max,
            "last_finish": last_rel,
        }
        for pfx in ("lat", "wait"):
            for q, tag in slo.DEFAULT_QS:
                out[f"{pfx}_{tag}"] = float(slo.hist_percentile(
                    out[f"{pfx}_hist"], self.edges, q))
        return out
