"""Open-loop arrival processes for the batched engine: jit-compatible
count streams plus traffic scenario construction/stacking.

Three processes, selected by the *static* ``VecSimConfig.traffic`` field
(so every scenario in a compile group shares one process):

  * ``poisson`` — homogeneous Poisson with per-scenario rate
    ``arr_rate`` (jobs / simulated second);
  * ``diurnal`` — rate-modulated Poisson,
    ``rate(now) = arr_rate * (1 + arr_amp * sin(2 pi (now + arr_phase)
    / arr_period))`` clipped at zero — the day/night pattern that makes
    T3 credit regeneration bind over multi-day horizons;
  * ``replay`` — a submit-time-sorted trace ``arr_t`` (+ per-arrival
    template row ``arr_tmpl``); an arrival is admitted at the first tick
    whose ``now >= arr_t``.

Count streams are *derived, not carried*: `arrival_counts` produces the
whole ``(n_ticks,)`` per-tick admission count inside the jitted program
(ONE vectorized Poisson draw / searchsorted per scenario, fed to the
scan as xs) rather than one draw per tick in the carry. The stochastic
processes key off ``fold_in(fold_in(PRNGKey(cfg.seed), TAG), rng_seed)``
— the same per-scenario ``rng_seed`` plumbing `build_scenario` uses for
``shuffle="random"``, under a distinct stream tag so arrival and shuffle
streams never alias. A seed or rate sweep therefore batches into ONE
compile, and the Python oracle replays the identical stream by calling
`arrival_counts` eagerly.

Jobs are drawn from a small per-scenario *template table* (work, demand,
class): arrival ``i`` instantiates template row ``i mod tmpl_n``
(stochastic modes) or ``arr_tmpl[i]`` (replay). This is the cluster-trace
simulator shape — a task catalogue replayed against a capacity pattern —
without carrying per-arrival arrays for unbounded streams.
"""
from __future__ import annotations

import pathlib
from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vecsim
from repro.core.vecsim import (
    CLS_BURST_CPU,
    CLS_NET,
    CLS_NONE,
    VecSimConfig,
)

# fold_in tag separating the arrival stream from the shuffle stream that
# shares PRNGKey(cfg.seed) + rng_seed
ARRIVAL_STREAM_TAG = 0x0A51

TRAFFIC_MODES = ("poisson", "diurnal", "replay")

# batched per-scenario arrays that define a group's traffic content —
# hashed into the WorkQueue manifest so a resumed sweep detects a changed
# or regenerated trace/template and names it
TRAFFIC_CONTENT_KEYS = ("tmpl_work", "tmpl_dem", "tmpl_cls", "tmpl_n",
                        "arr_t", "arr_tmpl", "arr_rate", "arr_amp",
                        "arr_period", "arr_phase")


def stream_key(seed: int, rng_seed) -> jax.Array:
    """The per-scenario arrival-stream key: static config seed folded
    with the batched scenario seed (one compile per static config)."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), ARRIVAL_STREAM_TAG)
    return jax.random.fold_in(base, rng_seed)


def diurnal_rate(now, rate, amp, period, phase):
    """Sinusoidal day/night arrival rate, clipped at zero."""
    two_pi = 2.0 * np.pi
    return jnp.maximum(
        rate * (1.0 + amp * jnp.sin(two_pi * (now + phase) / period)), 0.0)


def arrival_counts(cfg: VecSimConfig, sc: Dict[str, jnp.ndarray],
                   dtype) -> jnp.ndarray:
    """``(n_ticks,)`` int32 arrivals admitted at each tick. Traced inside
    the engine (per scenario, under vmap) AND called eagerly by the
    oracle — both sides see the identical stream."""
    now = jnp.arange(cfg.n_ticks, dtype=dtype) * cfg.dt
    if cfg.traffic == "replay":
        total = jnp.searchsorted(sc["arr_t"].astype(dtype), now,
                                 side="right").astype(jnp.int32)
        return jnp.diff(total, prepend=jnp.zeros(1, jnp.int32))
    if cfg.traffic == "poisson":
        lam = jnp.broadcast_to(sc["arr_rate"] * cfg.dt, (cfg.n_ticks,))
    elif cfg.traffic == "diurnal":
        lam = diurnal_rate(now, sc["arr_rate"], sc["arr_amp"],
                           sc["arr_period"], sc["arr_phase"]) * cfg.dt
    else:
        raise ValueError(f"unknown traffic mode {cfg.traffic!r}")
    return jax.random.poisson(stream_key(cfg.seed, sc["rng_seed"]),
                              lam.astype(dtype), dtype=jnp.int32)


# ---------------------------------------------------------------------------
# scenario construction
# ---------------------------------------------------------------------------

def make_template(n_kinds: int = 8, *, seed: int = 0,
                  work=(20.0, 120.0), demand=(0.3, 0.95),
                  burst_fraction: float = 0.7) -> Dict[str, np.ndarray]:
    """A random job-template table: ``n_kinds`` (work, demand, class)
    rows, ``burst_fraction`` of them CPU-burst annotated."""
    if n_kinds < 1:
        raise ValueError("need at least one template row")
    rng = np.random.default_rng(seed)
    cls = np.where(rng.random(n_kinds) < burst_fraction,
                   CLS_BURST_CPU, CLS_NONE).astype(np.int32)
    return {
        "tmpl_work": rng.uniform(*work, n_kinds).astype(np.float64),
        "tmpl_dem": rng.uniform(*demand, n_kinds).astype(np.float64),
        "tmpl_cls": cls,
    }


def load_trace(path: Union[str, pathlib.Path]):
    """Load a submit-time trace: ``.npz`` with ``arr_t`` (+ optional
    ``arr_tmpl``) or a text file of ``time [template_row]`` lines.
    Returns ``(arr_t float64, arr_tmpl int32)``; refuses an unsorted or
    non-finite trace by name."""
    p = pathlib.Path(path)
    if p.suffix == ".npz":
        with np.load(p) as z:
            t = np.asarray(z["arr_t"], np.float64)
            k = (np.asarray(z["arr_tmpl"], np.int32)
                 if "arr_tmpl" in z.files else np.zeros(len(t), np.int32))
    else:
        data = np.loadtxt(p, ndmin=2, dtype=np.float64)
        t = data[:, 0]
        k = (data[:, 1].astype(np.int32) if data.shape[1] > 1
             else np.zeros(len(t), np.int32))
    if not np.all(np.isfinite(t)):
        raise ValueError(f"trace {p} has non-finite submit times")
    if np.any(np.diff(t) < 0):
        raise ValueError(f"trace {p} is not submit-time sorted")
    return t, k


def build_traffic_scenario(nodes: Sequence, template: Dict[str, np.ndarray],
                           *, mode: str = "poisson", rate: float = 1.0,
                           amp: float = 0.0, period: float = 86400.0,
                           phase: float = 0.0,
                           trace_t: Optional[np.ndarray] = None,
                           trace_tmpl: Optional[np.ndarray] = None,
                           rng_seed: int = 0) -> Dict[str, np.ndarray]:
    """Freeze one open-loop scenario: a cluster + a job-template table +
    an arrival process. The node arrays match `vecsim.build_scenario`'s;
    ``mode`` must agree with the static ``VecSimConfig.traffic`` the
    scenario runs under."""
    if mode not in TRAFFIC_MODES:
        raise ValueError(f"mode must be one of {TRAFFIC_MODES}, got {mode!r}")
    k = len(template["tmpl_work"])
    if not (len(template["tmpl_dem"]) == len(template["tmpl_cls"]) == k):
        raise ValueError("template columns disagree on row count")
    if np.any(np.asarray(template["tmpl_cls"]) == CLS_NET):
        raise ValueError("network-annotated templates are not supported "
                         "under open-loop traffic (cpu pool only)")

    f = np.float64
    sc: Dict[str, np.ndarray] = dict(vecsim.node_arrays(nodes))
    sc["tmpl_work"] = np.asarray(template["tmpl_work"], f)
    sc["tmpl_dem"] = np.minimum(np.asarray(template["tmpl_dem"], f), 1.0)
    sc["tmpl_cls"] = np.asarray(template["tmpl_cls"], np.int32)
    sc["tmpl_n"] = np.int32(k)
    sc["arr_rate"] = f(rate)
    sc["arr_amp"] = f(amp)
    sc["arr_period"] = f(period)
    sc["arr_phase"] = f(phase)
    sc["rng_seed"] = np.int32(rng_seed)
    if mode == "replay":
        if trace_t is None:
            raise ValueError("replay mode needs trace_t")
        t = np.asarray(trace_t, f)
        if np.any(np.diff(t) < 0):
            raise ValueError("trace_t must be submit-time sorted")
        tk = (np.zeros(len(t), np.int32) if trace_tmpl is None
              else np.asarray(trace_tmpl, np.int32))
        if len(tk) != len(t):
            raise ValueError("trace_t / trace_tmpl length mismatch")
        if len(tk) and (tk.min() < 0 or tk.max() >= k):
            raise ValueError("trace_tmpl rows out of template range")
        sc["arr_t"] = t
        sc["arr_tmpl"] = tk
    return sc


# ---------------------------------------------------------------------------
# serving-fleet scenarios (core.servesim)
# ---------------------------------------------------------------------------

# per-scenario arrays a serving-fleet scenario carries (replica-side token
# buckets + request-kind templates + the arrival process above)
SERVE_SCENARIO_KEYS = ("rep_balance0", "rep_baseline", "rep_burst",
                       "rep_capacity", "rep_unlimited", "tmpl_pre",
                       "tmpl_dec", "tmpl_dpre", "tmpl_ddec", "tmpl_n",
                       "arr_rate", "arr_amp", "arr_period", "arr_phase",
                       "rng_seed")


def make_serve_template(n_kinds: int = 4, *, seed: int = 0,
                        prompt=(64.0, 768.0), decode=(32.0, 256.0),
                        prefill_rate=(800.0, 2400.0),
                        decode_rate=(40.0, 160.0)) -> Dict[str, np.ndarray]:
    """A random request-kind table for the serving fleet: ``n_kinds``
    rows of (prompt tokens, decode tokens, prefill token-demand rate,
    decode token-demand rate). Prefill is compute-dense and bursty
    (demand far above a replica's sustained rate); decode is a steady
    trickle — the map/reduce annotation split of
    `sched.serve_scheduler`, in token units."""
    if n_kinds < 1:
        raise ValueError("need at least one template row")
    rng = np.random.default_rng(seed)
    f = np.float64
    return {
        "tmpl_pre": rng.uniform(*prompt, n_kinds).astype(f),
        "tmpl_dec": rng.uniform(*decode, n_kinds).astype(f),
        "tmpl_dpre": rng.uniform(*prefill_rate, n_kinds).astype(f),
        "tmpl_ddec": rng.uniform(*decode_rate, n_kinds).astype(f),
    }


def _snap_rates(a) -> np.ndarray:
    """Snap demand rates to the 2^-10 dyadic grid. Per-replica demand is
    a SUM of these across resident requests, and the engine
    (``dot_general``) and the replay oracle (a python loop) reduce in
    different orders — off the grid, a single ulp of summation-order
    drift leaks into the token-bucket balance, flips the credit-richest
    admission sort at near-tie balances, and forks the whole decision
    trace. On the grid every term is an integer multiple of 2^-10, so
    any sum of fewer than ~2^30 requests is EXACT in float64 whatever
    the reduction order."""
    return np.round(np.asarray(a, np.float64) * 1024.0) / 1024.0


def build_serve_scenario(template: Dict[str, np.ndarray], *,
                         n_replicas: int, balance0=600.0, baseline=200.0,
                         burst=2000.0, capacity=600.0,
                         unlimited: bool = False, rate: float = 1.0,
                         amp: float = 0.0, period: float = 86400.0,
                         phase: float = 0.0,
                         rng_seed: int = 0) -> Dict[str, np.ndarray]:
    """Freeze one serving-fleet scenario: a replica fleet (each replica a
    token bucket in token/s units) + a request-kind template table + an
    arrival process. Bucket fields broadcast from scalars or ride
    per-replica arrays; ``mode`` is the static ``ServeSimConfig.traffic``
    (stochastic only — trace replay is a vecsim-path feature)."""
    if n_replicas < 1:
        raise ValueError("need at least one replica")
    k = len(template["tmpl_pre"])
    if not (len(template["tmpl_dec"]) == len(template["tmpl_dpre"])
            == len(template["tmpl_ddec"]) == k):
        raise ValueError("serve template columns disagree on row count")

    f = np.float64

    def rep(v):
        return np.broadcast_to(np.asarray(v, f), (n_replicas,)).copy()

    sc: Dict[str, np.ndarray] = {
        "rep_balance0": rep(balance0),
        "rep_baseline": rep(baseline),
        "rep_burst": rep(burst),
        "rep_capacity": rep(capacity),
        "rep_unlimited": np.broadcast_to(
            np.asarray(unlimited, bool), (n_replicas,)).copy(),
        "tmpl_pre": np.asarray(template["tmpl_pre"], f),
        "tmpl_dec": np.asarray(template["tmpl_dec"], f),
        "tmpl_dpre": _snap_rates(template["tmpl_dpre"]),
        "tmpl_ddec": _snap_rates(template["tmpl_ddec"]),
        "tmpl_n": np.int32(k),
        "arr_rate": f(rate),
        "arr_amp": f(amp),
        "arr_period": f(period),
        "arr_phase": f(phase),
        "rng_seed": np.int32(rng_seed),
    }
    if np.any(sc["tmpl_pre"] < 0) or np.any(sc["tmpl_dec"] < 0):
        raise ValueError("template token counts must be >= 0")
    return sc


def stack_serve_scenarios(
        scenarios: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    """Stack serving-fleet scenarios on a leading axis. Template tables
    pad to the group's max row count (padded rows are never instantiated
    — ``i mod tmpl_n`` indexes real rows only); the replica count must be
    UNIFORM across the group — round-robin admission rotates over the
    replica axis, so a padded fleet would change the rotation sequence.
    Vary balances/rates across a group instead of fleet width."""
    widths = {len(s["rep_balance0"]) for s in scenarios}
    if len(widths) != 1:
        raise ValueError(
            "serving-fleet groups need a uniform replica count (round-"
            f"robin rotates over the replica axis); got widths {sorted(widths)}")
    K = max(len(s["tmpl_pre"]) for s in scenarios)

    out: Dict[str, list] = {}
    for s in scenarios:
        k_pad = K - len(s["tmpl_pre"])

        def pad(a, width, fill=0.0):
            a = np.asarray(a)
            if not width:
                return a
            return np.concatenate([a, np.full(width, fill, a.dtype)])

        row = dict(s)
        for key in ("tmpl_pre", "tmpl_dec", "tmpl_dpre", "tmpl_ddec"):
            row[key] = pad(s[key], k_pad)
        for key, v in row.items():
            out.setdefault(key, []).append(np.asarray(v))
    return {k: np.stack(v) for k, v in out.items()}


def stack_traffic_scenarios(
        scenarios: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    """Pad every traffic scenario to the group's max (nodes, template
    rows, trace length) and stack on a leading axis. Padded template rows
    are never instantiated (``i mod tmpl_n`` indexes the real rows only);
    padded trace entries sit at ``+inf`` so no horizon reaches them."""
    keys = set(scenarios[0])
    for s in scenarios[1:]:
        if set(s) != keys:
            raise ValueError("traffic scenarios in one group must share "
                             "one key set (mixed replay/stochastic?)")
    has_trace = "arr_t" in keys
    N = max(len(s["slots"]) for s in scenarios)
    K = max(len(s["tmpl_work"]) for s in scenarios)
    M = max(len(s["arr_t"]) for s in scenarios) if has_trace else 0

    node_keys = [k for k in vecsim.NODE_ARRAY_KEYS if k != "node_pad"]
    out: Dict[str, list] = {}
    for s in scenarios:
        n_pad = N - len(s["slots"])
        k_pad = K - len(s["tmpl_work"])

        def pad(a, width, fill=0.0):
            a = np.asarray(a)
            if not width:
                return a
            return np.concatenate([a, np.full(width, fill, a.dtype)])

        row = {k: pad(s[k], n_pad) for k in node_keys}
        row["node_pad"] = pad(s["node_pad"], n_pad, True)
        row["tmpl_work"] = pad(s["tmpl_work"], k_pad)
        row["tmpl_dem"] = pad(s["tmpl_dem"], k_pad)
        row["tmpl_cls"] = pad(s["tmpl_cls"], k_pad, vecsim.CLS_PAD)
        for k in ("tmpl_n", "rng_seed", "arr_rate", "arr_amp",
                  "arr_period", "arr_phase"):
            row[k] = s[k]
        # fault-process scalars (repro.faults.attach_fault_process) ride
        # through per-scenario; the key-set check above already enforces
        # uniform presence across the group
        for k in s:
            if k.startswith("fl_"):
                row[k] = s[k]
        if has_trace:
            m_pad = M - len(s["arr_t"])
            row["arr_t"] = pad(s["arr_t"], m_pad, np.inf)
            row["arr_tmpl"] = pad(s["arr_tmpl"], m_pad, 0)
        for k, v in row.items():
            out.setdefault(k, []).append(np.asarray(v))
    batch = {k: np.stack(v) for k, v in out.items()}
    batch["_meta"] = np.array([N, K, M])
    return batch
