"""SLO metric contract for open-loop traffic: per-job latency and
queue-wait tails as fixed-bin histograms.

A multi-day open-loop run completes far more jobs than any bounded carry
can hold timestamps for, so the engine never materializes per-job
latency arrays. Instead each completion is bucketed on-device into a
histogram with *static* bin edges (HdrHistogram / Prometheus style):
``edges[0] = 0`` and ``edges[1:]`` log-spaced from one tick (``dt``, the
smallest observable latency) to the horizon. Percentiles are then
nearest-rank reductions over the histogram, computed host-side — and a
percentile's value is its bin's UPPER edge, a conservative (pessimistic)
SLO estimate.

Parity contract: the engine and the Python oracle (`repro.traffic.
oracle`) bucket with the SAME comparison (``count of edges[1:] <= x``,
clipped to the last bin) on float64 latencies that are exact products of
tick index and dt, so their histograms — and therefore every percentile —
match exactly, not approximately.

This module is deliberately numpy-only (no jax, no repro imports): the
engine inlines the bucketing comparison against `edges_for`'s constant,
the oracle calls `bucket_index`, and both feed `hist_percentile`.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import numpy as np

# the percentiles surfaced as sweep scalars
DEFAULT_QS: Tuple[Tuple[float, str], ...] = (
    (0.50, "p50"), (0.95, "p95"), (0.99, "p99"))


def bin_edges(n_bins: int, max_s: float, min_s: float) -> np.ndarray:
    """``(n_bins + 1,)`` float64 edges: ``[0, geomspace(min_s, max_s)]``.
    Bin ``b`` covers ``[edges[b], edges[b+1])``; the last bin also absorbs
    every overflow ``>= max_s``."""
    if n_bins < 2:
        raise ValueError(f"need at least 2 histogram bins, got {n_bins}")
    if not (0.0 < min_s < max_s):
        raise ValueError(f"need 0 < min_s < max_s, got {min_s}, {max_s}")
    return np.concatenate([[0.0],
                           np.geomspace(min_s, max_s, n_bins)]).astype(
                               np.float64)


def edges_for(cfg: Any) -> np.ndarray:
    """The histogram edges a `VecSimConfig` implies (duck-typed — reads
    ``slo_bins``, ``slo_max_s``, ``n_ticks``, ``dt``). ``slo_max_s == 0``
    defaults the upper edge to the simulated horizon."""
    max_s = cfg.slo_max_s if cfg.slo_max_s > 0.0 else cfg.n_ticks * cfg.dt
    return bin_edges(cfg.slo_bins, max_s, cfg.dt)


def bucket_index(x: float, edges: np.ndarray) -> int:
    """The bin a value lands in — the oracle-side mirror of the engine's
    in-scan comparison sum."""
    n_bins = len(edges) - 1
    return min(int(np.sum(x >= edges[1:])), n_bins - 1)


def hist_percentile(hist: np.ndarray, edges: np.ndarray,
                    q: float) -> np.ndarray:
    """Nearest-rank percentile over histogram(s): the upper edge of the
    first bin whose cumulative count reaches ``q * total``, vectorized
    over any leading axes of ``hist``. Empty histograms yield NaN."""
    h = np.asarray(hist, np.float64)
    total = h.sum(axis=-1)
    c = np.cumsum(h, axis=-1)
    idx = np.argmax(c >= q * total[..., None], axis=-1)
    val = np.asarray(edges)[idx + 1]
    return np.where(total > 0, val, np.nan)


def attach_percentiles(res: Dict[str, Any], cfg: Any,
                       qs: Sequence[Tuple[float, str]] = DEFAULT_QS) -> None:
    """Reduce a finalized traffic output dict's ``lat_hist`` /
    ``wait_hist`` (leading scenario axis) to percentile + mean scalars,
    in place, and attach the shared ``slo_edges`` axis (group-level: one
    copy per compile group, like ``timeline_t``)."""
    edges = edges_for(cfg)
    n = np.maximum(np.asarray(res["n_completed"], np.float64), 1.0)
    for pfx in ("lat", "wait"):
        h = res[f"{pfx}_hist"]
        for q, tag in qs:
            res[f"{pfx}_{tag}"] = hist_percentile(h, edges, q)
        res[f"{pfx}_mean"] = np.asarray(res[f"{pfx}_sum"],
                                        np.float64) / n
    res["slo_edges"] = edges
