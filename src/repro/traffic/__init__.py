"""Open-loop traffic for the vectorized engine: arrival processes
(`repro.traffic.arrivals`), SLO histogram metrics (`repro.traffic.slo`)
and the pure-Python ring-buffer replay oracle (`repro.traffic.oracle`).

The engine side lives in `repro.core.vecsim` (`VecSimConfig.traffic`,
the ring-buffer task table in `_simulate_traffic`); this package holds
everything that is not the scan itself: scenario construction, trace
loading, the latency/queue-wait histogram contract, and the oracle the
engine is parity-tested against.
"""
from repro.traffic.arrivals import (  # noqa: F401
    arrival_counts,
    build_traffic_scenario,
    load_trace,
    make_template,
    stack_traffic_scenarios,
)
from repro.traffic.oracle import TrafficOracle  # noqa: F401
from repro.traffic.slo import (  # noqa: F401
    attach_percentiles,
    edges_for,
    hist_percentile,
)
