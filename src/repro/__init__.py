"""repro: CASH (credit-aware scheduling) as a production JAX framework.

Paper core (token buckets, Algorithm 1+2, simulator) in repro.core;
the CASH runtime layer for JAX training/serving in repro.sched;
models/kernels/distribution/training/serving substrates alongside.
"""
__version__ = "1.0.0"
