"""Mesh-native scenario-axis execution: a named ``scenario`` axis over the
local devices, with the batched tick engine dispatched through `shard_map`.

This is the device layer under `sweep.runner`: the runner stacks, chunks
and checkpoints; this module owns *where the arrays live*. A group's
stacked batch ``[B, ...]`` pads the scenario axis to a multiple of the
shard count (repeating scenario 0 — scenarios are independent under
``vmap``, so padding never perturbs real rows) and runs ONE jitted
`shard_map` of `vecsim.batched_engine` over a 1-D `jax.sharding.Mesh`
whose only axis is ``scenario``: each device scans its ``B/D`` block while
the others run theirs, and the timeline's sample-tick gather happens
*inside* the sharded program, so sampled sweeps stay device-resident end
to end. Because the sharded path wraps the SAME `batched_engine` callable
the single-device jit path runs, per-scenario results are bitwise
identical between the two (asserted by `tests/test_sweep.py` and the
``sweep/smoke`` benchmark under forced host-platform device counts).

The module also hosts the production mesh constructors (absorbed from the
seed's ``launch/mesh.py``): the serving dry-run builds its ``(data,
model)`` / ``(pod, data, model)`` meshes from here too, keeping every mesh
shape the repo uses in one place.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core import vecsim

SCENARIO_AXIS = "scenario"


def device_count() -> int:
    """Local devices available for scenario-axis sharding (force >1 on CPU
    hosts with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    return len(jax.local_devices())


@functools.lru_cache(maxsize=None)
def scenario_mesh(n_shards: Optional[int] = None) -> Mesh:
    """A 1-D mesh named ``scenario`` over the first ``n_shards`` local
    devices (all of them by default)."""
    devs = jax.local_devices()
    n = len(devs) if n_shards is None else n_shards
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_shards={n} outside [1, {len(devs)}] "
                         "local devices")
    return Mesh(np.asarray(devs[:n]), (SCENARIO_AXIS,))


def mesh_topology() -> Dict[str, Any]:
    """What the sweep ran on — recorded next to throughput numbers so
    sharded results stay comparable across machines."""
    return {
        "devices": device_count(),
        "platform": jax.default_backend(),
        "jax": jax.__version__,
        "axis": SCENARIO_AXIS,
    }


@functools.lru_cache(maxsize=None)
def _sharded_engine(cfg: vecsim.VecSimConfig, smax: int, n_waves: int,
                    n_jobs: int, active: Tuple[bool, ...], n_shards: int,
                    donate: bool):
    """jit(shard_map(batched_engine)) over the scenario mesh — one compile
    per (static config, shard count)."""
    engine = vecsim.batched_engine(cfg, smax, n_waves, n_jobs, active)
    spec = PartitionSpec(SCENARIO_AXIS)
    # check_rep=False: the replication checker has no rule for the
    # `while` loop inside jax.random.poisson (open-loop traffic's arrival
    # sampler). Every input and output is fully partitioned along the
    # scenario axis — nothing is replicated — and vmap-vs-sharded bitwise
    # parity is asserted by tests/test_sweep.py and the sweep/smoke
    # benchmark, so the check buys nothing here.
    fn = shard_map(engine, mesh=scenario_mesh(n_shards),
                   in_specs=spec, out_specs=spec, check_rep=False)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def pad_rows(arrays: Dict[str, np.ndarray],
             target: int) -> Dict[str, np.ndarray]:
    """Pad the leading scenario axis to exactly ``target`` rows by
    repeating row 0 — scenarios are independent under ``vmap``, so padding
    never perturbs real rows. The ONE home of that invariant: shard
    padding and the runner's ragged-tail chunk padding both call this."""
    b = int(next(iter(arrays.values())).shape[0])
    pad = target - b
    if pad <= 0:
        return arrays

    def grow(v: np.ndarray) -> np.ndarray:
        v = np.asarray(v)
        return np.concatenate([v, np.repeat(v[:1], pad, axis=0)])

    return {k: grow(v) for k, v in arrays.items()}


def pad_scenario_axis(arrays: Dict[str, np.ndarray],
                      n_shards: int) -> Tuple[Dict[str, np.ndarray], int]:
    """Pad the scenario axis to a multiple of ``n_shards``. Returns
    ``(padded arrays, real B)``."""
    b = int(next(iter(arrays.values())).shape[0])
    return pad_rows(arrays, b + (-b) % n_shards), b


def dispatch_sharded(arrays: Dict[str, np.ndarray],
                     cfg: vecsim.VecSimConfig, statics, n_shards: int, *,
                     donate: bool = False) -> Tuple[Any, int]:
    """Launch one stacked batch over ``n_shards`` devices WITHOUT waiting:
    jax dispatch is async, so this returns ``(device output tree, real B)``
    as soon as the computation is enqueued. The pipelined runner dispatches
    chunk i+1 while chunk i's outputs are still materializing; call
    `finalize_sharded` (which blocks on device->host transfer) to get
    numpy. ``dispatch + finalize`` is exactly the old synchronous path —
    same compiled program, bitwise-identical results."""
    smax, n_waves, n_jobs, active = statics
    padded, n_real = pad_scenario_axis(
        {k: np.asarray(v) for k, v in arrays.items()}, n_shards)
    fn = _sharded_engine(cfg, smax, n_waves, n_jobs, active, n_shards,
                         donate)
    return fn(padded), n_real


def finalize_sharded(out: Any, n_real: int) -> Dict[str, Any]:
    """Block on a `dispatch_sharded` output tree: device->host transfer,
    padding rows dropped."""
    return jax.tree_util.tree_map(lambda v: np.asarray(v)[:n_real], out)


def run_sharded(arrays: Dict[str, np.ndarray], cfg: vecsim.VecSimConfig,
                statics, n_shards: int, *,
                donate: bool = False) -> Dict[str, Any]:
    """Dispatch one stacked batch over ``n_shards`` devices. Returns raw
    engine outputs (numpy, padding rows dropped) — the caller finalizes."""
    return finalize_sharded(*dispatch_sharded(arrays, cfg, statics,
                                              n_shards, donate=donate))


# ---------------------------------------------------------------------------
# production mesh shapes (absorbed from the seed's launch/mesh.py)
# ---------------------------------------------------------------------------

def make_production_mesh(*, multi_pod: bool = False):
    """Brief-mandated serving/training mesh shapes."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Mesh over whatever devices exist (CPU smoke / single host)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
