"""Sharded sweep runner: chunked, resumable, multi-host-drainable
execution of compile groups with the *scenario axis* sharded over a named
device mesh.

Layout: a group's stacked batch ``[B, ...]`` dispatches through
`repro.sweep.mesh` — one jitted `shard_map` of the batched tick engine
over a 1-D ``scenario`` mesh: device ``d`` scans its ``B/D`` block while
the others run theirs, timeline sampling included, so sampled sweeps stay
device-resident end to end. ``shards=1`` (or a single-device platform)
falls back to the plain jitted ``vmap`` path — both paths execute the
SAME `vecsim.batched_engine` callable, so per-scenario results are
bitwise-identical (asserted by `tests/test_sweep.py` and the
``sweep/smoke`` benchmark).

Chunking slices the *stacked* group batch, so every chunk shares the
group's padded dims and static flags: one compile per group regardless of
chunk count, and chunked results concatenate (and bit-match) the unchunked
run. Execution is double-buffered by default (`RunnerOptions.pipeline`):
each chunk is dispatched asynchronously and its device->host transfer,
NPZ compression and atomic rename run on a background writer thread while
the next chunk dispatches — the queue drains at device speed instead of
serializing compute -> transfer -> compress -> rename, and results stay
bitwise-identical to the synchronous path (same compiled program). With ``checkpoint_dir`` set the chunk store is a **work queue**:
finished chunks persist as atomically-renamed NPZs and in-flight chunks
are guarded by claim-file leases, so several host processes pointed at the
same directory drain one calibration grid concurrently with zero
double-compute — and any of them resumes cleanly after a crash.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import pathlib
import queue
import threading
import time
import uuid
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import vecsim
from repro.sweep import mesh
from repro.sweep.mesh import device_count
from repro.sweep.results import (
    GROUP_LEVEL_OUTPUTS,
    GroupResult,
    SweepResult,
    flatten_outputs,
    unflatten_outputs,
)
from repro.sweep.spec import CompileGroup, SweepSpec


@dataclasses.dataclass(frozen=True)
class RunnerOptions:
    shards: Optional[int] = None     # None = all local devices; 1 = vmap path
    chunk_size: Optional[int] = None  # scenarios per dispatch (None = group)
    checkpoint_dir: Optional[str] = None  # resumable multi-host work queue
    donate: bool = False             # donate chunk arrays (no-op on CPU)
    lease_s: float = 900.0           # claim lease before takeover
    poll_s: float = 0.1              # wait between passes over peers' chunks
    # double-buffered execution: dispatch chunk i+1 while chunk i's
    # results transfer + its NPZ compresses/renames on a background writer
    # thread (bitwise-identical to the synchronous path — same compiled
    # program, the overlap is host-side only)
    pipeline: bool = True
    # self-healing: a live runner renews its claim mtimes every
    # ``lease_s / 3`` (heartbeat thread), so ``lease_s`` bounds CRASH
    # detection latency instead of worst-case chunk wall time — a slow
    # chunk on a live host is never stolen. False restores the
    # write-once lease clock.
    heartbeat: bool = True
    # chunk compute failures retry with exponential backoff
    # (``backoff_s * 2**attempt``); a chunk failing ``max_attempts``
    # times is QUARANTINED — marked on disk so no peer re-attempts it,
    # its scenario rows NaN-filled — and the rest of the grid drains.
    max_attempts: int = 3
    backoff_s: float = 1.0
    # host-side structured spans (repro.obs.spans.SpanTracer): claim /
    # lease-renew / lease-steal / retry / quarantine / chunk-write land
    # on the same Perfetto timeline as the device event rings
    # (repro.obs.trace.export_perfetto). None = no tracing.
    tracer: Optional[Any] = None


def _span(tracer, name: str, **args):
    """`tracer.span(...)` or a no-op context when tracing is off."""
    if tracer is None:
        return contextlib.nullcontext()
    return tracer.span(name, **args)


def _instant(tracer, name: str, **args) -> None:
    if tracer is not None:
        tracer.instant(name, **args)


# --------------------------------------------------------------------------
# sharded dispatch (device layer lives in repro.sweep.mesh)
# --------------------------------------------------------------------------

def _resolve_shards(shards: Optional[int], n_scenarios: int) -> int:
    if shards is None:
        shards = device_count()
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shards > device_count():
        raise ValueError(f"shards={shards} exceeds the {device_count()} "
                         "available devices")
    return max(1, min(shards, n_scenarios))


def run_group(batch: Dict[str, np.ndarray], cfg: vecsim.VecSimConfig, *,
              shards: Optional[int] = None,
              donate: bool = False) -> Dict[str, np.ndarray]:
    """Run one stacked batch, scenario axis sharded over ``shards`` devices
    (1 = the single-device `vecsim.run_batch` vmap path)."""
    statics = vecsim.batch_statics(batch)
    arrays = vecsim.batch_arrays(batch)
    return _run_arrays(arrays, cfg, statics, shards, donate)


def _dispatch_arrays(arrays: Dict[str, np.ndarray],
                     cfg: vecsim.VecSimConfig, statics,
                     shards: Optional[int], donate: bool) -> Tuple[Any, int]:
    """Enqueue one chunk on the devices without blocking (jax dispatch is
    async). Returns ``(device output tree, real B)`` for
    `_finalize_arrays`; dispatch + finalize == the synchronous path."""
    smax, n_waves, n_jobs, active = statics
    b = int(next(iter(arrays.values())).shape[0])
    n_shards = _resolve_shards(shards, b)
    if n_shards == 1:
        out = vecsim._run_batch_jit(cfg, smax, n_waves, n_jobs, active,
                                    {k: np.asarray(v)
                                     for k, v in arrays.items()})
        return out, b     # vmap path: no padding; the [:b] trim is a no-op
    return mesh.dispatch_sharded(arrays, cfg, statics, n_shards,
                                 donate=donate)


def _finalize_arrays(out: Any, n_real: int,
                     cfg: vecsim.VecSimConfig) -> Dict[str, np.ndarray]:
    """Block on a dispatched chunk: device->host transfer, padding rows
    dropped, host-side finalization."""
    return vecsim.finalize_outputs(mesh.finalize_sharded(out, n_real), cfg)


def _run_arrays(arrays: Dict[str, np.ndarray], cfg: vecsim.VecSimConfig,
                statics, shards: Optional[int],
                donate: bool) -> Dict[str, np.ndarray]:
    out, n_real = _dispatch_arrays(arrays, cfg, statics, shards, donate)
    return _finalize_arrays(out, n_real, cfg)


# --------------------------------------------------------------------------
# work-queue checkpoint store (multi-host drainable)
# --------------------------------------------------------------------------

_MANIFEST_WHAT = {
    "spec": "spec axes/base (a different sweep grid)",
    "chunk_size": "chunk_size (saved chunks would slice the stacked "
                  "batch differently)",
    "layout": "resolved group configs / scenario content (a changed "
              "`configure` hook or an edited builder)",
    "traffic": "traffic content (a regenerated arrival trace, an edited "
               "job-template table, or changed process parameters)",
}


class WorkQueue:
    """Per-(group, chunk) NPZ store several host processes can drain.

    Three on-disk facts, all transitioned atomically:

      * ``manifest.json`` — the sweep fingerprint plus its components
        (spec, chunk_size, group layout), written tmp-then-rename; a
        mismatch refuses the directory and names *what* changed.
      * ``group*_chunk*.npz`` — a finished chunk, written tmp-then-rename
        so readers never observe a torn file.
      * ``group*_chunk*.claim`` — an in-flight lease, created with
        ``O_CREAT|O_EXCL`` (atomic test-and-set); a claim older than
        ``lease_s`` is presumed dead and stolen by renaming it aside
        (exactly one stealer's rename succeeds). A live owner renews the
        mtime of every claim it holds via `heartbeat` (driven by the
        `start_heartbeat` thread), so only a DEAD owner's claims age out.
      * ``group*_chunk*.quarantine.json`` — a poisoned chunk: it failed
        ``max_attempts`` compute attempts somewhere, no peer should burn
        more attempts on it. Mirrored (best-effort) into the manifest's
        ``quarantined`` list; the marker files are the authority.

    Leftover ``*.tmp.npz`` from a crashed mid-save are ignored by readers
    (loads address final paths only) and swept on startup once stale.
    """

    def __init__(self, directory: Union[str, pathlib.Path],
                 fingerprint: str,
                 components: Optional[Dict[str, str]] = None, *,
                 lease_s: float = 900.0, poll_s: float = 0.1,
                 tracer: Optional[Any] = None):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.lease_s = lease_s
        self.poll_s = poll_s
        self.tracer = tracer
        self.owner = f"{os.getpid()}-{uuid.uuid4().hex[:8]}"
        self._owned: Dict[Tuple[int, int], pathlib.Path] = {}
        self._owned_lock = threading.Lock()
        self._hb_stop: Optional[threading.Event] = None
        self._hb_thread: Optional[threading.Thread] = None
        self._check_manifest(fingerprint, components or {})
        self._sweep_stale_tmp()

    # ------------------------------------------------------------- manifest
    def _check_manifest(self, fingerprint: str,
                        components: Dict[str, str]) -> None:
        path = self.dir / "manifest.json"
        if path.exists():
            prev = json.loads(path.read_text())
            if prev.get("fingerprint") == fingerprint:
                return
            old = prev.get("components", {})
            changed = [k for k in components
                       if old.get(k) != components[k]] or ["fingerprint"]
            what = "; ".join(_MANIFEST_WHAT.get(k, k) for k in changed)
            raise ValueError(
                f"checkpoint dir {self.dir} holds a different sweep — "
                f"changed: {what} (fingerprint {prev.get('fingerprint')!r}"
                f" != {fingerprint!r}); point it elsewhere or clear it")
        doc = {"fingerprint": fingerprint, "components": components}
        tmp = path.with_name(f"manifest.{self.owner}.tmp.json")
        tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        tmp.replace(path)  # atomic: concurrent writers race to same bytes

    def _sweep_stale_tmp(self) -> None:
        """Drop ``*.tmp.*`` debris from crashed saves. Age-gated on the
        lease so a live peer's in-flight tmp is never yanked away."""
        now = time.time()
        for pat in ("*.tmp.npz", "*.tmp.json", "*.claim.stale.*"):
            for f in self.dir.glob(pat):
                try:
                    if now - f.stat().st_mtime > self.lease_s:
                        f.unlink(missing_ok=True)
                except FileNotFoundError:
                    pass

    # ---------------------------------------------------------------- chunks
    def _path(self, gi: int, ci: int) -> pathlib.Path:
        return self.dir / f"group{gi:03d}_chunk{ci:04d}.npz"

    def load(self, gi: int, ci: int) -> Optional[Dict[str, Any]]:
        p = self._path(gi, ci)
        if not p.exists():
            return None
        with np.load(p) as z:
            return unflatten_outputs({k: z[k] for k in z.files})

    def save(self, gi: int, ci: int, outputs: Dict[str, Any]) -> None:
        p = self._path(gi, ci)
        # owner-unique tmp name: two workers can never collide mid-save
        tmp = p.with_name(f"{p.stem}.{self.owner}.tmp.npz")
        np.savez_compressed(tmp, **flatten_outputs(outputs))
        tmp.replace(p)

    # ---------------------------------------------------------------- claims
    def _claim_path(self, gi: int, ci: int) -> pathlib.Path:
        return self.dir / f"group{gi:03d}_chunk{ci:04d}.claim"

    def try_claim(self, gi: int, ci: int) -> bool:
        """Atomically claim (group, chunk) for this process. False means a
        live peer holds it — poll `load` for its finished NPZ instead.

        The claim's mtime is the lease clock, renewed by `heartbeat` while
        the owner lives: a claim older than ``lease_s`` means its owner
        stopped heartbeating (crashed / was killed) and is stolen by a
        peer. `release` is ownership-checked, so even a comatose owner
        that wakes up late never yanks the thief's live claim."""
        path = self._claim_path(gi, ci)
        for _ in range(3):
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                try:
                    age = time.time() - path.stat().st_mtime
                except FileNotFoundError:
                    continue                    # released just now — retry
                if age <= self.lease_s:
                    return False
                # stale lease: move it aside (atomic — one stealer wins),
                # then race for a fresh claim
                aside = path.with_name(
                    f"{path.name}.stale.{self.owner}")
                try:
                    os.rename(path, aside)
                except FileNotFoundError:
                    continue
                aside.unlink(missing_ok=True)
                _instant(self.tracer, "lease-steal", group=gi, chunk=ci,
                         age_s=round(age, 3))
                continue
            with os.fdopen(fd, "w") as f:
                json.dump({"owner": self.owner, "t": time.time()}, f)
            with self._owned_lock:
                self._owned[(gi, ci)] = path
            return True
        return False

    def release(self, gi: int, ci: int) -> None:
        """Drop OUR claim. Ownership-checked: if the lease expired mid-
        compute and a peer stole it, the live thief's claim stays put."""
        path = self._claim_path(gi, ci)
        with self._owned_lock:
            self._owned.pop((gi, ci), None)
        try:
            if json.loads(path.read_text()).get("owner") != self.owner:
                return
        except (FileNotFoundError, json.JSONDecodeError):
            return
        path.unlink(missing_ok=True)

    # ------------------------------------------------------------- heartbeat
    def heartbeat(self) -> None:
        """Renew the lease clock (mtime) of every claim this process still
        owns. A claim that vanished or changed owner (stolen after a
        genuine lease expiry) is dropped from the renewal set — the thief
        owns it now."""
        now = time.time()
        with self._owned_lock:
            owned = list(self._owned.items())
        renewed = 0
        for key, path in owned:
            try:
                if json.loads(path.read_text()).get("owner") != self.owner:
                    raise FileNotFoundError(path)
                os.utime(path, (now, now))
                renewed += 1
            except (FileNotFoundError, json.JSONDecodeError, OSError):
                with self._owned_lock:
                    self._owned.pop(key, None)
        if owned:
            _instant(self.tracer, "lease-renew", renewed=renewed,
                     held=len(owned))

    def start_heartbeat(self, period_s: Optional[float] = None) -> None:
        """Spawn the daemon renewal thread (default period: a third of the
        lease, so one missed beat never expires a live claim)."""
        if self._hb_thread is not None:
            return
        period = period_s if period_s else self.lease_s / 3.0
        self._hb_stop = threading.Event()

        def loop(stop=self._hb_stop):
            while not stop.wait(period):
                self.heartbeat()

        self._hb_thread = threading.Thread(
            target=loop, name="workqueue-heartbeat", daemon=True)
        self._hb_thread.start()

    def stop_heartbeat(self) -> None:
        if self._hb_thread is None:
            return
        self._hb_stop.set()
        self._hb_thread.join()
        self._hb_thread = None
        self._hb_stop = None

    # ------------------------------------------------------------ quarantine
    def _quarantine_path(self, gi: int, ci: int) -> pathlib.Path:
        return self.dir / f"group{gi:03d}_chunk{ci:04d}.quarantine.json"

    def quarantined(self, gi: int, ci: int) -> Optional[Dict[str, Any]]:
        """The chunk's quarantine record, or None if it is healthy."""
        try:
            return json.loads(self._quarantine_path(gi, ci).read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def quarantine(self, gi: int, ci: int, error: str,
                   attempts: int) -> None:
        """Mark (group, chunk) poisoned: write the marker file (tmp-then-
        rename) and mirror it into the manifest's ``quarantined`` list so
        the directory's state is legible without globbing. The marker is
        the authority — the manifest mirror is best-effort (concurrent
        quarantines race read-modify-write, markers never do)."""
        path = self._quarantine_path(gi, ci)
        _instant(self.tracer, "quarantine", group=gi, chunk=ci,
                 attempts=attempts, error=error[:200])
        doc = {"owner": self.owner, "group": gi, "chunk": ci,
               "attempts": attempts, "error": error, "t": time.time()}
        tmp = path.with_name(f"{path.stem}.{self.owner}.tmp.json")
        tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        tmp.replace(path)
        mpath = self.dir / "manifest.json"
        try:
            man = json.loads(mpath.read_text())
            rec = [gi, ci]
            quar = man.setdefault("quarantined", [])
            if rec not in quar:
                quar.append(rec)
                quar.sort()
                mtmp = mpath.with_name(f"manifest.{self.owner}.tmp.json")
                mtmp.write_text(json.dumps(man, indent=2, sort_keys=True)
                                + "\n")
                mtmp.replace(mpath)
        except (FileNotFoundError, json.JSONDecodeError):
            pass


class _ChunkWriter:
    """One background thread that finalizes + persists completed chunks so
    the main thread can dispatch the next chunk meanwhile.

    ``Queue(maxsize=1)`` IS the double buffer: at most one chunk is
    finalizing/writing while one more is dispatched on the devices; a
    third `submit` blocks, so memory stays bounded at two chunks. Each
    submitted job owns its chunk's claim and releases it when the NPZ
    rename (or a failure) lands — the WorkQueue lease/tmp-then-rename
    contract is untouched, the work just moved off the dispatch thread.
    A job failure parks the error and surfaces it on the next `submit` or
    on `close`; later jobs are skipped (their claims still release) so a
    broken sweep stops instead of burning through the queue."""

    def __init__(self) -> None:
        self._q: "queue.Queue[Optional[Any]]" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._loop,
                                   name="sweep-chunk-writer", daemon=True)
        self._t.start()

    def _loop(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            try:
                job(skip=self._err is not None)
            except BaseException as e:        # parked, re-raised on submit
                if self._err is None:
                    self._err = e

    def _raise_pending(self) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def submit(self, job) -> None:
        """Hand one chunk job to the writer (blocks while both buffers are
        busy). Jobs take ``skip=`` and must release their claim even when
        skipped."""
        self._raise_pending()
        self._q.put(job)

    def close(self) -> None:
        """Drain the queue, join the thread, re-raise any parked error."""
        self._q.put(None)
        self._t.join()
        self._raise_pending()


# placeholder parked in `outs` for a quarantined chunk until siblings
# provide the output structure to NaN-fill (resolved post-drain)
_QUARANTINED = object()


def _retry_chunk(attempt, opts: RunnerOptions, first=None, where=()):
    """Run one chunk compute with retry + exponential backoff. ``first``
    (when given) is tried once before ``attempt`` — the pipeline path uses
    it to consume an already-dispatched device tree, then falls back to
    full re-dispatches. Raises the last error after ``max_attempts``.
    ``where`` = (group, chunk) labels the tracer's retry events."""
    tries = max(1, opts.max_attempts)
    last: Optional[BaseException] = None
    for i in range(tries):
        try:
            if i == 0 and first is not None:
                return first()
            return attempt()
        except Exception as e:          # noqa: BLE001 — quarantine decides
            last = e
            _instant(opts.tracer, "retry", attempt=i + 1,
                     error=repr(e)[:200],
                     **dict(zip(("group", "chunk"), where)))
            if i + 1 < tries:
                with _span(opts.tracer, "retry-backoff", attempt=i + 1,
                           **dict(zip(("group", "chunk"), where))):
                    time.sleep(opts.backoff_s * (2.0 ** i))
    raise last


def _nan_outputs(tmpl: Dict[str, Any], n: int) -> Dict[str, Any]:
    """A quarantined chunk's stand-in outputs: the sibling chunk ``tmpl``'s
    structure with ``n`` scenario rows of NaN (floats) / zeros (ints,
    bools — ``all_done`` reads False). Keeps the grid drainable and the
    poisoned rows unmistakable in the scalar table."""
    def conv(k, v):
        if k in GROUP_LEVEL_OUTPUTS:
            return v
        if isinstance(v, dict):
            return {kk: conv(kk, vv) for kk, vv in v.items()}
        a = np.asarray(v)
        shape = (n,) + a.shape[1:]
        if np.issubdtype(a.dtype, np.floating):
            return np.full(shape, np.nan, a.dtype)
        return np.zeros(shape, a.dtype)

    return {k: conv(k, v) for k, v in tmpl.items()}


def _trim_outputs(out: Dict[str, Any], n_real: int) -> Dict[str, Any]:
    """Drop padded scenario rows from a chunk's outputs (group-level
    entries pass through untouched)."""
    def trim(k, v):
        if k in GROUP_LEVEL_OUTPUTS:
            return v
        if isinstance(v, dict):
            return {kk: vv[:n_real] for kk, vv in v.items()}
        return v[:n_real]

    return {k: trim(k, v) for k, v in out.items()}


def _concat_outputs(chunks: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Concatenate chunk outputs along the scenario axis. Group-level
    entries (identified by NAME — a shape test misfires when the sample
    count coincides with the scenario count) are identical across chunks
    and pass through; everything else, nested timeline dicts included,
    concatenates."""
    if len(chunks) == 1:
        return chunks[0]

    def cat(key, vals):
        if key in GROUP_LEVEL_OUTPUTS:
            return vals[0]
        if isinstance(vals[0], dict):
            return {k: cat(k, [v[k] for v in vals]) for k in vals[0]}
        return np.concatenate([np.asarray(v) for v in vals])

    return {k: cat(k, [c[k] for c in chunks]) for k in chunks[0]}


def run_sweep(spec: Union[SweepSpec, Sequence[CompileGroup]],
              options: Optional[RunnerOptions] = None, *,
              shards: Optional[int] = None,
              chunk_size: Optional[int] = None,
              checkpoint_dir: Optional[str] = None) -> SweepResult:
    """Execute a sweep spec (or pre-built compile groups): stack each group
    once, run it in (optionally sharded, optionally chunked) dispatches,
    and aggregate a `SweepResult`. With ``checkpoint_dir`` set the chunk
    store doubles as a work queue — start the same call in several
    processes and they drain the grid together.

    Keyword args override the corresponding `RunnerOptions` fields.
    """
    opts = options or RunnerOptions()
    if shards is not None:
        opts = dataclasses.replace(opts, shards=shards)
    if chunk_size is not None:
        opts = dataclasses.replace(opts, chunk_size=chunk_size)
    if checkpoint_dir is not None:
        opts = dataclasses.replace(opts, checkpoint_dir=checkpoint_dir)

    if isinstance(spec, SweepSpec):
        groups = spec.groups()
        axes = spec.axes
        spec_fp = spec.fingerprint()
    else:
        groups = list(spec)
        axes = {}
        spec_fp = f"groups:{len(groups)}"

    # chunk layout, the *resolved* group configs AND the scenario content
    # must match for saved chunks to be reusable: chunk_size changes
    # re-slice the arrays, a changed `configure` hook changes what a
    # point's config means, and an edited builder changes the scenarios
    # themselves — all without touching the axes the spec fingerprint
    # hashes. The components ride along in the manifest so a mismatch can
    # say WHAT changed.
    ckpt = None
    if opts.checkpoint_dir:
        layout = hashlib.sha256(",".join(
            g.content_digest() for g in groups).encode()).hexdigest()[:12]
        components = {"spec": spec_fp, "chunk_size": repr(opts.chunk_size),
                      "layout": layout}
        fingerprint = f"{spec_fp}:chunk={opts.chunk_size}:{layout}"
        # traffic content gets its OWN manifest component (beyond its
        # bytes inside `layout`) so a resumed sweep whose trace file was
        # regenerated names the trace, not just "scenario content" —
        # appended only when present, preserving closed-sweep fingerprints
        tdigs = [g.traffic_digest() for g in groups]
        if any(tdigs):
            traffic = hashlib.sha256(
                ",".join(tdigs).encode()).hexdigest()[:12]
            components["traffic"] = traffic
            fingerprint += f":traffic={traffic}"
        ckpt = WorkQueue(opts.checkpoint_dir, fingerprint, components,
                         lease_s=opts.lease_s, poll_s=opts.poll_s,
                         tracer=opts.tracer)

    t0 = time.perf_counter()
    n_scen = 0
    n_cached = 0
    scen_ticks = 0
    # ONE flat work pool across ALL groups: a worker blocked on one
    # group's peer-claimed chunks claims unstarted chunks elsewhere
    # instead of sleeping, so multi-host drains of multi-group grids never
    # serialize on group order. Groups still stack lazily (and memoized,
    # via `CompileGroup.stacked_batch`) on their first computed chunk:
    # chunks slice the stacked arrays, so padded dims and static flags are
    # group-wide (one compile per group, chunked == unchunked bitwise),
    # and a group fully drained from the queue never stacks at all.
    steps: Dict[int, int] = {}
    outs: Dict[int, Dict[int, Dict[str, Any]]] = {}
    cached: Dict[int, int] = {}
    stacked: Dict[int, Any] = {}    # gi -> (statics, arrays)
    pool: List[Tuple[int, int]] = []
    for gi, g in enumerate(groups):
        n = len(g.scenarios)
        steps[gi] = opts.chunk_size or max(n, 1)
        outs[gi] = {}
        cached[gi] = 0
        pool.extend((gi, ci) for ci in range(-(-n // steps[gi])))

    quar: List[Tuple[int, int]] = []    # poisoned chunks (writer-appended)
    if ckpt is not None and opts.heartbeat:
        ckpt.start_heartbeat()
    writer = _ChunkWriter() if opts.pipeline else None
    try:
        while pool:
            progressed = False
            still: List[Tuple[int, int]] = []
            for gi, ci in pool:
                g = groups[gi]
                step = steps[gi]
                lo = ci * step
                real = min(step, len(g.scenarios) - lo)
                if ckpt is not None and ckpt.quarantined(gi, ci):
                    # a peer (or an earlier run) burned this chunk's
                    # attempts — don't re-attempt a poisoned chunk
                    outs[gi][ci] = _QUARANTINED
                    quar.append((gi, ci))
                    progressed = True
                    continue
                with _span(opts.tracer, "chunk-load", group=gi, chunk=ci) \
                        if ckpt else contextlib.nullcontext():
                    out = ckpt.load(gi, ci) if ckpt else None
                if out is None and ckpt is not None:
                    with _span(opts.tracer, "claim", group=gi, chunk=ci):
                        claimed = ckpt.try_claim(gi, ci)
                    if not claimed:
                        _instant(opts.tracer, "claim-miss", group=gi,
                                 chunk=ci)
                        still.append((gi, ci))  # a live peer is computing it
                        continue
                    # close the load->claim window: a peer may have saved
                    # and released between our miss and our claim — use its
                    # chunk rather than recomputing it
                    out = ckpt.load(gi, ci)
                    if out is not None:
                        ckpt.release(gi, ci)
                if out is not None:
                    if ckpt is not None:
                        _instant(opts.tracer, "resume-hit", group=gi,
                                 chunk=ci, scenarios=real)
                    outs[gi][ci] = out
                    cached[gi] += real
                    progressed = True
                    continue
                handed_off = False
                try:
                    if gi not in stacked:
                        batch = g.stacked_batch()
                        stacked[gi] = (vecsim.batch_statics(batch),
                                       vecsim.batch_arrays(batch))
                    statics, arrays = stacked[gi]
                    sub = {k: v[lo:lo + step] for k, v in arrays.items()}
                    pad_tail = real < step and lo > 0
                    if pad_tail:
                        # pad the ragged tail chunk to the uniform chunk
                        # shape so every chunk hits ONE compiled program;
                        # pad rows are dropped right after
                        sub = mesh.pad_rows(sub, step)
                    if writer is not None:
                        # async dispatch now; transfer + save overlap the
                        # NEXT chunk's dispatch on the writer thread. The
                        # job inherits this chunk's claim.
                        dev, n_real = _dispatch_arrays(
                            sub, g.cfg, statics, opts.shards, opts.donate)

                        def job(*, skip, gi=gi, ci=ci, dev=dev,
                                n_real=n_real, cfg=g.cfg, real=real,
                                pad_tail=pad_tail, sub=sub,
                                statics=statics):
                            try:
                                if skip:
                                    return
                                try:
                                    # attempt 1 consumes the dispatched
                                    # tree; retries re-dispatch from `sub`
                                    with _span(opts.tracer, "chunk-compute",
                                               group=gi, chunk=ci):
                                        res = _retry_chunk(
                                            lambda: _run_arrays(
                                                sub, cfg, statics,
                                                opts.shards, opts.donate),
                                            opts,
                                            first=lambda: _finalize_arrays(
                                                dev, n_real, cfg),
                                            where=(gi, ci))
                                except Exception as e:  # noqa: BLE001
                                    if ckpt:
                                        ckpt.quarantine(gi, ci, repr(e),
                                                        opts.max_attempts)
                                    outs[gi][ci] = _QUARANTINED
                                    quar.append((gi, ci))
                                    return
                                if pad_tail:
                                    res = _trim_outputs(res, real)
                                if ckpt:
                                    with _span(opts.tracer, "chunk-write",
                                               group=gi, chunk=ci):
                                        ckpt.save(gi, ci, res)
                                outs[gi][ci] = res
                            finally:
                                if ckpt:
                                    ckpt.release(gi, ci)

                        writer.submit(job)
                        handed_off = True
                    else:
                        try:
                            with _span(opts.tracer, "chunk-compute",
                                       group=gi, chunk=ci):
                                out = _retry_chunk(
                                    lambda: _run_arrays(sub, g.cfg, statics,
                                                        opts.shards,
                                                        opts.donate),
                                    opts, where=(gi, ci))
                        except Exception as e:      # noqa: BLE001
                            if ckpt:
                                ckpt.quarantine(gi, ci, repr(e),
                                                opts.max_attempts)
                            outs[gi][ci] = _QUARANTINED
                            quar.append((gi, ci))
                            progressed = True
                            continue
                        if pad_tail:
                            out = _trim_outputs(out, real)
                        if ckpt:
                            with _span(opts.tracer, "chunk-write",
                                       group=gi, chunk=ci):
                                ckpt.save(gi, ci, out)
                        outs[gi][ci] = out
                finally:
                    if ckpt and not handed_off:
                        ckpt.release(gi, ci)
                progressed = True
            pool = still
            if pool and not progressed:
                time.sleep(ckpt.poll_s)  # peers hold every pending chunk
    finally:
        if writer is not None:
            writer.close()    # drain in-flight saves; re-raise their errors
        if ckpt is not None:
            ckpt.stop_heartbeat()

    # resolve quarantined chunks: NaN-fill from a healthy sibling chunk's
    # output structure so the grid stays drainable and concatenable. A
    # group with NO healthy chunk has no structure to clone — that is a
    # fully-poisoned sweep, not a drainable grid.
    for gi, ci in quar:
        g = groups[gi]
        tmpl = next((v for v in outs[gi].values() if v is not _QUARANTINED),
                    None)
        if tmpl is None:
            raise RuntimeError(
                f"every chunk of group {gi} is quarantined — nothing "
                f"healthy to drain (see {opts.checkpoint_dir})")
        real = min(steps[gi], len(g.scenarios) - ci * steps[gi])
        outs[gi][ci] = _nan_outputs(tmpl, real)

    results: List[GroupResult] = []
    for gi, g in enumerate(groups):
        n = len(g.scenarios)
        results.append(GroupResult(g.cfg, g.points, _concat_outputs(
            [outs[gi][ci] for ci in range(-(-n // steps[gi]))])))
        n_scen += n
        n_cached += cached[gi]
        # throughput counts only scenarios actually computed this run —
        # queue-drained chunks (resumed or peer-computed) are loads, not work
        n_nodes = max((len(s["slots"]) for s in g.scenarios), default=0)
        scen_ticks += (n - cached[gi]) * g.cfg.n_ticks * n_nodes
    wall = time.perf_counter() - t0
    meta = {
        "wall_s": wall,
        "n_points": n_scen,
        "n_groups": len(groups),
        "shards": _resolve_shards(opts.shards, max(n_scen, 1)),
        "chunk_size": opts.chunk_size,
        "pipeline": bool(opts.pipeline),
        "resumed_scenarios": n_cached,
        "computed_scenarios": n_scen - n_cached,
        # poisoned chunks NaN-filled this run ([group, chunk] pairs) —
        # their scenario rows are NaN in the scalar table
        "quarantined_chunks": sorted([gi, ci] for gi, ci in quar),
        "mesh": mesh.mesh_topology(),
        "ticks_nodes_scen_per_s": scen_ticks / max(wall, 1e-9),
    }
    return SweepResult(axes, results, meta)
