"""Sharded sweep runner: chunked, resumable execution of compile groups
with the *scenario axis* sharded across local devices.

Layout: a group's stacked batch ``[B, ...]`` pads the scenario axis to a
multiple of the shard count ``D`` (repeating scenario 0 — scenarios are
independent under ``vmap``, so padding never perturbs real rows), reshapes
to ``[D, B/D, ...]`` and dispatches one ``jax.pmap`` of the vmapped tick
engine: device ``d`` scans its ``B/D`` scenarios while the others run
theirs. ``shards=1`` (or a single-device platform) falls back to the plain
jitted ``vmap`` path — bitwise-identical per-scenario results, which
`tests/test_sweep.py` and the ``sweep/smoke`` benchmark assert.

Chunking slices the *stacked* group batch, so every chunk shares the
group's padded dims and static flags: one compile per group regardless of
chunk count, and chunked results concatenate (and bit-match) the unchunked
run. With ``checkpoint_dir`` set, each finished chunk persists as an NPZ;
re-running the same spec resumes after the last completed chunk — the
1k+-scenario calibration-sweep workflow.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import pathlib
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core import vecsim
from repro.sweep.results import (
    GROUP_LEVEL_OUTPUTS,
    GroupResult,
    SweepResult,
    flatten_outputs,
    unflatten_outputs,
)
from repro.sweep.spec import CompileGroup, SweepSpec


def device_count() -> int:
    """Local devices available for scenario-axis sharding (force >1 on CPU
    hosts with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    return len(jax.local_devices())


@dataclasses.dataclass(frozen=True)
class RunnerOptions:
    shards: Optional[int] = None     # None = all local devices; 1 = vmap path
    chunk_size: Optional[int] = None  # scenarios per dispatch (None = group)
    checkpoint_dir: Optional[str] = None  # resumable chunk store
    donate: bool = False             # donate chunk arrays (no-op on CPU)


# --------------------------------------------------------------------------
# sharded dispatch
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pmapped_engine(cfg: vecsim.VecSimConfig, smax: int, n_waves: int,
                    n_jobs: int, active: Tuple[bool, ...], donate: bool):
    fn = jax.vmap(functools.partial(vecsim._simulate_one, cfg, smax,
                                    n_waves, n_jobs, active))
    return jax.pmap(fn, donate_argnums=(0,) if donate else ())


def _resolve_shards(shards: Optional[int], n_scenarios: int) -> int:
    if shards is None:
        shards = device_count()
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shards > device_count():
        raise ValueError(f"shards={shards} exceeds the {device_count()} "
                         "available devices")
    return max(1, min(shards, n_scenarios))


def _shard_arrays(arrays: Dict[str, np.ndarray],
                  n_shards: int) -> Tuple[Dict[str, np.ndarray], int]:
    """Pad the scenario axis to a multiple of ``n_shards`` (repeating row 0)
    and fold it into ``[D, B/D, ...]``. Returns (sharded arrays, real B)."""
    b = int(next(iter(arrays.values())).shape[0])
    per = -(-b // n_shards)
    pad = n_shards * per - b

    def fold(v: np.ndarray) -> np.ndarray:
        v = np.asarray(v)
        if pad:
            v = np.concatenate([v, np.repeat(v[:1], pad, axis=0)])
        return v.reshape((n_shards, per) + v.shape[1:])

    return {k: fold(v) for k, v in arrays.items()}, b


def _unshard(out: Any, n_real: int) -> Any:
    """[D, B/D, ...] outputs -> [B, ...] with padding rows dropped."""
    def unfold(v):
        v = np.asarray(v)
        return v.reshape((-1,) + v.shape[2:])[:n_real]

    return jax.tree_util.tree_map(unfold, out)


def run_group(batch: Dict[str, np.ndarray], cfg: vecsim.VecSimConfig, *,
              shards: Optional[int] = None,
              donate: bool = False) -> Dict[str, np.ndarray]:
    """Run one stacked batch, scenario axis sharded over ``shards`` devices
    (1 = the single-device `vecsim.run_batch` vmap path)."""
    statics = vecsim.batch_statics(batch)
    arrays = vecsim.batch_arrays(batch)
    return _run_arrays(arrays, cfg, statics, shards, donate)


def _run_arrays(arrays: Dict[str, np.ndarray], cfg: vecsim.VecSimConfig,
                statics, shards: Optional[int],
                donate: bool) -> Dict[str, np.ndarray]:
    smax, n_waves, n_jobs, active = statics
    b = int(next(iter(arrays.values())).shape[0])
    n_shards = _resolve_shards(shards, b)
    if n_shards == 1:
        out = vecsim._run_batch_jit(cfg, smax, n_waves, n_jobs, active,
                                    {k: np.asarray(v)
                                     for k, v in arrays.items()})
        return vecsim.finalize_outputs(out, cfg)
    sharded, n_real = _shard_arrays(arrays, n_shards)
    fn = _pmapped_engine(cfg, smax, n_waves, n_jobs, active, donate)
    out = _unshard(fn(sharded), n_real)
    return vecsim.finalize_outputs(out, cfg)


# --------------------------------------------------------------------------
# chunked, resumable sweep execution
# --------------------------------------------------------------------------

class _Checkpoint:
    """Per-chunk NPZ store guarded by a spec fingerprint manifest."""

    def __init__(self, directory: Union[str, pathlib.Path], fingerprint: str):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        manifest = self.dir / "manifest.json"
        if manifest.exists():
            prev = json.loads(manifest.read_text())
            if prev.get("fingerprint") != fingerprint:
                raise ValueError(
                    f"checkpoint dir {self.dir} holds a different sweep "
                    f"(fingerprint {prev.get('fingerprint')!r} != "
                    f"{fingerprint!r}); point it elsewhere or clear it")
        else:
            manifest.write_text(json.dumps({"fingerprint": fingerprint}))

    def _path(self, gi: int, ci: int) -> pathlib.Path:
        return self.dir / f"group{gi:03d}_chunk{ci:04d}.npz"

    def load(self, gi: int, ci: int) -> Optional[Dict[str, Any]]:
        p = self._path(gi, ci)
        if not p.exists():
            return None
        with np.load(p) as z:
            return unflatten_outputs({k: z[k] for k in z.files})

    def save(self, gi: int, ci: int, outputs: Dict[str, Any]) -> None:
        p = self._path(gi, ci)
        tmp = p.with_suffix(".tmp.npz")
        np.savez_compressed(tmp, **flatten_outputs(outputs))
        tmp.replace(p)


def _trim_outputs(out: Dict[str, Any], n_real: int) -> Dict[str, Any]:
    """Drop padded scenario rows from a chunk's outputs (group-level
    entries pass through untouched)."""
    def trim(k, v):
        if k in GROUP_LEVEL_OUTPUTS:
            return v
        if isinstance(v, dict):
            return {kk: vv[:n_real] for kk, vv in v.items()}
        return v[:n_real]

    return {k: trim(k, v) for k, v in out.items()}


def _concat_outputs(chunks: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Concatenate chunk outputs along the scenario axis. Group-level
    entries (identified by NAME — a shape test misfires when the sample
    count coincides with the scenario count) are identical across chunks
    and pass through; everything else, nested timeline dicts included,
    concatenates."""
    if len(chunks) == 1:
        return chunks[0]

    def cat(key, vals):
        if key in GROUP_LEVEL_OUTPUTS:
            return vals[0]
        if isinstance(vals[0], dict):
            return {k: cat(k, [v[k] for v in vals]) for k in vals[0]}
        return np.concatenate([np.asarray(v) for v in vals])

    return {k: cat(k, [c[k] for c in chunks]) for k in chunks[0]}


def run_sweep(spec: Union[SweepSpec, Sequence[CompileGroup]],
              options: Optional[RunnerOptions] = None, *,
              shards: Optional[int] = None,
              chunk_size: Optional[int] = None,
              checkpoint_dir: Optional[str] = None) -> SweepResult:
    """Execute a sweep spec (or pre-built compile groups): stack each group
    once, run it in (optionally sharded, optionally chunked) dispatches,
    and aggregate a `SweepResult`.

    Keyword args override the corresponding `RunnerOptions` fields.
    """
    opts = options or RunnerOptions()
    if shards is not None:
        opts = dataclasses.replace(opts, shards=shards)
    if chunk_size is not None:
        opts = dataclasses.replace(opts, chunk_size=chunk_size)
    if checkpoint_dir is not None:
        opts = dataclasses.replace(opts, checkpoint_dir=checkpoint_dir)

    if isinstance(spec, SweepSpec):
        groups = spec.groups()
        axes = spec.axes
        fingerprint = spec.fingerprint()
    else:
        groups = list(spec)
        axes = {}
        fingerprint = f"groups:{len(groups)}"

    # chunk layout and the *resolved* group configs must match for saved
    # chunks to be reusable: chunk_size changes re-slice the arrays, and a
    # changed `configure` hook changes what a point's config means without
    # touching the axes the spec fingerprint hashes
    import hashlib

    layout = hashlib.sha256(",".join(
        f"{len(g)}@{g.cfg!r}" for g in groups).encode()).hexdigest()[:12]
    fingerprint += f":chunk={opts.chunk_size}:{layout}"
    ckpt = (_Checkpoint(opts.checkpoint_dir, fingerprint)
            if opts.checkpoint_dir else None)

    t0 = time.perf_counter()
    n_scen = 0
    n_cached = 0
    scen_ticks = 0
    results: List[GroupResult] = []
    for gi, g in enumerate(groups):
        # stack the WHOLE group once — but lazily, on the first chunk that
        # actually computes: chunks slice the stacked arrays, so padded
        # dims and static flags are group-wide (one compile per group,
        # chunked == unchunked bitwise), while a fully checkpoint-resumed
        # group skips the host-side stacking cost entirely
        statics = arrays = None
        n = len(g.scenarios)
        step = opts.chunk_size or n
        chunk_outs: List[Dict[str, Any]] = []
        g_cached = 0
        for ci, lo in enumerate(range(0, n, step)):
            real = min(step, n - lo)
            pad_tail = real < step and lo > 0
            out = ckpt.load(gi, ci) if ckpt else None
            if out is None:
                if arrays is None:
                    batch = vecsim.stack_scenarios(g.scenarios)
                    statics = vecsim.batch_statics(batch)
                    arrays = vecsim.batch_arrays(batch)
                sub = {k: v[lo:lo + step] for k, v in arrays.items()}
                if pad_tail:
                    # pad the ragged tail chunk to the uniform chunk shape
                    # (repeating row 0) so every chunk hits ONE compiled
                    # program; pad rows are dropped right after
                    sub = {k: np.concatenate(
                        [v, np.repeat(v[:1], step - real, axis=0)])
                        for k, v in sub.items()}
                out = _run_arrays(sub, g.cfg, statics, opts.shards,
                                  opts.donate)
                if pad_tail:
                    out = _trim_outputs(out, real)
                if ckpt:
                    ckpt.save(gi, ci, out)
            else:
                g_cached += real
            chunk_outs.append(out)
        results.append(GroupResult(g.cfg, g.points,
                                   _concat_outputs(chunk_outs)))
        n_scen += n
        n_cached += g_cached
        # throughput counts only scenarios actually computed this run —
        # checkpoint-resumed chunks are loads, not work
        n_nodes = max((len(s["slots"]) for s in g.scenarios), default=0)
        scen_ticks += (n - g_cached) * g.cfg.n_ticks * n_nodes
    wall = time.perf_counter() - t0
    meta = {
        "wall_s": wall,
        "n_points": n_scen,
        "n_groups": len(groups),
        "shards": _resolve_shards(opts.shards, max(n_scen, 1)),
        "chunk_size": opts.chunk_size,
        "resumed_scenarios": n_cached,
        "ticks_nodes_scen_per_s": scen_ticks / max(wall, 1e-9),
    }
    return SweepResult(axes, results, meta)
