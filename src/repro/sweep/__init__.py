"""repro.sweep — sweep orchestration over the batched fleet simulator.

Dataflow: **spec** (declare a cartesian grid over `VecSimConfig` fields +
scenario-builder params) → **group** (partition points by static config;
one jit compile each) → **shard** (scenario axis across local devices via
`jax.pmap`, chunked + resumable) → **stream** (per-tick timeline ys at
`sample_period`) → **aggregate** (`SweepResult` JSON/NPZ artifact keyed by
grid coordinates).
"""
from repro.sweep.results import GroupResult, SweepResult
from repro.sweep.runner import RunnerOptions, device_count, run_group, run_sweep
from repro.sweep.spec import CompileGroup, SweepPoint, SweepSpec

__all__ = [
    "CompileGroup",
    "GroupResult",
    "RunnerOptions",
    "SweepPoint",
    "SweepResult",
    "SweepSpec",
    "device_count",
    "run_group",
    "run_sweep",
]
