"""repro.sweep — sweep orchestration over the batched fleet simulator.

Dataflow: **spec** (declare a cartesian grid over `VecSimConfig` fields +
scenario-builder params) → **group** (partition points by static config;
one jit compile each) → **mesh** (scenario axis over a named device mesh
via `shard_map`, chunked + work-queue checkpointed so several hosts drain
one grid) → **stream** (per-tick timeline ys at `sample_period`, gathered
device-side) → **aggregate** (`SweepResult` JSON/NPZ artifact keyed by
grid coordinates).
"""
from repro.sweep.mesh import (
    SCENARIO_AXIS,
    make_local_mesh,
    make_production_mesh,
    mesh_topology,
    scenario_mesh,
)
from repro.sweep.results import GroupResult, SweepResult
from repro.sweep.runner import (
    RunnerOptions,
    WorkQueue,
    device_count,
    run_group,
    run_sweep,
)
from repro.sweep.spec import CompileGroup, SweepPoint, SweepSpec

__all__ = [
    "CompileGroup",
    "GroupResult",
    "RunnerOptions",
    "SCENARIO_AXIS",
    "SweepPoint",
    "SweepResult",
    "SweepSpec",
    "WorkQueue",
    "device_count",
    "make_local_mesh",
    "make_production_mesh",
    "mesh_topology",
    "run_group",
    "run_sweep",
    "scenario_mesh",
]
