"""Sweep spec/grid layer: declare a cartesian grid over `VecSimConfig`
fields and scenario-builder parameters, expand it, and partition the points
into *compile groups* by static configuration.

CASH's headline results are all sweeps — credit seeds × fleet mixes ×
schedulers × telemetry modes driven through the batched engine
(`core.vecsim`). Every `VecSimConfig` field is compile-time static, so a
grid mixes two kinds of axes:

  * **static axes** — names matching a `VecSimConfig` field (``scheduler``,
    ``telemetry``, ``resource``, ``joint_anti_affinity``, …). Each distinct
    combination is its own jit compilation; the spec groups points so each
    group compiles exactly once.
  * **scenario axes** — anything else; values are forwarded to the
    ``builder`` callable, which freezes one scenario
    (`vecsim.build_scenario` output) per distinct parameter combination.
    Builders are memoized on those parameters, so a grid that crosses the
    same scenarios with many static configs (e.g. stock vs cash on the same
    fleets) builds each scenario once.

An axis name the builder's signature explicitly accepts is a *scenario*
axis even when it collides with a `VecSimConfig` field name (``seed`` is
the common case: a workload seed, not the engine's shuffle-key seed); set
colliding config fields through ``configure`` or ``base`` instead.

Non-cartesian static config (fig7's "label" axis choosing the scheduler)
goes through ``configure``: a callable mapping the point's coordinates to
`VecSimConfig` field overrides, applied after the static axes.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import vecsim
from repro.core.vecsim import VecSimConfig

CFG_FIELDS = frozenset(f.name for f in dataclasses.fields(VecSimConfig))

Scenario = Dict[str, np.ndarray]
Builder = Callable[..., Scenario]


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One cell of the expanded grid."""
    index: int                       # position in expansion (row-major) order
    coords: Tuple[Tuple[str, Any], ...]   # full axis-name -> value mapping
    cfg: VecSimConfig                # resolved static configuration

    @property
    def coord_dict(self) -> Dict[str, Any]:
        return dict(self.coords)


@dataclasses.dataclass
class CompileGroup:
    """Points sharing one static `VecSimConfig` — one jit compile, one (or
    a few chunked) batched dispatches."""
    cfg: VecSimConfig
    points: List[SweepPoint]
    scenarios: List[Scenario]
    _batch: Optional[Scenario] = dataclasses.field(
        default=None, repr=False, compare=False)

    def __len__(self) -> int:
        return len(self.points)

    def stacked_batch(self) -> Scenario:
        """Stack (and memoize) the group's scenarios: repeated `run_sweep`
        calls over the same groups — e.g. a vmap baseline then several
        shard widths — pay the host-side stacking once. The memo keeps one
        stacked copy alive as long as the caller holds the group; set
        ``g._batch = None`` to free it after the last dispatch."""
        if self._batch is None:
            self._batch = vecsim.stack_scenarios(self.scenarios)
        return self._batch

    def content_digest(self) -> str:
        """Hash of the resolved config + every scenario's arrays. Folded
        into the checkpoint manifest so an edited builder (same axes, new
        scenario content) refuses stale chunks instead of silently
        resuming them."""
        import hashlib

        h = hashlib.sha256()
        h.update(f"{len(self)}@{self.cfg!r}".encode())
        for s in self.scenarios:
            for k in sorted(s):
                v = np.asarray(s[k])
                # key, dtype AND shape delimit the raw bytes: a reshape
                # (or a key whose name is another's prefix) must change
                # the digest, not just the payload
                h.update(f"{k}:{v.dtype}:{v.shape};".encode())
                h.update(v.tobytes())
        return h.hexdigest()

    def traffic_digest(self) -> str:
        """Hash of the group's traffic content only (arrival traces,
        template tables, process parameters — `arrivals.
        TRAFFIC_CONTENT_KEYS`), or ``""`` for a closed-batch group.

        `content_digest` already covers these arrays, but as one opaque
        blob: a regenerated trace and an edited fleet refuse resume with
        the same error. Splitting traffic into its own manifest component
        lets `WorkQueue` NAME the trace as what changed."""
        if not self.scenarios or "tmpl_work" not in self.scenarios[0]:
            return ""
        import hashlib

        from repro.traffic.arrivals import TRAFFIC_CONTENT_KEYS

        h = hashlib.sha256()
        h.update(f"{self.cfg.traffic}@{len(self)}".encode())
        for s in self.scenarios:
            for k in TRAFFIC_CONTENT_KEYS:
                if k not in s:
                    continue
                v = np.asarray(s[k])
                h.update(f"{k}:{v.dtype}:{v.shape};".encode())
                h.update(v.tobytes())
        return h.hexdigest()


class SweepSpec:
    """Cartesian sweep declaration.

    Parameters
    ----------
    builder:
        ``builder(**scenario_params) -> scenario dict`` (the output of
        `vecsim.build_scenario`). Receives the point's non-`VecSimConfig`
        coordinates, filtered to the builder's signature unless it takes
        ``**kwargs``.
    axes:
        Ordered mapping of axis name -> sequence of values. Expansion is
        row-major (last axis fastest), like ``itertools.product``.
    base:
        `VecSimConfig` defaults for fields no axis covers.
    configure:
        Optional ``configure(coords: dict) -> dict`` returning extra
        `VecSimConfig` field overrides derived from the coordinates.
    """

    def __init__(self, builder: Builder, axes: Mapping[str, Sequence[Any]],
                 *, base: Optional[VecSimConfig] = None,
                 configure: Optional[Callable[[Dict[str, Any]],
                                              Dict[str, Any]]] = None):
        if not axes:
            raise ValueError("a sweep needs at least one axis")
        self.builder = builder
        self.axes: Dict[str, List[Any]] = {k: list(v) for k, v in axes.items()}
        for name, vals in self.axes.items():
            if not vals:
                raise ValueError(f"axis {name!r} has no values")
        self.base = base or VecSimConfig()
        self.configure = configure
        self._builder_params = self._accepted_params(builder)
        # an axis that feeds neither the builder nor the config is a typo
        # that would silently duplicate the whole grid; only a `configure`
        # hook (whose reads we cannot introspect) can consume extra axes
        if configure is None and self._builder_params is not None:
            unknown = [n for n in self.axes
                       if n not in CFG_FIELDS and n not in self._builder_params]
            if unknown:
                raise ValueError(
                    f"axes {unknown} match neither a builder parameter nor "
                    "a VecSimConfig field (add a `configure` hook if they "
                    "are meant to derive config)")

    @staticmethod
    def _accepted_params(builder: Builder) -> Optional[frozenset]:
        """Parameter names the builder accepts, or None for **kwargs."""
        try:
            sig = inspect.signature(builder)
        except (TypeError, ValueError):
            return None
        if any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in sig.parameters.values()):
            return None
        return frozenset(sig.parameters)

    # ------------------------------------------------------------- expansion
    @property
    def n_points(self) -> int:
        n = 1
        for vals in self.axes.values():
            n *= len(vals)
        return n

    def expand(self) -> List[SweepPoint]:
        """All grid points in row-major axis order, with resolved configs."""
        names = list(self.axes)
        points: List[SweepPoint] = []
        taken = self._builder_params or frozenset()
        for i, combo in enumerate(itertools.product(*self.axes.values())):
            coords = dict(zip(names, combo))
            overrides = {k: v for k, v in coords.items()
                         if k in CFG_FIELDS and k not in taken}
            if self.configure is not None:
                derived = self.configure(dict(coords))
                bad = set(derived) - CFG_FIELDS
                if bad:
                    raise ValueError(
                        f"configure returned non-VecSimConfig fields: {bad}")
                overrides.update(derived)
            cfg = dataclasses.replace(self.base, **overrides)
            points.append(SweepPoint(index=i, coords=tuple(coords.items()),
                                     cfg=cfg))
        return points

    def scenario_params(self, point: SweepPoint) -> Dict[str, Any]:
        """The coordinates forwarded to the builder for this point."""
        if self._builder_params is not None:
            return {k: v for k, v in point.coords
                    if k in self._builder_params}
        return {k: v for k, v in point.coords if k not in CFG_FIELDS}

    def groups(self) -> List[CompileGroup]:
        """Expand and partition by static config, building each distinct
        scenario once (memoized on the builder parameters)."""
        cache: Dict[Tuple[Tuple[str, Any], ...], Scenario] = {}
        grouped: Dict[VecSimConfig, CompileGroup] = {}
        for point in self.expand():
            params = self.scenario_params(point)
            key = tuple(sorted(params.items()))
            if key not in cache:
                cache[key] = self.builder(**params)
            g = grouped.get(point.cfg)
            if g is None:
                g = grouped[point.cfg] = CompileGroup(point.cfg, [], [])
            g.points.append(point)
            g.scenarios.append(cache[key])
        return list(grouped.values())

    # --------------------------------------------------------- fingerprinting
    def fingerprint(self) -> str:
        """Stable id of the grid shape + static base — guards checkpoint
        resume against running a different spec into the same directory.
        Axis values are stringified; builders are intentionally excluded
        (two specs over the same grid may close over equivalent builders)."""
        import hashlib

        h = hashlib.sha256()
        h.update(repr(dataclasses.asdict(self.base)).encode())
        for name, vals in self.axes.items():
            h.update(name.encode())
            for v in vals:
                h.update(repr(v).encode())
        return h.hexdigest()[:16]
