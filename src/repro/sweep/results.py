"""Results layer: aggregate per-scenario engine outputs (+ optional
timelines) across compile groups into one artifact keyed by grid
coordinates.

A sweep's outputs are ragged across groups — per-task `start`/`finish`
arrays pad to each group's max task count, `job_completion` to its max job
count, timelines exist only when the group's config sampled them. The
`SweepResult` therefore keeps full arrays per group and assembles the
*scalar* metrics (makespan, all_done, surplus, …) into flat per-point
columns in grid order, which is what calibration sweeps consume.

Persistence is a JSON + NPZ pair: ``<prefix>.json`` holds the grid (axes,
coordinates, configs, scalar metric table, run metadata) — human-diffable
and keyed by coordinates; ``<prefix>.npz`` holds every dense array under
``g<gi>/<name>`` keys. `SweepResult.load` round-trips both.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.vecsim import VecSimConfig
from repro.obs import registry
from repro.sweep.spec import SweepPoint

# per-scenario scalar outputs assembled into the flat metric table, in
# the metrics registry's declaration order (repro.obs.registry is the
# single source of truth for names/units/schemas). `scalars()` skips any
# name a group lacks, so the traffic-only columns (stream counters + SLO
# percentiles from `traffic.slo`) cost closed sweeps nothing.
SCALAR_OUTPUTS = registry.scalar_names()

# outputs that are group-level (no leading scenario axis). Identified by
# NAME, never by shape — a shape heuristic misfires whenever the sample
# count happens to equal the group's scenario count.
GROUP_LEVEL_OUTPUTS = frozenset({"timeline_t", "slo_edges"})


def flatten_outputs(outputs: Dict[str, Any],
                    prefix: str = "") -> Dict[str, np.ndarray]:
    """Flatten the (possibly nested: ``timeline``) output dict to
    slash-separated keys — the NPZ/checkpoint wire format."""
    flat: Dict[str, np.ndarray] = {}
    for k, v in outputs.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            flat.update(flatten_outputs(v, prefix=f"{key}/"))
        else:
            flat[key] = np.asarray(v)
    return flat


def unflatten_outputs(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


@dataclasses.dataclass
class GroupResult:
    """One compile group's points + the engine outputs for its scenarios
    (leading axis = position within the group)."""
    cfg: VecSimConfig
    points: List[SweepPoint]
    outputs: Dict[str, Any]


class SweepResult:
    def __init__(self, axes: Dict[str, Sequence[Any]],
                 groups: List[GroupResult],
                 meta: Optional[Dict[str, Any]] = None):
        self.axes = {k: list(v) for k, v in axes.items()}
        self.groups = groups
        self.meta = dict(meta or {})
        # global point index -> (group idx, row within group)
        self._where: Dict[int, Tuple[int, int]] = {}
        for gi, g in enumerate(groups):
            for row, p in enumerate(g.points):
                self._where[p.index] = (gi, row)
        n_poisoned = self.n_poisoned
        if n_poisoned:
            warnings.warn(
                f"{n_poisoned} of {self.n_points} scenario rows are "
                "poisoned (NaN-filled quarantined chunks) — their scalar "
                "metrics are NaN and all_done reads False; see "
                "meta['quarantined_chunks']", stacklevel=2)

    # ------------------------------------------------------------- accessors
    @property
    def n_points(self) -> int:
        return len(self._where)

    @property
    def points(self) -> List[SweepPoint]:
        """All points in grid (expansion) order."""
        pts = [p for g in self.groups for p in g.points]
        return sorted(pts, key=lambda p: p.index)

    def poisoned_mask(self) -> np.ndarray:
        """Per-point bool (grid order): True where the row came from a
        NaN-filled quarantined chunk (`runner._nan_outputs` stand-ins,
        identified by a NaN makespan — the engine never emits one)."""
        mask = []
        for p in self.points:
            gi, row = self._where[p.index]
            mk = self.groups[gi].outputs.get("makespan")
            mask.append(bool(np.isnan(np.asarray(mk[row])))
                        if mk is not None else False)
        return np.asarray(mask, bool)

    @property
    def n_poisoned(self) -> int:
        """Scenario rows NaN-filled because their chunk was quarantined."""
        return int(self.poisoned_mask().sum())

    def scalars(self) -> Dict[str, np.ndarray]:
        """Per-point scalar metric columns in grid order."""
        cols: Dict[str, np.ndarray] = {}
        order = self.points
        for name in SCALAR_OUTPUTS:
            if not all(name in g.outputs for g in self.groups):
                continue
            vals = []
            for p in order:
                gi, row = self._where[p.index]
                vals.append(self.groups[gi].outputs[name][row])
            cols[name] = np.asarray(vals)
        return cols

    def point_outputs(self, index: int) -> Dict[str, Any]:
        """Every output (scalars, per-task arrays, timeline row) for one
        grid point."""
        gi, row = self._where[index]
        g = self.groups[gi]
        out: Dict[str, Any] = {}
        for k, v in g.outputs.items():
            if k in GROUP_LEVEL_OUTPUTS:    # e.g. the timeline_t time axis
                out[k] = v
            elif isinstance(v, dict):
                out[k] = {kk: vv[row] for kk, vv in v.items()}
            else:
                out[k] = v[row]
        return out

    def select(self, **coords: Any) -> List[SweepPoint]:
        """Points whose coordinates match every given axis value."""
        return [p for p in self.points
                if all(p.coord_dict.get(k) == v for k, v in coords.items())]

    def metric(self, name: str, **coords: Any) -> np.ndarray:
        """A scalar output filtered by coordinates, in grid order."""
        pts = self.select(**coords)
        vals = []
        for p in pts:
            gi, row = self._where[p.index]
            vals.append(self.groups[gi].outputs[name][row])
        return np.asarray(vals)

    # ------------------------------------------------------------ persistence
    def to_tidy(self) -> Dict[str, Any]:
        """JSON-able artifact: grid + per-point coordinate/metric rows.
        Every output key is validated against the metrics registry
        (repro.obs.registry) — an undeclared engine output cannot
        persist without a registered name/unit/schema."""
        for g in self.groups:
            registry.validate_outputs(g.outputs)
        scalars = self.scalars()
        poisoned = self.poisoned_mask()
        rows = []
        for i, p in enumerate(self.points):
            gi, _ = self._where[p.index]
            rows.append({
                "index": p.index,
                "coords": p.coord_dict,
                "group": gi,
                "poisoned": bool(poisoned[i]),
                "metrics": {k: _jsonify(v[i]) for k, v in scalars.items()},
            })
        return {
            "axes": {k: [_jsonify(v) for v in vs]
                     for k, vs in self.axes.items()},
            "groups": [dataclasses.asdict(g.cfg) for g in self.groups],
            "points": rows,
            "meta": {**self.meta, "n_poisoned": int(poisoned.sum())},
        }

    def save(self, prefix: str) -> Tuple[pathlib.Path, pathlib.Path]:
        """Write ``<prefix>.json`` (tidy grid) + ``<prefix>.npz`` (dense
        arrays, ``g<gi>/<name>`` keys)."""
        prefix_p = pathlib.Path(prefix)
        jpath = prefix_p.with_suffix(".json")
        npath = prefix_p.with_suffix(".npz")
        jpath.parent.mkdir(parents=True, exist_ok=True)
        jpath.write_text(json.dumps(self.to_tidy(), indent=2,
                                    sort_keys=True) + "\n")
        dense: Dict[str, np.ndarray] = {}
        for gi, g in enumerate(self.groups):
            dense.update(flatten_outputs(g.outputs, prefix=f"g{gi}/"))
            dense[f"g{gi}/_point_index"] = np.asarray(
                [p.index for p in g.points])
        np.savez_compressed(npath, **dense)
        return jpath, npath

    @classmethod
    def load(cls, prefix: str) -> "SweepResult":
        prefix_p = pathlib.Path(prefix)
        tidy = json.loads(prefix_p.with_suffix(".json").read_text())
        with np.load(prefix_p.with_suffix(".npz")) as z:
            dense = {k: z[k] for k in z.files}
        cfgs = [VecSimConfig(**d) for d in tidy["groups"]]
        by_group: List[Dict[str, np.ndarray]] = [dict() for _ in cfgs]
        for k, v in dense.items():
            gi, _, rest = k.partition("/")
            by_group[int(gi[1:])][rest] = v
        groups = []
        for gi, cfg in enumerate(cfgs):
            flat = by_group[gi]
            idxs = flat.pop("_point_index")
            rows = [r for r in tidy["points"] if r["group"] == gi]
            rows.sort(key=lambda r: list(idxs).index(r["index"]))
            points = [SweepPoint(index=r["index"],
                                 coords=tuple(r["coords"].items()), cfg=cfg)
                      for r in rows]
            groups.append(GroupResult(cfg, points, unflatten_outputs(flat)))
        return cls(tidy["axes"], groups, tidy.get("meta"))


def _jsonify(v: Any) -> Any:
    if isinstance(v, (np.bool_,)):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    return v
