"""Elastic scaling: recover from host loss / gain by re-planning the mesh
and resharding state from the latest checkpoint.

Recovery contract: on failure of any subset of hosts, ``plan(n_alive)``
picks the largest valid (data, model) mesh <= alive capacity, the data
pipeline re-splits shards over survivors (pure function of step -> no data
loss or duplication), and checkpoint.restore(..., shardings=new) reshards
parameters/optimizer state. Tested end-to-end in tests/test_elastic.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax

from repro.distributed import sharding as SH
from repro.train import checkpoint as CKPT


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_hosts: int
    devices_per_host: int
    mesh_shape: Tuple[int, int]          # (data, model)
    shard_map: Dict[int, List[int]]      # host -> data-shard ids

    @property
    def n_devices(self) -> int:
        return self.n_hosts * self.devices_per_host


def plan(n_alive_hosts: int, devices_per_host: int, num_shards: int,
         model_parallel: int = 1) -> ElasticPlan:
    """Largest data-parallel degree that divides the alive device pool."""
    if n_alive_hosts < 1:
        raise ValueError("no hosts alive")
    total = n_alive_hosts * devices_per_host
    if total % model_parallel != 0:
        raise ValueError(f"{total} devices not divisible by mp={model_parallel}")
    data = total // model_parallel
    shard_map: Dict[int, List[int]] = {
        h: [s for s in range(num_shards) if s % n_alive_hosts == h]
        for h in range(n_alive_hosts)}
    return ElasticPlan(n_alive_hosts, devices_per_host, (data, model_parallel),
                       shard_map)


def make_mesh(p: ElasticPlan):
    devs = jax.devices()[: p.n_devices]
    import numpy as np
    arr = np.array(devs).reshape(p.mesh_shape)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


def resume(ckpt_dir: str, target_state, p: ElasticPlan):
    """Restore the latest checkpoint resharded for the new plan's mesh."""
    mesh = make_mesh(p)
    shardings = {
        "params": SH.param_shardings(target_state["params"], mesh),
        "opt": SH.param_shardings(target_state["opt"], mesh),
    }
    state, step, extra = CKPT.restore(ckpt_dir, target_state,
                                      shardings=shardings)
    return state, step, extra, mesh
