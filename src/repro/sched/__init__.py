"""CASH applied to the JAX runtime: credit-aware training-work scheduling,
serving admission, straggler prediction, elastic recovery."""
from repro.sched.elastic import ElasticPlan, plan, resume
from repro.sched.serve_scheduler import CashServeScheduler, Replica, Request, make_replicas
from repro.sched.straggler import StragglerMonitor
from repro.sched.train_scheduler import CashTrainScheduler, TrainHost, make_hosts

__all__ = ["ElasticPlan", "plan", "resume", "CashServeScheduler", "Replica",
           "Request", "make_replicas", "StragglerMonitor",
           "CashTrainScheduler", "TrainHost", "make_hosts"]
