"""CASH admission control for serving — the paper's map/reduce annotation
mapped onto inference work:

  prefill chunks  -> burst-intensive (compute-dense, "map-like")
  decode batches  -> network annotation (light compute, bandwidth-bound,
                     load-balanced across replicas like reduce tasks)

Replicas are nodes with credit state (burstable hosts / thermally throttled
chips modeled as token buckets); Algorithm 1 places prefills on the
credit-richest replicas and spreads decode batches from the credit-poorest
up, keeping burst headroom where the heavy work lands.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.annotations import Annotation, Task
from repro.core.cluster import Node
from repro.core.credits import CloudWatchEmulator, CreditPredictor
from repro.core.scheduler import CashScheduler, StockScheduler
from repro.core.token_bucket import INSTANCE_TYPES, ebs_gp2_bucket, network_dual_bucket


@dataclasses.dataclass
class Request:
    rid: int
    prompt_tokens: int
    max_new_tokens: int
    arrival: float = 0.0
    prefill_done: float = 0.0
    finished: float = 0.0
    replica: Optional[int] = None


@dataclasses.dataclass
class Replica:
    rep_id: int
    node: Node
    queue_depth: int = 0


def make_replicas(n: int, instance_type: str = "t3.2xlarge",
                  slots: int = 4,
                  cpu_initial_fraction: float = 1.0) -> List[Replica]:
    spec = INSTANCE_TYPES[instance_type]
    reps = []
    for i in range(n):
        node = Node(nid=i, spec=spec,
                    cpu=spec.cpu_bucket(initial_fraction=cpu_initial_fraction),
                    disk=ebs_gp2_bucket(200.0),
                    net=network_dual_bucket(),
                    slots=slots)
        reps.append(Replica(rep_id=i, node=node))
    return reps


def admission_order(credits: Sequence[float], *, credit_aware: bool = True,
                    ptr: int = 0) -> List[int]:
    """The replica visit order for admitting queued prefills — the ONE
    contract `core.servesim`, `kernels.serve_admit`, and the numpy
    replay oracle all implement:

      credit-aware (CASH): credit-richest replica first, replica id as
        the tie-break (prefill is the burst; it lands where headroom
        lives — Algorithm 1's sort, collapsed to the serving fleet);
      credit-blind (round-robin): rotation from ``ptr`` — replica
        ``(ptr + i) mod n`` is visited i-th regardless of credit state.

    The engine consumes the queue-rank prefix along this order, filling
    each visited replica's free KV slots before moving on (round-robin
    takes ONE slot per replica per rotation pass)."""
    n = len(credits)
    if credit_aware:
        return sorted(range(n), key=lambda j: (-credits[j], j))
    return [(ptr + i) % n for i in range(n)]


class CashServeScheduler:
    """Route prefill (burst) and decode (network) work by credit state."""

    def __init__(self, replicas: Sequence[Replica], credit_aware: bool = True,
                 actual_period: float = 300.0, usage_period: float = 60.0):
        self.replicas = list(replicas)
        self.credit_aware = credit_aware
        self.watcher = CloudWatchEmulator("cpu", actual_period, usage_period)
        self.predictor = CreditPredictor(self.watcher)
        self.scheduler = CashScheduler() if credit_aware else StockScheduler()
        self._tid = 0

    def observe(self, now: float, usage: Dict[int, float]) -> None:
        self.watcher.observe(now, [r.node for r in self.replicas], usage)

    def admit(self, now: float, prefills: List[Request],
              decode_batches: int) -> Tuple[Dict[int, List[Request]], Dict[int, int]]:
        """Assign pending prefill requests + decode batch slots to replicas.

        Returns (replica -> prefill requests, replica -> #decode batches)."""
        nodes = [r.node for r in self.replicas]
        for n in nodes:
            n.running = []
        credits = self.predictor.update(now, nodes)
        queue: List[Task] = []
        req_by_tid: Dict[int, Request] = {}
        for req in prefills:
            self._tid += 1
            t = Task(tid=self._tid, job=f"req{req.rid}", vertex="prefill",
                     work_cpu=req.prompt_tokens / 1e3, demand_cpu=1.0,
                     annotation=Annotation.BURST_CPU)
            queue.append(t)
            req_by_tid[t.tid] = req
        decode_tids = []
        for _ in range(decode_batches):
            self._tid += 1
            t = Task(tid=self._tid, job="decode", vertex="decode_step",
                     work_net=1.0, demand_net=5e7,
                     annotation=Annotation.NETWORK)
            queue.append(t)
            decode_tids.append(t.tid)
        assignments = self.scheduler.schedule(queue, nodes, credits, now)
        pf: Dict[int, List[Request]] = {r.rep_id: [] for r in self.replicas}
        dc: Dict[int, int] = {r.rep_id: 0 for r in self.replicas}
        for task, node in assignments:
            if task.tid in req_by_tid:
                req_by_tid[task.tid].replica = node.nid
                pf[node.nid].append(req_by_tid[task.tid])
            else:
                dc[node.nid] += 1
        return pf, dc
