"""Straggler detection + proactive mitigation via credit forecasts.

Reactive detectors flag a rank only after it slows down. The CASH insight
gives a *leading* indicator: a host whose token bucket will deplete within
the next rebalance horizon is a straggler-to-be — shrink its shard share
now (paper SS4.1: assigning burst-intensive work to throttled VMs "can
severely affect performance" and heightens "possibility of being deemed
stragglers").

`predictive_blacklist` is the vectorized form of the same contract: the
batched engine calls it per tick (on *estimated* credits — CASH sees
telemetry, not ground truth) to mask predicted-to-throttle nodes out of
placement, and the fault oracle calls it eagerly on the same state, so
the Python `StragglerMonitor` and the in-scan mask must agree
flag-for-flag on identical bucket states (tests/test_straggler.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp

from repro.core.token_bucket import TokenBucket


def time_to_deplete_vec(balance, demand, baseline, burst, unlimited):
    """Vectorized `TokenBucket.time_to_deplete`: seconds until each
    node's bucket empties at current demand (+inf when not draining or
    unlimited). Elementwise float64 — bit-identical whether traced in
    the engine or replayed eagerly by the oracle."""
    rate = jnp.minimum(demand, burst)
    drain = rate - baseline
    inf = jnp.asarray(jnp.inf, dtype=jnp.asarray(balance).dtype)
    return jnp.where((drain <= 0.0) | (unlimited > 0.0), inf,
                     balance / jnp.where(drain > 0.0, drain, 1.0))


def predictive_blacklist(balance, demand, baseline, burst, unlimited,
                         horizon_s: float):
    """Boolean per-node mask: bucket depletes strictly within
    ``horizon_s`` at current demand — `StragglerMonitor.
    predictive_stragglers`, array form. ``horizon_s <= 0`` flags
    nothing."""
    if horizon_s <= 0.0:
        return jnp.zeros(jnp.shape(balance), bool)
    return time_to_deplete_vec(balance, demand, baseline, burst,
                               unlimited) < horizon_s


@dataclasses.dataclass
class HostTiming:
    ema: float = 0.0
    n: int = 0

    def update(self, dt: float, alpha: float = 0.3) -> None:
        self.ema = dt if self.n == 0 else (1 - alpha) * self.ema + alpha * dt
        self.n += 1


class StragglerMonitor:
    def __init__(self, n_hosts: int, slow_factor: float = 1.5,
                 horizon_s: float = 120.0):
        self.timings: Dict[int, HostTiming] = {i: HostTiming() for i in range(n_hosts)}
        self.slow_factor = slow_factor
        self.horizon_s = horizon_s

    def record_step(self, host: int, duration: float) -> None:
        self.timings[host].update(duration)

    def _median_ema(self) -> float:
        vals = sorted(t.ema for t in self.timings.values() if t.n > 0)
        return vals[len(vals) // 2] if vals else 0.0

    def reactive_stragglers(self) -> List[int]:
        med = self._median_ema()
        if med <= 0:
            return []
        return [h for h, t in self.timings.items()
                if t.n > 0 and t.ema > self.slow_factor * med]

    def predictive_stragglers(self, buckets: Dict[int, TokenBucket],
                              demand: Dict[int, float]) -> List[int]:
        """Hosts whose bucket depletes within the horizon at current demand
        — the credit-aware leading indicator."""
        out = []
        for h, b in buckets.items():
            t_dep = b.time_to_deplete(demand.get(h, 0.0))
            if t_dep < self.horizon_s:
                out.append(h)
        return out

    def flagged(self, buckets: Optional[Dict[int, TokenBucket]] = None,
                demand: Optional[Dict[int, float]] = None) -> List[int]:
        flags = set(self.reactive_stragglers())
        if buckets is not None:
            flags.update(self.predictive_stragglers(buckets, demand or {}))
        return sorted(flags)
