"""CASH applied to distributed training (the paper's Algorithm 1 + 2 at the
work-assignment layer of the training fleet).

Hosts (data-parallel ranks) run their input pipelines / checkpoint writes on
variable-service-rate resources (burstable host VMs, throttled disks). The
scheduler:

  * annotates work items exactly like the paper's framework annotation:
    data-shard preprocessing  -> burst-intensive ("map-like": tokenize)
    checkpoint write / upload -> network
    metrics/eval odds-and-ends-> unannotated
  * tracks per-host credit state with the Algorithm-2 predictor
    (actual every ``actual_period``, predicted every ``usage_period``),
  * each rebalance tick runs the three-phase Algorithm-1 pass to assign
    shards, and
  * derives *credit-weighted microbatch splits* — hosts forecast to throttle
    get proportionally fewer rows (unbalanced data parallelism), the
    straggler-avoidance analogue of the paper's placement rule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.annotations import Annotation, Task
from repro.core.cluster import Node
from repro.core.credits import CloudWatchEmulator, CreditPredictor
from repro.core.scheduler import CashScheduler, StockScheduler
from repro.core.token_bucket import INSTANCE_TYPES, ebs_gp2_bucket, network_dual_bucket


@dataclasses.dataclass
class TrainHost:
    host_id: int
    node: Node                      # reuses the core node/slot/bucket model
    assigned_shards: List[int] = dataclasses.field(default_factory=list)
    step_time_ema: float = 0.0


def make_hosts(n_hosts: int, instance_type: str = "t3.2xlarge",
               ebs_size_gb: float = 200.0, slots: int = 4,
               cpu_initial_fraction: float = 0.5) -> List[TrainHost]:
    spec = INSTANCE_TYPES[instance_type]
    hosts = []
    for i in range(n_hosts):
        node = Node(
            nid=i, spec=spec,
            cpu=spec.cpu_bucket(initial_fraction=cpu_initial_fraction),
            disk=ebs_gp2_bucket(ebs_size_gb),
            net=network_dual_bucket(),
            slots=slots,
        )
        hosts.append(TrainHost(host_id=i, node=node))
    return hosts


class CashTrainScheduler:
    """Credit-aware shard + duty assignment across training hosts."""

    def __init__(self, hosts: Sequence[TrainHost], num_shards: int,
                 bottleneck: Annotation = Annotation.BURST_CPU,
                 credit_aware: bool = True,
                 actual_period: float = 300.0, usage_period: float = 60.0):
        self.hosts = list(hosts)
        self.num_shards = num_shards
        self.bottleneck = bottleneck
        self.credit_aware = credit_aware
        resource = "cpu" if bottleneck == Annotation.BURST_CPU else "disk"
        self.watcher = CloudWatchEmulator(resource, actual_period, usage_period)
        self.predictor = CreditPredictor(self.watcher)
        self.scheduler = CashScheduler() if credit_aware else StockScheduler()
        self._tid = 0
        # initial contiguous assignment
        for i, h in enumerate(self.hosts):
            h.assigned_shards = [s for s in range(num_shards)
                                 if s % len(self.hosts) == i]

    def _next_tid(self) -> int:
        self._tid += 1
        return self._tid

    # -------------------------------------------------------------- tick
    def observe(self, now: float, usage_rates: Dict[int, float]) -> None:
        self.watcher.observe(now, [h.node for h in self.hosts], usage_rates)

    def rebalance(self, now: float,
                  checkpoint_duty: bool = False) -> Dict[int, List[int]]:
        """Run one Algorithm-1 pass assigning all shards (+ the checkpoint
        duty) onto host slots; returns host_id -> shard ids."""
        nodes = [h.node for h in self.hosts]
        for n in nodes:
            n.running = []                      # assignment pass, not service
        credits = self.predictor.update(now, nodes)
        queue: List[Task] = []
        for s in range(self.num_shards):
            queue.append(Task(tid=self._next_tid(), job="data", vertex="map",
                              work_cpu=1.0, demand_cpu=0.8,
                              annotation=self.bottleneck))
        shard_tids = {t.tid: s for s, t in enumerate(queue)}
        if checkpoint_duty:
            t = Task(tid=self._next_tid(), job="ckpt", vertex="sync",
                     work_net=1.0, demand_net=1e8,
                     annotation=Annotation.NETWORK)
            queue.append(t)
        assignments = self.scheduler.schedule(queue, nodes, credits, now)
        out: Dict[int, List[int]] = {h.host_id: [] for h in self.hosts}
        for task, node in assignments:
            if task.tid in shard_tids:
                out[node.nid].append(shard_tids[task.tid])
        # any unassigned shards (slots exhausted): round-robin fallback
        assigned = {s for ss in out.values() for s in ss}
        left = [s for s in range(self.num_shards) if s not in assigned]
        for i, s in enumerate(left):
            out[self.hosts[i % len(self.hosts)].host_id].append(s)
        for h in self.hosts:
            h.assigned_shards = out[h.host_id]
        return out

    # --------------------------------------------- microbatch weighting
    def microbatch_weights(self, now: float) -> Dict[int, float]:
        """Per-host relative throughput forecast (normalized to mean 1.0).

        Hosts whose credit forecast implies throttling get weight
        baseline/burst (< 1); the trainer scales their row counts."""
        nodes = [h.node for h in self.hosts]
        credits = self.predictor.update(now, nodes)
        weights = {}
        for h in self.hosts:
            b = h.node.cpu if self.bottleneck == Annotation.BURST_CPU else h.node.disk
            if not self.credit_aware:
                weights[h.host_id] = 1.0
                continue
            throttled = credits.get(h.host_id, 0.0) <= 0.0
            weights[h.host_id] = (b.baseline / b.burst) if throttled else 1.0
        mean = sum(weights.values()) / len(weights)
        return {k: v / mean for k, v in weights.items()}

    def split_rows(self, global_rows: int, now: float) -> Dict[int, int]:
        """Integer row split of the global batch proportional to forecast
        throughput (sums exactly to ``global_rows``)."""
        w = self.microbatch_weights(now)
        total = sum(w.values())
        raw = {k: global_rows * v / total for k, v in w.items()}
        out = {k: int(v) for k, v in raw.items()}
        rem = global_rows - sum(out.values())
        # distribute remainder to the largest fractional parts
        fracs = sorted(raw, key=lambda k: raw[k] - out[k], reverse=True)
        for k in fracs[:rem]:
            out[k] += 1
        return out
