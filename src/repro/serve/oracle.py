"""Pure-Python replay oracle for the vectorized serving fleet.

`ServeFleetOracle` interprets ONE (unstacked) serving-fleet scenario
under the same `ServeSimConfig` the vectorized engine
(`core.servesim._simulate_serve`) compiles, mirroring it tick-for-tick
with plain Python loops over numpy float64 state — and doubling as the
Python-loop baseline the `benchmarks/serve_bench.py` speedup is measured
against:

  * the arrival stream IS the engine's stream — `arrivals.arrival_counts`
    called eagerly, so the per-scenario Poisson draws match
    integer-for-integer;
  * KV-slot accounting runs through REAL `serve.kv_cache.KVCacheManager`
    instances (one per replica): admit takes the lowest free slot,
    release recycles it — the occupancy counts the engine carries are
    exactly ``kv_slots - len(mgr.free_slots())``;
  * the admission visit order comes from `sched.serve_scheduler
    .admission_order` — the ONE contract the engine's packed-cumsum
    placement and the fused kernel's interval assignment implement;
  * token-bucket serve mirrors `kernels.ref` branch-for-branch via the
    scalar `traffic.oracle._serve_bucket`.

Latencies are exact float64 products of tick index and ``dt`` on both
sides and both bucket with `slo.bucket_index`, so under
``jax_enable_x64`` the oracle's counters, histograms, token totals and
percentiles equal the engine's EXACTLY (tests assert equality, not a
tolerance). With ``collect_events=True`` the oracle also emits the
engine's decision-trace stream (`obs.ring.EventCollector`) in the same
canonical per-tick block order.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.servesim import ServeSimConfig
from repro.obs import ring as obsring
from repro.sched.serve_scheduler import admission_order
from repro.serve.kv_cache import KVCacheManager
from repro.traffic import arrivals, slo
from repro.traffic.oracle import _serve_bucket

# far above any prompt: the manager's per-slot length cap never binds in
# the fleet simulation (overflow is a serve.engine concern)
_ORACLE_MAX_LEN = 1 << 20


class ServeFleetOracle:
    """Interpret one serving-fleet scenario; `run()` returns the engine's
    scalar/histogram output keys (plus lat/wait percentiles) as plain
    numpy values, with the decision-trace events on ``self.events`` when
    ``collect_events`` is set."""

    def __init__(self, sc: Dict[str, np.ndarray], cfg: ServeSimConfig,
                 collect_events: bool = False):
        if cfg.scheduler not in ("cash", "rr"):
            raise NotImplementedError(
                f"serving fleet supports cash|rr, got {cfg.scheduler!r}")
        if cfg.traffic not in ("poisson", "diurnal"):
            raise NotImplementedError(
                f"stochastic traffic only, got {cfg.traffic!r}")
        self.sc = {k: np.asarray(v) for k, v in sc.items()}
        self.cfg = cfg
        self.R = len(self.sc["rep_balance0"])
        self.C = (cfg.table_slots if cfg.table_slots > 0
                  else 2 * self.R * cfg.kv_slots)
        self.edges = slo.edges_for(cfg)
        self.counts = np.asarray(arrivals.arrival_counts(cfg, self.sc,
                                                         np.float64))
        self.collector: Optional[obsring.EventCollector] = \
            obsring.EventCollector() if collect_events else None

    @property
    def events(self) -> List[obsring.Event]:
        return self.collector.events if self.collector else []

    # ------------------------------------------------------------------ tick
    def run(self) -> Dict[str, np.ndarray]:
        cfg, sc, R, C = self.cfg, self.sc, self.R, self.C
        dt = cfg.dt
        B = cfg.slo_bins
        cash = cfg.scheduler == "cash"
        col = self.collector

        rq_pre = np.zeros(C)
        rq_dec = np.zeros(C)
        rq_dpre = np.zeros(C)
        rq_ddec = np.zeros(C)
        rq_tmpl = np.full(C, -1, np.int64)
        rq_seq = np.full(C, np.iinfo(np.int32).max, np.int64)
        rq_submit = np.zeros(C)
        rq_start = np.full(C, np.inf)
        rq_rep = np.full(C, -1, np.int64)
        rq_kv = np.full(C, -1, np.int64)       # owning KV slot on its replica

        kv = [KVCacheManager(cfg.kv_slots, _ORACLE_MAX_LEN)
              for _ in range(R)]
        rel_pending: List[int] = []            # table slots finishing last tick
        bal = sc["rep_balance0"].astype(np.float64).copy()
        sur = np.zeros(R)
        baseline = sc["rep_baseline"].astype(np.float64)
        burst = sc["rep_burst"].astype(np.float64)
        capacity = sc["rep_capacity"].astype(np.float64)
        unlimited = sc["rep_unlimited"].astype(np.float64) > 0.0
        tmpl_n = max(int(sc["tmpl_n"]), 1)

        rr_ptr = 0
        n_seen = n_adm = n_done = 0
        lat_hist = np.zeros(B, np.int64)
        wait_hist = np.zeros(B, np.int64)
        lat_sum = wait_sum = 0.0
        lat_max = wait_max = 0.0
        last_rel = -np.inf
        tok_pre = tok_dec = busy_seconds = 0.0

        for t in range(cfg.n_ticks):
            now = float(t) * dt

            # 1) release finished requests: SLO buckets + KV-slot recycle
            fin_prev = sorted(rel_pending)
            for i in fin_prev:
                lat = now - rq_submit[i]
                wait = rq_start[i] - rq_submit[i]
                if col and lat >= self.edges[-1]:
                    col.emit(t, obsring.EV_SLO_OVER, i, -1, -1, lat)
                lat_hist[slo.bucket_index(lat, self.edges)] += 1
                wait_hist[slo.bucket_index(wait, self.edges)] += 1
                lat_sum += lat
                wait_sum += wait
                lat_max = max(lat_max, lat)
                wait_max = max(wait_max, wait)
            if col:
                for i in fin_prev:
                    col.emit(t, obsring.EV_RELEASE, i, int(rq_rep[i]), -1,
                             now - rq_submit[i])
            for i in fin_prev:
                kv[rq_rep[i]].release(int(rq_kv[i]))
                rq_tmpl[i] = -1
                rq_rep[i] = -1
                rq_kv[i] = -1
                rq_seq[i] = np.iinfo(np.int32).max
            if fin_prev:
                n_done += len(fin_prev)
                last_rel = now
            rel_pending = []

            # 2) arrivals into free table slots, lowest index first
            k = int(self.counts[t])
            free_slots = np.flatnonzero(rq_tmpl < 0)
            admitted = free_slots[:k]
            for r, i in enumerate(admitted):
                aidx = n_seen + r
                row = aidx % tmpl_n
                rq_pre[i] = float(sc["tmpl_pre"][row])
                rq_dec[i] = float(sc["tmpl_dec"][row])
                rq_dpre[i] = float(sc["tmpl_dpre"][row])
                rq_ddec[i] = float(sc["tmpl_ddec"][row])
                rq_tmpl[i] = row
                rq_submit[i] = now
                rq_seq[i] = aidx
            n_seen += k
            n_adm += len(admitted)
            if col and k > len(admitted):
                col.emit(t, obsring.EV_DROP, -1, k - len(admitted), -1, 0.0)

            # 3) admission: FIFO queue onto replicas with free KV slots,
            #    visited in the admission_order contract
            bal0 = bal.copy()
            pending = (rq_tmpl >= 0) & (rq_rep < 0)
            q = np.flatnonzero(pending)
            queue = list(q[np.argsort(rq_seq[q], kind="stable")])
            free = [len(kv[n].free_slots()) for n in range(R)]
            n_placed = min(len(queue), sum(free))
            order = admission_order(bal0, credit_aware=cash, ptr=rr_ptr)
            placed_now: List[int] = []

            def place(i: int, n: int) -> None:
                rq_rep[i] = n
                rq_kv[i] = kv[n].admit(int(rq_seq[i]),
                                       int(min(rq_pre[i],
                                               _ORACLE_MAX_LEN - 1)))
                rq_start[i] = now
                free[n] -= 1
                placed_now.append(i)

            if cash:
                for n in order:
                    while free[n] > 0 and queue:
                        place(queue.pop(0), n)
            else:    # round-robin: ONE KV slot per replica per pass
                progress = True
                while queue and progress:
                    progress = False
                    for n in order:
                        if free[n] > 0 and queue:
                            place(queue.pop(0), n)
                            progress = True
            rr_ptr = (rr_ptr + n_placed) % R
            if col:
                desc_pos = {n: r for r, n in enumerate(
                    admission_order(bal0, credit_aware=True))}
                for i in sorted(placed_now):
                    n = int(rq_rep[i])
                    if cash:
                        col.emit(t, obsring.EV_PLACE, i, n, desc_pos[n],
                                 bal0[n])
                    else:
                        col.emit(t, obsring.EV_PLACE, i, n, n, 0.0)

            # 4) serve: phase demand, bucket throttle, pro-rata distribute
            running = rq_rep >= 0
            # phase thresholds + balance snap mirror kernels.serve_admit:
            # sub-1e-9 residue means the phase is over, and balances live
            # on the 2^-10 grid so FMA-vs-two-roundings ulps between the
            # engine's fused arithmetic and this loop cannot reorder the
            # cash admission sort
            in_pre = rq_pre > 1e-9
            live = running & (in_pre | (rq_dec > 1e-9))
            dem_i = np.where(in_pre, rq_dpre, rq_ddec)
            dem_node = np.zeros(R)
            for i in np.flatnonzero(live):
                dem_node[rq_rep[i]] += dem_i[i]
            w_node = np.zeros(R)
            for n in range(R):
                w, nb, over = _serve_bucket(
                    bal[n], dem_node[n], baseline[n], burst[n],
                    capacity[n], unlimited[n], dt)
                bal[n] = np.round(nb * 1024.0) / 1024.0
                w_node[n] = w
                sur[n] += over
            for i in np.flatnonzero(live):
                n = rq_rep[i]
                share = (w_node[n] * dem_i[i] / dem_node[n]
                         if dem_node[n] > 0.0 else 0.0)
                if in_pre[i]:
                    inc = min(share, rq_pre[i])
                    rq_pre[i] -= inc
                    tok_pre += inc
                else:
                    inc = min(share, rq_dec[i])
                    rq_dec[i] -= inc
                    tok_dec += inc
            for i in np.flatnonzero(running):
                if rq_pre[i] <= 1e-9 and rq_dec[i] <= 1e-9:
                    rel_pending.append(i)
            occ = [cfg.kv_slots - len(kv[n].free_slots()) for n in range(R)]
            busy_seconds += float(sum(1 for o in occ if o > 0)) * dt
            if col:
                for n in range(R):
                    if bal0[n] > 1e-9 and bal[n] <= 1e-9:
                        col.emit(t, obsring.EV_DEPLETE, n, -1, -1, bal[n])
                for n in range(R):
                    if bal0[n] <= 1e-9 and bal[n] > 1e-9:
                        col.emit(t, obsring.EV_REGEN, n, -1, -1, bal[n])

        all_done = n_done == n_adm
        makespan = ((last_rel if n_done > 0 else 0.0) if all_done
                    else cfg.n_ticks * dt)
        out = {
            "makespan": makespan, "all_done": all_done,
            "surplus_credits": float(np.sum(sur)),
            "node_busy_seconds": busy_seconds,
            "n_arrived": n_seen, "n_admitted": n_adm,
            "n_dropped": n_seen - n_adm, "n_completed": n_done,
            "lat_hist": lat_hist, "wait_hist": wait_hist,
            "lat_sum": lat_sum, "wait_sum": wait_sum,
            "lat_max": lat_max, "wait_max": wait_max,
            "last_finish": last_rel,
            "tokens_prefilled": tok_pre, "tokens_decoded": tok_dec,
        }
        for pfx in ("lat", "wait"):
            for q_, tag in slo.DEFAULT_QS:
                out[f"{pfx}_{tag}"] = float(slo.hist_percentile(
                    out[f"{pfx}_hist"], self.edges, q_))
        return out
