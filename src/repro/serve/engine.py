"""Continuous-batching serving engine.

A fixed pool of KV slots; each engine step decodes one token for every live
request, admits pending requests into free slots (prefill), and retires
finished ones. Admission order across replicas is CASH's job
(repro.sched.serve_scheduler) — this engine is the per-replica executor.

Prefill here uses the decode path token-by-token for small models (exact,
simple); ``prefill_chunk`` switches to chunked forward prefill when the
model/file sizes warrant it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MD
from repro.serve.kv_cache import KVCacheManager
from repro.serve.sampler import SamplerConfig, sample


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    output: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    t_arrive: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, n_slots: int = 8,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 sampler: SamplerConfig = SamplerConfig(),
                 impl: str = "auto", dtype: Any = jnp.float32):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                "Engine's token-feed prefill is exact only for attention "
                f"families (recurrent state can't rewind); got {cfg.family}")
        self.cfg = cfg
        self.params = params
        self.kv = KVCacheManager(n_slots, max_len)
        self.sampler = sampler
        self.eos_id = eos_id
        self.impl = impl
        self.cache = MD.init_decode_cache(cfg, n_slots, max_len, dtype)
        self._step = jax.jit(
            lambda p, c, t: MD.decode_step(cfg, p, c, t, impl=impl))
        self.pending: List[ServeRequest] = []
        self.live: Dict[int, ServeRequest] = {}   # slot -> request
        self.finished: List[ServeRequest] = []
        self.key = jax.random.PRNGKey(0)
        self.steps = 0

    # ------------------------------------------------------------- intake
    def submit(self, req: ServeRequest) -> None:
        req.t_arrive = time.time()
        self.pending.append(req)

    def _sync_lengths(self) -> None:
        lengths = np.zeros((self.kv.n_slots,), np.int32)
        for slot, info in enumerate(self.kv.slots):
            lengths[slot] = info.length
        self.cache["lengths"] = jnp.asarray(lengths)

    def _admit(self) -> None:
        while self.pending and self.kv.free_slots():
            req = self.pending.pop(0)
            slot = self.kv.admit(req.rid, 0)
            req.slot = slot
            self.live[slot] = req
            # prefill: feed all prompt tokens but the last through the decode
            # path; the last is fed by the first step() so its logits give
            # the first generated token
            for tok in req.prompt[:-1]:
                self._feed_single(slot, tok)

    def _feed_single(self, slot: int, tok: int) -> None:
        # batch a single-slot token feed: other slots feed a dummy but their
        # cache is masked by lengths (only `slot` advances)
        tokens = np.zeros((self.kv.n_slots,), np.int32)
        tokens[slot] = tok
        lengths_before = list(self.kv.lengths())
        self._sync_lengths()
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens))
        # revert the length bump for every slot except `slot`
        for s2 in range(self.kv.n_slots):
            if s2 == slot:
                self.kv.slots[s2].length = lengths_before[s2] + 1 \
                    if not self.kv.slots[s2].free else 0
            else:
                if not self.kv.slots[s2].free:
                    self.kv.slots[s2].length = lengths_before[s2]
        self._sync_lengths()

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """One engine iteration; returns number of live requests served."""
        self._admit()
        if not self.live:
            return 0
        tokens = np.zeros((self.kv.n_slots,), np.int32)
        for slot, req in self.live.items():
            tokens[slot] = (req.output[-1] if req.output
                            else (req.prompt[-1] if req.prompt else 0))
        self._sync_lengths()
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens))
        self.key, sub = jax.random.split(self.key)
        next_tokens = np.asarray(sample(logits, sub, self.sampler))
        served = 0
        for slot, req in list(self.live.items()):
            tok = int(next_tokens[slot])
            if not req.output:
                req.t_first_token = time.time()
            req.output.append(tok)
            self.kv.append_token(slot)
            served += 1
            if req.done or (self.eos_id is not None and tok == self.eos_id):
                req.t_done = time.time()
                self.finished.append(req)
                self.kv.release(slot)
                del self.live[slot]
        self.steps += 1
        return served

    def run_until_done(self, max_steps: int = 10_000) -> List[ServeRequest]:
        for _ in range(max_steps):
            if not self.pending and not self.live:
                break
            self.step()
        return self.finished
