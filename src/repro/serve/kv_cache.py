"""Block (paged) KV-cache manager for continuous batching.

Host-side block table (vLLM-style): the device cache is the dense stacked
(L, B_slots, Hkv, S_max, hd) tensor from models.init_decode_cache; this
manager tracks slot allocation, per-slot lengths and block accounting so
the engine can admit/evict requests without device reallocation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

BLOCK_TOKENS = 128


@dataclasses.dataclass
class SlotInfo:
    rid: Optional[int] = None           # owning request
    length: int = 0

    @property
    def free(self) -> bool:
        return self.rid is None

    def blocks(self) -> int:
        return -(-max(self.length, 1) // BLOCK_TOKENS)


class KVCacheManager:
    def __init__(self, n_slots: int, max_len: int,
                 block_tokens: int = BLOCK_TOKENS):
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_tokens = block_tokens
        self.slots: List[SlotInfo] = [SlotInfo() for _ in range(n_slots)]
        self.total_blocks = n_slots * (max_len // block_tokens)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def used_blocks(self) -> int:
        return sum(s.blocks() for s in self.slots if not s.free)

    def can_admit(self, prompt_len: int) -> bool:
        return bool(self.free_slots()) and prompt_len < self.max_len

    def admit(self, rid: int, prompt_len: int) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free KV slots")
        slot = free[0]
        self.slots[slot] = SlotInfo(rid=rid, length=prompt_len)
        return slot

    def append_token(self, slot: int) -> None:
        s = self.slots[slot]
        if s.free:
            raise RuntimeError(f"slot {slot} not allocated")
        if s.length + 1 >= self.max_len:
            raise RuntimeError("KV slot overflow")
        s.length += 1

    def release(self, slot: int) -> None:
        self.slots[slot] = SlotInfo()

    def lengths(self) -> List[int]:
        return [s.length for s in self.slots]

    def active(self) -> Dict[int, int]:
        """rid -> slot for live requests."""
        return {s.rid: i for i, s in enumerate(self.slots) if not s.free}
