from repro.serve.engine import Engine, ServeRequest
from repro.serve.kv_cache import KVCacheManager
from repro.serve.sampler import SamplerConfig, sample

__all__ = ["Engine", "ServeRequest", "KVCacheManager", "SamplerConfig", "sample"]
