"""repro.obs: end-to-end observability for the vectorized engine.

Submodules:

  ring      device-side event ring carried through the jitted scan
            (structured placement/blacklist/preempt/SLO events) + the
            numpy decode and the replay oracle's `EventCollector`
  registry  declared names/units/schemas for every streamed metric
            (`sweep.results` derives its table columns from it)
  spans     host-side structured spans for the sweep runner
  trace     trace sink: decode rings, bundle traces, export
            Chrome/Perfetto `trace_event` JSON + JSONL
  oracle    numpy replay -> decision-event stream (explainer backend)
  explain   ``python -m repro.obs.explain`` decision explainer

``trace``/``oracle``/``explain`` import the engine, and the engine
imports ``obs.ring`` back — those three load lazily so the package
never cycles.
"""
from repro.obs import registry, ring, spans  # noqa: F401

_LAZY = ("trace", "oracle", "explain")


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
