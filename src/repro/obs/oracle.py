"""Replay front-end for the decision trace: run the numpy fault oracle
(repro.faults.oracle) over one scenario with an `EventCollector`
attached, yielding the decision-event stream the engine's ring records —
plus optional pre-placement state snapshots at requested ticks, which is
what ``python -m repro.obs.explain`` narrates from.

The heavy lifting (mirrored tick math, emit points) lives in the fault
oracles themselves; this module only routes a scenario to the right one
(closed vs open-loop) and packages the results.
"""
from __future__ import annotations

from typing import Dict, Iterable, Tuple

import numpy as np

from repro.core.vecsim import VecSimConfig
from repro.obs.ring import Event, EventCollector


def replay_events(sc: Dict[str, np.ndarray], cfg: VecSimConfig,
                  snap_ticks: Iterable[int] = ()
                  ) -> Tuple[list, Dict[int, dict], dict]:
    """Replay one (unstacked) scenario eagerly, collecting the decision
    events the engine's ring would record.

    Returns ``(events, snaps, outputs)``: the chronological `Event`
    list, ``{tick: snapshot}`` pre-placement state snapshots for every
    requested tick (est / free / blacklist / queue contents — see
    `faults.oracle`), and the oracle's scalar output dict.
    """
    from repro.faults.oracle import ClosedFaultOracle, FaultTrafficOracle

    col = EventCollector()
    snaps = frozenset(int(t) for t in snap_ticks)
    cls = FaultTrafficOracle if cfg.traffic != "none" else ClosedFaultOracle
    oracle = cls(sc, cfg, trace=col, snap_ticks=snaps)
    out = oracle.run()
    return col.events, oracle.snaps, out
