"""``python -m repro.obs.explain <trace.npz> --tick K [--task T]``: the
decision explainer.

Loads a `repro.obs.trace.save_trace` bundle, replays the scenario
against the numpy oracle (`repro.obs.oracle.replay_events`) with a
pre-placement snapshot at the requested tick, verifies the recorded
ring agrees with the replay event-for-event, and narrates WHY the
engine decided what it decided at that tick: which nodes were free,
their estimated credits and rank, who was blacklisted (and the
predicted time-to-deplete that triggered it), what the queues held,
and — for ``--task`` — where the task went and why.

Exit status: 0 when the ring and the replay agree, 2 on any mismatch
(a real engine/oracle divergence — file a bug), 1 for usage errors
(tick out of range, tick's events already overwritten in the ring).
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence

import numpy as np

from repro.obs import oracle as _oracle
from repro.obs import trace as _trace
from repro.obs.ring import (
    EV_BLACKLIST,
    EV_PLACE,
    Event,
    assert_event_parity,
)


def _fmt_val(v: float) -> str:
    if not np.isfinite(v):
        return repr(float(v))
    return f"{v:.6g}"


def _narrate_tick(events: Sequence[Event], tick: int,
                  task: Optional[int]) -> List[str]:
    lines = []
    at_tick = [e for e in events if e.tick == tick]
    if not at_tick:
        lines.append(f"tick {tick}: no events recorded")
        return lines
    lines.append(f"tick {tick}: {len(at_tick)} event(s)")
    for e in at_tick:
        if task is not None and e.kind not in (EV_BLACKLIST,) \
                and e.subject != task:
            continue
        if e.kind == EV_PLACE:
            lines.append(
                f"  place: task/slot {e.subject} -> node {e.aux} "
                f"(credit rank {e.rank}, est {_fmt_val(e.value)})")
        elif e.kind == EV_BLACKLIST:
            why = "preemption notice" if e.aux else \
                f"time-to-deplete {_fmt_val(e.value)} s under horizon"
            lines.append(f"  blacklist: node {e.subject} ({why})")
        else:
            lines.append(
                f"  {e.kind_name}: subject {e.subject} aux {e.aux} "
                f"rank {e.rank} value {_fmt_val(e.value)}")
    return lines


def _narrate_snapshot(snap: dict, task: Optional[int]) -> List[str]:
    lines = ["pre-placement state:"]
    est = snap.get("est")
    free = np.asarray(snap["free"])
    black = np.asarray(snap["black"])
    tdep = np.asarray(snap["tdep"])
    order = snap["order"]
    if est is not None:
        est = np.asarray(est)
        lines.append("  node  est-credits  free-slots  blacklisted")
        for n in range(len(free)):
            b = ""
            if black[n]:
                b = (f"YES (tdep {_fmt_val(float(tdep[n]))} s)"
                     if np.isfinite(tdep[n]) else "YES (notice)")
            lines.append(f"  {n:4d}  {est[n]:11.4f}  {int(free[n]):10d}"
                         f"  {b}")
        lines.append(f"  placement order (desc est, id ties): {order}")
    else:
        lines.append("  node  free-slots")
        for n in range(len(free)):
            lines.append(f"  {n:4d}  {int(free[n]):10d}")
    for i, q in enumerate(snap["queues"]):
        qi = [int(x) for x in q]
        shown = qi if len(qi) <= 16 else qi[:16] + ["..."]
        lines.append(f"  queue[{i}] ({len(q)} waiting): {shown}")
    if task is not None:
        where = [i for i, q in enumerate(snap["queues"]) if task in q]
        if where:
            i = where[0]
            lines.append(f"  task {task}: rank "
                         f"{snap['queues'][i].index(task)} in queue[{i}]")
    return lines


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.explain",
        description="Replay a saved trace against the numpy oracle and "
                    "explain one tick's scheduling decisions.")
    p.add_argument("trace", help="bundle written by repro.obs.trace"
                                 ".save_trace (.npz)")
    p.add_argument("--tick", type=int, required=True,
                   help="tick to explain")
    p.add_argument("--task", type=int, default=None,
                   help="focus on one task/slot's events")
    args = p.parse_args(argv)

    # the replay mirrors the engine's float64 math (the fault-event
    # streams regenerate through jax) — force x64 before any tracing
    import jax
    jax.config.update("jax_enable_x64", True)

    cfg, sc, engine_events, head = _trace.load_trace(args.trace)
    if not (0 <= args.tick < cfg.n_ticks):
        print(f"error: --tick {args.tick} outside [0, {cfg.n_ticks})",
              file=sys.stderr)
        return 1

    events, snaps, _ = _oracle.replay_events(sc, cfg,
                                             snap_ticks=(args.tick,))
    try:
        assert_event_parity(engine_events, events, total=head)
    except AssertionError as e:
        print(f"RING/REPLAY MISMATCH: {e}", file=sys.stderr)
        return 2
    print(f"ring/replay agreement: {head} event(s), "
          f"{len(engine_events)} retained — OK")

    # the ring keeps the LAST `len(engine_events)` events; refuse to
    # "explain" a tick whose records were overwritten
    oldest = head - len(engine_events)
    tick_seqs = [e.seq for e in events if e.tick == args.tick]
    if tick_seqs and tick_seqs[0] < oldest:
        print(f"error: tick {args.tick}'s events (seq {tick_seqs[0]}..) "
              f"were overwritten in the ring (oldest retained seq "
              f"{oldest}); re-run with a larger trace_slots",
              file=sys.stderr)
        return 1

    for line in _narrate_tick(events, args.tick, args.task):
        print(line)
    snap = snaps.get(args.tick)
    if snap is not None:
        for line in _narrate_snapshot(snap, args.task):
            print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
