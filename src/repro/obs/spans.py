"""Host-side structured spans for the sweep runner.

`SpanTracer` is the orchestration half of the observability story: the
`WorkQueue` and `run_sweep` (sweep/runner.py) emit spans/instants for
claim, lease renewal, stale-lease steal, retry, quarantine, and chunk
writes, so device events (the in-scan ring) and host orchestration land
on ONE Perfetto timeline (`obs.trace.export_perfetto`).

Thread-safe by construction — the pipelined runner's background
`_ChunkWriter` thread and the heartbeat thread both emit — and cheap
when absent: every call site guards on ``tracer is not None``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

# span taxonomy (DESIGN.md "Observability"): durations vs point events
SPAN_NAMES = ("claim", "chunk-load", "chunk-compute", "chunk-write",
              "retry-backoff")
INSTANT_NAMES = ("claim-miss", "lease-renew", "lease-steal", "retry",
                 "quarantine", "resume-hit")


@dataclasses.dataclass(frozen=True)
class Span:
    """One runner event: a duration (``ph="X"``) when ``dur`` is set, a
    point instant (``ph="i"``) otherwise. ``t0``/``dur`` are seconds
    relative to the tracer's epoch."""
    name: str
    t0: float
    dur: Optional[float]
    thread: str
    args: Dict[str, Any]


class SpanTracer:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self.spans: List[Span] = []

    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    def instant(self, name: str, **args: Any) -> None:
        s = Span(name, self._now(), None, threading.current_thread().name,
                 args)
        with self._lock:
            self.spans.append(s)

    @contextlib.contextmanager
    def span(self, name: str, **args: Any):
        t0 = self._now()
        try:
            yield
        finally:
            s = Span(name, t0, self._now() - t0,
                     threading.current_thread().name, args)
            with self._lock:
                self.spans.append(s)

    def snapshot(self) -> List[Span]:
        with self._lock:
            return list(self.spans)
