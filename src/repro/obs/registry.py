"""Metrics registry: declared names/units/schemas for every metric the
vectorized engine streams.

The engine's output dict grew organically (~20 ad-hoc keys across the
closed, traffic, and fault paths); this module is the single source of
truth for what each is called, what unit it carries, its dtype kind, and
which scope it lives in:

  scalar    one value per scenario, assembled into the flat metric table
            (`sweep.results.SCALAR_OUTPUTS` is derived from this order)
  aux       per-scenario scalar NOT surfaced in the table (summation
            inputs the percentile reduction consumes)
  array     per-scenario dense array (per-task timestamps, histograms,
            the decision-trace ring)
  timeline  sampled per-tick series under the nested ``timeline`` dict
  group     group-level axis, no leading scenario axis (timeline_t,
            slo_edges — the `results.GROUP_LEVEL_OUTPUTS` set)

`validate_outputs` walks one group's output dict and raises on any
undeclared key or dtype-kind mismatch — `SweepResult.to_tidy` calls it
at persist time and benchmarks/sweep_smoke.py asserts it directly, so a
new engine output cannot ship without a declared name/unit/schema.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np

# dtype kinds: "f" float, "i" integer, "b" boolean
_KIND_OK = {"f": ("f",), "i": ("i", "u"), "b": ("b",)}


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    name: str
    unit: str
    kind: str          # f | i | b
    scope: str         # scalar | aux | array | timeline | group
    description: str


def _m(name, unit, kind, scope, description) -> MetricSpec:
    return MetricSpec(name, unit, kind, scope, description)


METRICS: Tuple[MetricSpec, ...] = (
    # ---- scalar table (declaration order IS the table column order) ----
    _m("makespan", "s", "f", "scalar",
       "time of the last release; horizon when not drained"),
    _m("all_done", "bool", "b", "scalar",
       "every (non-shed) job released by the horizon"),
    _m("surplus_credits", "credits", "f", "scalar",
       "fleet-total surplus (unlimited overdraft) credits"),
    _m("total_cpu_work", "vcpu-s", "f", "scalar",
       "cpu work applied to job progress"),
    _m("cpu_work_served", "vcpu-s", "f", "scalar",
       "cpu work the buckets served (incl. later-lost work)"),
    _m("node_busy_seconds", "node-s", "f", "scalar",
       "seconds with at least one resident task, summed over nodes"),
    _m("n_arrived", "jobs", "i", "scalar", "open-loop arrivals seen"),
    _m("n_admitted", "jobs", "i", "scalar", "arrivals admitted to the table"),
    _m("n_dropped", "jobs", "i", "scalar", "arrivals shed to a full table"),
    _m("n_completed", "jobs", "i", "scalar", "jobs released by the horizon"),
    _m("lat_p50", "s", "f", "scalar", "completion latency p50 (upper-edge)"),
    _m("lat_p95", "s", "f", "scalar", "completion latency p95"),
    _m("lat_p99", "s", "f", "scalar", "completion latency p99"),
    _m("lat_mean", "s", "f", "scalar", "completion latency mean"),
    _m("lat_max", "s", "f", "scalar", "completion latency max"),
    _m("wait_p50", "s", "f", "scalar", "queue wait p50 (upper-edge)"),
    _m("wait_p95", "s", "f", "scalar", "queue wait p95"),
    _m("wait_p99", "s", "f", "scalar", "queue wait p99"),
    _m("wait_mean", "s", "f", "scalar", "queue wait mean"),
    _m("wait_max", "s", "f", "scalar", "queue wait max"),
    _m("last_finish", "s", "f", "scalar", "time of the last release"),
    _m("tokens_prefilled", "tokens", "f", "scalar",
       "prefill tokens applied across the serving fleet (core.servesim)"),
    _m("tokens_decoded", "tokens", "f", "scalar",
       "decode tokens applied across the serving fleet (core.servesim)"),
    _m("n_preempted", "events", "i", "scalar",
       "task-preemption events (node deaths hitting residents)"),
    _m("n_reexec", "events", "i", "scalar", "requeues after preemption"),
    _m("n_shed", "jobs", "i", "scalar", "tasks shed past max_retries"),
    _m("work_lost", "vcpu-s", "f", "scalar",
       "partial progress discarded by preemptions"),
    _m("goodput", "vcpu-s", "f", "scalar", "work applied minus work lost"),
    _m("n_kill_events", "events", "i", "scalar", "node-death edges"),
    _m("node_down_ticks", "node-ticks", "i", "scalar",
       "node-ticks spent dead"),
    # ---- aux per-scenario scalars (feed the percentile reduction) ------
    _m("lat_sum", "s", "f", "aux", "sum of completion latencies"),
    _m("wait_sum", "s", "f", "aux", "sum of queue waits"),
    # ---- per-scenario arrays -------------------------------------------
    _m("job_completion", "s", "f", "array", "per-job completion time"),
    _m("job_mask", "bool", "b", "array", "per-job slot validity"),
    _m("start", "s", "f", "array", "per-task first placement time"),
    _m("finish", "s", "f", "array", "per-task release time"),
    _m("lat_hist", "jobs", "i", "array", "completion-latency histogram"),
    _m("wait_hist", "jobs", "i", "array", "queue-wait histogram"),
    _m("trace_ev_i", "-", "i", "array",
       "decision-trace ring, int32 columns (tick/kind/subject/aux/rank)"),
    _m("trace_ev_f", "-", "f", "array",
       "decision-trace ring, per-event float32 value"),
    _m("trace_head", "events", "i", "array",
       "decision-trace total events recorded"),
    # ---- sampled timeline series ---------------------------------------
    _m("cpu_util", "fraction", "f", "timeline",
       "served cpu rate over fleet vcpus"),
    _m("cpu_credit_mean", "credits", "f", "timeline",
       "mean effective cpu-bucket balance (surplus counts negative)"),
    _m("cpu_credit_std", "credits", "f", "timeline",
       "std of effective cpu-bucket balance"),
    _m("disk_credit_mean", "credits", "f", "timeline",
       "mean disk-bucket balance"),
    _m("disk_credit_std", "credits", "f", "timeline",
       "std of disk-bucket balance"),
    _m("iops", "iops", "f", "timeline", "served disk rate per node"),
    _m("queue_depth", "tasks", "i", "timeline",
       "ready tasks left unplaced this tick"),
    _m("occupancy", "slots", "i", "timeline", "occupied table slots"),
    _m("completed_cum", "jobs", "i", "timeline", "cumulative completions"),
    _m("dropped_cum", "jobs", "i", "timeline", "cumulative drops"),
    _m("surplus_cum", "credits", "f", "timeline",
       "cumulative fleet surplus (billing-window input)"),
    # ---- group-level axes ----------------------------------------------
    _m("timeline_t", "s", "f", "group", "timeline sample times"),
    _m("slo_edges", "s", "f", "group", "SLO histogram bin edges"),
)

BY_NAME: Dict[str, MetricSpec] = {m.name: m for m in METRICS}


def scalar_names() -> Tuple[str, ...]:
    """The flat metric-table columns, in declaration order — the value of
    `sweep.results.SCALAR_OUTPUTS`."""
    return tuple(m.name for m in METRICS if m.scope == "scalar")


def spec(name: str) -> MetricSpec:
    return BY_NAME[name]


def _check_kind(name: str, value: Any) -> None:
    kind = np.asarray(value).dtype.kind
    want = BY_NAME[name].kind
    if kind not in _KIND_OK[want]:
        raise ValueError(
            f"metric {name!r}: dtype kind {kind!r} does not match the "
            f"registered kind {want!r} ({BY_NAME[name].unit})")


def validate_outputs(outputs: Dict[str, Any]) -> None:
    """Validate one group's engine output dict against the registry:
    every key must be declared (the nested ``timeline`` dict against the
    timeline scope) with a matching dtype kind. Raises ValueError naming
    the first offender."""
    for k, v in outputs.items():
        if k == "timeline":
            if not isinstance(v, dict):
                raise ValueError("'timeline' must be a nested dict")
            for tk, tv in v.items():
                m = BY_NAME.get(tk)
                if m is None or m.scope != "timeline":
                    raise ValueError(
                        f"undeclared timeline metric {tk!r}: add a "
                        "MetricSpec to repro.obs.registry.METRICS")
                _check_kind(tk, tv)
            continue
        m = BY_NAME.get(k)
        if m is None or m.scope == "timeline":
            raise ValueError(
                f"undeclared engine output {k!r}: add a MetricSpec to "
                "repro.obs.registry.METRICS")
        _check_kind(k, v)
