"""Device-side event ring: the in-scan decision trace.

The engine (core.vecsim) records structured per-tick events — placement
decisions with the credit rank that won them, CASH blacklist triggers
with the predicted time-to-deplete, preemption/requeue/shed, SLO-bucket
overflow, token-bucket depletion/regeneration crossings — into a
fixed-capacity ring carried through the jitted `lax.scan`:

    ev_i : (S, 5) int32    columns (tick, kind, subject, aux, rank)
    ev_f : (S,)   float32  one value per event (latency, tdep, est, bal)
    head : ()     int32    total events EVER recorded (not a slot index)

Overwrite-oldest semantics: event number ``g`` (0-based, global) lives at
slot ``g % S``; once ``head > S`` the ring retains exactly the last ``S``
events. Recording is one masked scatter per tick: candidate event rows
are concatenated in a canonical per-tick block order (the tick's phase
order — see EVENT_ORDER), invalid rows get the out-of-range index ``S``
and are dropped by ``mode="drop"``. Index uniqueness — and therefore
scatter determinism — needs ``S >= (rows per tick)``; the engine sizes
the ring ``max(cfg.trace_slots, per-tick block width)`` and
`record_blocks` asserts it.

When ``cfg.trace_slots == 0`` none of this exists: the scan carries zero
trace state and compiles to the identical program (the faults/traffic
zero-overhead contract, asserted by tests/test_obs.py).

The numpy side of the same schema lives here too: `decode` rotates a
finished ring back into chronological `Event` records, and
`EventCollector` is the replay oracle's appender (repro.faults.oracle
emits the SAME tuples at the mirrored tick points, so engine rings and
oracle replays compare exactly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

# event kinds, in canonical per-tick block order (= the tick phase order:
# release -> fault step -> arrivals -> placement -> serve). Within one
# block, events are ordered by array index (slot/task/node ascending).
EV_SLO_OVER = 1      # release: latency beyond the top histogram edge
EV_PREEMPT = 2       # fault step: resident task hit by a node death
EV_SHED = 3          # fault step: hit task past max_retries leaves
EV_DROP = 4          # arrivals: admissions lost to a full table (1 row)
EV_BLACKLIST = 5     # placement: CASH blacklist applied to a node
EV_PLACE = 6         # placement: task/slot assigned to a node
EV_DEPLETE = 7       # serve: node bucket crossed to empty
EV_REGEN = 8         # serve: node bucket crossed back above empty
EV_RELEASE = 9       # release: finished request frees its KV slot
                     # (serving fleet, core.servesim; ordered with
                     # EV_SLO_OVER in the release block)

EVENT_ORDER = (EV_SLO_OVER, EV_RELEASE, EV_PREEMPT, EV_SHED, EV_DROP,
               EV_BLACKLIST, EV_PLACE, EV_DEPLETE, EV_REGEN)

KIND_NAMES = {
    EV_SLO_OVER: "slo_overflow",
    EV_RELEASE: "release",
    EV_PREEMPT: "preempt",
    EV_SHED: "shed",
    EV_DROP: "drop",
    EV_BLACKLIST: "blacklist",
    EV_PLACE: "place",
    EV_DEPLETE: "deplete",
    EV_REGEN: "regen",
}

# int32 ring columns, in storage order
I_FIELDS = ("tick", "kind", "subject", "aux", "rank")


@dataclasses.dataclass(frozen=True)
class Event:
    """One decoded ring row. Field meaning by kind:

    ============ ========== ============== ============ ================
    kind         subject    aux            rank         value
    ============ ========== ============== ============ ================
    slo_overflow slot       -1             -1           latency (s)
    release      slot       replica        -1           latency (s)
    preempt      task/slot  node (before)  retry count  work lost
    shed         task/slot  node (before)  retry count  0
    drop         -1         dropped count  -1           0
    blacklist    node       notice flag    -1           time-to-deplete
    place        task/slot  node assigned  credit rank  est credits
    deplete      node       -1             -1           new balance
    regen        node       -1             -1           new balance
    ============ ========== ============== ============ ================

    ``seq`` is the global event number (monotone across the run); for
    ``place`` under the stock scheduler ``rank`` is the node id (stock
    never consults credits) and ``value`` is 0.
    """
    seq: int
    tick: int
    kind: int
    subject: int
    aux: int
    rank: int
    value: float

    @property
    def kind_name(self) -> str:
        return KIND_NAMES.get(self.kind, f"kind{self.kind}")

    def key(self) -> Tuple[int, int, int, int, int]:
        return (self.tick, self.kind, self.subject, self.aux, self.rank)


def ring_init(slots: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fresh carried ring state ``(ev_i, ev_f, head)``."""
    return (jnp.zeros((slots, len(I_FIELDS)), jnp.int32),
            jnp.zeros(slots, jnp.float32), jnp.int32(0))


def record_blocks(ev_i: jnp.ndarray, ev_f: jnp.ndarray, head: jnp.ndarray,
                  tick, blocks: Sequence[Tuple]):
    """Scatter one tick's candidate event blocks into the ring.

    ``blocks`` is a sequence of ``(valid, kind, subject, aux, rank, value)``
    tuples in canonical block order; every element except ``kind`` (a
    Python int) is a 1-D array or a scalar broadcast against ``valid``.
    Returns the updated ``(ev_i, ev_f, head)``.
    """
    S = ev_i.shape[0]

    def cols(idx, dtype):
        parts = []
        for b in blocks:
            n = b[0].shape[0]
            v = jnp.asarray(b[idx])
            parts.append(jnp.broadcast_to(v, (n,)).astype(dtype))
        return jnp.concatenate(parts)

    valid = jnp.concatenate([b[0] for b in blocks])
    E = valid.shape[0]
    if S < E:   # static shapes: a drifted ring size is a trace-time error
        raise ValueError(
            f"ring capacity {S} < per-tick event block width {E}; "
            "scatter indices would collide")
    subj = cols(2, jnp.int32)
    aux = cols(3, jnp.int32)
    rank = cols(4, jnp.int32)
    val = cols(5, jnp.float32)
    kind = jnp.concatenate([
        jnp.full((b[0].shape[0],), int(b[1]), jnp.int32) for b in blocks])
    tick_col = jnp.broadcast_to(jnp.asarray(tick, jnp.int32), (E,))

    r = jnp.cumsum(valid.astype(jnp.int32)) - 1
    pos = jnp.where(valid, (head + r) % S, S)          # S = dropped
    rows = jnp.stack([tick_col, kind, subj, aux, rank], axis=1)
    ev_i = ev_i.at[pos].set(rows, mode="drop")
    ev_f = ev_f.at[pos].set(val, mode="drop")
    return ev_i, ev_f, head + r[-1] + 1


def decode(ev_i: np.ndarray, ev_f: np.ndarray, head) -> List[Event]:
    """Rotate one scenario's finished ring into chronological `Event`
    records: the retained events are numbers ``[head - min(head, S),
    head)``, event ``g`` at slot ``g % S``."""
    ev_i = np.asarray(ev_i)
    ev_f = np.asarray(ev_f)
    total = int(head)
    S = ev_i.shape[0]
    n = min(total, S)
    out: List[Event] = []
    for g in range(total - n, total):
        r = g % S
        t, k, s, a, rk = (int(x) for x in ev_i[r])
        out.append(Event(seq=g, tick=t, kind=k, subject=s, aux=a, rank=rk,
                         value=float(ev_f[r])))
    return out


class EventCollector:
    """The replay oracle's appender: `emit` at the mirrored tick points
    yields the same `Event` stream the engine's ring records (values are
    rounded through float32, matching the ring's storage dtype)."""

    def __init__(self) -> None:
        self.events: List[Event] = []

    def emit(self, tick: int, kind: int, subject: int, aux: int, rank: int,
             value: float) -> None:
        self.events.append(Event(
            seq=len(self.events), tick=int(tick), kind=int(kind),
            subject=int(subject), aux=int(aux), rank=int(rank),
            value=float(np.float32(value))))

    def extend(self, events: Sequence[Event]) -> None:
        for e in events:
            self.emit(e.tick, e.kind, e.subject, e.aux, e.rank, e.value)

    def tail(self, n: int) -> List[Event]:
        return self.events[max(0, len(self.events) - n):]


def assert_event_parity(engine_events: Sequence[Event],
                        oracle_events: Sequence[Event],
                        total: Optional[int] = None) -> None:
    """Agreement between a decoded engine ring and the oracle replay's
    retained tail: same count, DECISION FIELDS EXACT (tick, kind,
    subject, aux, rank — int-for-int), float values float32-close.

    Values are not compared bitwise because XLA contracts the serve's
    ``balance - drain * t_burst`` into an FMA, which leaves a ~1e-17
    residue exactly where pure-double math (the numpy oracle, which has
    no fma on this interpreter) cancels to 0.0 — e.g. a just-depleted
    bucket. The residue is additive noise far below every threshold the
    engine compares against (1e-9), so decisions never diverge; the
    tolerance below admits it while still catching any real mismatch."""
    if total is not None and total != len(oracle_events):
        raise AssertionError(
            f"event totals differ: engine head={total}, "
            f"oracle={len(oracle_events)}")
    tail = oracle_events[len(oracle_events) - len(engine_events):]
    for i, (e, o) in enumerate(zip(engine_events, tail)):
        if e.key() != o.key():
            raise AssertionError(
                f"event {i}: engine {e} != oracle {o}")
        ev, ov = np.float32(e.value), np.float32(o.value)
        if np.isnan(ev) or np.isnan(ov):
            same = bool(np.isnan(ev) and np.isnan(ov))
        elif not (np.isfinite(ev) and np.isfinite(ov)):
            same = bool(ev == ov)
        else:
            same = abs(float(ev) - float(ov)) \
                <= 1e-9 + 1e-5 * abs(float(ov))
        if not same:
            raise AssertionError(
                f"event {i} value: engine {e} != oracle {o}")
