"""Host-side trace sink: decode device rings, bundle traces, export
Chrome/Perfetto `trace_event` JSON and JSONL.

Two clocks land on one timeline: device events carry SIMULATED time
(``tick * cfg.dt`` seconds, pid "device", one Perfetto thread per
scenario) and runner spans carry WALL time relative to the tracer epoch
(pid "runner", one thread per host thread). Perfetto renders both from
t=0; the pid split keeps the scales visually separate while claim /
steal / retry / chunk-write orchestration sits next to the placement /
blacklist / preempt decisions it computed.

`save_trace`/`load_trace` persist a self-contained NPZ bundle — the
scenario arrays, the static config, and one scenario's ring — which is
what ``python -m repro.obs.explain`` replays against the numpy oracle.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.vecsim import VecSimConfig
from repro.obs.ring import Event, KIND_NAMES, decode
from repro.obs.spans import Span

TRACE_KEYS = ("trace_ev_i", "trace_ev_f", "trace_head")


def _scenario_ring(outputs: Dict[str, Any], scenario: int):
    """One scenario's ``(ev_i, ev_f, head)`` from an engine output dict —
    batched (leading scenario axis) or already per-scenario."""
    ev_i = np.asarray(outputs["trace_ev_i"])
    ev_f = np.asarray(outputs["trace_ev_f"])
    head = np.asarray(outputs["trace_head"])
    if ev_i.ndim == 3:
        return ev_i[scenario], ev_f[scenario], head[scenario]
    return ev_i, ev_f, head


def decode_trace(outputs: Dict[str, Any], scenario: int = 0) -> List[Event]:
    """Decode one scenario's ring from an engine output dict into
    chronological typed `Event` records."""
    ev_i, ev_f, head = _scenario_ring(outputs, scenario)
    return decode(ev_i, ev_f, head)


def save_trace(path, cfg: VecSimConfig, sc: Dict[str, np.ndarray],
               outputs: Dict[str, Any], scenario: int = 0) -> pathlib.Path:
    """Write a self-contained trace bundle: the (unstacked) scenario
    arrays, the static config, and one scenario's ring."""
    path = pathlib.Path(path)
    ev_i, ev_f, head = _scenario_ring(outputs, scenario)
    payload: Dict[str, np.ndarray] = {
        "trace/ev_i": ev_i, "trace/ev_f": ev_f,
        "trace/head": np.asarray(head),
        "cfg_json": np.asarray(json.dumps(dataclasses.asdict(cfg))),
    }
    for k, v in sc.items():
        payload[f"sc/{k}"] = np.asarray(v)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path, **payload)
    return path


def load_trace(path):
    """Load a `save_trace` bundle -> ``(cfg, sc, events, head)``."""
    with np.load(pathlib.Path(path), allow_pickle=False) as z:
        cfg = VecSimConfig(**json.loads(str(z["cfg_json"])))
        sc = {k[3:]: z[k] for k in z.files if k.startswith("sc/")}
        ev_i, ev_f = z["trace/ev_i"], z["trace/ev_f"]
        head = int(z["trace/head"])
    return cfg, sc, decode(ev_i, ev_f, head), head


def _device_trace_events(events: Sequence[Event], dt: float,
                         scenario: int) -> List[Dict[str, Any]]:
    rows: List[Dict[str, Any]] = []
    for e in events:
        rows.append({
            "name": e.kind_name, "cat": "device", "ph": "i", "s": "t",
            "pid": 1, "tid": int(scenario),
            "ts": float(e.tick) * dt * 1e6,     # sim seconds -> "us"
            "args": {"seq": e.seq, "tick": e.tick, "subject": e.subject,
                     "aux": e.aux, "rank": e.rank,
                     "value": _finite(e.value)},
        })
    return rows


def _runner_trace_events(spans: Sequence[Span]) -> List[Dict[str, Any]]:
    tids: Dict[str, int] = {}
    rows: List[Dict[str, Any]] = []
    for s in spans:
        tid = tids.setdefault(s.thread, len(tids))
        row: Dict[str, Any] = {
            "name": s.name, "cat": "runner", "pid": 2, "tid": tid,
            "ts": s.t0 * 1e6, "args": dict(s.args),
        }
        if s.dur is None:
            row["ph"] = "i"
            row["s"] = "t"
        else:
            row["ph"] = "X"
            row["dur"] = s.dur * 1e6
        rows.append(row)
    return rows


def _finite(v: float) -> Any:
    # JSON has no Infinity/NaN literals; Perfetto chokes on them
    if np.isfinite(v):
        return float(v)
    return repr(float(v))


def export_perfetto(path, *, events: Sequence[Event] = (),
                    dt: float = 1.0, scenario: int = 0,
                    spans: Sequence[Span] = (),
                    thread_names: Optional[Dict[str, Any]] = None
                    ) -> pathlib.Path:
    """Write Chrome/Perfetto ``trace_event`` JSON: device ring events
    (instant, pid "device") + runner spans (complete/instant, pid
    "runner") on one timeline. Load via chrome://tracing or
    https://ui.perfetto.dev."""
    path = pathlib.Path(path)
    te: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": 1,
         "args": {"name": "device (simulated time)"}},
        {"name": "process_name", "ph": "M", "pid": 2,
         "args": {"name": "runner (wall time)"}},
    ]
    te += _device_trace_events(events, dt, scenario)
    te += _runner_trace_events(spans)
    doc = {"traceEvents": te, "displayTimeUnit": "ms",
           "otherData": dict(thread_names or {})}
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=1) + "\n")
    return path


def export_jsonl(path, *, events: Sequence[Event] = (), dt: float = 1.0,
                 spans: Sequence[Span] = ()) -> pathlib.Path:
    """One JSON object per line: device events (``src: "device"``, sim
    time) then runner spans (``src: "runner"``, wall time)."""
    path = pathlib.Path(path)
    lines = []
    for e in events:
        lines.append(json.dumps({
            "src": "device", "t": float(e.tick) * dt, "seq": e.seq,
            "kind": e.kind_name, "subject": e.subject, "aux": e.aux,
            "rank": e.rank, "value": _finite(e.value)}))
    for s in spans:
        lines.append(json.dumps({
            "src": "runner", "t": s.t0, "dur": s.dur, "name": s.name,
            "thread": s.thread, "args": s.args}))
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("\n".join(lines) + ("\n" if lines else ""))
    return path
