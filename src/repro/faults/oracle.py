"""Pure-Python fault oracles for the vectorized engine's fault paths.

Two interpreters, one per engine path, each mirroring its jitted
counterpart tick-for-tick over numpy float64 state with the fault step
spliced in at the exact same point of the tick (after release, before
admission/placement):

  * `FaultTrafficOracle` — `vecsim._simulate_traffic` with
    ``cfg.faults != "none"``: ring-buffer table, SLO histograms, node
    mortality, requeue-at-tail with retry counts, lost-work accounting,
    CASH blacklisting;
  * `ClosedFaultOracle` — `vecsim._simulate_one` on the cpu pool
    (cash|stock, ``shuffle="none"``, no disk/net work): fixed task
    table, waves, dependency groups, the same fault step.

The fault stream is the IDENTICAL stream: both oracles call
`processes.fault_events` eagerly on the same ``(cfg, sc)`` the engine
traces, so ``alive/died/fresh/notice/scale`` match bit-for-bit.
Event counters (kills, re-executions, sheds, histograms) must then
equal the engine's EXACTLY; float accumulators (lost work, goodput)
match to summation-order tolerance, the same convention
tests/test_traffic.py uses.

Fault-step semantics mirrored here (the contract DESIGN.md documents):

  * release happens BEFORE the fault step — work that completed last
    tick on a node dying this tick still counts;
  * tasks resident on a dying node requeue with ``retry += 1`` and this
    attempt's progress added to ``work_lost``; past ``cfg.max_retries``
    the task is SHED (leaves without finishing, still drains);
  * ``spot`` freezes down nodes' buckets AND telemetry (instance
    paused); ``crash`` replacements arrive fresh (``cpu_balance0`` +
    blank telemetry) ``fl_replace_ticks`` after death; ``degrade``
    multiplies the burst ceiling by ``fl_deg_factor`` inside windows;
  * CASH blacklisting: nodes whose ESTIMATED bucket drains within
    ``cfg.blacklist_horizon_s`` at their currently-running demand, plus
    nodes inside the preemption notice window, take no placements —
    unless every free slot is blacklisted, in which case the blacklist
    is void.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional

import numpy as np

from repro.core.vecsim import (
    CLS_BURST_CPU,
    CLS_BURST_DISK,
    CLS_NET,
    CLS_NONE,
    CLS_PAD,
    VecSimConfig,
    _NEVER,
)
from repro.faults import processes
from repro.obs import ring as _ring
from repro.traffic.oracle import _serve_bucket


def _eager_events(cfg: VecSimConfig, sc: Dict[str, np.ndarray]
                  ) -> Dict[str, np.ndarray]:
    """The engine's fault stream, replayed eagerly as numpy arrays."""
    ev = processes.fault_events(cfg, sc, np.float64)
    return {k: np.asarray(v) for k, v in ev.items()}


def _blacklist(est: np.ndarray, dem_pre: np.ndarray, baseline: np.ndarray,
               burst: np.ndarray, unlimited: np.ndarray, horizon_s: float,
               n: int):
    """numpy mirror of `sched.straggler.predictive_blacklist` (same
    elementwise float64 ops, same strict comparison). Returns
    ``(mask, tdep)`` — the time-to-deplete vector is what the trace's
    blacklist events carry (+inf when the horizon term is off)."""
    if horizon_s <= 0.0:
        return np.zeros(n, bool), np.full(n, np.inf)
    rate = np.minimum(dem_pre, burst)
    drain = rate - baseline
    safe = np.where(drain > 0.0, drain, 1.0)
    tdep = np.where((drain <= 0.0) | (unlimited > 0.0), np.inf, est / safe)
    return tdep < horizon_s, tdep


def _estimate(cfg: VecSimConfig, tel, bal, baseline, capacity, now):
    """Mirror of the engine's `_telemetry_estimate` (Algorithm 2)."""
    if cfg.telemetry == "oracle":
        return bal.copy()
    has = tel["act_t"] > _NEVER / 2
    if cfg.telemetry == "stale":
        return np.where(has, tel["act_bal"], capacity)
    use_ok = tel["use_t"] >= tel["act_t"]
    dt_act = now - np.where(has, tel["act_t"], now)
    e = tel["act_bal"] + np.where(
        use_ok, (baseline - tel["use_rate"]) * dt_act, 0.0)
    return np.where(has, np.clip(e, 0.0, capacity), capacity)


def _observe(cfg: VecSimConfig, tel, bal, w_node, now, dt):
    """Mirror of the engine's `_telemetry_observe` (CloudWatch cadence)."""
    tel["accum"] = tel["accum"] + w_node / dt
    pub_a = now - tel["act_t"] >= cfg.actual_period
    pub_u = now - tel["use_t"] >= cfg.usage_period
    span = np.maximum(now - tel["win_start"], 1e-9)
    avg = tel["accum"] / np.maximum(1.0, span)
    tel["act_bal"] = np.where(pub_a, bal, tel["act_bal"])
    tel["act_t"] = np.where(pub_a, now, tel["act_t"])
    tel["use_rate"] = np.where(pub_u, avg, tel["use_rate"])
    tel["use_t"] = np.where(pub_u, now, tel["use_t"])
    tel["accum"] = np.where(pub_u, 0.0, tel["accum"])
    tel["win_start"] = np.where(pub_u, now, tel["win_start"])
    return tel


def _fresh_tel(n: int) -> Dict[str, np.ndarray]:
    return {"act_bal": np.zeros(n), "act_t": np.full(n, _NEVER),
            "use_rate": np.zeros(n), "use_t": np.full(n, _NEVER),
            "accum": np.zeros(n), "win_start": np.zeros(n)}


class FaultTrafficOracle:
    """Interpret one traffic scenario under a fault-enabled (or, for the
    decision-trace replay, fault-free) config; `run()` returns the
    engine's output keys (scalars, histograms, fault counters) as plain
    numpy values. With ``trace`` (an `repro.obs.ring.EventCollector`)
    the oracle also emits the engine's decision-trace events at the
    mirrored tick points, and ``snap_ticks`` records pre-placement
    snapshots (est/free/blacklist/queues) into ``self.snaps`` for the
    explainer."""

    def __init__(self, sc: Dict[str, np.ndarray], cfg: VecSimConfig,
                 trace=None, snap_ticks: FrozenSet[int] = frozenset()):
        from repro.traffic import arrivals, slo
        if cfg.faults != "none" and cfg.faults not in processes.FAULT_MODES:
            raise ValueError(f"not a fault config: {cfg.faults!r}")
        if cfg.shuffle != "none":
            raise NotImplementedError("oracle mirrors shuffle='none' only")
        if cfg.resource != "cpu" or cfg.scheduler not in ("cash", "stock"):
            raise NotImplementedError("traffic scope is cpu + cash|stock")
        self.sc = {k: np.asarray(v) for k, v in sc.items()}
        self.cfg = cfg
        self.N = len(self.sc["slots"])
        smax = int(self.sc["slots"].max()) if self.N else 1
        self.C = (cfg.table_slots if cfg.table_slots > 0
                  else 2 * self.N * max(smax, 1))
        self.edges = slo.edges_for(cfg)
        self.counts = np.asarray(arrivals.arrival_counts(cfg, self.sc,
                                                         np.float64))
        self.ev = (_eager_events(cfg, self.sc)
                   if cfg.faults != "none" else {})
        self._slo = slo
        self.trace = trace
        self.snap_ticks = frozenset(snap_ticks)
        self.snaps: Dict[int, Dict[str, np.ndarray]] = {}

    def run(self) -> Dict[str, np.ndarray]:
        cfg, sc, N, C = self.cfg, self.sc, self.N, self.C
        slo = self._slo
        dt = cfg.dt
        B = cfg.slo_bins
        need_credits = cfg.scheduler != "stock"
        mortal = cfg.faults in ("spot", "crash")
        degrading = cfg.faults == "degrade"
        use_black = (cfg.scheduler == "cash"
                     and (cfg.blacklist_horizon_s > 0.0
                          or (mortal and cfg.preempt_notice_s > 0.0)))
        ev = self.ev
        tr = self.trace
        pad = (sc["node_pad"].astype(bool) if "node_pad" in sc
               else np.zeros(N, bool))

        tb_rem = np.zeros(C)
        tb_work = np.zeros(C)
        tb_dem = np.zeros(C)
        tb_cls = np.full(C, CLS_PAD, np.int64)
        tb_seq = np.full(C, np.iinfo(np.int64).max, np.int64)
        tb_retry = np.zeros(C, np.int64)
        tb_submit = np.zeros(C)
        tb_start = np.full(C, np.inf)
        tb_node = np.full(C, -1, np.int64)
        seq_ctr = 0               # queue-order counter: arrivals + requeues

        run_cnt = np.zeros(N, np.int64)
        rel_cnt = np.zeros(N, np.int64)
        bal = sc["cpu_balance0"].astype(np.float64).copy()
        bal0 = sc["cpu_balance0"].astype(np.float64)
        sur = np.zeros(N)
        baseline = sc["cpu_baseline"].astype(np.float64)
        burst = sc["cpu_burst"].astype(np.float64)
        capacity = sc["cpu_capacity"].astype(np.float64)
        unlimited = sc["cpu_unlimited"].astype(np.float64)
        slots = sc["slots"].astype(np.int64)
        tel = _fresh_tel(N)

        n_seen = n_adm = n_done = 0
        n_preempt = n_reexec = n_shed = 0
        work_lost = 0.0
        lat_hist = np.zeros(B, np.int64)
        wait_hist = np.zeros(B, np.int64)
        lat_sum = wait_sum = 0.0
        lat_max = wait_max = 0.0
        last_rel = -np.inf
        work_done = work_served = busy_seconds = 0.0

        tmpl_n = max(int(sc["tmpl_n"]), 1)
        replay = cfg.traffic == "replay"

        for t in range(cfg.n_ticks):
            now = float(t) * dt
            alive = ev["alive"][t] if mortal else None
            scale = ev["scale"][t] if degrading else None
            burst_t = burst * scale if degrading else burst

            # 1) release finished jobs, bucket SLOs, recycle slots
            fin_now = np.flatnonzero((tb_cls != CLS_PAD) & (tb_node >= 0)
                                     & (tb_rem <= 1e-9))
            for i in fin_now:
                lat = now - tb_submit[i]
                wait = tb_start[i] - tb_submit[i]
                if tr is not None and lat >= self.edges[-1]:
                    tr.emit(t, _ring.EV_SLO_OVER, int(i), -1, -1, lat)
                lat_hist[slo.bucket_index(lat, self.edges)] += 1
                wait_hist[slo.bucket_index(wait, self.edges)] += 1
                lat_sum += lat
                wait_sum += wait
                lat_max = max(lat_max, lat)
                wait_max = max(wait_max, wait)
                tb_cls[i] = CLS_PAD
                tb_node[i] = -1
            if len(fin_now):
                n_done += len(fin_now)
                last_rel = now
            run_cnt -= rel_cnt
            rel_cnt = np.zeros(N, np.int64)

            # 1b) fault step: kill/restore nodes, requeue resident jobs
            if mortal:
                died = ev["died"][t]
                if cfg.faults == "crash":
                    fresh = ev["fresh"][t]
                    bal = np.where(fresh, bal0, bal)
                    if need_credits and cfg.telemetry != "oracle":
                        blank = _fresh_tel(N)
                        for k in tel:
                            tel[k] = np.where(fresh, blank[k], tel[k])
                resident = (tb_cls != CLS_PAD) & (tb_node >= 0)
                hit = np.flatnonzero(
                    resident & died[np.clip(tb_node, 0, N - 1)])
                shed_buf = []          # SHED events trail the PREEMPT block
                for i in hit:                     # slot-index order
                    node_pre = int(tb_node[i])
                    tb_retry[i] += 1
                    lost_i = tb_work[i] - tb_rem[i]
                    work_lost += lost_i
                    n_preempt += 1
                    tb_node[i] = -1
                    if tr is not None:
                        tr.emit(t, _ring.EV_PREEMPT, int(i), node_pre,
                                int(tb_retry[i]), lost_i)
                    if tb_retry[i] > cfg.max_retries:
                        n_shed += 1               # shed: leaves the table
                        tb_cls[i] = CLS_PAD
                        shed_buf.append((int(i), node_pre, int(tb_retry[i])))
                    else:
                        n_reexec += 1
                        tb_rem[i] = tb_work[i]    # restart from scratch
                        tb_seq[i] = seq_ctr       # tail of its queue,
                        seq_ctr += 1              # ahead of new arrivals
                if tr is not None:
                    for i, npre, rt in shed_buf:
                        tr.emit(t, _ring.EV_SHED, i, npre, rt, 0.0)
                run_cnt = np.where(alive, run_cnt, 0)

            # 2) arrivals into free slots, lowest index first, FIFO order
            k = int(self.counts[t])
            free_slots = np.flatnonzero(tb_cls == CLS_PAD)
            admitted = free_slots[:k]
            for r, i in enumerate(admitted):
                aidx = n_seen + r
                if replay:
                    row = int(sc["arr_tmpl"][aidx])
                    tb_submit[i] = float(sc["arr_t"][aidx])
                else:
                    row = aidx % tmpl_n
                    tb_submit[i] = now
                tb_rem[i] = float(sc["tmpl_work"][row])
                tb_work[i] = float(sc["tmpl_work"][row])
                tb_dem[i] = float(sc["tmpl_dem"][row])
                tb_cls[i] = int(sc["tmpl_cls"][row])
                tb_retry[i] = 0
                tb_seq[i] = seq_ctr
                seq_ctr += 1
                tb_start[i] = np.inf
            n_seen += k
            n_adm += len(admitted)
            dropped = k - len(admitted)
            if tr is not None and dropped > 0:
                tr.emit(t, _ring.EV_DROP, -1, dropped, -1, 0.0)

            # 3) telemetry estimate (pre-observe, Algorithm 2)
            est = None
            if need_credits:
                est = _estimate(cfg, tel, bal, baseline, capacity, now)

            # 4) placement: FIFO by queue seq within each phase
            free = slots - run_cnt
            if mortal:
                free = np.where(alive, free, 0)
            black = np.zeros(N, bool)
            tdep = np.full(N, np.inf)
            notice = np.zeros(N, bool)
            if use_black:
                running0 = tb_node >= 0
                dem_pre = np.zeros(N)
                for i in np.flatnonzero(running0 & (tb_rem > 0.0)):
                    dem_pre[tb_node[i]] += tb_dem[i]
                black, tdep = _blacklist(est, dem_pre, baseline, burst_t,
                                         unlimited, cfg.blacklist_horizon_s,
                                         N)
                if mortal and "notice" in ev:
                    notice = ev["notice"][t].astype(bool)
                    black = black | notice
                ok = bool(np.any(~black & (free > 0)))
                if ok:
                    free = np.where(black, 0, free)
                if tr is not None and ok:
                    for n in np.flatnonzero(black):
                        tr.emit(t, _ring.EV_BLACKLIST, int(n),
                                int(notice[n]), -1, tdep[n])

            def fifo(mask: np.ndarray) -> List[int]:
                q = np.flatnonzero(mask)
                return list(q[np.argsort(tb_seq[q], kind="stable")])

            placed_map: Dict[int, int] = {}

            def pack(order, queue):
                for n in order:
                    while free[n] > 0 and queue:
                        i = queue.pop(0)
                        tb_node[i] = n
                        tb_start[i] = now
                        free[n] -= 1
                        run_cnt[n] += 1
                        placed_map[int(i)] = int(n)

            ready = (tb_cls != CLS_PAD) & (tb_node < 0)
            if cfg.scheduler == "stock":
                order = list(range(N))
                queues = [fifo(ready)]
            else:
                order = sorted(range(N), key=lambda n: (-est[n], n))
                queues = [fifo(ready & ((tb_cls == CLS_BURST_CPU)
                                        | (tb_cls == CLS_BURST_DISK))),
                          fifo(ready & (tb_cls == CLS_NONE))]
            if t in self.snap_ticks:
                self.snaps[t] = {
                    "est": (est.copy() if est is not None else None),
                    "free": free.copy(), "black": black.copy(),
                    "tdep": tdep.copy(), "order": list(order),
                    "queues": [list(q) for q in queues],
                }
            pack(order, queues[0])
            if cfg.scheduler != "stock":
                pack(range(N), queues[1])
            if tr is not None:
                rank_of = {n: r for r, n in enumerate(order)}
                for i in sorted(placed_map):
                    n = placed_map[i]
                    if cfg.scheduler == "cash":
                        tr.emit(t, _ring.EV_PLACE, i, n, rank_of[n], est[n])
                    else:  # stock never consults credits: rank = node id
                        tr.emit(t, _ring.EV_PLACE, i, n, n, 0.0)

            # 5) serve + pro-rata distribute
            running = tb_node >= 0
            live = running & (tb_rem > 0.0)
            dem_node = np.zeros(N)
            for i in np.flatnonzero(live):
                dem_node[tb_node[i]] += tb_dem[i]
            bal_prev = bal.copy()
            w_node = np.zeros(N)
            for n in range(N):
                w, bal[n], over = _serve_bucket(
                    bal[n], dem_node[n], baseline[n], burst_t[n],
                    capacity[n], unlimited[n] > 0.0, dt)
                w_node[n] = w
                sur[n] += over
                work_served += w
            if mortal:
                # down nodes' buckets freeze: no spend, no regeneration
                bal = np.where(alive, bal, bal_prev)
            if tr is not None:
                dep = (bal_prev > 1e-9) & (bal <= 1e-9) & ~pad
                reg = (bal_prev <= 1e-9) & (bal > 1e-9) & ~pad
                for n in np.flatnonzero(dep):
                    tr.emit(t, _ring.EV_DEPLETE, int(n), -1, -1, bal[n])
                for n in np.flatnonzero(reg):
                    tr.emit(t, _ring.EV_REGEN, int(n), -1, -1, bal[n])
            for i in np.flatnonzero(live):
                n = tb_node[i]
                share = (w_node[n] * tb_dem[i] / dem_node[n]
                         if dem_node[n] > 0.0 else 0.0)
                inc = min(share, tb_rem[i])
                tb_rem[i] -= inc
                work_done += inc
                if tb_rem[i] <= 1e-9:
                    rel_cnt[n] += 1
            busy_seconds += float(np.sum(run_cnt > 0)) * dt

            # 6) CloudWatch observe (frozen for down nodes)
            if need_credits and cfg.telemetry != "oracle":
                tel_prev = {k: v.copy() for k, v in tel.items()}
                tel = _observe(cfg, tel, bal, w_node, now, dt)
                if mortal:
                    for k in tel:
                        tel[k] = np.where(alive, tel[k], tel_prev[k])

        drained = n_done + n_shed == n_adm
        if replay:
            all_done = drained and n_seen >= int(
                np.sum(np.isfinite(sc["arr_t"])))
        else:
            all_done = drained
        makespan = ((last_rel if n_done > 0 else 0.0) if all_done
                    else cfg.n_ticks * dt)
        out = {
            "makespan": makespan, "all_done": all_done,
            "surplus_credits": float(np.sum(sur)),
            "total_cpu_work": work_done, "cpu_work_served": work_served,
            "node_busy_seconds": busy_seconds,
            "n_arrived": n_seen, "n_admitted": n_adm,
            "n_dropped": n_seen - n_adm, "n_completed": n_done,
            "lat_hist": lat_hist, "wait_hist": wait_hist,
            "lat_sum": lat_sum, "wait_sum": wait_sum,
            "lat_max": lat_max, "wait_max": wait_max,
            "last_finish": last_rel,
        }
        if cfg.faults != "none":
            # fault counters exist only on the fault-enabled engine path
            out["n_preempted"] = n_preempt
            out["n_reexec"] = n_reexec
            out["n_shed"] = n_shed
            out["work_lost"] = work_lost
            out["goodput"] = work_done - work_lost
            if mortal:
                out["n_kill_events"] = int(np.sum(ev["died"]))
                out["node_down_ticks"] = int(np.sum(~ev["alive"]))
            else:
                out["n_kill_events"] = 0
                out["node_down_ticks"] = 0
        for pfx in ("lat", "wait"):
            for q, tag in slo.DEFAULT_QS:
                out[f"{pfx}_{tag}"] = float(slo.hist_percentile(
                    out[f"{pfx}_hist"], self.edges, q))
        return out


class ClosedFaultOracle:
    """Interpret one closed (fixed task table) scenario under a
    fault-enabled (or, for the decision-trace replay, fault-free) config,
    mirroring `vecsim._simulate_one` on the cpu pool: cash|stock,
    ``shuffle="none"``, no disk/net work, no round-robin network class.
    Waves and dependency groups ARE mirrored. ``trace``/``snap_ticks``
    behave as in `FaultTrafficOracle`."""

    def __init__(self, sc: Dict[str, np.ndarray], cfg: VecSimConfig,
                 trace=None, snap_ticks: FrozenSet[int] = frozenset()):
        if cfg.faults != "none" and cfg.faults not in processes.FAULT_MODES:
            raise ValueError(f"not a fault config: {cfg.faults!r}")
        if cfg.shuffle != "none":
            raise NotImplementedError("oracle mirrors shuffle='none' only")
        if cfg.resource != "cpu" or cfg.scheduler not in ("cash", "stock"):
            raise NotImplementedError("closed scope is cpu + cash|stock")
        sc = {k: np.asarray(v) for k, v in sc.items()}
        if np.any(sc["work_disk"] > 0) or np.any(sc["work_net"] > 0):
            raise NotImplementedError("closed scope is cpu work only")
        if np.any(sc["cls"] == CLS_NET):
            raise NotImplementedError("no round-robin network phase")
        self.sc = sc
        self.cfg = cfg
        self.N = len(sc["slots"])
        self.T = len(sc["work_cpu"])
        self.ev = (_eager_events(cfg, sc)
                   if cfg.faults != "none" else {})
        self.trace = trace
        self.snap_ticks = frozenset(snap_ticks)
        self.snaps: Dict[int, Dict[str, np.ndarray]] = {}

    def run(self) -> Dict[str, np.ndarray]:
        cfg, sc, N, T = self.cfg, self.sc, self.N, self.T
        dt = cfg.dt
        need_credits = cfg.scheduler != "stock"
        mortal = cfg.faults in ("spot", "crash")
        degrading = cfg.faults == "degrade"
        use_black = (cfg.scheduler == "cash"
                     and (cfg.blacklist_horizon_s > 0.0
                          or (mortal and cfg.preempt_notice_s > 0.0)))
        ev = self.ev
        tr = self.trace
        pad = (sc["node_pad"].astype(bool) if "node_pad" in sc
               else np.zeros(N, bool))
        n_waves = int(sc.get("n_waves", 1))
        G = sc["member"].shape[0]

        work = sc["work_cpu"].astype(np.float64)
        dem = sc["dem_cpu"].astype(np.float64)
        cls = sc["cls"].astype(np.int64)
        wave = sc["wave"].astype(np.int64)
        is_burst = (cls == CLS_BURST_CPU) | (cls == CLS_BURST_DISK)
        is_plain = cls == CLS_NONE

        done = np.zeros(T)
        node_of = np.full(T, -1, np.int64)
        released = sc["task_pad"].astype(bool).copy()
        retry = np.zeros(T, np.int64)
        finish = np.full(T, np.inf)
        run_cnt = np.zeros(N, np.int64)
        rel_cnt = np.zeros(N, np.int64)
        bal = sc["cpu_balance0"].astype(np.float64).copy()
        bal0 = sc["cpu_balance0"].astype(np.float64)
        sur = np.zeros(N)
        baseline = sc["cpu_baseline"].astype(np.float64)
        burst = sc["cpu_burst"].astype(np.float64)
        capacity = sc["cpu_capacity"].astype(np.float64)
        unlimited = sc["cpu_unlimited"].astype(np.float64)
        slots = sc["slots"].astype(np.int64)
        tel = _fresh_tel(N)
        wave_adm = 0
        work_lost = 0.0
        work_served = busy_seconds = 0.0

        for t in range(cfg.n_ticks):
            now = float(t) * dt
            alive = ev["alive"][t] if mortal else None
            scale = ev["scale"][t] if degrading else None
            burst_t = burst * scale if degrading else burst

            # 1) release finished tasks (work completed last tick)
            rem = work - done
            newly = (rem <= 1e-9) & (node_of >= 0) & ~released
            released = released | newly
            finish = np.where(newly, now, finish)
            run_cnt -= rel_cnt
            rel_cnt = np.zeros(N, np.int64)

            # 1b) fault step
            if mortal:
                died = ev["died"][t]
                if cfg.faults == "crash":
                    fresh = ev["fresh"][t]
                    bal = np.where(fresh, bal0, bal)
                    if need_credits and cfg.telemetry != "oracle":
                        blank = _fresh_tel(N)
                        for k in tel:
                            tel[k] = np.where(fresh, blank[k], tel[k])
                resident = (node_of >= 0) & ~released
                hit = resident & died[np.clip(node_of, 0, N - 1)]
                retry = retry + hit.astype(np.int64)
                shed_now = hit & (retry > cfg.max_retries)
                if tr is not None:       # before done/node_of are cleared
                    for i in np.flatnonzero(hit):
                        tr.emit(t, _ring.EV_PREEMPT, int(i), int(node_of[i]),
                                int(retry[i]), done[i])
                    for i in np.flatnonzero(shed_now):
                        tr.emit(t, _ring.EV_SHED, int(i), int(node_of[i]),
                                int(retry[i]), 0.0)
                work_lost += float(np.sum(np.where(hit, done, 0.0)))
                done = np.where(hit, 0.0, done)
                rem = work - done
                node_of = np.where(hit, -1, node_of)
                released = released | shed_now
                run_cnt = np.where(alive, run_cnt, 0)

            # 2) sequential wave admission
            if n_waves > 1:
                pending = (~released) & (wave <= wave_adm)
                if not np.any(pending) and wave_adm < n_waves - 1:
                    wave_adm += 1

            # 3) telemetry estimate
            est = None
            if need_credits:
                est = _estimate(cfg, tel, bal, baseline, capacity, now)

            # 4) placement
            dep_ok = np.ones(T, bool)
            if G > 0:
                done_cnt = sc["member"] @ released.astype(np.float64)
                g = np.clip(sc["dep_group"], 0, G - 1)
                frac = done_cnt[g] / sc["group_size"][g]
                dep_ok = (sc["dep_group"] < 0) | \
                    (frac + 1e-12 >= sc["dep_threshold"])
            ready = (node_of < 0) & (~released) & dep_ok & (cls != CLS_PAD)
            if n_waves > 1:
                ready &= wave <= wave_adm
            free = slots - run_cnt
            if mortal:
                free = np.where(alive, free, 0)
            black = np.zeros(N, bool)
            tdep = np.full(N, np.inf)
            notice = np.zeros(N, bool)
            if use_black:
                running0 = (node_of >= 0) & ~released
                dem_pre = np.zeros(N)
                for i in np.flatnonzero(running0 & (rem > 0.0)):
                    dem_pre[node_of[i]] += dem[i]
                black, tdep = _blacklist(est, dem_pre, baseline, burst_t,
                                         unlimited, cfg.blacklist_horizon_s,
                                         N)
                if mortal and "notice" in ev:
                    notice = ev["notice"][t].astype(bool)
                    black = black | notice
                ok = bool(np.any(~black & (free > 0)))
                if ok:
                    free = np.where(black, 0, free)
                if tr is not None and ok:
                    for n in np.flatnonzero(black):
                        tr.emit(t, _ring.EV_BLACKLIST, int(n),
                                int(notice[n]), -1, tdep[n])

            placed_map: Dict[int, int] = {}

            def pack(order, queue):
                for n in order:
                    while free[n] > 0 and queue:
                        i = queue.pop(0)
                        node_of[i] = n
                        free[n] -= 1
                        run_cnt[n] += 1
                        placed_map[int(i)] = int(n)

            # phase queues in task-index order (the engine's cumsum rank)
            if cfg.scheduler == "stock":
                order = list(range(N))
                queues = [list(np.flatnonzero(ready))]
            else:
                order = sorted(range(N), key=lambda n: (-est[n], n))
                queues = [list(np.flatnonzero(ready & is_burst)),
                          list(np.flatnonzero(ready & is_plain))]
            if t in self.snap_ticks:
                self.snaps[t] = {
                    "est": (est.copy() if est is not None else None),
                    "free": free.copy(), "black": black.copy(),
                    "tdep": tdep.copy(), "order": list(order),
                    "queues": [list(q) for q in queues],
                }
            pack(order, queues[0])
            if cfg.scheduler != "stock":
                pack(range(N), queues[1])
            if tr is not None:
                rank_of = {n: r for r, n in enumerate(order)}
                for i in sorted(placed_map):
                    n = placed_map[i]
                    if cfg.scheduler == "cash":
                        tr.emit(t, _ring.EV_PLACE, i, n, rank_of[n], est[n])
                    else:  # stock never consults credits: rank = node id
                        tr.emit(t, _ring.EV_PLACE, i, n, n, 0.0)

            # 5) serve + pro-rata distribute
            running = (node_of >= 0) & ~released
            live = running & (rem > 0.0)
            dem_node = np.zeros(N)
            for i in np.flatnonzero(live):
                dem_node[node_of[i]] += dem[i]
            bal_prev = bal.copy()
            w_node = np.zeros(N)
            for n in range(N):
                w, bal[n], over = _serve_bucket(
                    bal[n], dem_node[n], baseline[n], burst_t[n],
                    capacity[n], unlimited[n] > 0.0, dt)
                w_node[n] = w
                sur[n] += over
                work_served += w
            if mortal:
                bal = np.where(alive, bal, bal_prev)
            if tr is not None:
                dep = (bal_prev > 1e-9) & (bal <= 1e-9) & ~pad
                reg = (bal_prev <= 1e-9) & (bal > 1e-9) & ~pad
                for n in np.flatnonzero(dep):
                    tr.emit(t, _ring.EV_DEPLETE, int(n), -1, -1, bal[n])
                for n in np.flatnonzero(reg):
                    tr.emit(t, _ring.EV_REGEN, int(n), -1, -1, bal[n])
            for i in np.flatnonzero(live):
                n = node_of[i]
                share = (w_node[n] * dem[i] / dem_node[n]
                         if dem_node[n] > 0.0 else 0.0)
                done[i] = min(work[i], done[i] + share)
                if work[i] - done[i] <= 1e-9:
                    rel_cnt[n] += 1
            busy_seconds += float(np.sum(run_cnt > 0)) * dt

            # 6) observe (frozen for down nodes)
            if need_credits and cfg.telemetry != "oracle":
                tel_prev = {k: v.copy() for k, v in tel.items()}
                tel = _observe(cfg, tel, bal, w_node, now, dt)
                if mortal:
                    for k in tel:
                        tel[k] = np.where(alive, tel[k], tel_prev[k])

        real = ~sc["task_pad"].astype(bool)
        all_done = bool(np.all(released | ~real))
        shed = real & (retry > cfg.max_retries)
        fin_ok = real & ~shed
        if all_done:
            makespan = (float(np.max(finish[fin_ok]))
                        if np.any(fin_ok) else 0.0)
        else:
            makespan = cfg.n_ticks * dt
        retry_r = np.where(real, retry, 0)
        out = {
            "makespan": makespan, "all_done": all_done,
            "surplus_credits": float(np.sum(sur)),
            "total_cpu_work": float(np.sum(np.where(real, done, 0.0))),
            "cpu_work_served": work_served,
            "node_busy_seconds": busy_seconds,
        }
        if cfg.faults != "none":
            # fault counters exist only on the fault-enabled engine path
            out["n_preempted"] = int(np.sum(retry_r))
            out["n_reexec"] = int(np.sum(np.minimum(retry_r,
                                                    cfg.max_retries)))
            out["n_shed"] = int(np.sum(shed))
            out["work_lost"] = work_lost
            out["goodput"] = out["total_cpu_work"]
            if mortal:
                out["n_kill_events"] = int(np.sum(ev["died"]))
                out["node_down_ticks"] = int(np.sum(~ev["alive"]))
            else:
                out["n_kill_events"] = 0
                out["node_down_ticks"] = 0
        return out
