"""Fault injection for the vectorized simulator: declarative per-scenario
fault processes (spot preemption, crash-and-replace, transient
degradation) with one `fault_events` contract traced in-scan AND
replayed eagerly by the numpy fault oracle."""
from repro.faults.processes import (  # noqa: F401
    FAULT_MODES,
    FAULT_PARAM_KEYS,
    FAULT_STREAM_TAG,
    attach_fault_process,
    event_totals,
    fault_events,
    has_fault_params,
    stream_key,
)
