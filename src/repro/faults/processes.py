"""Declarative per-scenario fault processes for the batched engine:
jit-compatible node-mortality/health streams plus scenario attachment.

Three processes, selected by the *static* ``VecSimConfig.faults`` field
(so every scenario in a compile group shares one process):

  * ``spot`` — spot-instance preemption as a two-state Markov on/off
    chain: an alive node is preempted each tick with probability
    ``fl_p_kill``, a preempted node is restored with ``fl_p_restore``.
    The node's token bucket and telemetry FREEZE while it is down (the
    instance is paused, not replaced) and resume where they left off;
  * ``crash`` — crash-and-replace: an alive node dies with
    ``fl_p_crash``; exactly ``fl_replace_ticks`` later a REPLACEMENT
    arrives with a fresh bucket (``cpu_balance0``) and blank telemetry —
    the public-cloud replace-the-VM path;
  * ``degrade`` — transient IOPS/CPU degradation windows: with
    probability ``fl_p_degrade`` a healthy node enters a window of
    ``fl_deg_ticks`` ticks during which its burst ceiling is multiplied
    by ``fl_deg_factor`` (< 1). Nodes stay alive; only throughput sags.

Event streams are *derived, not carried* (exactly the
`traffic.arrivals.arrival_counts` shape): `fault_events` produces the
whole ``(n_ticks, N)`` per-tick stream inside the jitted program — ONE
vectorized uniform draw plus a tiny boolean/int chain scan per scenario,
fed to the tick scan as xs — and the numpy fault oracle replays the
IDENTICAL stream by calling `fault_events` eagerly. The draws key off
``fold_in(fold_in(PRNGKey(cfg.seed), FAULT_STREAM_TAG), rng_seed)`` —
the same per-scenario ``rng_seed`` plumbing the arrival and shuffle
streams use, under a distinct tag so no stream ever aliases another. A
seed sweep over fault realizations therefore batches into ONE compile,
and CASH-vs-stock comparisons at equal ``(seed, rng_seed, fl_*)`` see
bit-identical fault streams: the scheduler axis never perturbs the
faults it is judged under.

This module is deliberately vecsim-free (``cfg`` is duck-typed, reading
``faults / n_ticks / dt / seed / preempt_notice_s``) so `core.vecsim`
can import it without a cycle.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# fold_in tag separating the fault stream from the arrival (0x0A51) and
# shuffle streams that share PRNGKey(cfg.seed) + rng_seed
FAULT_STREAM_TAG = 0xFA17

FAULT_MODES = ("spot", "crash", "degrade")

# batched per-scenario scalars a fault-attached scenario carries. All
# seven ride on EVERY faulty scenario (irrelevant ones at their inert
# defaults) so stackers pass them through uniformly and the WorkQueue
# content digest always covers the full parameterization.
FAULT_PARAM_KEYS = ("fl_p_kill", "fl_p_restore", "fl_p_crash",
                    "fl_replace_ticks", "fl_p_degrade", "fl_deg_ticks",
                    "fl_deg_factor")


def stream_key(seed: int, rng_seed) -> jax.Array:
    """The per-scenario fault-stream key: static config seed folded with
    the batched scenario seed (one compile per static config)."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), FAULT_STREAM_TAG)
    return jax.random.fold_in(base, rng_seed)


def attach_fault_process(sc: Dict[str, np.ndarray], *, mode: str,
                         dt: float = 1.0,
                         kill_rate: float = 0.0, restore_rate: float = 0.0,
                         crash_rate: float = 0.0, replace_s: float = 0.0,
                         degrade_rate: float = 0.0, degrade_s: float = 0.0,
                         degrade_factor: float = 1.0
                         ) -> Dict[str, np.ndarray]:
    """Attach a fault process to a (closed or traffic) scenario: rates are
    per simulated second and convert to per-tick probabilities at ``dt``
    (clipped to [0, 1]); durations convert to whole ticks (min 1). The
    returned copy carries all `FAULT_PARAM_KEYS`; ``mode`` must agree
    with the static ``VecSimConfig.faults`` the scenario runs under."""
    if mode not in FAULT_MODES:
        raise ValueError(f"mode must be one of {FAULT_MODES}, got {mode!r}")
    if dt <= 0.0:
        raise ValueError(f"dt must be positive, got {dt}")
    if not (0.0 < degrade_factor <= 1.0):
        raise ValueError(
            f"degrade_factor must be in (0, 1], got {degrade_factor}")
    f = np.float64

    def prob(rate: float) -> np.float64:
        return f(min(max(rate * dt, 0.0), 1.0))

    def ticks(seconds: float) -> np.int32:
        return np.int32(max(1, int(round(seconds / dt))))

    out = dict(sc)
    out["fl_p_kill"] = prob(kill_rate)
    out["fl_p_restore"] = prob(restore_rate)
    out["fl_p_crash"] = prob(crash_rate)
    out["fl_replace_ticks"] = ticks(replace_s)
    out["fl_p_degrade"] = prob(degrade_rate)
    out["fl_deg_ticks"] = ticks(degrade_s)
    out["fl_deg_factor"] = f(degrade_factor)
    return out


def has_fault_params(sc: Dict[str, np.ndarray]) -> bool:
    return "fl_p_kill" in sc


def _notice_window(alive: jnp.ndarray, k_notice: int) -> jnp.ndarray:
    """``notice[t, n]``: node ``n`` is alive at tick ``t`` but will be
    down at some tick in ``(t, t + k_notice]`` — the spot two-minute
    warning, as a cumulative-count window over the liveness stream."""
    n_ticks = alive.shape[0]
    dead_cum = jnp.cumsum((~alive).astype(jnp.int32), axis=0)
    idx = jnp.clip(jnp.arange(n_ticks) + k_notice, 0, n_ticks - 1)
    return alive & ((dead_cum[idx] - dead_cum) > 0)


def fault_events(cfg, sc: Dict[str, jnp.ndarray], dtype
                 ) -> Dict[str, jnp.ndarray]:
    """Per-tick ``(n_ticks, N)`` fault streams for one scenario. Traced
    inside the engine (per scenario, under vmap) AND called eagerly by
    the fault oracle — both sides see the identical stream.

    Keys by mode (absent keys are statically absent, never carried):

      * ``spot``    — ``alive`` (bool), ``died`` (bool: alive->down edge,
        resident tasks requeue this tick), plus ``notice`` when
        ``cfg.preempt_notice_s > 0``;
      * ``crash``   — ``alive``, ``died``, ``fresh`` (bool: the
        replacement arrives this tick — reset bucket + telemetry), plus
        ``notice`` when configured;
      * ``degrade`` — ``scale`` (float: burst multiplier, 1 outside
        windows).
    """
    if cfg.faults not in FAULT_MODES:
        raise ValueError(f"not a fault config: {cfg.faults!r}")
    n = sc["slots"].shape[0]
    u = jax.random.uniform(stream_key(cfg.seed, sc["rng_seed"]),
                           (cfg.n_ticks, n), dtype=dtype)
    k_notice = int(round(cfg.preempt_notice_s / cfg.dt)) \
        if cfg.preempt_notice_s > 0.0 else 0

    if cfg.faults == "spot":
        p_kill = sc["fl_p_kill"].astype(dtype)
        p_rest = sc["fl_p_restore"].astype(dtype)

        def step(prev, ut):
            alive = jnp.where(prev, ut >= p_kill, ut < p_rest)
            return alive, (alive, prev & ~alive)

        _, (alive, died) = jax.lax.scan(step, jnp.ones(n, bool), u)
        ev = {"alive": alive, "died": died}

    elif cfg.faults == "crash":
        p_crash = sc["fl_p_crash"].astype(dtype)
        rt = sc["fl_replace_ticks"].astype(jnp.int32)

        def step(down, ut):
            # down == 0: alive; down > 0: ticks until the replacement
            alive_prev = down == 0
            die = alive_prev & (ut < p_crash)
            down = jnp.where(die, rt, jnp.maximum(down - 1, 0))
            alive = down == 0
            fresh = (~alive_prev) & alive
            return down, (alive, die, fresh)

        _, (alive, died, fresh) = jax.lax.scan(
            step, jnp.zeros(n, jnp.int32), u)
        ev = {"alive": alive, "died": died, "fresh": fresh}

    else:  # degrade
        p_deg = sc["fl_p_degrade"].astype(dtype)
        dticks = sc["fl_deg_ticks"].astype(jnp.int32)
        factor = sc["fl_deg_factor"].astype(dtype)

        def step(deg, ut):
            begin = (deg == 0) & (ut < p_deg)
            deg = jnp.where(begin, dticks, jnp.maximum(deg - 1, 0))
            scale = jnp.where(deg > 0, factor, jnp.ones((), dtype))
            return deg, scale

        _, scale = jax.lax.scan(step, jnp.zeros(n, jnp.int32), u)
        return {"scale": scale}

    if k_notice > 0:
        ev["notice"] = _notice_window(ev["alive"], k_notice)
    return ev


def event_totals(ev: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Whole-stream event counts a fault run reports (computed OUTSIDE
    the scan — the streams are xs, so these reductions are free):
    ``n_kill_events`` (node-death edges) and ``node_down_ticks``
    (node-ticks spent dead)."""
    if "alive" not in ev:           # degrade: nodes never die
        z = jnp.zeros((), jnp.int32)
        return {"n_kill_events": z, "node_down_ticks": z}
    return {
        "n_kill_events": jnp.sum(ev["died"], dtype=jnp.int32),
        "node_down_ticks": jnp.sum(~ev["alive"], dtype=jnp.int32),
    }
