"""Gradient compression for cross-pod (DCN) synchronization.

int8 block-quantized all-reduce with error feedback: quantize(g + e) ->
all-reduce int-sum (done in f32 of dequantized values under XLA; on a real
DCN fabric the wire format is int8 + per-block scales, an 4x volume cut vs
bf16) -> residual e kept locally. Error feedback makes the scheme unbiased
over time (Seide et al.; 1-bit Adam lineage).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to(x: jax.Array, mult: int) -> Tuple[jax.Array, int]:
    n = x.size
    rem = (-n) % mult
    flat = x.reshape(-1)
    if rem:
        flat = jnp.concatenate([flat, jnp.zeros((rem,), x.dtype)])
    return flat, n


def quantize_int8(x: jax.Array, block: int = BLOCK):
    """Block-wise symmetric int8 quantization. Returns (q, scales, meta)."""
    flat, n = _pad_to(x.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), (x.shape, n)


def dequantize_int8(q: jax.Array, scale: jax.Array, meta) -> jax.Array:
    shape, n = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def compress_tree(grads: Any, err: Optional[Any] = None,
                  block: int = BLOCK) -> Tuple[Any, Any]:
    """Quantize every leaf (adding error feedback); returns
    (dequantized_grads, new_error). The dequantized values are what the
    all-reduce sums — wire volume is the int8+scales payload."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s, meta = quantize_int8(g32, block)
        deq = dequantize_int8(q, s, meta)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def wire_bytes(grads: Any, block: int = BLOCK) -> Tuple[int, int]:
    """(compressed, uncompressed bf16) cross-pod payload in bytes."""
    comp = unc = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        nb = -(-n // block)
        comp += n + nb * 4          # int8 payload + f32 scale per block
        unc += n * 2                # bf16
    return comp, unc


def cross_pod_allreduce(grads: Any, axis_name: str = "pod",
                        compress: bool = True,
                        err: Optional[Any] = None) -> Tuple[Any, Any]:
    """psum over the pod axis with optional int8+EF compression.

    Usable under shard_map with a 'pod' mesh axis; under plain pjit the
    all-reduce is implicit and this function models the payload (tests use
    shard_map)."""
    if compress:
        grads, err = compress_tree(grads, err)
    summed = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), grads)
    return summed, err
