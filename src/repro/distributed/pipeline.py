"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Stages are laid out along a mesh axis; microbatches stream through with the
classic (S + M - 1) schedule expressed as a lax.fori_loop of compute +
ppermute steps. Selectable (config.pipeline_stages > 1); the dry-run has a
PP variant and tests check equivalence against the sequential model.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_forward(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     stage_params: Any, x_microbatches: jax.Array,
                     mesh: Mesh, axis: str = "pod") -> jax.Array:
    """Run M microbatches through S pipeline stages.

    stage_params: pytree with leading dim S (sharded over ``axis``).
    x_microbatches: (M, mb, ...) replicated input; returns (M, mb, ...).
    """
    n_stage = mesh.shape[axis]
    m = x_microbatches.shape[0]

    def per_stage(params, xs):
        # params: this stage's slice (leading dim 1); xs: (M, mb, d)
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        n_iter = m + n_stage - 1
        buf = jnp.zeros_like(xs)

        def body(i, carry):
            cur, out = carry          # cur: (mb, d) inflight activation
            mb_idx = i - stage
            take = jnp.clip(mb_idx, 0, m - 1)
            inp = jnp.where(stage == 0, xs[take], cur)
            active = (mb_idx >= 0) & (mb_idx < m)
            y = stage_fn(params, inp)
            y = jnp.where(active, y, cur)
            out = jax.lax.cond(
                active & (stage == n_stage - 1),
                lambda o: o.at[take].set(y), lambda o: o, out)
            # hand activation to the next stage
            nxt = jax.lax.ppermute(
                y, axis, [(j, (j + 1) % n_stage) for j in range(n_stage)])
            return (nxt, out)

        _, out = jax.lax.fori_loop(0, n_iter, body,
                                   (jnp.zeros_like(xs[0]), buf))
        # only the last stage holds real outputs; broadcast to all
        out = jax.lax.ppermute(
            out, axis,
            [(n_stage - 1, j) for j in range(n_stage)])
        return out

    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x_microbatches)
