"""Logical-axis sharding rules -> NamedSharding, divisibility-aware.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
Policy (MaxText-style FSDP+TP+EP):
  - batch over ("pod", "data")  (pure DP across pods, DCN-friendly)
  - parameters: FSDP over "data" on the d_model-ish dim (intra-pod ICI
    all-gathers), tensor-parallel over "model" on heads/ff/vocab/experts;
    replicated over "pod" (cross-pod all-reduce on gradients)
  - decode KV caches: batch over data, cache sequence over "model"
    (sharded-softmax decode: XLA emits partial max/sum all-reduces)
Any dim not divisible by its mesh axis size falls back to replication.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit(shape: Sequence[int], spec: Sequence, mesh: Mesh) -> P:
    """Drop any spec entry whose dim isn't divisible by the axis size."""
    out = []
    for dim, axis in zip(shape, spec):
        out.append(axis if (axis is not None and dim % axis_size(mesh, axis) == 0)
                   else None)
    out.extend([None] * (len(shape) - len(spec)))
    return P(*out)


# rules keyed by the param leaf name; value = logical spec for the TRAILING
# dims (leading stack dims — layers / groups / in-group — get None).
_PARAM_RULES: Dict[str, Tuple] = {
    "embed": ("model", "data"),          # (V, d): vocab-parallel embedding
    "lm_head": ("data", "model"),        # (d, V)
    "wq": ("data", "model"),             # (d, Hq*hd)
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),             # (F, d)
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    "w1": ("data", "model"),             # dense mlp (d, ff)
    "w3": ("data", "model"),
    "w2": ("model", "data"),             # (ff, d)
    "router": ("data", None),            # (d, E)
    "in_proj": ("data", "model"),        # mamba (d, proj)
    "out_proj": ("model", "data"),       # (d_in, d)
    "conv_w": (None, "model"),           # (K, conv_dim)
    "conv_b": ("model",),
    "A_log": (None,), "D": (None,), "dt_bias": (None,),
    "norm": (None,), "scale": (None,), "bias": (None,),
}

# MoE expert tensors: (E, d, ff) / (E, ff, d) -> expert-parallel over model
_MOE_RULES = {
    "w1": ("model", "data", None),
    "w3": ("model", "data", None),
    "w2": ("model", None, "data"),
}


def spec_for_param(path: Tuple[str, ...], shape: Sequence[int], mesh: Mesh) -> P:
    name = path[-1]
    in_moe = "moe" in path
    if in_moe and name in _MOE_RULES:
        rule = _MOE_RULES[name]
    elif name in _PARAM_RULES:
        rule = _PARAM_RULES[name]
    else:
        rule = ()
    n_lead = len(shape) - len(rule)
    if n_lead < 0:   # scalar-ish leaf with an over-long rule
        rule = rule[-len(shape):] if len(shape) else ()
        n_lead = len(shape) - len(rule)
    full = tuple([None] * n_lead) + tuple(rule)
    return _fit(shape, full, mesh)


def _path_names(key_path) -> Tuple[str, ...]:
    names = []
    for k in key_path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_shardings(params_struct: Any, mesh: Mesh, serve_tp: bool = False):
    """Pytree of NamedSharding matching ``params_struct`` (arrays or
    ShapeDtypeStructs).

    ``serve_tp`` drops the FSDP ("data") axis — tensor-parallel-only weights
    replicated across data, the right layout for decode where per-step FSDP
    all-gathers dominate collectives."""
    def mk(key_path, leaf):
        spec = spec_for_param(_path_names(key_path), leaf.shape, mesh)
        if serve_tp:
            spec = P(*[None if s == "data" else s for s in spec])
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(mk, params_struct)


def batch_shardings(batch_struct: Any, mesh: Mesh):
    """Batch arrays: leading dim over (pod, data)."""
    dp = dp_axes(mesh)

    def mk(leaf):
        spec = _fit(leaf.shape, (dp,), mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(mk, batch_struct)


def cache_shardings(cache_struct: Any, mesh: Mesh):
    """Decode caches. KV tensors (L, B, Hkv, S, hd): B over data, S over
    model. Mamba states (L, B, ...): B over data, feature over model where
    divisible. lengths (B,): over data."""
    dp = dp_axes(mesh)

    def mk(key_path, leaf):
        names = _path_names(key_path)
        shape = leaf.shape
        if names[-1] in ("k", "v"):
            spec = (None, dp, None, "model", None)
            if len(shape) == 6:  # hybrid: (G, n?, B, H, S, hd) — not used
                spec = (None,) + spec
        elif names[-1] == "lengths":
            spec = (dp,)
        elif names[-1] == "enc_out":
            spec = (dp, None, None)
        elif names[-1] in ("conv", "ssd"):
            spec = (None,) * (len(shape) - 4) + (dp, None, "model", None) \
                if names[-1] == "ssd" else \
                (None,) * (len(shape) - 3) + (dp, None, "model")
        else:
            spec = (None,) * len(shape)
        return NamedSharding(mesh, _fit(shape, spec, mesh))
    return jax.tree_util.tree_map_with_path(mk, cache_struct)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------------
# activation constraint helper (no-op outside a mesh context)
# ----------------------------------------------------------------------

_ACTIVE_MESH: Optional[Mesh] = None
SP_RESIDUALS = False     # sequence-parallel residual streams (hillclimb knob):
                         # layer inputs (the remat-saved buffers) sharded over
                         # "model" on d_model -> saves /TP memory, adds
                         # per-layer all-gathers (Megatron-SP trade)


def set_sp_residuals(flag: bool) -> None:
    global SP_RESIDUALS
    SP_RESIDUALS = flag


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the active mesh; resolves the
    logical name "dp" to the mesh's data axes; drops non-divisible axes."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    resolved = tuple(dp_axes(mesh) if s == "dp" else s for s in spec)
    fitted = _fit(x.shape, resolved, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fitted))
