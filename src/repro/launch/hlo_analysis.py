"""Parse compiled/optimized HLO text for collective-communication volume.

cost_analysis() has no collective-bytes entry, so we sum the result-shape
bytes of every collective op in the optimized module (documented
approximation: result bytes ~= per-device payload moved per op instance).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %ag = bf16[4,128,2048]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Total + per-op-kind result bytes of collectives in an HLO module.

    ``-start`` ops are counted; their ``-done`` twins are skipped to avoid
    double counting."""
    per_kind: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_part, dtype, dims, kind = m.groups()
        if tuple_part is not None:
            nbytes = sum(_shape_bytes(dt, dm)
                         for dt, dm in _SHAPE_RE.findall(tuple_part))
        else:
            nbytes = _shape_bytes(dtype, dims)
        per_kind[kind] += nbytes
        counts[kind] += 1
    total = sum(per_kind.values())
    per_kind.update({f"n_{k}": counts[k] for k in COLLECTIVES})
    return total, per_kind
