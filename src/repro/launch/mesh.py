"""Production mesh construction (brief-mandated shapes)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Mesh over whatever devices exist (CPU smoke / single host)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
