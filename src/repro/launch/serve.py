"""Serving launcher: batched requests through the continuous-batching engine
with CASH admission across (simulated credit-state) replicas.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
      --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model as MD
from repro.sched.serve_scheduler import CashServeScheduler, Request, make_replicas
from repro.serve.engine import Engine, ServeRequest
from repro.serve.sampler import SamplerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-cash", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    params = MD.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    # one engine per replica; CASH routes prefills by credit state
    engines = [Engine(cfg, params, n_slots=args.slots, max_len=128)
               for _ in range(args.replicas)]
    replicas = make_replicas(args.replicas, slots=args.slots)
    cash = CashServeScheduler(replicas, credit_aware=not args.no_cash)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt_tokens=int(rng.integers(4, 12)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    pf, _ = cash.admit(0.0, reqs, decode_batches=args.replicas)
    t0 = time.time()
    done = 0
    for rep_id, assigned in pf.items():
        eng = engines[rep_id]
        for r in assigned:
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=(r.prompt_tokens,)).tolist()
            eng.submit(ServeRequest(rid=r.rid, prompt=prompt,
                                    max_new_tokens=r.max_new_tokens))
        finished = eng.run_until_done()
        done += len(finished)
        print(f"replica {rep_id}: {len(finished)} requests, "
              f"{eng.steps} engine steps")
    dt = time.time() - t0
    total_tokens = done * args.max_new
    print(f"served {done}/{args.requests} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
