import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell against the
production meshes; record memory / FLOPs / collective volume / roofline.

XLA's cost_analysis counts while-loop (lax.scan) bodies ONCE, independent of
trip count (verified empirically in EXPERIMENTS.md SS Dry-run methodology).
Layer stacks here are scanned, so each cell is lowered THREE times:

  A. full depth, scanned   -> compile success, memory_analysis, HLO text
  B. 2 scan-units, unrolled-> cost_B (counted exactly)
  C. 1 scan-unit,  unrolled-> cost_C (counted exactly)

  per_unit = cost_B - cost_C;  nonloop = cost_C - per_unit
  corrected_total = nonloop + n_units * per_unit

The same extrapolation corrects collective bytes parsed from the HLO.

MUST run as its own process (device count locks at first jax init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES_BY_NAME, get_config, shape_applicable
from repro.distributed import sharding as SH
from repro.kernels import ops as KOPS
from repro.launch import specs as SP
from repro.launch.hlo_analysis import collective_bytes
from repro.sweep.mesh import make_production_mesh
from repro.models import model as MD
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.step import jit_serve_step, jit_train_step

# TPU v5e constants (roofline)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link


def _unit_layers(cfg) -> int:
    return cfg.hybrid_period if cfg.family == "hybrid" else 1


def _n_units(cfg) -> int:
    return cfg.num_layers // _unit_layers(cfg)


def _reduced_depth(cfg, units: int):
    upd = {"num_layers": units * _unit_layers(cfg)}
    if cfg.encoder_layers:
        upd["encoder_layers"] = units
    return dataclasses.replace(cfg, **upd)


def _lower_cell(cfg, shape, mesh, *, remat: bool, unroll: bool,
                moments_dtype: str):
    """Lower one variant; returns the jax Lowered object."""
    params_struct = SP.param_specs(cfg)
    if shape.kind == "decode":
        cache_struct, tokens_struct = SP.decode_specs(cfg, shape)
        step, _ = jit_serve_step(cfg, mesh, impl="xla", unroll=unroll,
                                 params_struct=params_struct,
                                 cache_struct=cache_struct,
                                 tokens_struct=tokens_struct)
        with mesh:
            return step.lower(params_struct, cache_struct, tokens_struct)
    if shape.kind == "prefill":
        batch_struct = SP.batch_specs(cfg, shape)
        p_sh = SH.param_shardings(params_struct, mesh)
        b_sh = SH.batch_shardings(batch_struct, mesh)

        def prefill(params, batch):
            logits, _ = MD.forward(cfg, params, batch, impl="xla",
                                   remat=False, unroll=unroll)
            return logits[:, -1, :]

        step = jax.jit(prefill, in_shardings=(p_sh, b_sh), out_shardings=None)
        with mesh:
            return step.lower(params_struct, batch_struct)
    # train
    batch_struct = SP.batch_specs(cfg, shape)
    opt = make_optimizer(OptimizerConfig(name="adamw",
                                         moments_dtype=moments_dtype))
    step, _ = jit_train_step(cfg, opt, mesh, impl="xla", remat=remat,
                             unroll=unroll, params_struct=params_struct,
                             batch_struct=batch_struct)
    opt_struct = jax.eval_shape(opt.init, params_struct)
    with mesh:
        return step.lower(params_struct, opt_struct, batch_struct)


def _costs(compiled):
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll, kinds = collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll),
        "kinds": kinds,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path, remat: bool = True,
             moments_dtype: str = "bfloat16", tag: str = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_tag,
           "kind": shape.kind, "status": "skip", "reason": reason}
    if not ok:
        print(f"[dryrun] {cfg.name} x {shape_name} x {mesh_tag}: SKIP ({reason})")
        if out_dir is not None:
            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / f"{cfg.name}__{shape_name}__{mesh_tag}.json").write_text(
                json.dumps(rec, indent=1))
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)
    SH.set_mesh(mesh)
    t0 = time.time()
    try:
        # ---- A: full model, scanned -> compile success + memory ----
        lowered = _lower_cell(cfg, shape, mesh, remat=remat, unroll=False,
                              moments_dtype=moments_dtype)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        raw = _costs(compiled)

        # ---- B/C: calibrated cost extrapolation ----
        # cap the unrolled SSD chunk bodies for long-sequence ssm/hybrid
        # cells: HLO size would otherwise explode (chunks x mamba layers);
        # intra-chunk flops are linear in chunk length -> analytic delta
        ssd_override = None
        ssd_flop_delta = 0.0
        if cfg.ssm is not None and shape.kind != "decode":
            d_in = cfg.ssm.expand * cfg.d_model
            n_mamba_2u = sum(
                1 for i in range(2 * _unit_layers(cfg))
                if not cfg.is_attention_layer(i))
            n_chunks_2u = (shape.seq_len // cfg.ssm.chunk) * n_mamba_2u
            if n_chunks_2u > 64:
                ssd_override = shape.seq_len // max(
                    1, 64 // max(n_mamba_2u, 1))
                ssd_override = max(cfg.ssm.chunk, ssd_override)
                # fwd intra-chunk flops/token/layer ~= 2*d_in*(Q + 2N)
                passes = 3.0 if shape.kind == "train" else 1.0
                tokens_g = shape.global_batch * shape.seq_len
                n_mamba_total = sum(
                    1 for i in range(cfg.num_layers)
                    if not cfg.is_attention_layer(i))
                ssd_flop_delta = (passes * tokens_g * 2.0 * d_in
                                  * (cfg.ssm.chunk - ssd_override)
                                  * n_mamba_total) / mesh.devices.size
        KOPS.set_unroll_inner(True, ssd_chunk_override=ssd_override)
        try:
            c1 = _costs(_lower_cell(_reduced_depth(cfg, 1), shape, mesh,
                                    remat=False, unroll=True,
                                    moments_dtype=moments_dtype).compile())
            c2 = _costs(_lower_cell(_reduced_depth(cfg, 2), shape, mesh,
                                    remat=False, unroll=True,
                                    moments_dtype=moments_dtype).compile())
        finally:
            KOPS.set_unroll_inner(False)
        n_units = _n_units(cfg)
        corr = {}
        for key in ("flops", "bytes", "coll"):
            per_unit = max(c2[key] - c1[key], 0.0)
            nonloop = max(c1[key] - per_unit, 0.0)
            corr[key] = nonloop + n_units * per_unit
        corr["flops"] = max(corr["flops"] + ssd_flop_delta, 0.0)
        kinds = {}
        for k in c1["kinds"]:
            if k.startswith("n_"):
                continue
            pu = max(c2["kinds"][k] - c1["kinds"][k], 0)
            nl = max(c1["kinds"][k] - pu, 0)
            kinds[k] = nl + n_units * pu

        n_params = cfg.param_count()
        n_active = cfg.param_count(active_only=True)
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * n_active * tokens
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * n_active * tokens
        else:
            tokens = shape.global_batch
            model_flops = 2.0 * n_active * tokens

        t_compute = corr["flops"] / PEAK_FLOPS
        t_memory = corr["bytes"] / HBM_BW
        t_coll = corr["coll"] / ICI_BW
        dominant = max((t_compute, "compute"), (t_memory, "memory"),
                       (t_coll, "collective"))[1]
        mem_fields = {
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "args": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "alias": getattr(mem, "alias_size_in_bytes", None),
        }
        rec.update({
            "status": "ok", "n_chips": n_chips,
            "ssd_chunk_override": ssd_override,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "total_s": round(time.time() - t0, 2),
            "raw_reported": raw,
            "flops_per_device": corr["flops"],
            "bytes_per_device": corr["bytes"],
            "collective_bytes_per_device": corr["coll"],
            "collective_kinds": kinds,
            "memory": mem_fields,
            "model_flops_global": model_flops,
            "params_total": n_params, "params_active": n_active,
            "tokens": tokens,
            "roofline": {
                "t_compute_s": t_compute, "t_memory_s": t_memory,
                "t_collective_s": t_coll, "dominant": dominant,
                "useful_flops_ratio": model_flops / max(corr["flops"] * n_chips, 1.0),
            },
        })
        print(f"[dryrun] {cfg.name} x {shape_name} x {mesh_tag}: OK "
              f"compile={t_compile:.1f}s flops/dev={corr['flops']:.3e} "
              f"coll/dev={corr['coll']:.3e}B dom={dominant} "
              f"useful={rec['roofline']['useful_flops_ratio']:.2f}")
        print(f"  memory_analysis/device: temp={mem_fields['temp']} "
              f"args={mem_fields['args']} out={mem_fields['output']} "
              f"alias={mem_fields['alias']}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        print(f"[dryrun] {cfg.name} x {shape_name} x {mesh_tag}: FAIL {e}")
    finally:
        SH.set_mesh(None)
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = out_dir / f"{cfg.name}__{shape_name}__{mesh_tag}{suffix}.json"
        fn.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    # hillclimb knobs (EXPERIMENTS.md SSPerf)
    ap.add_argument("--sp-residuals", action="store_true")
    ap.add_argument("--kv-write", default="onehot", choices=["onehot", "dus"])
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--tag", default=None, help="suffix for result files")
    args = ap.parse_args()
    if args.sp_residuals:
        from repro.distributed import sharding as _sh
        _sh.set_sp_residuals(True)
    if args.kv_write != "onehot":
        from repro.models import layers as _lay
        _lay.set_kv_write_mode(args.kv_write)
    if args.moe_group is not None:
        from repro.models import moe as _moe
        _moe.set_default_group(args.moe_group)
    out = Path(args.out)
    meshes = [False, True] if args.both_meshes else [args.multipod]

    if args.all:
        cells = [(c.name, s) for c in ARCHS.values() for s in SHAPES_BY_NAME]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for multi_pod in meshes:
        tag = "pod2x16x16" if multi_pod else "pod16x16"
        for arch, shape_name in cells:
            if args.skip_existing:
                fn = out / f"{get_config(arch).name}__{shape_name}__{tag}.json"
                if fn.exists() and json.loads(fn.read_text()).get("status") in ("ok", "skip"):
                    continue
            rec = run_cell(arch, shape_name, multi_pod, out,
                           remat=not args.no_remat, tag=args.tag)
            n_fail += rec["status"] == "fail"
    print(f"[dryrun] done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
