"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 50 --batch 8 --seq 128

--smoke uses the reduced config (CPU-runnable); full configs train on real
accelerator fleets via the same pjit step (see launch/dryrun.py for the
production-mesh lowering of every assigned architecture).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.annotations import Annotation
from repro.sched.train_scheduler import CashTrainScheduler, make_hosts
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--hosts", type=int, default=4,
                    help="CASH-scheduled data hosts (simulated credit state)")
    ap.add_argument("--no-cash", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch,
                          num_shards=max(args.hosts, 1))
    sched = None
    if not args.no_cash:
        hosts = make_hosts(args.hosts)
        sched = CashTrainScheduler(hosts, num_shards=data_cfg.num_shards,
                                   bottleneck=Annotation.BURST_CPU)
    trainer = Trainer(
        cfg, data_cfg,
        opt_cfg=OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                                total_steps=args.steps),
        train_cfg=TrainConfig(steps=args.steps, grad_accum=args.grad_accum,
                              ckpt_dir=args.ckpt_dir),
        scheduler=sched, dtype=jnp.float32)
    if args.resume:
        restored = trainer.maybe_restore()
        print(f"resume: {'restored step ' + str(trainer.step) if restored else 'fresh run'}")
    hist = trainer.run()
    print(f"final loss: {hist[-1]['loss']:.4f} (first {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
