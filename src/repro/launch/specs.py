"""ShapeDtypeStruct stand-ins for every (arch x input shape) dry-run cell.

No device allocation: params via jax.eval_shape over init, inputs as bare
structs. Modality frontends are stubs — audio/vision cells receive
precomputed frame/patch embeddings as inputs (per the brief).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as MD


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training / prefill inputs."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), dt)
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend.num_tokens, cfg.d_model), dt)
    return specs


def param_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: MD.init_params(cfg, jax.random.PRNGKey(0)))


def decode_specs(cfg: ModelConfig, shape: InputShape) -> Tuple[Any, Any]:
    """(cache_specs, token_specs) for a serve_step cell: one new token with
    a KV cache of shape.seq_len."""
    b, s_max = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = jax.ShapeDtypeStruct((b, cfg.encoder_seq_len, cfg.d_model), dt)
    cache = jax.eval_shape(
        functools.partial(MD.init_decode_cache, cfg, b, s_max, dt,
                          enc_out=enc_out))
    tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    return cache, tokens


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """All structs a dry-run cell needs, keyed by role."""
    if shape.kind == "decode":
        cache, tokens = decode_specs(cfg, shape)
        return {"cache": cache, "tokens": tokens}
    return {"batch": batch_specs(cfg, shape)}
