"""Token-bucket serve step — Pallas TPU kernel (CASH fleet simulator).

One ``dt`` step of ``TokenBucket.serve`` for a whole fleet of buckets at
once: the vectorized simulator (core.vecsim) serves every node's CPU / disk
/ network regulator across all scenarios of a sweep in a single call, so
the array is (scenarios x nodes) flattened. The math is pure VPU
elementwise; the kernel tiles the flattened fleet into (SUBLANES x LANES)
blocks resident in VMEM.

Inputs broadcast elementwise: balance, demand (units/sec), baseline, burst,
capacity, unlimited (0/1 mask). Returns (work, new_balance, surplus_add) —
see kernels.ref.bucket_serve_ref for the semantics contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

LANES = 128
SUBLANES = 8
_BLOCK = LANES * SUBLANES


def _bucket_kernel(bal_ref, dem_ref, base_ref, burst_ref, cap_ref, unl_ref,
                   work_ref, nbal_ref, sur_ref, *, dt: float):
    work, nbal, sur = _serve_math(
        bal_ref[...], dem_ref[...], base_ref[...], burst_ref[...],
        cap_ref[...], unl_ref[...] > 0.5, dt=dt)
    work_ref[...] = work
    nbal_ref[...] = nbal
    sur_ref[...] = sur


def _serve_math(bal, dem, base, brst, cap, unl, *, dt: float):
    """The bucket-serve arithmetic, shared by both kernels (must mirror
    kernels.ref.bucket_serve_ref branch for branch)."""
    rate = jnp.minimum(dem, brst)
    drain = rate - base
    bursting = drain > 0.0
    safe_drain = jnp.where(bursting, drain, 1.0)
    t_burst = jnp.where(unl, dt, jnp.minimum(dt, bal / safe_drain))
    spent = drain * t_burst
    over = jnp.where(unl, jnp.maximum(0.0, spent - bal), 0.0)
    work_burst = rate * t_burst + jnp.minimum(dem, base) * (dt - t_burst)
    bal_burst = jnp.maximum(0.0, bal - spent)
    work = jnp.where(bursting, work_burst, rate * dt)
    nbal = jnp.where(bursting, bal_burst, jnp.minimum(cap, bal - drain * dt))
    sur = jnp.where(bursting, over, jnp.zeros_like(bal))
    return work, nbal, sur


def bucket_serve_pallas(balance: jax.Array, demand: jax.Array,
                        baseline: jax.Array, burst: jax.Array,
                        capacity: jax.Array, unlimited: jax.Array, *,
                        dt: float, interpret: bool = False):
    """Serve a fleet of buckets for one ``dt``. Any input shape; all inputs
    are broadcast to ``balance``'s shape, flattened, padded to the
    (8 x 128) tile and streamed block-by-block."""
    shape = balance.shape
    dtype = balance.dtype
    n = balance.size

    def prep(x):
        x = jnp.broadcast_to(jnp.asarray(x, dtype), shape).reshape(-1)
        pad = (-n) % _BLOCK
        if pad:
            # pad with inert buckets (all-zero: idle, nothing accrues)
            x = jnp.concatenate([x, jnp.zeros((pad,), dtype)])
        return x.reshape(-1, LANES)

    args = [prep(x) for x in
            (balance, demand, baseline, burst, capacity, unlimited)]
    rows = args[0].shape[0]
    grid = (rows // SUBLANES,)
    spec = pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_bucket_kernel, dt=dt),
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=[spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), dtype)] * 3,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    return tuple(o.reshape(-1)[:n].reshape(shape) for o in out)


# ---------------------------------------------------------------------------
# fused serve + pro-rata distribution
# ---------------------------------------------------------------------------

def _serve_distribute_kernel(bal_ref, dem_ref, base_ref, burst_ref, cap_ref,
                             unl_ref, dd_ref, nidx_ref, tdem_ref,
                             share_ref, work_ref, nbal_ref, sur_ref, *,
                             dt: float):
    """Grid runs over task tiles; the (small) node fleet rides along whole
    in VMEM. Each tile recomputes the node serve (a handful of elementwise
    ops) and gathers its tasks' (work, dist-demand) node columns as a
    one-hot matmul — exact, since every row has a single unit entry and the
    other products are exact zeros. Only tile 0 writes the node outputs."""
    work, nbal, sur = _serve_math(
        bal_ref[...], dem_ref[...], base_ref[...], burst_ref[...],
        cap_ref[...], unl_ref[...] > 0.5, dt=dt)

    @pl.when(pl.program_id(0) == 0)
    def _write_nodes():
        work_ref[...] = work
        nbal_ref[...] = nbal
        sur_ref[...] = sur

    npad = work.shape[-1]
    nidx = nidx_ref[...]
    tdem = tdem_ref[...]
    tb = nidx.shape[0] * nidx.shape[1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (tb, npad), 1)
              == nidx.reshape(tb, 1)).astype(tdem.dtype)
    node_cols = jnp.concatenate(
        [work.reshape(npad, 1), dd_ref[...].reshape(npad, 1)], axis=1)
    g = jnp.dot(onehot, node_cols, preferred_element_type=tdem.dtype)
    w_t = g[:, 0].reshape(nidx.shape)
    dd_t = g[:, 1].reshape(nidx.shape)
    share_ref[...] = jnp.where(dd_t > 0.0, w_t * tdem / dd_t,
                               jnp.zeros_like(tdem))


def bucket_serve_distribute_pallas(balance: jax.Array, demand: jax.Array,
                                   baseline: jax.Array, burst: jax.Array,
                                   capacity: jax.Array, unlimited: jax.Array,
                                   nidx: jax.Array, dem_task: jax.Array, *,
                                   dt: float, dist_demand=None,
                                   interpret: bool = False):
    """Fused serve + pro-rata distribution (see
    kernels.ref.bucket_serve_distribute_ref for the semantics contract).
    Node arrays are 1-D ``(N,)`` (broadcast to ``balance``'s shape), task
    arrays 1-D ``(T,)``; returns ``(share, work, new_balance,
    surplus_add)`` with the serve and the per-task gather in ONE kernel."""
    nshape = balance.shape
    dtype = balance.dtype
    n = balance.size
    t = dem_task.size
    npad = -(-n // LANES) * LANES

    def prep_node(x):
        x = jnp.broadcast_to(jnp.asarray(x, dtype), nshape).reshape(-1)
        if npad - n:
            # inert padding buckets: all-zero, so serve math stays finite
            # and the one-hot matmul's zero products stay exact
            x = jnp.concatenate([x, jnp.zeros((npad - n,), dtype)])
        return x.reshape(1, npad)

    def prep_task(x, fill_dtype):
        x = jnp.asarray(x, fill_dtype).reshape(-1)
        pad = (-t) % _BLOCK
        if pad:
            # padded tasks point at node 0 with zero demand -> zero share
            x = jnp.concatenate([x, jnp.zeros((pad,), fill_dtype)])
        return x.reshape(-1, LANES)

    dd = demand if dist_demand is None else dist_demand
    node_args = [prep_node(x) for x in
                 (balance, demand, baseline, burst, capacity, unlimited, dd)]
    task_args = [prep_task(nidx, jnp.int32), prep_task(dem_task, dtype)]
    rows = task_args[0].shape[0]
    grid = (rows // SUBLANES,)
    node_spec = pl.BlockSpec((1, npad), lambda i: (0, 0))
    task_spec = pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))
    share, work, nbal, sur = pl.pallas_call(
        functools.partial(_serve_distribute_kernel, dt=dt),
        grid=grid,
        in_specs=[node_spec] * 7 + [task_spec] * 2,
        out_specs=[task_spec] + [node_spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), dtype)]
        + [jax.ShapeDtypeStruct((1, npad), dtype)] * 3,
        # every tile maps the SAME node output block (tile 0 writes it):
        # the grid must run sequentially, not as parallel workers
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*node_args, *task_args)
    tshape = dem_task.shape
    unflat = tuple(o.reshape(-1)[:n].reshape(nshape)
                   for o in (work, nbal, sur))
    return (share.reshape(-1)[:t].reshape(tshape),) + unflat
