"""Token-bucket serve step — Pallas TPU kernel (CASH fleet simulator).

One ``dt`` step of ``TokenBucket.serve`` for a whole fleet of buckets at
once: the vectorized simulator (core.vecsim) serves every node's CPU / disk
/ network regulator across all scenarios of a sweep in a single call, so
the array is (scenarios x nodes) flattened. The math is pure VPU
elementwise; the kernel tiles the flattened fleet into (SUBLANES x LANES)
blocks resident in VMEM.

Inputs broadcast elementwise: balance, demand (units/sec), baseline, burst,
capacity, unlimited (0/1 mask). Returns (work, new_balance, surplus_add) —
see kernels.ref.bucket_serve_ref for the semantics contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

LANES = 128
SUBLANES = 8
_BLOCK = LANES * SUBLANES


def _bucket_kernel(bal_ref, dem_ref, base_ref, burst_ref, cap_ref, unl_ref,
                   work_ref, nbal_ref, sur_ref, *, dt: float):
    bal = bal_ref[...]
    dem = dem_ref[...]
    base = base_ref[...]
    brst = burst_ref[...]
    cap = cap_ref[...]
    unl = unl_ref[...] > 0.5

    rate = jnp.minimum(dem, brst)
    drain = rate - base
    bursting = drain > 0.0
    safe_drain = jnp.where(bursting, drain, 1.0)
    t_burst = jnp.where(unl, dt, jnp.minimum(dt, bal / safe_drain))
    spent = drain * t_burst
    over = jnp.where(unl, jnp.maximum(0.0, spent - bal), 0.0)
    work_burst = rate * t_burst + jnp.minimum(dem, base) * (dt - t_burst)
    bal_burst = jnp.maximum(0.0, bal - spent)

    work_ref[...] = jnp.where(bursting, work_burst, rate * dt)
    nbal_ref[...] = jnp.where(bursting, bal_burst,
                              jnp.minimum(cap, bal - drain * dt))
    sur_ref[...] = jnp.where(bursting, over, jnp.zeros_like(bal))


def bucket_serve_pallas(balance: jax.Array, demand: jax.Array,
                        baseline: jax.Array, burst: jax.Array,
                        capacity: jax.Array, unlimited: jax.Array, *,
                        dt: float, interpret: bool = False):
    """Serve a fleet of buckets for one ``dt``. Any input shape; all inputs
    are broadcast to ``balance``'s shape, flattened, padded to the
    (8 x 128) tile and streamed block-by-block."""
    shape = balance.shape
    dtype = balance.dtype
    n = balance.size

    def prep(x):
        x = jnp.broadcast_to(jnp.asarray(x, dtype), shape).reshape(-1)
        pad = (-n) % _BLOCK
        if pad:
            # pad with inert buckets (all-zero: idle, nothing accrues)
            x = jnp.concatenate([x, jnp.zeros((pad,), dtype)])
        return x.reshape(-1, LANES)

    args = [prep(x) for x in
            (balance, demand, baseline, burst, capacity, unlimited)]
    rows = args[0].shape[0]
    grid = (rows // SUBLANES,)
    spec = pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_bucket_kernel, dt=dt),
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=[spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), dtype)] * 3,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    return tuple(o.reshape(-1)[:n].reshape(shape) for o in out)
