"""Blockwise (flash) attention — Pallas TPU kernel.

Online-softmax over KV blocks with accumulators held in VMEM scratch across
the sequential last grid dimension. Block shapes are MXU-aligned (multiples
of 128 on the sequence dims; head dim rides along whole).

TPU adaptation notes: the CUDA flash algorithm's warp-level reductions map to
full-block VPU reductions here; block sizes are chosen so (block_q x D) +
2 x (block_k x D) + (block_q x block_k) fits VMEM (~16 MB/core) with room for
double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  offset: int):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    iq = pl.program_id(2)
    # causal: skip KV blocks that lie entirely in the future of this Q block
    run = True
    if causal:
        last_q_pos = (iq + 1) * block_q - 1 + offset
        run = last_q_pos >= ik * block_k

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = (iq * block_q + offset
                    + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[:, :1]                              # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, block_q, skv, block_k)
    scale = (d ** -0.5) if scale is None else scale
    offset = skv - sq

    grid = (b, hq, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, offset=offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, group=group: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, group=group: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
