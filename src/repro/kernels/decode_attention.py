"""Decode attention (flash-decoding style) — Pallas TPU kernel.

One-token queries against a long (possibly partially-filled) KV cache. The
KV sequence is split across the sequential grid dimension; the per-kv-head
query group (GQA) rides as the row dimension of each block so a single MXU
matvec batch covers all query heads of the group.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30
LANES = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, group: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0, 0]
    # skip KV blocks entirely past the filled length
    @pl.when(j * block_k < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], block_k), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: jax.Array, *,
                            scale: Optional[float] = None,
                            block_k: int = 512,
                            interpret: bool = False) -> jax.Array:
    """q (B, Hq, D); k, v (B, Hkv, S, D); lengths (B,) -> (B, Hq, D)."""
    b, hq, d = q.shape
    hkv, s_max = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    block_k = min(block_k, s_max)
    assert s_max % block_k == 0, (s_max, block_k)
    scale = (d ** -0.5) if scale is None else scale

    qg = q.reshape(b, hkv, group, d)
    len2d = lengths.astype(jnp.int32).reshape(b, 1)
    grid = (b, hkv, s_max // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k, group=group)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, j: (b_, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(len2d, qg, k, v)
    return out.reshape(b, hq, d)
