"""Serving-fleet tick kernel: admission rank + KV-slot assign +
bucket-throttled decode + release detection, fused.

One device step covering the hot phases of `core.servesim`'s per-tick
loop for a replica fleet serving continuous-batching inference traffic:

  * **admission**: the pending FIFO queue (carried ranks, a rank prefix
    is always consumed) is placed onto replicas with free KV slots —
    either CASH credit-aware (credit-richest replica first, replica-id
    tie-break: prefill is the burst, so it lands where headroom lives)
    or credit-blind round-robin (one slot per replica per round,
    rotation carried via ``ptr``);
  * **serve**: each replica's token bucket (`_serve_math`, the
    `bucket_serve` arithmetic) serves its residents' aggregate token
    demand — prefill demand while a request's prompt remains, decode
    demand after — and the delivered work is distributed pro-rata;
  * **release**: requests whose prefill AND decode work both fall to
    ``<= 1e-9`` are flagged finished (their KV slot frees next tick,
    mirroring the engine's release-at-k+1 contract).

Placement is expressed as *interval assignment* exactly like
`kernels.megatick`: CASH ranks replicas by balance descending and each
replica's packed slots cover queue ranks ``[cum_excl_j, cum_excl_j +
free_j)``; round-robin enumerates the (replica, round) grid — the cell
for replica j in round r has global rank ``sum_k min(free_k, r) +
|participants before j this round|`` — as a static loop over
``max_rounds`` (the per-replica KV-slot count). Both are bitwise-equal
to `core.servesim`'s unfused packed-cumsum (`_pack_counts`/`_rr_table`)
formulation: identical integer bookkeeping, identical serve arithmetic.

`serve_admit_ref` is the XLA lowering; `serve_admit_pallas` is the
single `pl.pallas_call` TPU kernel (fleet + request table whole in
VMEM, lane-padded, runnable under ``interpret=True`` on CPU). Both wrap
the SAME `serve_admit_math`, differing only in the work/demand gather
(direct index vs one-hot matmul, the `megatick` pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bucket_serve import LANES, _serve_math
from repro.kernels.compat import CompilerParams
from repro.kernels.megatick import _pad_to

# pad filler for queue ranks: far above any reachable rank so a padded
# lane can never match a round-robin (replica, round) cell rank
_RANK_PAD = 1 << 28


def serve_admit_math(pending, rank, rep_prev, pre, dec, dpre, ddec,
                     balance, baseline, burst, capacity, unlimited, free,
                     qlen, ptr, *, dt: float, policy: str, max_rounds: int,
                     gather: str = "direct"):
    """One fused serving tick step.

    Request-side (C,): ``pending`` admitted-but-unplaced mask, ``rank``
    carried FIFO queue ranks (contiguous from 0 over pending),
    ``rep_prev`` resident replica before placement (-1 unplaced),
    ``pre``/``dec`` remaining prefill/decode tokens, ``dpre``/``ddec``
    token demand rates per phase. Replica-side (R,): the token-bucket
    fields plus ``free`` KV-slot counts. ``qlen`` is the carried queue
    length, ``ptr`` the round-robin rotation origin (read only when
    ``policy == "rr"``); ``max_rounds`` bounds free KV slots per replica
    (the static KV capacity).

    Returns ``(assign, taken, n_placed, inc_pre, inc_dec, new_pre,
    new_dec, fin, work, new_balance, surplus_add)`` — ``inc_*`` are the
    tokens applied this tick per request (masked to served lanes, so
    both gather formulations agree lane-for-lane), ``fin`` the requests
    finishing this tick (released by the engine next tick).
    """
    dtype = balance.dtype
    n = balance.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    unl = unlimited > 0.5 if unlimited.dtype != jnp.bool_ else unlimited

    # ---- admission: interval assignment over the visit order -------------
    if policy == "cash":
        # credit-richest first, replica-id tie-break (prefill = the burst)
        ck, cj = balance[None, :], balance[:, None]
        tie = (ck == cj) & (ids[None, :] < ids[:, None])
        before = (ck > cj) | tie
        cum_excl = jnp.sum(jnp.where(before, free[None, :], 0), axis=1,
                           dtype=jnp.int32)                   # (R,)
        taken = jnp.clip(qlen - cum_excl, 0, free)
        r = rank[:, None]
        hit = pending[:, None] & (cum_excl[None, :] <= r) \
            & (r < (cum_excl + free)[None, :])                # (C, R)
    elif policy == "rr":
        # one KV slot per replica per round, replicas visited in rotation
        # order from ptr; padded replicas (free == 0) never participate,
        # and only the RELATIVE rotation order matters, so mod by the
        # (possibly lane-padded) width is safe
        pos = jnp.mod(ids - ptr, n)                           # visit order
        hit = jnp.zeros((pending.shape[0], n), bool)
        taken = jnp.zeros(n, jnp.int32)
        start = jnp.zeros((), jnp.int32)
        for rd in range(max_rounds):
            part = free > rd                                  # (R,)
            earlier = part[None, :] & (pos[None, :] < pos[:, None])
            rib = jnp.sum(earlier, axis=1, dtype=jnp.int32)   # (R,)
            cell = start + rib            # global rank of cell (j, rd)
            hit = hit | (part[None, :] & (rank[:, None] == cell[None, :]))
            taken = taken + (part & (cell < qlen)).astype(jnp.int32)
            start = start + jnp.sum(part, dtype=jnp.int32)
        hit = hit & pending[:, None]
    else:
        raise ValueError(f"policy must be cash|rr, got {policy!r}")
    assign = jnp.sum(jnp.where(hit, ids[None, :] + 1, 0), axis=1,
                     dtype=jnp.int32) - 1
    n_placed = jnp.minimum(qlen, jnp.sum(free, dtype=jnp.int32))

    # ---- serve: phase-dependent demand, bucket throttle, pro-rata --------
    rep_new = jnp.where(assign >= 0, assign, rep_prev)
    running = rep_new >= 0
    nidx = jnp.clip(rep_new, 0, n - 1)
    # phase predicates share the release threshold: min(share, remaining)
    # zeroes a phase exactly when the bucket covers it, but an ulp of
    # work-arithmetic drift (XLA fuses mul+sub into FMA; numpy rounds
    # twice) can leave ~1e-14 behind on one side only — below 1e-9 a
    # phase is OVER everywhere, or the demand mix forks
    in_pre = pre > 1e-9
    live = in_pre | (dec > 1e-9)
    dem_i = jnp.where(in_pre, dpre, ddec)
    onehot = jnp.where((rep_new[:, None] == ids[None, :]) &
                       running[:, None], jnp.ones((), dtype), 0.0)
    col = jnp.where(running & live, dem_i, 0.0)
    dem_node = jax.lax.dot_general(
        col[None, :], onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=dtype)[0]                      # (R,)
    work, new_bal, sur_add = _serve_math(balance, dem_node, baseline, burst,
                                         capacity, unl, dt=dt)
    # the carried balance snaps to the 2^-10 grid (the demand-rate grid,
    # `traffic.arrivals._snap_rates`): balance ORDERS the cash admission
    # sort, so the FMA-vs-two-roundings ulp in `balance - drain*t_burst`
    # would otherwise compound across ticks and flip near-tie sorts
    # between this kernel, the unfused engine, and the replay oracle
    new_bal = jnp.round(new_bal * 1024.0) / 1024.0
    if gather == "direct":
        w_t, dd_t = work[nidx], dem_node[nidx]
    else:   # one-hot matmul gather (TPU kernel path) — identical values
        w_t = jax.lax.dot_general(onehot, work[:, None],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=dtype)[:, 0]
        dd_t = jax.lax.dot_general(onehot, dem_node[:, None],
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=dtype)[:, 0]
    share = jnp.where(dd_t > 0.0, w_t * dem_i / dd_t, 0.0)
    share = jnp.where(running & live, share, 0.0)
    # a request finishing its prefill mid-tick starts decoding next tick;
    # leftover share at the phase boundary is discarded (the engine's
    # min(share, remaining) contract, as core.vecsim)
    inc_pre = jnp.where(in_pre, jnp.minimum(share, pre), 0.0)
    inc_dec = jnp.where(~in_pre, jnp.minimum(share, dec), 0.0)
    new_pre = pre - inc_pre
    new_dec = dec - inc_dec

    # ---- release detection (the engine frees the KV slot next tick) -----
    fin = running & (new_pre <= 1e-9) & (new_dec <= 1e-9)
    return (assign, taken, n_placed, inc_pre, inc_dec, new_pre, new_dec,
            fin, work, new_bal, sur_add)


def serve_admit_ref(*args, **kw):
    """XLA reference lowering of the fused serving tick."""
    return serve_admit_math(*args, gather="direct", **kw)


# ---------------------------------------------------------------------------
# Pallas kernel: fleet + request table resident in VMEM, one grid step
# ---------------------------------------------------------------------------

def _serve_admit_kernel(pend_ref, rank_ref, rprev_ref, pre_ref, dec_ref,
                        dpre_ref, ddec_ref, bal_ref, base_ref, brst_ref,
                        cap_ref, unl_ref, free_ref, qlen_ref, ptr_ref,
                        assign_ref, taken_ref, npl_ref, ipre_ref, idec_ref,
                        npre_ref, ndec_ref, fin_ref, work_ref, nbal_ref,
                        sur_ref, *, dt, policy, max_rounds):
    (assign, taken, n_placed, inc_pre, inc_dec, new_pre, new_dec, fin,
     work, nbal, sur) = serve_admit_math(
        pend_ref[0, :] > 0, rank_ref[0, :], rprev_ref[0, :], pre_ref[0, :],
        dec_ref[0, :], dpre_ref[0, :], ddec_ref[0, :], bal_ref[0, :],
        base_ref[0, :], brst_ref[0, :], cap_ref[0, :], unl_ref[0, :],
        free_ref[0, :], qlen_ref[0, 0], ptr_ref[0, 0], dt=dt, policy=policy,
        max_rounds=max_rounds, gather="onehot")
    assign_ref[0, :] = assign
    taken_ref[0, :] = taken
    npl_ref[0, 0] = n_placed
    ipre_ref[0, :] = inc_pre
    idec_ref[0, :] = inc_dec
    npre_ref[0, :] = new_pre
    ndec_ref[0, :] = new_dec
    fin_ref[0, :] = fin.astype(jnp.int32)
    work_ref[0, :] = work
    nbal_ref[0, :] = nbal
    sur_ref[0, :] = sur


@functools.partial(jax.jit, static_argnames=(
    "dt", "policy", "max_rounds", "interpret"))
def serve_admit_pallas(pending, rank, rep_prev, pre, dec, dpre, ddec,
                       balance, baseline, burst, capacity, unlimited, free,
                       qlen, ptr, *, dt: float, policy: str,
                       max_rounds: int, interpret: bool = False):
    """`serve_admit_math` as one `pl.pallas_call`: the request table and
    replica fleet ride whole in VMEM (lane-padded), one grid step per
    tick — fleets are tens of replicas and at most a few thousand table
    slots, so whole-block residency beats any tiling."""
    c, n = pre.shape[0], balance.shape[0]
    dtype = balance.dtype
    cp, np_ = -(-c // LANES) * LANES, -(-n // LANES) * LANES

    fmask = functools.partial(jnp.asarray, dtype=dtype)
    req_in = [
        _pad_to(fmask(pending), cp, 0.0),
        _pad_to(rank.astype(jnp.int32), cp, _RANK_PAD),
        _pad_to(rep_prev.astype(jnp.int32), cp, -1),
        _pad_to(pre.astype(dtype), cp, 0.0),
        _pad_to(dec.astype(dtype), cp, 0.0),
        _pad_to(dpre.astype(dtype), cp, 0.0),
        _pad_to(ddec.astype(dtype), cp, 0.0),
    ]
    rep_in = [_pad_to(v.astype(dtype), np_, 0.0)
              for v in (balance, baseline, burst, capacity)]
    rep_in.append(_pad_to(fmask(unlimited), np_, 0.0))
    rep_in.append(_pad_to(free.astype(jnp.int32), np_, 0))
    inputs = [v.reshape(1, -1) for v in req_in + rep_in] + [
        jnp.asarray(qlen, jnp.int32).reshape(1, 1),
        jnp.asarray(ptr, jnp.int32).reshape(1, 1),
    ]

    out_shape = [
        jax.ShapeDtypeStruct((1, cp), jnp.int32),       # assign
        jax.ShapeDtypeStruct((1, np_), jnp.int32),      # taken
        jax.ShapeDtypeStruct((1, 1), jnp.int32),        # n_placed
        jax.ShapeDtypeStruct((1, cp), dtype),           # inc_pre
        jax.ShapeDtypeStruct((1, cp), dtype),           # inc_dec
        jax.ShapeDtypeStruct((1, cp), dtype),           # new_pre
        jax.ShapeDtypeStruct((1, cp), dtype),           # new_dec
        jax.ShapeDtypeStruct((1, cp), jnp.int32),       # fin
        jax.ShapeDtypeStruct((1, np_), dtype),          # work
        jax.ShapeDtypeStruct((1, np_), dtype),          # new balance
        jax.ShapeDtypeStruct((1, np_), dtype),          # surplus add
    ]
    kernel = functools.partial(_serve_admit_kernel, dt=dt, policy=policy,
                               max_rounds=max_rounds)
    # no grid: every ref is the whole (lane-padded) array in VMEM
    outs = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        compiler_params=CompilerParams(),
        interpret=interpret,
    )(*inputs)
    (assign, taken, npl, ipre, idec, npre, ndec, fin, work, nbal,
     sur) = outs
    return (assign[0, :c], taken[0, :n], npl[0, 0], ipre[0, :c],
            idec[0, :c], npre[0, :c], ndec[0, :c], fin[0, :c] > 0,
            work[0, :n], nbal[0, :n], sur[0, :n])
