"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Conventions
-----------
- attention: q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D); GQA via Hq % Hkv == 0.
  causal uses offset = Skv - Sq (query i attends keys <= i + offset).
- decode attention: q (B, Hq, D); cache k, v (B, Hkv, S, D); lengths (B,)
  masks positions >= length.
- SSD (Mamba-2): x (B, L, H, P); dt (B, L, H) post-softplus; A (H,) negative;
  Bm, Cm (B, L, N) single-group. State per head: S (N, P).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, Hkv, S, D) -> (B, Hkv * n_rep, S, D)."""
    if n_rep == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, n_rep, s, d)).reshape(b, h * n_rep, s, d)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: Optional[float] = None) -> jax.Array:
    """Full softmax attention oracle (fp32 accumulation)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        offset = skv - sq
        qpos = jnp.arange(sq)[:, None] + offset
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, scale: Optional[float] = None,
                        block_k: int = 1024, unroll: bool = False) -> jax.Array:
    """Online-softmax attention in pure XLA: lax.scan over KV blocks.

    O(S) memory (never materializes the S x S score matrix) — the dry-run /
    training path on non-TPU backends; matches attention_ref numerically.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    group = hq // hkv
    block_k = min(block_k, skv)
    if skv % block_k != 0:
        # largest divisor of skv not exceeding the requested block
        block_k = next(bk for bk in range(block_k, 0, -1) if skv % bk == 0)
    nk = skv // block_k
    offset = skv - sq
    # GQA without materializing repeated KV: fold q heads as (hkv, group)
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, sq, d)
    kb = jnp.moveaxis(k.astype(jnp.float32).reshape(b, hkv, nk, block_k, d), 2, 0)
    vb = jnp.moveaxis(v.astype(jnp.float32).reshape(b, hkv, nk, block_k, d), 2, 0)
    qpos = jnp.arange(sq) + offset

    def body(carry, inp):
        m, l, acc, j = carry
        kj, vj = inp                                        # (B,Hkv,bk,D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kj)
        if causal:
            kpos = j * block_k + jnp.arange(block_k)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vj)
        return (m_new, l, acc, j + 1), ()

    m0 = jnp.full((b, hkv, group, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, jnp.int32(0)),
                                     (kb, vb), unroll=unroll)
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(b, hq, sq, d)
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array, *,
                         scale: Optional[float] = None) -> jax.Array:
    """One-token-query attention over a (partially filled) KV cache.

    GQA via a grouped einsum — never materializes repeated KV. This also
    keeps XLA SPMD on the cheap path when the cache sequence dim is sharded
    (a broadcast repeat makes the partitioner re-shard the whole cache)."""
    b, hq, d = q.shape
    hkv, s_max = k.shape[1], k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    group = hq // hkv
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32)) * scale
    mask = jnp.arange(s_max)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Token-bucket serve (CASH fleet simulator, paper SS2)
# ---------------------------------------------------------------------------

def bucket_serve_ref(balance: jax.Array, demand: jax.Array, baseline: jax.Array,
                     burst: jax.Array, capacity: jax.Array,
                     unlimited: jax.Array, *, dt: float):
    """Vectorized ``TokenBucket.serve`` (core.token_bucket): one ``dt`` step
    for arrays of buckets. All arguments broadcast elementwise; ``unlimited``
    is a boolean (or 0/1) mask selecting T3-unlimited surplus accounting.

    Returns ``(work, new_balance, surplus_add)`` — work completed
    (units x sec), the post-step balance in [0, capacity], and the surplus
    credits booked beyond the bucket this step (zero unless ``unlimited``).
    The arithmetic mirrors the scalar reference branch-for-branch so a
    float64 run is bit-identical to the Python simulator.
    """
    unl = unlimited.astype(bool) if hasattr(unlimited, "astype") else unlimited
    rate = jnp.minimum(demand, burst)
    drain = rate - baseline                    # net credit flow (neg = accrue)
    bursting = drain > 0.0
    safe_drain = jnp.where(bursting, drain, 1.0)
    # bursting: spend credits until the bucket empties (unlimited never stops)
    t_burst = jnp.where(unl, dt, jnp.minimum(dt, balance / safe_drain))
    spent = drain * t_burst
    over = jnp.where(unl, jnp.maximum(0.0, spent - balance), 0.0)
    work_burst = rate * t_burst + jnp.minimum(demand, baseline) * (dt - t_burst)
    bal_burst = jnp.maximum(0.0, balance - spent)
    # accruing (demand <= baseline, incl. idle): earn the shortfall
    work = jnp.where(bursting, work_burst, rate * dt)
    new_balance = jnp.where(bursting, bal_burst,
                            jnp.minimum(capacity, balance - drain * dt))
    surplus_add = jnp.where(bursting, over, jnp.zeros_like(balance))
    return work, new_balance, surplus_add


def bucket_serve_distribute_ref(balance: jax.Array, demand: jax.Array,
                                baseline: jax.Array, burst: jax.Array,
                                capacity: jax.Array, unlimited: jax.Array,
                                nidx: jax.Array, dem_task: jax.Array, *,
                                dt: float,
                                dist_demand: Optional[jax.Array] = None):
    """Fused token-bucket serve + pro-rata work distribution.

    One ``dt`` serve step over the node fleet (``bucket_serve_ref``)
    followed by each task's share of its node's delivered work, in one op:
    ``share[t] = work[nidx[t]] * dem_task[t] / dist_demand[nidx[t]]`` (zero
    where the node's aggregate demand is zero). ``nidx`` (T,) maps tasks to
    their (clipped) node; ``dist_demand`` is the per-node aggregate demand
    the pro-rata rule divides by and defaults to ``demand`` — the network
    dual regulator serves the sustained bucket at the peak-shaped rate but
    distributes against the *original* aggregate demand, so the two differ
    there.

    Returns ``(share, work, new_balance, surplus_add)``; the task never
    sees node-level state, so a sharded sweep's serve step stays one kernel
    instead of serve-then-gather. Bitwise-identical to the unfused
    serve + stacked-gather formulation under float64.
    """
    work, new_balance, surplus_add = bucket_serve_ref(
        balance, demand, baseline, burst, capacity, unlimited, dt=dt)
    dd = demand if dist_demand is None else dist_demand
    w_t, dd_t = work[nidx], dd[nidx]
    share = jnp.where(dd_t > 0.0, w_t * dem_task / dd_t, 0.0)
    return share, work, new_balance, surplus_add


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

def ssd_sequential_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bm: jax.Array, Cm: jax.Array,
                       init_state: Optional[jax.Array] = None):
    """Step-by-step recurrence oracle: h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t.

    Returns (y (B,L,H,P), final_state (B,H,N,P)).
    """
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf, Af = Bm.astype(jnp.float32), Cm.astype(jnp.float32), A.astype(jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp          # (B,H,P), (B,H), (B,N), (B,N)
        a = jnp.exp(dtt * Af[None, :])                     # (B,H)
        upd = dtt[..., None, None] * Bt[:, None, :, None] * xt[:, :, None, :]
        S = a[..., None, None] * S + upd                   # (B,H,N,P)
        y = jnp.einsum("bn,bhnp->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((b, h, n, p), jnp.float32) if init_state is None else init_state
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    S, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1)                             # (B,L,H,P)
    return y.astype(x.dtype), S


def ssd_chunked_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, *, chunk: int = 64,
                    init_state: Optional[jax.Array] = None,
                    unroll: bool = False):
    """Chunked state-space-duality oracle (the algorithm the kernel mirrors).

    Scans over chunks carrying the (B,H,N,P) state, so peak memory is one
    chunk's intra-buffers — matches the kernel's streaming structure.
    """
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc, q = l // chunk, chunk
    xf = jnp.moveaxis(x.astype(jnp.float32).reshape(b, nc, q, h, p), 1, 0)
    dtf = jnp.moveaxis(dt.astype(jnp.float32).reshape(b, nc, q, h), 1, 0)
    Bf = jnp.moveaxis(Bm.astype(jnp.float32).reshape(b, nc, q, n), 1, 0)
    Cf = jnp.moveaxis(Cm.astype(jnp.float32).reshape(b, nc, q, n), 1, 0)
    Af = A.astype(jnp.float32)
    tri = jnp.tril(jnp.ones((q, q), bool))

    def step(S, inp):
        xc, dtc, Bc, Cc = inp          # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        dtA = dtc * Af[None, None, :]                       # (B,Q,H)
        cum = jnp.cumsum(dtA, axis=1)
        diff = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Q,Q,H)
        # clamp the (masked) upper triangle BEFORE exp: avoids inf in the
        # unselected where-branch, whose cotangent would be 0 * inf = NaN
        diff = jnp.where(tri[None, :, :, None], diff, 0.0)
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", Cc, Bc)         # (B,Q,Q)
        xdt = xc * dtc[..., None]                           # (B,Q,H,P)
        y = jnp.einsum("bij,bijh,bjhp->bihp", scores, Lmat, xdt)
        y = y + jnp.einsum("bqn,bhnp->bqhp", Cc, S) * jnp.exp(cum)[..., None]
        decay_in = jnp.exp(cum[:, -1:, :] - cum)            # (B,Q,H)
        S_new = jnp.exp(cum[:, -1, :])[..., None, None] * S + \
            jnp.einsum("bqn,bqh,bqhp->bhnp", Bc, decay_in * dtc, xc)
        return S_new, y

    S0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None else init_state)
    S_final, ys = jax.lax.scan(step, S0, (xf, dtf, Bf, Cf), unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, h, p)
    return y.astype(x.dtype), S_final
