"""Pallas TPU kernels (flash attention, decode attention, Mamba-2 SSD,
token-bucket serve) with pure-jnp oracles (ref.py) and jit'd dispatch
(ops.py)."""
from repro.kernels import ops, ref
from repro.kernels.bucket_serve import bucket_serve_pallas
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

__all__ = ["ops", "ref", "bucket_serve_pallas", "decode_attention_pallas",
           "flash_attention_pallas", "ssd_scan_pallas"]
