"""Whole-tick megakernel (CASH fleet simulator, paper SS2 + Algorithm 1/2).

One fused device step covering everything between a tick's bookkeeping
prologue and its release epilogue for the single-phase, cpu-pool engine
configurations (`core.vecsim` resolves eligibility):

  * Algorithm-2 telemetry **estimate** from the carried CloudWatch state
    (``predicted`` / ``stale`` / ``oracle`` / ``none`` for stock);
  * Algorithm-1 **placement** of the phase's FIFO queue over the
    credit-ordered (cash) or id-ordered (stock / plain-class) node visit
    sequence — expressed as *interval assignment*: node j's packed slots
    cover queue ranks ``[cum_excl_j, cum_excl_j + free_j)``, so the
    rank -> node map is one (T, N) containment test instead of the
    unfused path's packed cumsum + lookup-table gather;
  * token-bucket **serve + pro-rata distribution** (the `bucket_serve`
    arithmetic, shared via `_serve_math`);
  * Algorithm-2 telemetry **observe** (CloudWatch publish on period
    boundaries).

The interval-assignment placement is bitwise-identical to the unfused
packed-cumsum formulation: both place each phase's rank prefix onto the
same visit order with the same id tie-break, and all bookkeeping is exact
integer arithmetic (asserted engine-wide by tests/test_megatick.py).
`megatick_ref` is the XLA lowering; `megatick_pallas` is the single
`pl.pallas_call` TPU kernel (whole pool resident in VMEM, runnable under
``interpret=True`` on CPU). Both wrap the SAME `megatick_math`, differing
only in the work/demand gather formulation (direct index vs one-hot
matmul — identical values; the share is masked to served lanes so the two
agree lane-for-lane).

The telemetry arithmetic lives HERE (not in core.vecsim) so the kernel
layer never imports the engine (vecsim -> ops -> megatick); vecsim
delegates its `_telemetry_estimate` / `_telemetry_observe` wrappers to
these functions, keeping one source of truth for Algorithm 2.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bucket_serve import LANES, _serve_math
from repro.kernels.compat import CompilerParams

NEVER = -1.0e30           # "no telemetry sample yet" timestamp sentinel
TEL_KEYS = ("act_bal", "act_t", "use_rate", "use_t", "accum", "win_start")


# ---------------------------------------------------------------------------
# Algorithm 2 (CloudWatch credit telemetry) — the one source of truth
# ---------------------------------------------------------------------------

def telemetry_estimate(tel: Optional[Dict[str, jax.Array]],
                       balance: jax.Array, baseline: jax.Array,
                       capacity: jax.Array, now: jax.Array,
                       mode: str) -> jax.Array:
    """Credit estimate from the carried telemetry state (mirrors
    core.credits / the paper's Algorithm 2 ablations)."""
    if mode == "oracle":
        return balance
    has = tel["act_t"] > NEVER / 2
    if mode == "stale":
        return jnp.where(has, tel["act_bal"], capacity)
    # predicted: extrapolate from the 1-min utilization samples
    use_ok = tel["use_t"] >= tel["act_t"]
    dt_act = now - jnp.where(has, tel["act_t"], now)
    est = tel["act_bal"] + jnp.where(use_ok,
                                     (baseline - tel["use_rate"]) * dt_act,
                                     0.0)
    est = jnp.clip(est, 0.0, capacity)
    return jnp.where(has, est, capacity)


def telemetry_observe(tel: Dict[str, jax.Array], balance: jax.Array,
                      rate: jax.Array, now: jax.Array, *,
                      actual_period: float,
                      usage_period: float) -> Dict[str, jax.Array]:
    """CloudWatch emulation: publish actuals / windowed usage on period
    boundaries (mirrors core.credits.CloudWatchEmulator.observe)."""
    accum = tel["accum"] + rate
    pub_a = now - tel["act_t"] >= actual_period
    pub_u = now - tel["use_t"] >= usage_period
    span = jnp.maximum(now - tel["win_start"], 1e-9)
    avg = accum / jnp.maximum(1.0, span)
    return {
        "act_bal": jnp.where(pub_a, balance, tel["act_bal"]),
        "act_t": jnp.where(pub_a, now, tel["act_t"]),
        "use_rate": jnp.where(pub_u, avg, tel["use_rate"]),
        "use_t": jnp.where(pub_u, now, tel["use_t"]),
        "accum": jnp.where(pub_u, 0.0, accum),
        "win_start": jnp.where(pub_u, now, tel["win_start"]),
    }


# ---------------------------------------------------------------------------
# the fused tick math (shared by the XLA reference and the Pallas kernel)
# ---------------------------------------------------------------------------

def megatick_math(m_pend, rank, n_pend, node_prev, alive, dem_task, live,
                  balance, baseline, burst, capacity, unlimited, free, tel,
                  now, *, dt: float, actual_period: float,
                  usage_period: float, tel_mode: str, by_credit: bool,
                  carried_rank: bool, gather: str = "direct"):
    """One fused tick step for a single placement phase over one pool.

    Task-side (T,): ``m_pend`` pending-in-phase mask, ``rank`` carried
    FIFO queue ranks (read only when ``carried_rank``; the closed path
    derives ranks from one cumsum of ``m_pend``), ``node_prev`` node
    before placement (-1 unplaced), ``alive`` slot-participates mask
    (closed: not released; traffic: everything), ``dem_task`` demand,
    ``live`` work-remaining mask. Node-side (N,): the token-bucket fields,
    ``free`` slot counts, ``tel`` the Algorithm-2 carry (or None).
    ``n_pend`` is the carried queue length (read only when
    ``carried_rank``).

    Returns ``(assign, taken, share, work, new_balance, surplus_add,
    new_tel)`` — ``share`` is masked to lanes actually served
    (running & live), so both gather formulations agree lane-for-lane.
    """
    dtype = balance.dtype
    n = balance.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    unl = unlimited > 0.5 if unlimited.dtype != jnp.bool_ else unlimited

    # ---- Algorithm 2 estimate (pre-observe state) ------------------------
    est = None
    if tel_mode != "none":
        est = telemetry_estimate(tel, balance, baseline, capacity, now,
                                 tel_mode)

    # ---- queue ranks ------------------------------------------------------
    if carried_rank:
        n_q = n_pend
    else:
        rank = jnp.cumsum(m_pend.astype(jnp.int32)) - 1
        n_q = rank[-1] + 1

    # ---- placement: interval assignment over the visit order -------------
    # before[j, k]: node k is visited before node j. Cash visits by credit
    # estimate descending with id tie-break (sorted(key=(-credit, nid)));
    # stock / the plain-class phase visit in id order.
    if by_credit:
        ck, cj = est[None, :], est[:, None]
        tie = (ck == cj) & (ids[None, :] < ids[:, None])
        before = (ck > cj) | tie
    else:
        before = ids[None, :] < ids[:, None]
    cum_excl = jnp.sum(jnp.where(before, free[None, :], 0), axis=1,
                       dtype=jnp.int32)                       # (N,)
    taken = jnp.clip(n_q - cum_excl, 0, free)
    # rank r lands on the unique node whose packed-slot interval covers it
    r = rank[:, None]
    hit = m_pend[:, None] & (cum_excl[None, :] <= r) \
        & (r < (cum_excl + free)[None, :])                    # (T, N)
    assign = jnp.sum(jnp.where(hit, ids[None, :] + 1, 0), axis=1,
                     dtype=jnp.int32) - 1

    # ---- post-placement occupancy ----------------------------------------
    node_of = jnp.where(assign >= 0, assign, node_prev)
    running = (node_of >= 0) & alive
    nidx = jnp.clip(node_of, 0, n - 1)

    # ---- aggregate demand + serve + pro-rata distribute ------------------
    onehot = jnp.where((node_of[:, None] == ids[None, :]) &
                       running[:, None], jnp.ones((), dtype), 0.0)
    col = jnp.where(running & live, dem_task, 0.0)
    dem_node = jax.lax.dot_general(
        col[None, :], onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=dtype)[0]                      # (N,)
    work, new_bal, sur_add = _serve_math(balance, dem_node, baseline, burst,
                                         capacity, unl, dt=dt)
    if gather == "direct":
        w_t, dd_t = work[nidx], dem_node[nidx]
    else:   # one-hot matmul gather (TPU kernel path) — identical values
        w_t = jax.lax.dot_general(onehot, work[:, None],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=dtype)[:, 0]
        dd_t = jax.lax.dot_general(onehot, dem_node[:, None],
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=dtype)[:, 0]
    share = jnp.where(dd_t > 0.0, w_t * dem_task / dd_t, 0.0)
    share = jnp.where(running & live, share, 0.0)

    # ---- Algorithm 2 observe ---------------------------------------------
    new_tel = None
    if tel_mode in ("predicted", "stale"):
        new_tel = telemetry_observe(tel, new_bal, work / dt, now,
                                    actual_period=actual_period,
                                    usage_period=usage_period)
    return assign, taken, share, work, new_bal, sur_add, new_tel


def megatick_ref(*args, **kw):
    """XLA reference lowering of the whole-tick kernel."""
    return megatick_math(*args, gather="direct", **kw)


# ---------------------------------------------------------------------------
# Pallas kernel: the whole pool resident in VMEM, one grid step
# ---------------------------------------------------------------------------

def _pad_to(x, width, fill):
    pad = width - x.shape[0]
    if pad <= 0:
        return x
    return jnp.concatenate([x, jnp.full(pad, fill, x.dtype)])


def _megatick_kernel(mp_ref, rank_ref, npend_ref, nprev_ref, alive_ref,
                     dem_ref, live_ref, bal_ref, base_ref, brst_ref,
                     cap_ref, unl_ref, free_ref, tel_ref, now_ref,
                     assign_ref, taken_ref, share_ref, work_ref, nbal_ref,
                     sur_ref, ntel_ref, *, dt, actual_period, usage_period,
                     tel_mode, by_credit, carried_rank):
    tel = None
    if tel_mode in ("predicted", "stale"):
        tel = {k: tel_ref[i, :] for i, k in enumerate(TEL_KEYS)}
    assign, taken, share, work, nbal, sur, ntel = megatick_math(
        mp_ref[0, :] > 0, rank_ref[0, :], npend_ref[0, 0], nprev_ref[0, :],
        alive_ref[0, :] > 0, dem_ref[0, :], live_ref[0, :] > 0,
        bal_ref[0, :], base_ref[0, :], brst_ref[0, :], cap_ref[0, :],
        unl_ref[0, :], free_ref[0, :], tel, now_ref[0, 0], dt=dt,
        actual_period=actual_period, usage_period=usage_period,
        tel_mode=tel_mode, by_credit=by_credit, carried_rank=carried_rank,
        gather="onehot")
    assign_ref[0, :] = assign
    taken_ref[0, :] = taken
    share_ref[0, :] = share
    work_ref[0, :] = work
    nbal_ref[0, :] = nbal
    sur_ref[0, :] = sur
    if ntel is None:
        ntel_ref[...] = jnp.zeros(ntel_ref.shape, ntel_ref.dtype)
    else:
        ntel_ref[...] = jnp.stack([ntel[k] for k in TEL_KEYS])


@functools.partial(jax.jit, static_argnames=(
    "dt", "actual_period", "usage_period", "tel_mode", "by_credit",
    "carried_rank", "interpret"))
def megatick_pallas(m_pend, rank, n_pend, node_prev, alive, dem_task, live,
                    balance, baseline, burst, capacity, unlimited, free,
                    tel, now, *, dt: float, actual_period: float,
                    usage_period: float, tel_mode: str, by_credit: bool,
                    carried_rank: bool, interpret: bool = False):
    """`megatick_math` as one `pl.pallas_call`: the task table and node
    fleet ride whole in VMEM (lane-padded), one grid step per tick. Pool
    shapes here are small — tens of nodes, at most a few thousand task
    slots — so whole-block residency beats any tiling."""
    t, n = dem_task.shape[0], balance.shape[0]
    dtype = balance.dtype
    tp, np_ = -(-t // LANES) * LANES, -(-n // LANES) * LANES

    fmask = functools.partial(jnp.asarray, dtype=dtype)
    task_in = [
        _pad_to(fmask(m_pend), tp, 0.0),
        _pad_to(rank.astype(jnp.int32), tp, 0),
        jnp.asarray(n_pend, jnp.int32).reshape(1, 1),
        _pad_to(node_prev.astype(jnp.int32), tp, -1),
        _pad_to(fmask(alive), tp, 0.0),
        _pad_to(dem_task.astype(dtype), tp, 0.0),
        _pad_to(fmask(live), tp, 0.0),
    ]
    node_in = [_pad_to(v.astype(dtype), np_, 0.0)
               for v in (balance, baseline, burst, capacity)]
    node_in.append(_pad_to(fmask(unlimited), np_, 0.0))
    node_in.append(_pad_to(free.astype(jnp.int32), np_, 0))
    if tel is None:
        tel_arr = jnp.zeros((len(TEL_KEYS), np_), dtype)
    else:
        tel_arr = jnp.stack([_pad_to(tel[k].astype(dtype), np_, 0.0)
                             for k in TEL_KEYS])
    inputs = [v.reshape(1, -1) if v.ndim == 1 else v
              for v in task_in + node_in] + \
        [tel_arr, jnp.asarray(now, dtype).reshape(1, 1)]

    out_shape = [
        jax.ShapeDtypeStruct((1, tp), jnp.int32),       # assign
        jax.ShapeDtypeStruct((1, np_), jnp.int32),      # taken
        jax.ShapeDtypeStruct((1, tp), dtype),           # share
        jax.ShapeDtypeStruct((1, np_), dtype),          # work
        jax.ShapeDtypeStruct((1, np_), dtype),          # new balance
        jax.ShapeDtypeStruct((1, np_), dtype),          # surplus add
        jax.ShapeDtypeStruct((len(TEL_KEYS), np_), dtype),  # new telemetry
    ]
    kernel = functools.partial(
        _megatick_kernel, dt=dt, actual_period=actual_period,
        usage_period=usage_period, tel_mode=tel_mode, by_credit=by_credit,
        carried_rank=carried_rank)
    # no grid: every ref is the whole (lane-padded) array in VMEM — the
    # pool is tens of nodes x at most a few thousand task slots
    outs = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        compiler_params=CompilerParams(),
        interpret=interpret,
    )(*inputs)
    assign, taken, share, work, nbal, sur, ntel = outs
    new_tel = None
    if tel_mode in ("predicted", "stale"):
        new_tel = {k: ntel[i, :n] for i, k in enumerate(TEL_KEYS)}
    return (assign[0, :t], taken[0, :n], share[0, :t], work[0, :n],
            nbal[0, :n], sur[0, :n], new_tel)
