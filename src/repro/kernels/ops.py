"""jit'd dispatch wrappers over the Pallas kernels and their XLA references.

``impl`` selects the execution path:
  - "xla":       pure-jnp reference lowered by XLA. Used on CPU, in the
                 multi-pod dry-run (so cost_analysis sees true FLOPs) and as
                 the autodiff path for training.
  - "pallas":    the TPU kernel (compiled; TPU target).
  - "interpret": the TPU kernel executed by the Pallas interpreter (CPU
                 correctness testing).
  - "auto":      "pallas" on TPU backends, "xla" elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bucket_serve import (
    bucket_serve_distribute_pallas,
    bucket_serve_pallas,
)
from repro.kernels.megatick import megatick_pallas, megatick_ref
from repro.kernels.serve_admit import serve_admit_pallas, serve_admit_ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


_UNROLL_INNER = False
_SSD_CHUNK_OVERRIDE = None


def set_unroll_inner(flag: bool, ssd_chunk_override=None) -> None:
    """Dry-run calibration: unroll the inner KV-block / chunk scans so XLA
    cost analysis counts every iteration (see launch/dryrun.py).

    ``ssd_chunk_override`` caps the number of unrolled SSD chunk bodies for
    very long sequences; the dry-run applies an analytic FLOP correction for
    the chunk-size delta (intra-chunk cost is linear in chunk length)."""
    global _UNROLL_INNER, _SSD_CHUNK_OVERRIDE
    _UNROLL_INNER = flag
    _SSD_CHUNK_OVERRIDE = ssd_chunk_override


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, scale: Optional[float] = None,
              impl: str = "auto", block_q: int = 128,
              block_k: int = 128) -> jax.Array:
    impl = _resolve(impl)
    if impl == "xla":
        # blockwise online-softmax (O(S) memory); "xla_naive" keeps the
        # quadratic oracle for small-shape testing
        return ref.flash_attention_xla(q, k, v, causal=causal, scale=scale,
                                       unroll=_UNROLL_INNER)
    if impl == "xla_naive":
        return ref.attention_ref(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, interpret=(impl == "interpret"))


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, scale: Optional[float] = None,
                     impl: str = "auto", block_k: int = 512) -> jax.Array:
    impl = _resolve(impl)
    if impl == "xla":
        return ref.decode_attention_ref(q, k, v, lengths, scale=scale)
    return decode_attention_pallas(
        q, k, v, lengths, scale=scale, block_k=block_k,
        interpret=(impl == "interpret"))


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, *, chunk: int = 256, impl: str = "auto") -> jax.Array:
    impl = _resolve(impl)
    if impl == "xla":
        if _SSD_CHUNK_OVERRIDE is not None:
            chunk = min(_SSD_CHUNK_OVERRIDE, x.shape[1])
        y, _ = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=chunk,
                                   unroll=_UNROLL_INNER)
        return y
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=(impl == "interpret"))


def bucket_serve(balance: jax.Array, demand: jax.Array, baseline: jax.Array,
                 burst: jax.Array, capacity: jax.Array, unlimited: jax.Array,
                 *, dt: float, impl: str = "auto"):
    """One token-bucket serve step for a fleet of buckets (core.vecsim hot
    path). Returns (work, new_balance, surplus_add)."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.bucket_serve_ref(balance, demand, baseline, burst,
                                    capacity, unlimited, dt=dt)
    return bucket_serve_pallas(balance, demand, baseline, burst, capacity,
                               unlimited, dt=dt,
                               interpret=(impl == "interpret"))


def bucket_serve_distribute(balance: jax.Array, demand: jax.Array,
                            baseline: jax.Array, burst: jax.Array,
                            capacity: jax.Array, unlimited: jax.Array,
                            nidx: jax.Array, dem_task: jax.Array, *,
                            dt: float, impl: str = "auto",
                            dist_demand: Optional[jax.Array] = None):
    """Fused token-bucket serve + pro-rata work distribution (core.vecsim
    hot path): one serve step over the node fleet AND each task's share of
    its node's delivered work in a single kernel, so the sharded sweep's
    serve stays device-resident with no serve-then-gather round trip.
    Returns (share, work, new_balance, surplus_add)."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.bucket_serve_distribute_ref(
            balance, demand, baseline, burst, capacity, unlimited, nidx,
            dem_task, dt=dt, dist_demand=dist_demand)
    return bucket_serve_distribute_pallas(
        balance, demand, baseline, burst, capacity, unlimited, nidx,
        dem_task, dt=dt, dist_demand=dist_demand,
        interpret=(impl == "interpret"))


def megatick(m_pend, rank, n_pend, node_prev, alive, dem_task, live,
             balance, baseline, burst, capacity, unlimited, free, tel, now,
             *, dt: float, actual_period: float, usage_period: float,
             tel_mode: str, by_credit: bool, carried_rank: bool,
             impl: str = "auto"):
    """Whole-tick megakernel (core.vecsim fused path): Algorithm-2
    telemetry estimate, single-phase Algorithm-1 placement, token-bucket
    serve + pro-rata distribution, and the telemetry observe, in one fused
    step. Returns ``(assign, taken, share, work, new_balance,
    surplus_add, new_tel)`` — see kernels.megatick.megatick_math for the
    semantics contract."""
    impl = _resolve(impl)
    kw = dict(dt=dt, actual_period=actual_period, usage_period=usage_period,
              tel_mode=tel_mode, by_credit=by_credit,
              carried_rank=carried_rank)
    if impl == "xla":
        return megatick_ref(m_pend, rank, n_pend, node_prev, alive,
                            dem_task, live, balance, baseline, burst,
                            capacity, unlimited, free, tel, now, **kw)
    return megatick_pallas(m_pend, rank, n_pend, node_prev, alive, dem_task,
                           live, balance, baseline, burst, capacity,
                           unlimited, free, tel, now,
                           interpret=(impl == "interpret"), **kw)


def serve_admit(pending, rank, rep_prev, pre, dec, dpre, ddec, balance,
                baseline, burst, capacity, unlimited, free, qlen, ptr, *,
                dt: float, policy: str, max_rounds: int, impl: str = "auto"):
    """Fused serving-fleet tick (core.servesim hot path): credit-aware
    (cash) or round-robin admission of the pending FIFO queue onto
    replicas with free KV slots, token-bucket-throttled prefill/decode
    serve with pro-rata distribution, and release detection, in one
    step. Returns ``(assign, taken, n_placed, inc_pre, inc_dec, new_pre,
    new_dec, fin, work, new_balance, surplus_add)`` — see
    kernels.serve_admit.serve_admit_math for the semantics contract."""
    impl = _resolve(impl)
    kw = dict(dt=dt, policy=policy, max_rounds=max_rounds)
    if impl == "xla":
        return serve_admit_ref(pending, rank, rep_prev, pre, dec, dpre,
                               ddec, balance, baseline, burst, capacity,
                               unlimited, free, qlen, ptr, **kw)
    return serve_admit_pallas(pending, rank, rep_prev, pre, dec, dpre, ddec,
                              balance, baseline, burst, capacity, unlimited,
                              free, qlen, ptr,
                              interpret=(impl == "interpret"), **kw)


def megatick_estimate(tel, balance, baseline, capacity, now, *,
                      tel_mode: str):
    """The megakernel's Algorithm-2 credit estimate, standalone — the SAME
    `kernels.megatick.telemetry_estimate` the fused tick evaluates
    internally. The engine's decision trace (core.vecsim, trace_slots>0)
    calls this on the fused path so recorded placement events carry the
    bitwise-identical credit estimate the kernel ranked nodes by."""
    from repro.kernels import megatick as _mk
    return _mk.telemetry_estimate(tel, balance, baseline, capacity, now,
                                  tel_mode)


attention_jit = jax.jit(attention, static_argnames=(
    "causal", "impl", "block_q", "block_k"))
decode_attention_jit = jax.jit(decode_attention, static_argnames=(
    "impl", "block_k"))
ssd_jit = jax.jit(ssd, static_argnames=("chunk", "impl"))
bucket_serve_jit = jax.jit(bucket_serve, static_argnames=("dt", "impl"))
bucket_serve_distribute_jit = jax.jit(bucket_serve_distribute,
                                      static_argnames=("dt", "impl"))
serve_admit_jit = jax.jit(serve_admit, static_argnames=(
    "dt", "policy", "max_rounds", "impl"))
