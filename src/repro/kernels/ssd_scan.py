"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

The state-space-duality algorithm: within each chunk the output is a masked
quadratic form (two MXU matmuls), across chunks a cheap (N x P) state
recurrence carried in VMEM scratch over the sequential chunk grid dimension.

TPU adaptation: the CUDA implementation splits intra-chunk work across warps;
here the whole (Q x Q) score block and (Q x P) outputs are single MXU calls,
with chunk length Q chosen so Q^2 + 2 Q max(N, P) floats fit VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *,
                chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)              # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)               # (Q,)
    a = a_ref[0].astype(jnp.float32)                       # scalar
    bm = b_ref[0].astype(jnp.float32)                      # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                      # (Q, N)

    dta = dt * a                                           # (Q,)
    cum = jnp.cumsum(dta)                                  # (Q,)
    q = chunk
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    diff = cum[:, None] - cum[None, :]
    diff = jnp.where(ii >= jj, diff, 0.0)   # clamp before exp (overflow)
    lmat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)         # (Q, Q)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * lmat
    xdt = x * dt[:, None]                                  # (Q, P)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    s_prev = s_ref[...]                                    # (N, P)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, s_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    decay_in = jnp.exp(cum[-1] - cum)                      # (Q,)
    s_ref[...] = jnp.exp(cum[-1]) * s_prev + jax.lax.dot_general(
        bm, xdt * decay_in[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, *, chunk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """x (B,L,H,P); dt (B,L,H); A (H,); Bm, Cm (B,L,N) -> y (B,L,H,P)."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    grid = (b, h, l // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c: (b_, c, h_)),
            pl.BlockSpec((1,), lambda b_, h_, c: (h_,)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c: (b_, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c: (b_, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c: (b_, c, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
