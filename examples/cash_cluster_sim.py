"""Walk through the paper's experiments end-to-end (SS6):

CPU burst: EMR baseline vs naive-T3 vs reordered vs T3-unlimited vs CASH
  (Experiments 1-4, Fig 7/8) and the billing consequences.
Disk burst: stock YARN vs CASH on TPC-DS at three scales (Fig 9/10/11).

  PYTHONPATH=src python examples/cash_cluster_sim.py [--fast]
"""
import argparse
import statistics

from repro.core.experiments import (
    CPU_PHASES,
    run_cpu_experiment,
    run_disk_pair,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="single seed, CPU side only")
    args = ap.parse_args()

    print("=" * 70)
    print("CPU-burst experiments (paper SS6.2-6.3, Fig 7/8)")
    print("=" * 70)
    res = {}
    for label in ("emr", "naive", "reordered", "unlimited", "cash"):
        res[label] = run_cpu_experiment(label, n_nodes=10, seed=0)
    emr_cum = res["emr"].cumulative_total()
    print(f"{'setup':<11}{'cum elapsed':>12}{'vs EMR':>9}{'cost':>9}"
          f"{'saving':>9}{'credit-std':>12}")
    for label, r in res.items():
        tl = r.result.timeline
        half = len(tl["cpu_credit_std"]) // 2
        cstd = statistics.mean(tl["cpu_credit_std"][half:])
        print(f"{label:<11}{r.cumulative_total():>12.0f}"
              f"{r.cumulative_total() / emr_cum - 1:>+9.1%}"
              f"{r.billing.total:>9.2f}"
              f"{1 - r.billing.total / res['emr'].billing.total:>+9.1%}"
              f"{cstd:>12.0f}")
    print("\npaper: naive ~+40%, reordered ~+19%, CASH ~+13%, unlimited ~CASH"
          "\n       but billed for surplus credits; CASH has lowest credit-std")

    if args.fast:
        return
    print()
    print("=" * 70)
    print("Disk-burst experiments (paper SS6.5-6.6, Fig 9/11)")
    print("=" * 70)
    print(f"{'scale':<8}{'stock qct':>11}{'cash qct':>10}{'impr':>8}"
          f"{'makespan impr':>15}")
    for setup in ("2vm", "10vm", "20vm"):
        p = run_disk_pair(setup, seeds=(1, 2))
        qct = 1 - p["cash"]["avg_qct"] / p["stock"]["avg_qct"]
        mk = 1 - p["cash"]["makespan"] / p["stock"]["makespan"]
        print(f"{setup:<8}{p['stock']['avg_qct']:>11.0f}"
              f"{p['cash']['avg_qct']:>10.0f}{qct:>+8.1%}{mk:>+15.1%}")
    print("\npaper: ~5% / ~10.7% / ~31% query completion, up to 22% makespan"
          "\n       -> equal-valuation billing savings (Fig 11)")


if __name__ == "__main__":
    main()
