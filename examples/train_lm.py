"""End-to-end training driver: train a small LM for a few hundred steps with
the full production stack — CASH-scheduled data hosts, checkpointing,
resume, and a real learning curve on structured synthetic data.

  PYTHONPATH=src python examples/train_lm.py --steps 150
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300   # beefier

The 100m preset is the brief's ~100M-parameter class; the default preset is
sized to finish in minutes on this CPU container. Both run the same code
path as the full assigned architectures.
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.annotations import Annotation
from repro.sched.train_scheduler import CashTrainScheduler, make_hosts
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainConfig, Trainer


PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)  ~params
    "tiny": (4, 128, 4, 2, 512, 2048),          # ~1.6M
    "20m": (8, 384, 8, 4, 1536, 8192),          # ~20M
    "100m": (12, 768, 12, 4, 3072, 32768),      # ~110M
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    layers, d, h, kv, ff, vocab = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("granite-3-2b"), name=f"lm-{args.preset}",
        num_layers=layers, d_model=d, num_heads=h, num_kv_heads=kv,
        d_ff=ff, vocab_size=vocab, head_dim=d // h, max_seq_len=args.seq)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({layers}L x {d}d, vocab {vocab})")

    data_cfg = DataConfig(vocab_size=vocab, seq_len=args.seq,
                          global_batch=args.batch, num_shards=4)
    hosts = make_hosts(4)
    sched = CashTrainScheduler(hosts, num_shards=4,
                               bottleneck=Annotation.BURST_CPU)
    trainer = Trainer(
        cfg, data_cfg,
        opt_cfg=OptimizerConfig(lr=2e-3, warmup_steps=20,
                                total_steps=args.steps),
        train_cfg=TrainConfig(steps=args.steps, log_every=10, ckpt_every=50,
                              ckpt_dir=args.ckpt_dir),
        scheduler=sched, dtype=jnp.float32)
    if args.resume and trainer.maybe_restore():
        print(f"resumed from step {trainer.step}")
    hist = trainer.run()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({(1 - last / first):+.1%} over {len(hist)} steps)")
    assert last < first, "model failed to learn"


if __name__ == "__main__":
    main()
