"""Quickstart: the CASH scheduler in 60 seconds.

1. Build a burstable cluster (paper's T3 fleet).
2. Run the same workload under stock YARN and under CASH.
3. See the credit-aware placement win.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import SCHEDULERS, SimConfig, Simulation, make_cluster
from repro.core.workloads import make_tpcds_suite, reset_tids


def main() -> None:
    results = {}
    for sched_name in ("stock", "cash"):
        reset_tids()
        # ten m5.2xlarge VMs whose EBS volumes start with empty burst buckets
        nodes = make_cluster(10, "m5.2xlarge", ebs_size_gb=170.0,
                             disk_initial_credits=0.0)
        sim = Simulation(nodes, SCHEDULERS[sched_name](),
                         SimConfig(resource="disk"))
        # three TPC-DS-style streaming queries over a 1.2 TB warehouse
        sim.submit_parallel(make_tpcds_suite(1200.0, 10, 8, seed=1))
        r = sim.run()
        results[sched_name] = r
        print(f"{sched_name:6s}: makespan {r.makespan:7.0f}s   "
              f"avg query completion {r.avg_query_completion():7.0f}s")
    mk = 1 - results["cash"].makespan / results["stock"].makespan
    qct = (1 - results["cash"].avg_query_completion()
           / results["stock"].avg_query_completion())
    print(f"\nCASH vs stock: makespan {mk:+.1%}, query completion {qct:+.1%}")
    print("(paper Fig 9(b): ~10.7% query completion, ~13% makespan)")


if __name__ == "__main__":
    main()
