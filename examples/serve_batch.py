"""Serve a small model with batched requests: CASH admission over two
credit-asymmetric replicas + the continuous-batching engine.

  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced_config
from repro.models import init_params
from repro.sched.serve_scheduler import CashServeScheduler, Request, make_replicas
from repro.serve.engine import Engine, ServeRequest


def main() -> None:
    cfg = reduced_config(ARCHS["granite-3-2b"])
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    # two replicas: replica 1 has a full burst bucket, replica 0 is drained
    replicas = make_replicas(2, slots=4, cpu_initial_fraction=0.0)
    replicas[1].node.cpu.balance = replicas[1].node.cpu.capacity
    cash = CashServeScheduler(replicas)
    for t in range(301):                      # telemetry warm-up
        cash.observe(float(t), {0: 0.0, 1: 0.0})

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt_tokens=int(rng.integers(4, 10)),
                    max_new_tokens=8) for i in range(6)]
    pf, dc = cash.admit(301.0, reqs, decode_batches=2)
    print("CASH admission (prefill counts per replica):",
          {k: len(v) for k, v in pf.items()})
    print("  -> compute-heavy prefills land on the credit-rich replica 1")

    engines = [Engine(cfg, params, n_slots=4, max_len=64, impl="xla")
               for _ in range(2)]
    t0 = time.time()
    total = 0
    for rep_id, assigned in pf.items():
        for r in assigned:
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=(r.prompt_tokens,)).tolist()
            engines[rep_id].submit(ServeRequest(
                rid=r.rid, prompt=prompt, max_new_tokens=r.max_new_tokens))
        done = engines[rep_id].run_until_done()
        total += sum(len(d.output) for d in done)
        print(f"replica {rep_id}: served {len(done)} requests "
              f"in {engines[rep_id].steps} engine steps")
    dt = time.time() - t0
    print(f"\n{total} tokens in {dt:.1f}s ({total / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
