"""Fault tolerance walkthrough: failure injection -> checkpoint restart ->
elastic shrink, with credit-aware straggler mitigation along the way.

  PYTHONPATH=src python examples/elastic_training.py
"""
import tempfile

import jax.numpy as jnp

from repro.configs import ARCHS, reduced_config
from repro.core.annotations import Annotation
from repro.sched.elastic import plan
from repro.sched.straggler import StragglerMonitor
from repro.sched.train_scheduler import CashTrainScheduler, make_hosts
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    cfg = reduced_config(ARCHS["granite-3-2b"])
    ckpt_dir = tempfile.mkdtemp(prefix="repro_elastic_")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8, num_shards=4)

    def mk_trainer(fail_at=None):
        return Trainer(cfg, data_cfg,
                       opt_cfg=OptimizerConfig(lr=1e-3, warmup_steps=5,
                                               total_steps=30),
                       train_cfg=TrainConfig(steps=30, log_every=10,
                                             ckpt_every=10, ckpt_dir=ckpt_dir,
                                             fail_at_step=fail_at),
                       dtype=jnp.float32)

    print("== phase 1: train until an injected node failure at step 17 ==")
    t1 = mk_trainer(fail_at=17)
    try:
        t1.run()
    except RuntimeError as e:
        print(f"CRASH: {e}")
    if t1._ckpt:
        t1._ckpt.wait()

    print("\n== phase 2: restart from the latest checkpoint ==")
    t2 = mk_trainer()
    assert t2.maybe_restore()
    print(f"restored at step {t2.step}; continuing to 30")
    hist = t2.run(steps=30 - t2.step)
    print(f"final loss {hist[-1]['loss']:.4f}")

    print("\n== phase 3: elastic shrink 8 -> 5 hosts ==")
    p8 = plan(8, devices_per_host=1, num_shards=16)
    p5 = plan(5, devices_per_host=1, num_shards=16)
    print(f"mesh {p8.mesh_shape} -> {p5.mesh_shape}; "
          f"shards/host: {[len(v) for v in p5.shard_map.values()]}")
    print("(data is a pure function of (seed, shard, step): no loss/dup)")

    print("\n== phase 4: credit-aware straggler mitigation ==")
    hosts = make_hosts(4, cpu_initial_fraction=0.0)
    hosts[0].node.cpu.balance = hosts[0].node.cpu.capacity
    sched = CashTrainScheduler(hosts, num_shards=8,
                               bottleneck=Annotation.BURST_CPU)
    mon = StragglerMonitor(4, horizon_s=300.0)
    for t in range(301):
        sched.observe(float(t), {h.host_id: 6.0 if h.host_id else 0.0
                                 for h in hosts})
    flagged = mon.predictive_stragglers(
        {h.host_id: h.node.cpu for h in hosts},
        {h.host_id: 6.0 for h in hosts})
    split = sched.split_rows(32, 301.0)
    print(f"predicted stragglers (credit depletion): {flagged}")
    print(f"credit-weighted microbatch split of 32 rows: {split}")
    print("host 0 (full bucket) carries more rows; throttled hosts carry fewer")


if __name__ == "__main__":
    main()
